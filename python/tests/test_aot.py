"""AOT pipeline: manifest schema, HLO text well-formedness, init binaries.

These tests run against a freshly lowered throwaway directory so they do
not depend on (or dirty) the repo-level artifacts/.
"""

import json
import os
import struct

import numpy as np
import pytest

from compile import aot
from compile.specs import SPECS, SPECS_BY_NAME


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    dedup = {}
    entries = [aot.build_spec(SPECS_BY_NAME[n], out, dedup)
               for n in ("test_logreg", "test_mlp")]
    manifest = {"version": 1, "specs": entries}
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return out, manifest


def test_manifest_schema(built):
    out, manifest = built
    assert manifest["version"] == 1
    for e in manifest["specs"]:
        for key in ("name", "kind", "p", "p_pad", "batch", "eval_batch",
                    "beta1", "beta2", "eps", "grad_hlo", "eval_hlo",
                    "update_hlo", "innov_hlo", "init_bin", "grad_inputs",
                    "eval_inputs"):
            assert key in e, key
        assert e["p_pad"] % 1024 == 0
        assert e["p"] <= e["p_pad"]
        for inp in e["grad_inputs"]:
            assert inp["dtype"] in ("f32", "i32")
            assert inp["shape"][0] == e["batch"]


def test_hlo_text_wellformed(built):
    out, manifest = built
    files = set()
    for e in manifest["specs"]:
        files |= {e["grad_hlo"], e["eval_hlo"], e["update_hlo"], e["innov_hlo"]}
    for fname in files:
        text = open(os.path.join(out, fname)).read()
        assert "ENTRY" in text, fname
        assert "ROOT" in text, fname
        # HLO text, not a serialized proto (must be ascii-ish)
        assert text.isprintable() or "\n" in text


def test_init_bin_roundtrip(built):
    out, manifest = built
    for e in manifest["specs"]:
        raw = open(os.path.join(out, e["init_bin"]), "rb").read()
        assert len(raw) == 4 * e["p_pad"]
        vals = np.frombuffer(raw, "<f4")
        assert np.all(np.isfinite(vals))
        assert np.all(vals[e["p"]:] == 0.0)


def test_update_artifact_dedup(built):
    """Specs sharing (p_pad, betas, eps) must share one update artifact."""
    out = str(built[0]) + "_dedup"
    os.makedirs(out, exist_ok=True)
    dedup = {}
    a = aot.build_spec(SPECS_BY_NAME["test_logreg"], out, dedup)
    b = aot.build_spec(SPECS_BY_NAME["test_mlp"], out, dedup)
    assert a["update_hlo"] == b["update_hlo"]
    assert a["innov_hlo"] == b["innov_hlo"]


def test_spec_names_unique():
    names = [s.name for s in SPECS]
    assert len(names) == len(set(names))


def test_grad_and_eval_shapes_differ_only_in_batch():
    e = SPECS_BY_NAME["test_logreg"]
    assert e.batch != e.eval_batch
