"""L1 correctness: Pallas kernels vs pure-jnp oracles (the CORE signal).

hypothesis sweeps shapes (tile counts), hyperparameters and value scales;
assert_allclose against ref.py. Kernels run under interpret=True — exactly
the configuration that is AOT-lowered into the artifacts the rust runtime
executes, so these tests certify the artifact numerics too.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

TILE = kernels.BLOCK_ROWS * kernels.LANES


def _rand(rng, p, scale=1.0):
    return jnp.asarray(rng.normal(size=p).astype(np.float32) * scale)


# --------------------------------------------------------------- padded_dim
@pytest.mark.parametrize("p,expect", [
    (1, TILE), (TILE, TILE), (TILE + 1, 2 * TILE), (5 * TILE, 5 * TILE),
])
def test_padded_dim(p, expect):
    assert kernels.padded_dim(p) == expect


@given(p=st.integers(min_value=1, max_value=10 * TILE))
@settings(max_examples=50, deadline=None)
def test_padded_dim_properties(p):
    pad = kernels.padded_dim(p)
    assert pad >= p
    assert pad % TILE == 0
    assert pad - p < TILE


# -------------------------------------------------------------- cada_update
@given(
    tiles=st.integers(min_value=1, max_value=4),
    beta1=st.floats(min_value=0.0, max_value=0.99),
    beta2=st.floats(min_value=0.9, max_value=0.9999),
    eps=st.sampled_from([1e-8, 1e-6, 1e-3]),
    alpha=st.floats(min_value=1e-5, max_value=1.0),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_cada_update_matches_ref(tiles, beta1, beta2, eps, alpha, scale, seed):
    p = tiles * TILE
    rng = np.random.default_rng(seed)
    theta = _rand(rng, p, scale)
    h = _rand(rng, p, scale)
    vhat = jnp.abs(_rand(rng, p, scale))
    grad = _rand(rng, p, scale)

    out = kernels.cada_update(theta, h, vhat, grad, alpha,
                              beta1=beta1, beta2=beta2, eps=eps)
    exp = ref.cada_update_ref(theta, h, vhat, grad, alpha,
                              beta1=beta1, beta2=beta2, eps=eps)
    # f32 fma/reassociation noise between the fused kernel and the oracle
    # grows with the value scale; tolerances scale accordingly.
    for got, want, name in zip(out, exp, ("theta", "h", "vhat")):
        np.testing.assert_allclose(got, want, rtol=2e-4,
                                   atol=1e-5 * scale + 1e-6, err_msg=name)


def test_cada_update_amsgrad_clamp_monotone():
    """vhat must be entrywise non-decreasing (the AMSGrad max in 2b)."""
    p = TILE
    rng = np.random.default_rng(7)
    theta, h = _rand(rng, p), _rand(rng, p)
    vhat = jnp.abs(_rand(rng, p))
    for step in range(5):
        grad = _rand(rng, p, scale=0.1)
        theta, h, vhat_new = kernels.cada_update(
            theta, h, vhat, grad, 0.01, beta1=0.9, beta2=0.999, eps=1e-8)
        assert bool(jnp.all(vhat_new >= vhat - 1e-7)), f"step {step}"
        vhat = vhat_new


def test_cada_update_zero_padding_inert():
    """Padding invariant: zero tail stays exactly zero through the update."""
    p = 2 * TILE
    live = 100
    rng = np.random.default_rng(3)
    def padded(scale=1.0):
        v = np.zeros(p, np.float32)
        v[:live] = rng.normal(size=live).astype(np.float32) * scale
        return jnp.asarray(v)

    theta, h, grad = padded(), padded(), padded()
    vhat = jnp.abs(padded())
    for _ in range(3):
        theta, h, vhat = kernels.cada_update(
            theta, h, vhat, grad, 0.05, beta1=0.9, beta2=0.999, eps=1e-8)
        assert np.all(np.asarray(theta)[live:] == 0.0)
        assert np.all(np.asarray(h)[live:] == 0.0)
        assert np.all(np.asarray(vhat)[live:] == 0.0)


def test_cada_update_beta_zero_is_rms_step():
    """beta1=0 reduces (2a) to the raw gradient direction."""
    p = TILE
    rng = np.random.default_rng(11)
    theta = _rand(rng, p)
    grad = _rand(rng, p)
    zeros = jnp.zeros(p)
    t2, h2, v2 = kernels.cada_update(theta, zeros, zeros, grad, 0.1,
                                     beta1=0.0, beta2=0.0, eps=1e-8)
    np.testing.assert_allclose(h2, grad, rtol=1e-6)
    np.testing.assert_allclose(v2, grad * grad, rtol=1e-6)
    np.testing.assert_allclose(
        t2, theta - 0.1 * grad / jnp.sqrt(1e-8 + grad * grad), rtol=1e-5)


# --------------------------------------------------------- innovation_sqnorm
@given(
    tiles=st.integers(min_value=1, max_value=6),
    scale=st.sampled_from([1e-3, 1.0, 1e2]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
@settings(max_examples=40, deadline=None)
def test_innovation_matches_ref(tiles, scale, seed):
    p = tiles * TILE
    rng = np.random.default_rng(seed)
    g1, g2 = _rand(rng, p, scale), _rand(rng, p, scale)
    got = kernels.innovation_sqnorm(g1, g2)
    want = ref.innovation_sqnorm_ref(g1, g2)
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_innovation_identity_is_zero():
    p = 3 * TILE
    g = _rand(np.random.default_rng(0), p)
    assert float(kernels.innovation_sqnorm(g, g)) == 0.0


def test_innovation_symmetry():
    p = 2 * TILE
    rng = np.random.default_rng(1)
    g1, g2 = _rand(rng, p), _rand(rng, p)
    a = float(kernels.innovation_sqnorm(g1, g2))
    b = float(kernels.innovation_sqnorm(g2, g1))
    np.testing.assert_allclose(a, b, rtol=1e-6)
