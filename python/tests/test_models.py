"""L2 correctness: model gradients, flat-parameter plumbing, eval metrics.

Gradients of each FlatModel are checked against central finite differences
of the (independent-path) loss value, and against analytic forms where one
exists (logistic regression).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.model import FlatModel
from compile.specs import SPECS_BY_NAME


def _batch_for(fm, batch_size, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for spec in fm.input_specs(batch_size):
        if str(spec.dtype) == "float32":
            out.append(jnp.asarray(rng.normal(size=spec.shape).astype(np.float32)))
        else:
            hi = fm.cfg.get("num_classes", fm.cfg.get("vocab", 2))
            out.append(jnp.asarray(
                rng.integers(0, hi, size=spec.shape).astype(np.int32)))
    return tuple(out)


def _fd_check(fm, batch, n_coords=12, h=1e-3, rtol=0.08, seed=0):
    """Central finite differences on a few random live coordinates."""
    theta = jnp.asarray(
        np.random.default_rng(seed).normal(size=fm.p_pad).astype(np.float32) * 0.1)
    theta = theta.at[fm.p:].set(0.0)
    loss, grad = jax.jit(fm.grad_fn)(theta, *batch)
    grad = np.asarray(grad)
    rng = np.random.default_rng(seed + 1)
    coords = rng.choice(fm.p, size=min(n_coords, fm.p), replace=False)
    f = jax.jit(lambda t: fm.grad_fn(t, *batch)[0])
    for i in coords:
        e = jnp.zeros(fm.p_pad).at[i].set(h)
        fd = (float(f(theta + e)) - float(f(theta - e))) / (2 * h)
        if abs(fd) < 1e-4 and abs(grad[i]) < 1e-4:
            continue
        np.testing.assert_allclose(grad[i], fd, rtol=rtol, atol=2e-3,
                                   err_msg=f"coord {i}")
    return float(loss), grad


@pytest.mark.parametrize("name", ["test_logreg", "test_mlp", "mlogreg_mnist"])
def test_grad_matches_finite_differences(name):
    s = SPECS_BY_NAME[name]
    fm = FlatModel(s.kind, s.cfg, s.seed)
    batch = _batch_for(fm, min(s.batch, 16))
    loss, grad = _fd_check(fm, batch)
    assert np.isfinite(loss)
    # padding must carry zero gradient
    assert np.all(grad[fm.p:] == 0.0)


def test_cnn_grad_finite_differences():
    s = SPECS_BY_NAME["test_mlp"]  # cnn fd is slow; use a tiny bespoke cnn
    fm = FlatModel("cnn", {"image_hw": 8, "in_channels": 1,
                           "conv_channels": [2, 4], "kernel": 3,
                           "fc_hidden": 8, "num_classes": 3}, 0)
    batch = _batch_for(fm, 4)
    loss, grad = _fd_check(fm, batch, n_coords=8)
    assert np.isfinite(loss) and np.all(np.isfinite(grad))


def test_transformer_grad_finite_differences():
    fm = FlatModel("transformer_lm", {"vocab": 17, "d_model": 16,
                                      "num_layers": 2, "num_heads": 2,
                                      "seq_len": 8}, 0)
    batch = _batch_for(fm, 2)
    loss, grad = _fd_check(fm, batch, n_coords=8, h=3e-3, rtol=0.15)
    assert np.isfinite(loss) and np.all(np.isfinite(grad))
    # a fresh LM should be near uniform: loss ~ log(vocab)
    assert abs(loss - np.log(17)) < 1.0


def _flat_from_params(fm, params):
    """Build a padded flat theta from an explicit param pytree (avoids
    assumptions about ravel_pytree's dict-key ordering)."""
    flat, _ = jax.flatten_util.ravel_pytree(
        jax.tree_util.tree_map(lambda a: jnp.asarray(a, jnp.float32), params))
    theta = np.zeros(fm.p_pad, np.float32)
    theta[: fm.p] = np.asarray(flat)
    return jnp.asarray(theta)


def test_binary_logreg_analytic_gradient():
    """Closed form: grad_w = X^T (sigmoid(z) - y)/B + lam*w."""
    s = SPECS_BY_NAME["test_logreg"]
    fm = FlatModel(s.kind, s.cfg, s.seed)
    rng = np.random.default_rng(5)
    B, d = 32, s.cfg["num_features"]
    X = rng.normal(size=(B, d)).astype(np.float32)
    y = rng.integers(0, 2, size=B).astype(np.int32)
    w = rng.normal(size=d).astype(np.float32) * 0.3
    b = np.float32(0.17)
    theta = _flat_from_params(fm, {"w": w, "b": b})

    z = X @ w + b
    sig = 1 / (1 + np.exp(-z))
    gw = X.T @ (sig - y) / B + 1e-5 * w
    gb = np.mean(sig - y)

    _, grad = jax.jit(fm.grad_fn)(theta, jnp.asarray(X), jnp.asarray(y))
    # recover the analytic gradient in flat layout via the same ravel
    gflat, _ = jax.flatten_util.ravel_pytree(
        {"w": jnp.asarray(gw), "b": jnp.asarray(gb + 1e-5 * b)})
    np.testing.assert_allclose(np.asarray(grad)[: fm.p], np.asarray(gflat),
                               rtol=1e-4, atol=1e-5)


def test_eval_fn_counts_correct():
    """eval_fn's `correct` is an exact count for a hand-built batch."""
    fm = FlatModel("logreg_binary", {"num_features": 2}, 0)
    theta = _flat_from_params(
        fm, {"w": jnp.asarray([1.0, 0.0]), "b": jnp.asarray(0.0)})  # z = x0
    X = jnp.asarray([[2.0, 0.0], [-2.0, 0.0], [3.0, 0.0], [-1.0, 0.0]],
                    jnp.float32)
    y = jnp.asarray([1, 0, 0, 0], jnp.int32)      # preds: 1,0,1,0 -> 3 correct
    loss, correct = jax.jit(fm.eval_fn)(theta, X, y)
    assert float(correct) == 3.0
    assert np.isfinite(float(loss))


@given(seed=st.integers(min_value=0, max_value=2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_init_flat_deterministic_and_padded(seed):
    fm = FlatModel("mlp", {"num_features": 6, "hidden": [4],
                           "num_classes": 3}, seed % 100)
    a, b = fm.init_flat(), fm.init_flat()
    np.testing.assert_array_equal(a, b)
    assert a.shape == (fm.p_pad,)
    assert np.all(a[fm.p:] == 0.0)


def test_unflatten_roundtrip():
    fm = FlatModel("mlp", {"num_features": 6, "hidden": [4],
                           "num_classes": 3}, 0)
    theta = fm.init_flat()
    tree = fm.unflatten(jnp.asarray(theta))
    flat2, _ = jax.flatten_util.ravel_pytree(tree)
    np.testing.assert_allclose(np.asarray(flat2), theta[: fm.p])


def test_adam_descends_on_logreg():
    """Sanity: running the (kernel) update with fresh grads reduces loss —
    the single-node Adam the distributed algorithms must reproduce."""
    from compile import kernels

    fm = FlatModel("logreg_binary", {"num_features": 8}, 0)
    rng = np.random.default_rng(2)
    X = rng.normal(size=(256, 8)).astype(np.float32)
    w_true = rng.normal(size=8).astype(np.float32)
    y = (X @ w_true > 0).astype(np.int32)
    Xj, yj = jnp.asarray(X), jnp.asarray(y)

    theta = jnp.asarray(fm.init_flat())
    h = jnp.zeros(fm.p_pad)
    vhat = jnp.zeros(fm.p_pad)
    grad_fn = jax.jit(fm.grad_fn)
    loss0 = float(grad_fn(theta, Xj, yj)[0])
    for _ in range(60):
        _, g = grad_fn(theta, Xj, yj)
        theta, h, vhat = kernels.cada_update(theta, h, vhat, g, 0.05,
                                             beta1=0.9, beta2=0.999, eps=1e-8)
    loss1 = float(grad_fn(theta, Xj, yj)[0])
    assert loss1 < loss0 * 0.5, (loss0, loss1)
