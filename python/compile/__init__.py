"""Build-time (AOT) compile path: JAX/Pallas -> HLO text artifacts.

Nothing in this package runs on the request path — `make artifacts`
invokes `compile.aot` once and the rust coordinator consumes the emitted
`artifacts/*.hlo.txt` + `manifest.json` via PJRT.
"""
