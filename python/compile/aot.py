"""AOT compile path: lower every experiment spec to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the xla crate's bundled
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Per spec `<name>` this emits into the output directory:

  <name>.grad.hlo.txt    (theta_pad, *batch)      -> (loss, grad_pad)
  <name>.eval.hlo.txt    (theta_pad, *eval_batch) -> (loss, correct)
  <name>.update.hlo.txt  (theta, h, vhat, grad, alpha) -> (theta', h', vhat')
                         [the L1 Pallas fused AMSGrad step, betas baked]
  <name>.innov.hlo.txt   (g1, g2) -> (sqnorm,)
                         [the L1 Pallas blocked reduction]
  <name>.init.bin        little-endian f32[p_pad] initial parameters

plus one `manifest.json` describing shapes/dtypes/hyperparameters, which is
the single source of truth the rust runtime loads. Update/innov artifacts
are deduplicated across specs that share (p_pad, beta1, beta2, eps).

Usage:  cd python && python -m compile.aot --out ../artifacts [--specs a,b]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np
from jax._src.lib import xla_client as xc

from .model import FlatModel, flat_spec, make_innov_fn, make_update_fn
from .specs import SPECS, SPECS_BY_NAME


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_to_file(fn, example_args, path: str) -> int:
    text = to_hlo_text(jax.jit(fn).lower(*example_args))
    with open(path, "w") as f:
        f.write(text)
    return len(text)


def _spec_dtype(s) -> str:
    return {"float32": "f32", "int32": "i32"}[str(s.dtype)]


def build_spec(spec, out_dir: str, dedup: dict) -> dict:
    t0 = time.time()
    fm = FlatModel(spec.kind, spec.cfg, spec.seed)
    p_pad = fm.p_pad
    theta = flat_spec(p_pad)

    entry = {
        "name": spec.name,
        "kind": spec.kind,
        "cfg": spec.cfg,
        "p": fm.p,
        "p_pad": p_pad,
        "batch": spec.batch,
        "eval_batch": spec.eval_batch,
        "beta1": spec.beta1,
        "beta2": spec.beta2,
        "eps": spec.eps,
        "seed": spec.seed,
        "tags": list(spec.tags),
    }

    # ---- per-spec artifacts: grad, eval, init ---------------------------
    grad_inputs = fm.input_specs(spec.batch)
    eval_inputs = fm.input_specs(spec.eval_batch)
    entry["grad_inputs"] = [
        {"shape": list(s.shape), "dtype": _spec_dtype(s)} for s in grad_inputs
    ]
    entry["eval_inputs"] = [
        {"shape": list(s.shape), "dtype": _spec_dtype(s)} for s in eval_inputs
    ]

    grad_file = f"{spec.name}.grad.hlo.txt"
    lower_to_file(fm.grad_fn, (theta, *grad_inputs),
                  os.path.join(out_dir, grad_file))
    entry["grad_hlo"] = grad_file

    eval_file = f"{spec.name}.eval.hlo.txt"
    lower_to_file(fm.eval_fn, (theta, *eval_inputs),
                  os.path.join(out_dir, eval_file))
    entry["eval_hlo"] = eval_file

    init_file = f"{spec.name}.init.bin"
    fm.init_flat().astype("<f4").tofile(os.path.join(out_dir, init_file))
    entry["init_bin"] = init_file

    # ---- shared artifacts: update (Pallas), innov (Pallas) --------------
    upd_key = ("update", p_pad, spec.beta1, spec.beta2, spec.eps)
    if upd_key not in dedup:
        upd_file = f"update_p{p_pad}_b1{spec.beta1}_b2{spec.beta2}_e{spec.eps}.hlo.txt"
        update_fn = make_update_fn(p_pad, spec.beta1, spec.beta2, spec.eps)
        alpha = jax.ShapeDtypeStruct((), np.float32)
        lower_to_file(update_fn, (theta, theta, theta, theta, alpha),
                      os.path.join(out_dir, upd_file))
        dedup[upd_key] = upd_file
    entry["update_hlo"] = dedup[upd_key]

    innov_key = ("innov", p_pad)
    if innov_key not in dedup:
        innov_file = f"innov_p{p_pad}.hlo.txt"
        lower_to_file(make_innov_fn(p_pad), (theta, theta),
                      os.path.join(out_dir, innov_file))
        dedup[innov_key] = innov_file
    entry["innov_hlo"] = dedup[innov_key]

    entry["lower_seconds"] = round(time.time() - t0, 2)
    return entry


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--specs", default="",
                    help="comma-separated spec names (default: all)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    if args.specs:
        selected = [SPECS_BY_NAME[n.strip()] for n in args.specs.split(",")]
    else:
        selected = SPECS

    dedup: dict = {}
    entries = []
    for spec in selected:
        print(f"[aot] lowering {spec.name} ({spec.kind}) ...", flush=True)
        entry = build_spec(spec, args.out, dedup)
        print(f"[aot]   p={entry['p']} p_pad={entry['p_pad']} "
              f"({entry['lower_seconds']}s)", flush=True)
        entries.append(entry)

    manifest = {"version": 1, "specs": entries}
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"[aot] wrote {len(entries)} specs -> {args.out}/manifest.json")


if __name__ == "__main__":
    main()
