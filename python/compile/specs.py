"""Experiment specs: everything aot.py needs to emit one artifact set.

One spec == one (model, per-worker batch shape, Adam hyperparameters)
combination. The names mirror the paper's workloads (Tables 1-4); the
`*_like` synthetic substitutions are documented in DESIGN.md section 3.

beta1/beta2/eps are baked into the update artifact as compile-time
constants (they are fixed per experiment in the paper); alpha stays a
runtime input because the 1/sqrt(K) and PL schedules change it every
iteration.
"""

from __future__ import annotations

from dataclasses import dataclass, field


@dataclass(frozen=True)
class Spec:
    name: str
    kind: str
    cfg: dict
    batch: int          # per-worker minibatch (grad artifact)
    eval_batch: int     # evaluation batch (eval artifact)
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    seed: int = 0
    tags: tuple = field(default_factory=tuple)


SPECS = [
    # Tiny spec: fast unit/integration tests on the rust side.
    Spec("test_logreg", "logreg_binary", {"num_features": 8}, batch=16,
         eval_batch=64, tags=("test",)),
    Spec("test_mlp", "mlp",
         {"num_features": 16, "hidden": [8], "num_classes": 3},
         batch=8, eval_batch=32, tags=("test",)),

    # Fig. 2 — covtype logistic regression (M=20, size-skewed, Table 1).
    # Paper batch-ratio 1e-3 of 581k/20 workers ~= 29 samples/worker.
    Spec("logreg_covtype", "logreg_binary", {"num_features": 54}, batch=32,
         eval_batch=4096, tags=("fig2",)),

    # Fig. 3 — ijcnn1 logistic regression (M=10 iid, Table 2).
    # batch-ratio 0.01 of 91.7k/10 workers ~= 92 samples/worker.
    Spec("logreg_ijcnn", "logreg_binary", {"num_features": 22}, batch=92,
         eval_batch=4096, tags=("fig3",)),

    # Supplement — multiclass logistic regression on MNIST-like data.
    Spec("mlogreg_mnist", "logreg_multiclass",
         {"num_features": 784, "num_classes": 10}, batch=64,
         eval_batch=2048, tags=("supp",)),

    # Fig. 4 — the paper's MNIST CNN (two conv-ELU-maxpool + two fc;
    # fc hidden scaled 500 -> 128 for CPU-PJRT budget, DESIGN.md section 3).
    Spec("cnn_mnist", "cnn",
         {"image_hw": 28, "in_channels": 1, "conv_channels": [20, 50],
          "kernel": 5, "fc_hidden": 128, "num_classes": 10},
         batch=12, eval_batch=512, beta2=0.999, tags=("fig4",)),

    # Fast nonconvex stand-in for the H-sweep benches (Figs. 6-7 dynamics).
    Spec("mlp_mnist", "mlp",
         {"num_features": 784, "hidden": [128], "num_classes": 10},
         batch=12, eval_batch=2048, tags=("fig4", "fig6")),

    # Fig. 5 — CIFAR10/ResNet20 stand-in: ~0.15M-param CNN on 16x16x3
    # synthetic images (Table 4: beta2 = 0.99, batch 50).
    Spec("cnn_cifar", "cnn",
         {"image_hw": 16, "in_channels": 3, "conv_channels": [32, 64],
          "kernel": 3, "fc_hidden": 128, "num_classes": 10},
         batch=50, eval_batch=512, beta2=0.99, tags=("fig5", "fig7")),

    # End-to-end validation driver (DESIGN.md section 6): ~2.7M-param LM.
    Spec("transformer_lm", "transformer_lm",
         {"vocab": 256, "d_model": 192, "num_layers": 6, "num_heads": 6,
          "seq_len": 128},
         batch=8, eval_batch=16, tags=("e2e",)),

    # Budget-scaled e2e default (~0.83M params, ~6x faster per grad on
    # CPU-PJRT); the full-size spec above stays available via --spec.
    Spec("transformer_sm", "transformer_lm",
         {"vocab": 256, "d_model": 128, "num_layers": 4, "num_heads": 4,
          "seq_len": 64},
         batch=8, eval_batch=32, tags=("e2e",)),
]

SPECS_BY_NAME = {s.name: s for s in SPECS}
