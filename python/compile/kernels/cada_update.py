"""L1 Pallas kernel: fused CADA/AMSGrad server update (paper Eq. 2a-2c).

The server step of CADA is, per coordinate i:

    h'    = beta1 * h + (1 - beta1) * g          (2a)  momentum direction
    v     = beta2 * vhat + (1 - beta2) * g^2     (2b)  second moment
    vhat' = max(v, vhat)                         (2b)  AMSGrad clamp
    theta'= theta - alpha * h' / sqrt(eps+vhat') (2c)  scaled descent

On a real accelerator this is the per-iteration O(p) hot spot of the
parameter server: four parameter-sized vectors stream HBM -> VMEM and three
stream back. Fusing all of (2a)-(2c) into ONE Pallas kernel gives a single
HBM round trip instead of the ~10 separate elementwise HLO ops a naive jnp
implementation would emit before fusion.

TPU adaptation (see DESIGN.md section "Hardware adaptation"): the flat
parameter vector is padded to a multiple of LANES=128 and viewed as
(rows, 128) so each BlockSpec tile is (BLOCK_ROWS, 128) — the native
VPU lane layout. `alpha` (the stepsize, which changes every iteration
under the 1/sqrt(K) and PL schedules) is a (1, 1) scalar input mapped to
every tile; beta1/beta2/eps are compile-time constants baked per
experiment spec.

Padding is self-consistent: with g = h = vhat = theta = 0 on the tail,
every recursion keeps the tail at exactly 0, so the rust side can treat
the padded region as inert.

CPU execution uses interpret=True (Mosaic custom-calls cannot run on the
CPU PJRT plugin); the kernel still lowers into the same HLO artifact.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 8


def _update_kernel(alpha_ref, theta_ref, h_ref, vhat_ref, g_ref,
                   theta_out, h_out, vhat_out, *, beta1, beta2, eps):
    """One (BLOCK_ROWS, LANES) tile of the fused update."""
    alpha = alpha_ref[0, 0]
    g = g_ref[...]
    h_new = beta1 * h_ref[...] + (1.0 - beta1) * g
    v_new = beta2 * vhat_ref[...] + (1.0 - beta2) * g * g
    vhat_new = jnp.maximum(v_new, vhat_ref[...])
    theta_out[...] = theta_ref[...] - alpha * h_new * jax.lax.rsqrt(eps + vhat_new)
    h_out[...] = h_new
    vhat_out[...] = vhat_new


def padded_dim(p: int) -> int:
    """Smallest multiple of BLOCK_ROWS*LANES >= p (tile-aligned length)."""
    tile = BLOCK_ROWS * LANES
    return ((p + tile - 1) // tile) * tile


def cada_update(theta, h, vhat, grad, alpha, *, beta1, beta2, eps,
                interpret=True):
    """Fused AMSGrad/CADA server update over flat, tile-aligned f32 vectors.

    Args:
      theta, h, vhat, grad: f32[P] with P a multiple of BLOCK_ROWS*LANES.
      alpha: f32 scalar stepsize (traced, changes every iteration).
    Returns:
      (theta', h', vhat'), each f32[P].
    """
    p = theta.shape[0]
    assert p % (BLOCK_ROWS * LANES) == 0, f"P={p} not tile aligned"
    rows = p // LANES
    shape2d = (rows, LANES)
    grid = (rows // BLOCK_ROWS,)
    alpha2d = jnp.asarray(alpha, jnp.float32).reshape(1, 1)

    tile = pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    scalar = pl.BlockSpec((1, 1), lambda i: (0, 0))
    out_shape = jax.ShapeDtypeStruct(shape2d, jnp.float32)

    kernel = functools.partial(
        _update_kernel, beta1=float(beta1), beta2=float(beta2), eps=float(eps)
    )
    theta2, h2, vhat2 = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[scalar, tile, tile, tile, tile],
        out_specs=[tile, tile, tile],
        out_shape=[out_shape, out_shape, out_shape],
        interpret=interpret,
    )(
        alpha2d,
        theta.reshape(shape2d),
        h.reshape(shape2d),
        vhat.reshape(shape2d),
        grad.reshape(shape2d),
    )
    return theta2.reshape(p), h2.reshape(p), vhat2.reshape(p)
