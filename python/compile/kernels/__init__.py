"""L1: Pallas kernels for CADA's per-iteration O(p) hot spots.

- cada_update: fused AMSGrad/CADA server step, paper Eq. (2a)-(2c).
- innovation: blocked ||g1 - g2||^2 reduction, the LHS of rules (5)/(7)/(10).
- ref: pure-jnp oracles used by pytest.
"""

from .cada_update import cada_update, padded_dim, BLOCK_ROWS, LANES
from .innovation import innovation_sqnorm
from . import ref

__all__ = [
    "cada_update",
    "innovation_sqnorm",
    "padded_dim",
    "BLOCK_ROWS",
    "LANES",
    "ref",
]
