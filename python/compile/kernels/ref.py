"""Pure-jnp oracles for the Pallas kernels (the correctness ground truth).

These are deliberately written as the most literal transcription of the
paper's equations — no fusion, no tiling — so any disagreement with the
Pallas kernels points at the kernels, not at the oracle.
"""

from __future__ import annotations

import jax.numpy as jnp


def cada_update_ref(theta, h, vhat, grad, alpha, *, beta1, beta2, eps):
    """Paper Eq. (2a)-(2c), AMSGrad-style clamp on the second moment."""
    h_new = beta1 * h + (1.0 - beta1) * grad
    v_new = beta2 * vhat + (1.0 - beta2) * grad * grad
    vhat_new = jnp.maximum(v_new, vhat)
    theta_new = theta - alpha * h_new / jnp.sqrt(eps + vhat_new)
    return theta_new, h_new, vhat_new


def innovation_sqnorm_ref(g1, g2):
    """LHS of rules (5), (7), (10): squared L2 norm of the difference."""
    d = g1 - g2
    return jnp.sum(d * d)
