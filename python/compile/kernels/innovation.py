"""L1 Pallas kernel: blocked squared-L2 innovation norm ||g1 - g2||^2.

This is the left-hand side of every communication rule in the paper —
stochastic LAG (Eq. 5), CADA1 (Eq. 7) and CADA2 (Eq. 10) all compare a
squared gradient-difference norm against the Delta-theta history term. Each
worker evaluates it once (CADA2/LAG) or twice (CADA1) per iteration, so on
an accelerator it is a bandwidth-bound O(p) reduction.

TPU shape: the two flat vectors are viewed as (rows, 128) lanes; the grid
walks (BLOCK_ROWS, 128) tiles and each grid step accumulates a partial sum
into a (1, 1) output tile (revisited by every step — the canonical Pallas
reduction idiom: initialise at step 0, accumulate afterwards). A single
scalar leaves the kernel, so HBM traffic is 2 reads of p floats and O(1)
writes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .cada_update import BLOCK_ROWS, LANES


def _innov_kernel(g1_ref, g2_ref, out_ref):
    i = pl.program_id(0)
    d = g1_ref[...] - g2_ref[...]
    partial = jnp.sum(d * d)

    @pl.when(i == 0)
    def _init():
        out_ref[0, 0] = 0.0

    out_ref[0, 0] += partial


def innovation_sqnorm(g1, g2, *, interpret=True):
    """||g1 - g2||^2 over flat tile-aligned f32 vectors -> f32 scalar."""
    p = g1.shape[0]
    assert p % (BLOCK_ROWS * LANES) == 0, f"P={p} not tile aligned"
    rows = p // LANES
    grid = (rows // BLOCK_ROWS,)
    tile = pl.BlockSpec((BLOCK_ROWS, LANES), lambda i: (i, 0))
    out = pl.pallas_call(
        _innov_kernel,
        grid=grid,
        in_specs=[tile, tile],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(g1.reshape(rows, LANES), g2.reshape(rows, LANES))
    return out[0, 0]
