"""Convolutional classifier: the paper's MNIST network (two conv-ELU-
maxpool layers followed by two fully-connected layers, section 13.2.2) and
the scaled CIFAR10 stand-in (DESIGN.md section 3: ResNet20's role is "a
larger nonconvex model"; we keep the parameter-count order of magnitude).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _conv2d(x, w, b):
    """NHWC conv with SAME padding, stride 1. w: [kh, kw, cin, cout]."""
    y = jax.lax.conv_general_dilated(
        x, w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return y + b


def _maxpool2(x):
    """2x2 max pooling, stride 2, NHWC."""
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max,
        window_dimensions=(1, 2, 2, 1),
        window_strides=(1, 2, 2, 1),
        padding="VALID",
    )


class Cnn:
    def __init__(self, image_hw: int, in_channels: int, conv_channels: tuple,
                 kernel: int, fc_hidden: int, num_classes: int):
        self.image_hw = image_hw
        self.in_channels = in_channels
        self.conv_channels = tuple(conv_channels)
        self.kernel = kernel
        self.fc_hidden = fc_hidden
        self.num_classes = num_classes
        hw = image_hw
        for _ in self.conv_channels:
            hw //= 2
        self.flat_dim = hw * hw * self.conv_channels[-1]

    def init_params(self, key):
        params = {"conv": [], "fc": []}
        cin = self.in_channels
        for cout in self.conv_channels:
            key, sub = jax.random.split(key)
            fan_in = self.kernel * self.kernel * cin
            params["conv"].append({
                "w": jnp.sqrt(2.0 / fan_in) * jax.random.normal(
                    sub, (self.kernel, self.kernel, cin, cout), jnp.float32),
                "b": jnp.zeros((cout,), jnp.float32),
            })
            cin = cout
        dims = (self.flat_dim, self.fc_hidden, self.num_classes)
        for din, dout in zip(dims[:-1], dims[1:]):
            key, sub = jax.random.split(key)
            params["fc"].append({
                "w": jnp.sqrt(2.0 / din) * jax.random.normal(
                    sub, (din, dout), jnp.float32),
                "b": jnp.zeros((dout,), jnp.float32),
            })
        return params

    def logits(self, params, x):
        h = x
        for layer in params["conv"]:
            h = _maxpool2(jax.nn.elu(_conv2d(h, layer["w"], layer["b"])))
        h = h.reshape(h.shape[0], -1)
        h = jax.nn.elu(h @ params["fc"][0]["w"] + params["fc"][0]["b"])
        return h @ params["fc"][1]["w"] + params["fc"][1]["b"]

    def loss_fn(self, params, x, y):
        logp = jax.nn.log_softmax(self.logits(params, x), axis=-1)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))

    def eval_fn(self, params, x, y):
        logits = self.logits(params, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))
        correct = jnp.sum((jnp.argmax(logits, axis=-1).astype(jnp.int32) == y).astype(jnp.float32))
        return loss, correct

    def input_specs(self, batch_size: int):
        return (
            jax.ShapeDtypeStruct(
                (batch_size, self.image_hw, self.image_hw, self.in_channels),
                jnp.float32),
            jax.ShapeDtypeStruct((batch_size,), jnp.int32),
        )
