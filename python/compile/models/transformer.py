"""Causal transformer language model — the end-to-end validation workload
(DESIGN.md section 6): train a few-million-parameter LM with CADA vs
distributed Adam and log the loss curve, proving L1+L2+L3 compose on a
realistic training job.

Pre-norm decoder blocks, learned positional embeddings, tied output
projection. Batch input is a single int32[B, S+1] token array; positions
[:, :-1] are inputs and [:, 1:] are next-token targets.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


class TransformerLm:
    def __init__(self, vocab: int, d_model: int, num_layers: int,
                 num_heads: int, seq_len: int):
        assert d_model % num_heads == 0
        self.vocab = vocab
        self.d_model = d_model
        self.num_layers = num_layers
        self.num_heads = num_heads
        self.seq_len = seq_len
        self.head_dim = d_model // num_heads

    def init_params(self, key):
        d = self.d_model
        def dense(key, din, dout, scale=None):
            scale = scale if scale is not None else (2.0 / din) ** 0.5
            return scale * jax.random.normal(key, (din, dout), jnp.float32)

        keys = jax.random.split(key, 2 + self.num_layers)
        params = {
            "embed": 0.02 * jax.random.normal(keys[0], (self.vocab, d), jnp.float32),
            "pos": 0.02 * jax.random.normal(keys[1], (self.seq_len, d), jnp.float32),
            "blocks": [],
            "ln_f": {"g": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)},
        }
        for i in range(self.num_layers):
            ks = jax.random.split(keys[2 + i], 6)
            params["blocks"].append({
                "ln1": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
                "wq": dense(ks[0], d, d), "wk": dense(ks[1], d, d),
                "wv": dense(ks[2], d, d),
                "wo": dense(ks[3], d, d, scale=0.02),
                "ln2": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
                "w1": dense(ks[4], d, 4 * d),
                "b1": jnp.zeros((4 * d,)),
                "w2": dense(ks[5], 4 * d, d, scale=0.02),
                "b2": jnp.zeros((d,)),
            })
        return jax.tree_util.tree_map(lambda a: jnp.asarray(a, jnp.float32), params)

    def _attn(self, blk, x):
        b, s, d = x.shape
        nh, hd = self.num_heads, self.head_dim
        q = (x @ blk["wq"]).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
        k = (x @ blk["wk"]).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
        v = (x @ blk["wv"]).reshape(b, s, nh, hd).transpose(0, 2, 1, 3)
        scores = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(float(hd))
        mask = jnp.tril(jnp.ones((s, s), bool))
        scores = jnp.where(mask[None, None], scores, -1e9)
        out = jax.nn.softmax(scores, axis=-1) @ v
        out = out.transpose(0, 2, 1, 3).reshape(b, s, d)
        return out @ blk["wo"]

    def logits(self, params, tokens_in):
        x = params["embed"][tokens_in] + params["pos"][None, : tokens_in.shape[1]]
        for blk in params["blocks"]:
            x = x + self._attn(blk, _layernorm(x, blk["ln1"]["g"], blk["ln1"]["b"]))
            h = _layernorm(x, blk["ln2"]["g"], blk["ln2"]["b"])
            x = x + jax.nn.gelu(h @ blk["w1"] + blk["b1"]) @ blk["w2"] + blk["b2"]
        x = _layernorm(x, params["ln_f"]["g"], params["ln_f"]["b"])
        return x @ params["embed"].T  # tied output projection

    def loss_fn(self, params, tokens):
        inputs, targets = tokens[:, :-1], tokens[:, 1:]
        logp = jax.nn.log_softmax(self.logits(params, inputs), axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
        return jnp.mean(nll)

    def eval_fn(self, params, tokens):
        inputs, targets = tokens[:, :-1], tokens[:, 1:]
        logits = self.logits(params, inputs)
        logp = jax.nn.log_softmax(logits, axis=-1)
        loss = jnp.mean(-jnp.take_along_axis(logp, targets[..., None], axis=-1))
        pred = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        correct = jnp.sum((pred == targets).astype(jnp.float32))
        return loss, correct

    def input_specs(self, batch_size: int):
        return (
            jax.ShapeDtypeStruct((batch_size, self.seq_len + 1), jnp.int32),
        )
