"""Fully-connected ELU classifier (the cheap stand-in for the paper's MNIST
network when a fast nonconvex workload is needed, e.g. in the H-sweep
benches). ELU matches the paper's activation choice.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


class Mlp:
    def __init__(self, num_features: int, hidden: tuple, num_classes: int,
                 lam: float = 0.0):
        self.num_features = num_features
        self.hidden = tuple(hidden)
        self.num_classes = num_classes
        self.lam = lam

    def init_params(self, key):
        dims = (self.num_features,) + self.hidden + (self.num_classes,)
        params = []
        for i, (din, dout) in enumerate(zip(dims[:-1], dims[1:])):
            key, sub = jax.random.split(key)
            scale = jnp.sqrt(2.0 / din)
            params.append({
                "w": scale * jax.random.normal(sub, (din, dout), jnp.float32),
                "b": jnp.zeros((dout,), jnp.float32),
            })
        return params

    def logits(self, params, x):
        h = x
        for layer in params[:-1]:
            h = jax.nn.elu(h @ layer["w"] + layer["b"])
        last = params[-1]
        return h @ last["w"] + last["b"]

    def _reg(self, params):
        if self.lam == 0.0:
            return 0.0
        return 0.5 * self.lam * sum(
            jnp.sum(p * p) for p in jax.tree_util.tree_leaves(params))

    def loss_fn(self, params, x, y):
        logp = jax.nn.log_softmax(self.logits(params, x), axis=-1)
        nll = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))
        return nll + self._reg(params)

    def eval_fn(self, params, x, y):
        logits = self.logits(params, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1)) + self._reg(params)
        correct = jnp.sum((jnp.argmax(logits, axis=-1).astype(jnp.int32) == y).astype(jnp.float32))
        return loss, correct

    def input_specs(self, batch_size: int):
        return (
            jax.ShapeDtypeStruct((batch_size, self.num_features), jnp.float32),
            jax.ShapeDtypeStruct((batch_size,), jnp.int32),
        )
