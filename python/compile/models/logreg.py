"""Logistic regression (binary + multiclass), paper section 4 "Logistic
regression" workloads (covtype, ijcnn1, multiclass MNIST in the
supplement). Loss is the paper's: logistic / cross-entropy augmented with
an l2 regulariser lambda = 1e-5.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _l2(params, lam):
    return 0.5 * lam * sum(jnp.sum(p * p) for p in jax.tree_util.tree_leaves(params))


class Binary:
    """Binary logistic regression: y in {0, 1}, logits z = Xw + b."""

    def __init__(self, num_features: int, lam: float = 1e-5):
        self.num_features = num_features
        self.lam = lam

    def init_params(self, key):
        del key  # zero init is standard for convex logreg
        return {
            "w": jnp.zeros((self.num_features,), jnp.float32),
            "b": jnp.zeros((), jnp.float32),
        }

    def logits(self, params, x):
        return x @ params["w"] + params["b"]

    def loss_fn(self, params, x, y):
        z = self.logits(params, x)
        yf = y.astype(jnp.float32)
        # BCE with logits: softplus(z) - y*z = -[y log s(z) + (1-y) log(1-s(z))]
        nll = jnp.mean(jax.nn.softplus(z) - yf * z)
        return nll + _l2(params, self.lam)

    def eval_fn(self, params, x, y):
        z = self.logits(params, x)
        yf = y.astype(jnp.float32)
        loss = jnp.mean(jax.nn.softplus(z) - yf * z) + _l2(params, self.lam)
        correct = jnp.sum(((z > 0).astype(jnp.int32) == y).astype(jnp.float32))
        return loss, correct

    def input_specs(self, batch_size: int):
        return (
            jax.ShapeDtypeStruct((batch_size, self.num_features), jnp.float32),
            jax.ShapeDtypeStruct((batch_size,), jnp.int32),
        )


class Multiclass:
    """Multiclass logistic regression (softmax cross-entropy)."""

    def __init__(self, num_features: int, num_classes: int, lam: float = 1e-5):
        self.num_features = num_features
        self.num_classes = num_classes
        self.lam = lam

    def init_params(self, key):
        del key
        return {
            "w": jnp.zeros((self.num_features, self.num_classes), jnp.float32),
            "b": jnp.zeros((self.num_classes,), jnp.float32),
        }

    def logits(self, params, x):
        return x @ params["w"] + params["b"]

    def loss_fn(self, params, x, y):
        logp = jax.nn.log_softmax(self.logits(params, x), axis=-1)
        nll = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))
        return nll + _l2(params, self.lam)

    def eval_fn(self, params, x, y):
        logits = self.logits(params, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        loss = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=-1))
        loss = loss + _l2(params, self.lam)
        correct = jnp.sum((jnp.argmax(logits, axis=-1).astype(jnp.int32) == y).astype(jnp.float32))
        return loss, correct

    def input_specs(self, batch_size: int):
        return (
            jax.ShapeDtypeStruct((batch_size, self.num_features), jnp.float32),
            jax.ShapeDtypeStruct((batch_size,), jnp.int32),
        )
