"""L2: JAX model definitions (build-time only).

Every model exposes the same interface consumed by `compile.model`:

    init_params(key)            -> params pytree (f32 leaves)
    loss_fn(params, *batch)     -> scalar training loss
    eval_fn(params, *batch)     -> (scalar mean loss, correct count f32)
    input_specs(batch_size)     -> tuple of jax.ShapeDtypeStruct for *batch

The rust coordinator only ever sees the FLAT padded parameter vector
(`compile.model.FlatModel`), so new models plug in without touching L3.
"""

from . import logreg, mlp, cnn, transformer

__all__ = ["logreg", "mlp", "cnn", "transformer"]
