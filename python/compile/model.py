"""L2 glue: flat-parameter views of the models + the jittable functions
that aot.py lowers to HLO text.

The rust coordinator (L3) is model-agnostic: it only ever manipulates flat,
tile-aligned f32 vectors of length `p_pad` (a multiple of the Pallas tile,
8*128 floats). This module owns the pytree <-> flat translation:

  grad_fn(theta_pad, *batch) -> (loss, grad_pad)      per-worker gradient
  eval_fn(theta_pad, *batch) -> (loss, correct_count) periodic evaluation
  update_fn(theta, h, vhat, grad, alpha) -> (theta', h', vhat')
      = the L1 Pallas kernel `kernels.cada_update` (Eq. 2a-2c)
  innov_fn(g1, g2) -> ||g1-g2||^2
      = the L1 Pallas kernel `kernels.innovation_sqnorm`

Padding invariant: positions >= p are zero in theta/h/vhat/grad and stay
zero under every one of these functions.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.flatten_util import ravel_pytree

from . import kernels
from .models import cnn, logreg, mlp, transformer


def build_model(kind: str, cfg: dict):
    """Instantiate a model object from a spec dict (see specs.py)."""
    if kind == "logreg_binary":
        return logreg.Binary(cfg["num_features"], cfg.get("lam", 1e-5))
    if kind == "logreg_multiclass":
        return logreg.Multiclass(cfg["num_features"], cfg["num_classes"],
                                 cfg.get("lam", 1e-5))
    if kind == "mlp":
        return mlp.Mlp(cfg["num_features"], tuple(cfg["hidden"]),
                       cfg["num_classes"], cfg.get("lam", 0.0))
    if kind == "cnn":
        return cnn.Cnn(cfg["image_hw"], cfg["in_channels"],
                       tuple(cfg["conv_channels"]), cfg["kernel"],
                       cfg["fc_hidden"], cfg["num_classes"])
    if kind == "transformer_lm":
        return transformer.TransformerLm(cfg["vocab"], cfg["d_model"],
                                         cfg["num_layers"], cfg["num_heads"],
                                         cfg["seq_len"])
    raise ValueError(f"unknown model kind: {kind}")


class FlatModel:
    """A model plus its flat-parameter plumbing."""

    def __init__(self, kind: str, cfg: dict, seed: int):
        self.kind = kind
        self.cfg = cfg
        self.model = build_model(kind, cfg)
        template = self.model.init_params(jax.random.PRNGKey(seed))
        flat, self._unravel = ravel_pytree(template)
        self.p = int(flat.shape[0])
        self.p_pad = kernels.padded_dim(self.p)
        self._init_flat = np.zeros((self.p_pad,), np.float32)
        self._init_flat[: self.p] = np.asarray(flat, np.float32)

    # ------------------------------------------------------------- params
    def init_flat(self) -> np.ndarray:
        """Initial padded flat parameter vector (deterministic per seed)."""
        return self._init_flat.copy()

    def unflatten(self, theta_pad):
        return self._unravel(theta_pad[: self.p])

    # ---------------------------------------------------- jittable functions
    def grad_fn(self, theta_pad, *batch):
        def loss_of_flat(t):
            return self.model.loss_fn(self._unravel(t), *batch)

        loss, grad = jax.value_and_grad(loss_of_flat)(theta_pad[: self.p])
        grad_pad = jnp.zeros((self.p_pad,), jnp.float32).at[: self.p].set(grad)
        return loss, grad_pad

    def eval_fn(self, theta_pad, *batch):
        loss, correct = self.model.eval_fn(self.unflatten(theta_pad), *batch)
        return loss, correct

    def input_specs(self, batch_size: int):
        return self.model.input_specs(batch_size)


def make_update_fn(p_pad: int, beta1: float, beta2: float, eps: float):
    """The lowered server step: L1 Pallas kernel with baked hyperparams."""

    def update_fn(theta, h, vhat, grad, alpha):
        return kernels.cada_update(theta, h, vhat, grad, alpha,
                                   beta1=beta1, beta2=beta2, eps=eps)

    return update_fn


def make_innov_fn(p_pad: int):
    def innov_fn(g1, g2):
        return (kernels.innovation_sqnorm(g1, g2),)

    return innov_fn


@functools.lru_cache(maxsize=None)
def flat_spec(p_pad: int):
    return jax.ShapeDtypeStruct((p_pad,), jnp.float32)
