//! Experiment driver: turn an [`ExpConfig`] (one figure's workload +
//! algorithm set) into runs, curves, and paper-style summary rows.

pub mod figure;
pub use figure::figure_bench;

use crate::algorithms::{LocalCfg, LocalLoop, LocalMethod};
use crate::comm::{CommStats, CostModel};
use crate::config::{AlgoConfig, ExpConfig, Schedule};
use crate::coordinator::rules::RuleKind;
use crate::coordinator::scheduler::{LoopCfg, ServerLoop};
use crate::coordinator::server::Optimizer;
use crate::data::{synthetic, Batch, Dataset, DatasetKind, Partition};
use crate::runtime::{Compute, SpecEntry};
use crate::telemetry::{average_curves, Curve, SummaryRow};
use crate::util::rng::Rng;

/// Result of all runs of one algorithm on one experiment.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub algo: String,
    /// per-run curves
    pub curves: Vec<Curve>,
    /// point-wise Monte-Carlo average
    pub mean_curve: Curve,
    pub comm: CommStats,
}

/// One experiment: workload + algorithms (one paper figure family).
pub struct Experiment {
    pub cfg: ExpConfig,
    pub spec: SpecEntry,
}

impl Experiment {
    pub fn new(cfg: ExpConfig, spec: SpecEntry) -> anyhow::Result<Self> {
        anyhow::ensure!(
            spec.name == cfg.spec,
            "spec mismatch: cfg wants {}, got {}",
            cfg.spec,
            spec.name
        );
        Ok(Experiment { cfg, spec })
    }

    /// Generate the synthetic dataset this experiment trains on.
    pub fn make_dataset(&self, run_seed: u64) -> Dataset {
        make_dataset(self.cfg.dataset, &self.spec, self.cfg.n, run_seed)
    }

    /// Held-out eval batch (fixed across iterations, sized to the eval
    /// artifact). Falls back to with-replacement sampling when the
    /// (budget-scaled) dataset is smaller than the artifact's eval batch.
    pub fn make_eval_batch(&self, data: &Dataset, rng: &mut Rng) -> Batch {
        let n = data.len();
        let b = self.spec.eval_batch;
        let idx = if b <= n {
            rng.sample_indices(n, b)
        } else {
            (0..b).map(|_| rng.below(n)).collect()
        };
        data.gather(&idx)
    }

    /// Run one algorithm for all Monte-Carlo runs.
    pub fn run_algo(
        &self,
        algo: &AlgoConfig,
        compute: &mut dyn Compute,
        init_theta: &[f32],
    ) -> anyhow::Result<RunResult> {
        let mut curves = Vec::new();
        let mut comm = CommStats::default();
        for run in 0..self.cfg.runs {
            let run_seed = self
                .cfg
                .seed
                .wrapping_mul(0x9E37)
                .wrapping_add(run as u64);
            let data = self.make_dataset(run_seed);
            let mut rng = Rng::new(run_seed ^ EVAL_SEED);
            let partition = Partition::build(self.cfg.partition, &data,
                                             self.cfg.workers, &mut rng);
            let eval_batch = self.make_eval_batch(&data, &mut rng);
            let (curve, run_comm) = run_one(
                &self.cfg,
                &self.spec,
                algo,
                compute,
                init_theta.to_vec(),
                &data,
                &partition,
                eval_batch,
                run_seed,
                run,
            )?;
            comm = run_comm;
            curves.push(curve);
        }
        let mean_curve = average_curves(&curves);
        Ok(RunResult {
            algo: algo.name().to_string(),
            curves,
            mean_curve,
            comm,
        })
    }

    /// Run every configured algorithm; returns results in config order.
    pub fn run_all(&self, compute: &mut dyn Compute, init_theta: &[f32])
                   -> anyhow::Result<Vec<RunResult>> {
        self.cfg
            .algos
            .iter()
            .map(|algo| {
                crate::info!("running {} on {}", algo.name(), self.cfg.name);
                self.run_algo(algo, compute, init_theta)
            })
            .collect()
    }

    /// Paper-style summary rows against the experiment's target loss.
    pub fn summarize(&self, results: &[RunResult]) -> Vec<SummaryRow> {
        results
            .iter()
            .map(|r| {
                let reach = r.mean_curve.first_reach(self.cfg.target_loss);
                SummaryRow {
                    algo: r.algo.clone(),
                    reached: reach.is_some(),
                    iters: reach.map(|p| p.iter).unwrap_or(0),
                    uploads: reach.map(|p| p.uploads).unwrap_or(0),
                    grad_evals: r
                        .mean_curve
                        .points
                        .last()
                        .map(|p| p.grad_evals)
                        .unwrap_or(0),
                    final_loss: r.mean_curve.final_loss(),
                    final_acc: r
                        .mean_curve
                        .points
                        .last()
                        .map(|p| p.accuracy)
                        .unwrap_or(0.0),
                    comm_stats: Some(r.comm.clone()),
                }
            })
            .collect()
    }
}

/// Map a dataset kind + spec geometry to an actual synthetic dataset.
pub fn make_dataset(kind: DatasetKind, spec: &SpecEntry, n: usize,
                    seed: u64) -> Dataset {
    match kind {
        DatasetKind::CovtypeLike => synthetic::covtype_like(n, seed),
        DatasetKind::IjcnnLike => synthetic::ijcnn_like(n, seed),
        DatasetKind::MnistLike => {
            // image-shaped input (CNN) vs flat input (mlp / logreg)
            if spec.grad_inputs[0].shape.len() == 4 {
                synthetic::mnist_like(n, seed)
            } else {
                synthetic::mnist_like_flat(n, seed)
            }
        }
        DatasetKind::CifarLike => synthetic::cifar_like(n, seed),
        DatasetKind::LmCorpus => {
            let spo = spec.grad_inputs[0].shape[1];
            let vocab = vocab_of(spec);
            synthetic::lm_corpus(n, spo - 1, vocab, seed)
        }
    }
}

fn vocab_of(spec: &SpecEntry) -> usize {
    spec.cfg
        .get("vocab")
        .and_then(|v| v.as_usize())
        .unwrap_or(256)
}

const EVAL_SEED: u64 = 0x5EED;

/// Build + run a single (algorithm, run) pair.
#[allow(clippy::too_many_arguments)]
fn run_one(
    cfg: &ExpConfig,
    spec: &SpecEntry,
    algo: &AlgoConfig,
    compute: &mut dyn Compute,
    init_theta: Vec<f32>,
    data: &Dataset,
    partition: &Partition,
    eval_batch: Batch,
    run_seed: u64,
    run: u32,
) -> anyhow::Result<(Curve, CommStats)> {
    let amsgrad = |alpha: Schedule| Optimizer::Amsgrad {
        alpha,
        beta1: spec.beta1,
        beta2: spec.beta2,
        eps: spec.eps,
        use_artifact: false,
    };
    let loop_cfg = |rule: RuleKind, d_max: usize, max_delay: u32| LoopCfg {
        iters: cfg.iters,
        eval_every: cfg.eval_every,
        rule,
        max_delay,
        snapshot_every: 0,
        d_max,
        batch: spec.batch,
        use_artifact_update: false,
        use_artifact_innov: false,
        cost_model: CostModel::default(),
        trace_cap: 0,
        upload_bytes: spec.upload_bytes(),
    };
    match *algo {
        AlgoConfig::Adam { alpha } => {
            let mut lp = ServerLoop::new(loop_cfg(RuleKind::Always, 1, u32::MAX),
                                         init_theta, amsgrad(alpha), data,
                                         partition, eval_batch, run_seed);
            let curve = lp.run(algo.name(), run, compute)?;
            Ok((curve, lp.comm))
        }
        AlgoConfig::Cada1 { alpha, c, d_max, max_delay } => {
            let mut lp = ServerLoop::new(
                loop_cfg(RuleKind::Cada1 { c }, d_max, max_delay),
                init_theta, amsgrad(alpha), data, partition, eval_batch,
                run_seed);
            let curve = lp.run(algo.name(), run, compute)?;
            Ok((curve, lp.comm))
        }
        AlgoConfig::Cada2 { alpha, c, d_max, max_delay } => {
            let mut lp = ServerLoop::new(
                loop_cfg(RuleKind::Cada2 { c }, d_max, max_delay),
                init_theta, amsgrad(alpha), data, partition, eval_batch,
                run_seed);
            let curve = lp.run(algo.name(), run, compute)?;
            Ok((curve, lp.comm))
        }
        AlgoConfig::Lag { eta, c, d_max, max_delay } => {
            let mut lp = ServerLoop::new(
                loop_cfg(RuleKind::Lag { c }, d_max, max_delay),
                init_theta, Optimizer::Sgd { eta }, data, partition,
                eval_batch, run_seed);
            let curve = lp.run(algo.name(), run, compute)?;
            Ok((curve, lp.comm))
        }
        AlgoConfig::Sgd { eta } => {
            let mut lp = ServerLoop::new(loop_cfg(RuleKind::Always, 1, u32::MAX),
                                         init_theta,
                                         Optimizer::Sgd { eta }, data,
                                         partition, eval_batch, run_seed);
            let curve = lp.run(algo.name(), run, compute)?;
            Ok((curve, lp.comm))
        }
        AlgoConfig::LocalMomentum { eta, beta, h } => {
            let mut lp = LocalLoop::new(
                local_cfg(cfg, spec, LocalMethod::LocalMomentum { eta, beta },
                          h),
                init_theta, data, partition, eval_batch, run_seed);
            let curve = lp.run(algo.name(), run, compute)?;
            Ok((curve, lp.comm))
        }
        AlgoConfig::FedAvg { eta, h } => {
            let mut lp = LocalLoop::new(
                local_cfg(cfg, spec, LocalMethod::FedAvg { eta }, h),
                init_theta, data, partition, eval_batch, run_seed);
            let curve = lp.run(algo.name(), run, compute)?;
            Ok((curve, lp.comm))
        }
        AlgoConfig::FedAdam { alpha_local, alpha_server, beta1, h } => {
            let method = LocalMethod::FedAdam {
                alpha_local,
                alpha_server,
                beta1,
                beta2: spec.beta2,
                eps: 1e-8,
            };
            let mut lp = LocalLoop::new(local_cfg(cfg, spec, method, h),
                                        init_theta, data, partition,
                                        eval_batch, run_seed);
            let curve = lp.run(algo.name(), run, compute)?;
            Ok((curve, lp.comm))
        }
    }
}

fn local_cfg(cfg: &ExpConfig, spec: &SpecEntry, method: LocalMethod, h: u32)
             -> LocalCfg {
    LocalCfg {
        iters: cfg.iters,
        eval_every: cfg.eval_every,
        h,
        batch: spec.batch,
        method,
        cost_model: CostModel::default(),
        upload_bytes: spec.upload_bytes(),
    }
}
