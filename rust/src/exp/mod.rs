//! Experiment driver: turn an [`ExpConfig`] (one figure's workload +
//! algorithm set) into runs, curves, and paper-style summary rows.

pub mod figure;
pub use figure::figure_bench;

use crate::algorithms::{
    Algorithm, Cada, CadaCfg, FedAdam, FedAdamCfg, FedAvg, LocalMomentum,
    TrainCfg, Trainer,
};
use crate::comm::CommStats;
use crate::config::{AlgoConfig, ExpConfig, Schedule};
use crate::coordinator::rules::RuleKind;
use crate::coordinator::server::Optimizer;
use crate::data::{synthetic, Batch, Dataset, DatasetKind, Partition};
use crate::runtime::{Compute, SpecEntry};
use crate::telemetry::{average_curves, Curve, SummaryRow};
use crate::util::rng::Rng;

/// Result of all runs of one algorithm on one experiment.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub algo: String,
    /// per-run curves
    pub curves: Vec<Curve>,
    /// point-wise Monte-Carlo average
    pub mean_curve: Curve,
    pub comm: CommStats,
    /// per-shard server-update timing of the last run (None for methods
    /// without sharded server state)
    pub shard_stats: Option<crate::coordinator::shard::ShardStats>,
    /// measured wire traffic of the last run (None off the socket
    /// transport)
    pub wire: Option<crate::comm::WireStats>,
}

/// The per-run seed of Monte-Carlo run `run`. `cada worker` processes
/// regenerate the server's run dataset from this, so it is THE contract
/// between `cada serve` and its workers — change it only with a wire
/// protocol version bump.
pub fn run_seed(seed: u64, run: u32) -> u64 {
    seed.wrapping_mul(0x9E37).wrapping_add(run as u64)
}

/// One experiment: workload + algorithms (one paper figure family).
pub struct Experiment {
    pub cfg: ExpConfig,
    pub spec: SpecEntry,
}

impl Experiment {
    pub fn new(cfg: ExpConfig, spec: SpecEntry) -> anyhow::Result<Self> {
        anyhow::ensure!(
            spec.name == cfg.spec,
            "spec mismatch: cfg wants {}, got {}",
            cfg.spec,
            spec.name
        );
        Ok(Experiment { cfg, spec })
    }

    /// Generate the synthetic dataset this experiment trains on.
    pub fn make_dataset(&self, run_seed: u64) -> Dataset {
        make_dataset(self.cfg.dataset, &self.spec, self.cfg.n, run_seed)
    }

    /// Held-out eval batch (fixed across iterations, sized to the eval
    /// artifact). Falls back to with-replacement sampling when the
    /// (budget-scaled) dataset is smaller than the artifact's eval batch.
    pub fn make_eval_batch(&self, data: &Dataset, rng: &mut Rng) -> Batch {
        let n = data.len();
        let b = self.spec.eval_batch;
        let idx = if b <= n {
            rng.sample_indices(n, b)
        } else {
            (0..b).map(|_| rng.below(n)).collect()
        };
        data.gather(&idx)
    }

    /// Run one algorithm for all Monte-Carlo runs.
    pub fn run_algo(
        &self,
        algo: &AlgoConfig,
        compute: &mut dyn Compute,
        init_theta: &[f32],
    ) -> anyhow::Result<RunResult> {
        let mut curves = Vec::new();
        let mut comm = CommStats::default();
        let mut shard_stats = None;
        let mut wire = None;
        for run in 0..self.cfg.runs {
            let run_seed = run_seed(self.cfg.seed, run);
            let data = self.make_dataset(run_seed);
            let mut rng = Rng::new(run_seed ^ EVAL_SEED);
            let partition = Partition::build(self.cfg.partition, &data,
                                             self.cfg.workers, &mut rng);
            let eval_batch = self.make_eval_batch(&data, &mut rng);
            let (curve, run_comm, run_shards, run_wire) = run_one(
                &self.cfg,
                &self.spec,
                algo,
                compute,
                init_theta.to_vec(),
                &data,
                &partition,
                eval_batch,
                run_seed,
                run,
            )?;
            comm = run_comm;
            shard_stats = run_shards;
            wire = run_wire;
            curves.push(curve);
        }
        let mean_curve = average_curves(&curves);
        Ok(RunResult {
            algo: algo.name().to_string(),
            curves,
            mean_curve,
            comm,
            shard_stats,
            wire,
        })
    }

    /// Run every configured algorithm; returns results in config order.
    pub fn run_all(&self, compute: &mut dyn Compute, init_theta: &[f32])
                   -> anyhow::Result<Vec<RunResult>> {
        self.cfg
            .algos
            .iter()
            .map(|algo| {
                crate::info!("running {} on {}", algo.name(), self.cfg.name);
                self.run_algo(algo, compute, init_theta)
            })
            .collect()
    }

    /// Paper-style summary rows against the experiment's target loss.
    pub fn summarize(&self, results: &[RunResult]) -> Vec<SummaryRow> {
        results
            .iter()
            .map(|r| {
                let reach = r.mean_curve.first_reach(self.cfg.target_loss);
                SummaryRow {
                    algo: r.algo.clone(),
                    reached: reach.is_some(),
                    iters: reach.map(|p| p.iter).unwrap_or(0),
                    uploads: reach.map(|p| p.uploads).unwrap_or(0),
                    grad_evals: r
                        .mean_curve
                        .points
                        .last()
                        .map(|p| p.grad_evals)
                        .unwrap_or(0),
                    final_loss: r.mean_curve.final_loss(),
                    final_acc: r
                        .mean_curve
                        .points
                        .last()
                        .map(|p| p.accuracy)
                        .unwrap_or(0.0),
                    comm_stats: Some(r.comm.clone()),
                }
            })
            .collect()
    }
}

/// Per-worker and per-shard breakdown tables for every result, when the
/// engine config makes them informative (shared by `cada train` and the
/// figure benches; empty under the uniform fully-sync unsharded
/// default).
pub fn render_breakdowns(cfg: &ExpConfig, results: &[RunResult])
                         -> String {
    let mut out = String::new();
    // lossy compression makes the per-worker table informative (raw vs
    // on-wire bytes) even under uniform fully-sync links
    if !cfg.comm.is_uniform_sync() || cfg.compress.is_lossy() {
        out.extend(results.iter().map(|r| {
            crate::telemetry::render_worker_breakdown(&r.algo, &r.comm)
        }));
    }
    if cfg.comm.server_shards != 1 {
        out.extend(results.iter().filter_map(|r| {
            r.shard_stats.as_ref().map(|s| {
                crate::telemetry::render_shard_breakdown(&r.algo, s)
            })
        }));
    }
    // socket runs also report what actually crossed the wire
    out.extend(results.iter().filter_map(|r| {
        r.wire
            .as_ref()
            .map(|w| crate::telemetry::render_wire_stats(&r.algo, w))
    }));
    out
}

/// Map a dataset kind + spec geometry to an actual synthetic dataset.
pub fn make_dataset(kind: DatasetKind, spec: &SpecEntry, n: usize,
                    seed: u64) -> Dataset {
    match kind {
        DatasetKind::CovtypeLike => synthetic::covtype_like(n, seed),
        DatasetKind::IjcnnLike => synthetic::ijcnn_like(n, seed),
        DatasetKind::MnistLike => {
            // image-shaped input (CNN) vs flat input (mlp / logreg)
            if spec.grad_inputs[0].shape.len() == 4 {
                synthetic::mnist_like(n, seed)
            } else {
                synthetic::mnist_like_flat(n, seed)
            }
        }
        DatasetKind::CifarLike => synthetic::cifar_like(n, seed),
        DatasetKind::LmCorpus => {
            let spo = spec.grad_inputs[0].shape[1];
            let vocab = vocab_of(spec);
            synthetic::lm_corpus(n, spo - 1, vocab, seed)
        }
    }
}

fn vocab_of(spec: &SpecEntry) -> usize {
    spec.cfg
        .get("vocab")
        .and_then(|v| v.as_usize())
        .unwrap_or(256)
}

const EVAL_SEED: u64 = 0x5EED;

/// Instantiate the [`Algorithm`] an [`AlgoConfig`] describes, with the
/// spec's Adam hyperparameters filled in.
pub fn build_algorithm(algo: &AlgoConfig, spec: &SpecEntry)
                       -> Box<dyn Algorithm> {
    let amsgrad = |alpha: Schedule| Optimizer::Amsgrad {
        alpha,
        beta1: spec.beta1,
        beta2: spec.beta2,
        eps: spec.eps,
        use_artifact: false,
    };
    let cada = |rule: RuleKind, opt: Optimizer, d_max: usize,
                max_delay: u32| {
        Box::new(Cada::new(CadaCfg {
            rule,
            opt,
            max_delay,
            snapshot_every: 0,
            d_max,
            use_artifact_innov: false,
        }))
    };
    match *algo {
        AlgoConfig::Adam { alpha } => {
            cada(RuleKind::Always, amsgrad(alpha), 1, u32::MAX)
        }
        AlgoConfig::Cada1 { alpha, c, d_max, max_delay } => {
            cada(RuleKind::Cada1 { c }, amsgrad(alpha), d_max, max_delay)
        }
        AlgoConfig::Cada2 { alpha, c, d_max, max_delay } => {
            cada(RuleKind::Cada2 { c }, amsgrad(alpha), d_max, max_delay)
        }
        AlgoConfig::Lag { eta, c, d_max, max_delay } => {
            cada(RuleKind::Lag { c }, Optimizer::Sgd { eta }, d_max,
                 max_delay)
        }
        AlgoConfig::Sgd { eta } => {
            cada(RuleKind::Always, Optimizer::Sgd { eta }, 1, u32::MAX)
        }
        AlgoConfig::LocalMomentum { eta, beta, h } => {
            Box::new(LocalMomentum::new(eta, beta, h))
        }
        AlgoConfig::FedAvg { eta, h } => Box::new(FedAvg::new(eta, h)),
        AlgoConfig::FedAdam { alpha_local, alpha_server, beta1, h } => {
            Box::new(FedAdam::new(FedAdamCfg {
                alpha_local,
                alpha_server,
                beta1,
                beta2: spec.beta2,
                eps: 1e-8,
                h,
            }))
        }
    }
}

/// Build + run a single (algorithm, run) pair through the unified
/// [`Trainer`].
#[allow(clippy::too_many_arguments)]
fn run_one(
    cfg: &ExpConfig,
    spec: &SpecEntry,
    algo: &AlgoConfig,
    compute: &mut dyn Compute,
    init_theta: Vec<f32>,
    data: &Dataset,
    partition: &Partition,
    eval_batch: Batch,
    run_seed: u64,
    run: u32,
) -> anyhow::Result<(
    Curve,
    CommStats,
    Option<crate::coordinator::shard::ShardStats>,
    Option<crate::comm::WireStats>,
)> {
    let mut algorithm = build_algorithm(algo, spec);
    let mut trainer = Trainer::builder()
        .cfg(TrainCfg {
            iters: cfg.iters,
            eval_every: cfg.eval_every,
            batch: spec.batch,
            seed: run_seed,
            cost_model: cfg.cost_model.clone(),
            upload_bytes: spec.upload_bytes(),
            broadcast_bytes: cfg.broadcast_bytes,
            trace_cap: cfg.trace_cap,
            comm: cfg.comm.clone(),
            compress: cfg.compress,
            fault: cfg.fault.clone(),
            checkpoint: cfg.checkpoint.clone(),
        })
        .algorithm(&mut *algorithm)
        .dataset(data)
        .partition(partition)
        .eval_batch(eval_batch)
        .init_theta(init_theta)
        .label(algo.name())
        .build()?;
    let curve = trainer.run(run, compute)?;
    let comm = trainer.comm.clone();
    let wire = trainer.wire_stats().cloned();
    drop(trainer);
    Ok((curve, comm, algorithm.shard_stats(), wire))
}
