//! Shared driver for the per-figure benches (`cargo bench --bench figN_*`).
//!
//! Each bench target regenerates one paper figure: it runs the preset's
//! full algorithm set on the PJRT engine, prints (a) the paper-style
//! summary table (who wins, by what factor) and (b) the loss-vs-
//! {iterations, gradient evaluations, uploads} series the figure plots,
//! and writes the raw curves to `results/<name>.jsonl`.
//!
//! Scaling knobs (benches must terminate on a laptop):
//!   CADA_BENCH_FAST=1        heavily scaled-down smoke run
//!   --iters N --runs R --n N CLI overrides (after `--`)

use crate::cli::Args;
use crate::config::{self, ExpConfig};
use crate::exp::Experiment;
use crate::runtime::{load_backend, Manifest};
use crate::telemetry::{render_table, write_jsonl, Curve};

/// Entry point used by every `benches/fig*.rs`.
pub fn figure_bench(preset: &str) -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let mut cfg = config::preset(preset)?;
    if std::env::var_os("CADA_BENCH_FAST").is_some() {
        cfg = fast_scale(cfg);
    }
    cfg.iters = args.usize_or("iters", cfg.iters)?;
    cfg.runs = args.u64_or("runs", cfg.runs as u64)? as u32;
    cfg.n = args.usize_or("n", cfg.n)?;
    cfg.workers = args.usize_or("workers", cfg.workers)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    // engine knobs: transport, semi-sync quorum, straggler jitter
    config::apply_comm_cli_overrides(&mut cfg.comm, &args)?;
    // `cargo bench` passes --bench to the binary; accept and ignore it.
    let _ = args.bool("bench");
    args.reject_unknown()?;

    println!(
        "=== {} — spec {}, M={}, {} iters, {} run(s) ===",
        cfg.name, cfg.spec, cfg.workers, cfg.iters, cfg.runs
    );
    let (spec, mut compute, init) =
        load_backend(Manifest::default_dir(), &cfg.spec)?;
    println!("backend: {}", compute.backend_name());
    let exp = Experiment::new(cfg.clone(), spec)?;
    let t0 = std::time::Instant::now();
    let results = exp.run_all(&mut *compute, &init)?;
    let rows = exp.summarize(&results);
    print!("{}", render_table(&cfg.name, cfg.target_loss, &rows));

    // the figure's series: loss against each of the paper's x-axes
    for r in &results {
        print_series(&r.mean_curve);
    }
    // under heterogeneous links / jitter / semi-sync the per-worker
    // breakdown is where stragglers become visible
    print!("{}", crate::exp::render_breakdowns(&cfg, &results));
    let curves: Vec<Curve> = results
        .iter()
        .flat_map(|r| r.curves.iter().cloned())
        .collect();
    let out = format!("results/{}.jsonl", cfg.name);
    write_jsonl(&out, &curves)?;
    println!(
        "\n[{}] total wall {:.1}s; curves -> {out}",
        cfg.name,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

fn fast_scale(mut cfg: ExpConfig) -> ExpConfig {
    cfg.iters = (cfg.iters / 10).max(40);
    cfg.n = (cfg.n / 4).max(1_000);
    cfg.runs = 1;
    cfg.eval_every = (cfg.eval_every / 2).max(5);
    cfg
}

/// Print a downsampled loss series over the figure's three x-axes.
fn print_series(curve: &Curve) {
    println!("\n-- {} (mean over runs) --", curve.algo);
    println!(
        "{:>8} {:>12} {:>10} {:>10} {:>9}",
        "iter", "grad_evals", "uploads", "loss", "acc"
    );
    let stride = (curve.points.len() / 12).max(1);
    for (i, p) in curve.points.iter().enumerate() {
        if i % stride == 0 || i + 1 == curve.points.len() {
            println!(
                "{:>8} {:>12} {:>10} {:>10.4} {:>9.4}",
                p.iter, p.grad_evals, p.uploads, p.loss, p.accuracy
            );
        }
    }
}
