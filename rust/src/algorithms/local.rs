//! Local-update baselines as [`Algorithm`]s: local momentum SGD
//! [Yu et al. 2019], FedAvg [McMahan et al. 2017] and FedAdam
//! [Reddi et al. 2020] — the paper's comparison methods where workers
//! update a LOCAL model and communicate only at averaging rounds (every
//! H iterations).
//!
//! Lifecycle mapping (see [`crate::algorithms`] docs): `broadcast` is a
//! no-op (models were pushed down when the previous averaging round
//! completed); `make_step` packages one local SGD/momentum step as a
//! self-contained job owning the worker's local model and its gradient
//! scratch (so any transport can run the M steps concurrently), and
//! `absorb_step` returns them home; `aggregate` averages the local
//! models on rounds with `(k+1) % H == 0`; `server_update` applies the
//! server-side rule (identity for FedAvg/local momentum, Adam on the
//! averaged pseudo-gradient for FedAdam) and broadcasts the new global
//! model back down.
//!
//! Participation note: model averaging needs EVERY local model, so these
//! methods always run fully synchronous — the engine forces
//! [`Participation::Full`](crate::comm::Participation) for the
//! `LocalUpdate` family and the semi-sync quorum only applies to the
//! server-centric methods.
//!
//! Sharding note: the `[comm] server_shards` hint is ignored here (the
//! trait default). These methods keep no server-side parameter-range
//! state on the round hot path — averaging happens once every H rounds
//! and already runs over per-worker vectors; sharding FedAdam's server
//! Adam the way [`crate::coordinator::shard`] shards CADA's is a
//! follow-up if H-small sweeps ever make it hot.

use super::{Algorithm, AlgorithmKind, RoundCtx};
use crate::comm::{JobOut, WorkerJob};
use crate::data::Batch;
use crate::runtime::Compute;
use crate::tensor;

/// Shared local-update machinery: the global model, per-worker local
/// models, and the averaging-round plumbing.
#[derive(Debug, Default)]
struct LocalModels {
    /// averaging period H
    h: u32,
    /// global (server) model
    theta: Vec<f32>,
    /// per-worker local models
    thetas: Vec<Vec<f32>>,
    /// per-worker gradient scratch, moved through the worker jobs
    /// (allocation-free hot path on every transport)
    grads: Vec<Vec<f32>>,
}

impl LocalModels {
    fn new(h: u32) -> Self {
        LocalModels { h, ..Default::default() }
    }

    fn init(&mut self, init_theta: &[f32], m: usize) -> anyhow::Result<()> {
        anyhow::ensure!(self.h >= 1, "averaging period H must be >= 1");
        self.theta = init_theta.to_vec();
        self.thetas = vec![init_theta.to_vec(); m];
        self.grads = vec![vec![0.0; init_theta.len()]; m];
        Ok(())
    }

    fn workers(&self) -> usize {
        self.thetas.len()
    }

    /// Does round `k` end with an averaging round?
    fn averaging_round(&self, k: u64) -> bool {
        (k + 1) % self.h as u64 == 0
    }

    /// All M workers upload at averaging rounds; none otherwise.
    fn pending_uploads(&self, k: u64) -> Vec<usize> {
        if self.averaging_round(k) {
            (0..self.workers()).collect()
        } else {
            Vec::new()
        }
    }

    /// Hand worker `w`'s local model + scratch to a job (placeholder
    /// empties keep the slots until the outcome returns).
    fn lend(&mut self, w: usize) -> (Vec<f32>, Vec<f32>) {
        (std::mem::take(&mut self.thetas[w]),
         std::mem::take(&mut self.grads[w]))
    }

    fn restore(&mut self, w: usize, theta_w: Vec<f32>, grad: Vec<f32>) {
        self.thetas[w] = theta_w;
        self.grads[w] = grad;
    }

    /// Mean of the local models, written into `dst`.
    fn mean_local_into(dst: &mut [f32], thetas: &[Vec<f32>]) {
        let parts: Vec<&[f32]> =
            thetas.iter().map(|t| t.as_slice()).collect();
        tensor::mean_into(dst, &parts);
    }

    /// Broadcast the global model back to every worker.
    fn push_down(&mut self, ctx: &mut RoundCtx) {
        ctx.count_broadcast(ctx.broadcast_bytes);
        for t in &mut self.thetas {
            t.copy_from_slice(&self.theta);
        }
    }
}

/// FedAvg / local SGD: parameter averaging only.
pub struct FedAvg {
    pub eta: f32,
    models: LocalModels,
}

impl FedAvg {
    pub fn new(eta: f32, h: u32) -> Self {
        FedAvg { eta, models: LocalModels::new(h) }
    }
}

impl Algorithm for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn kind(&self) -> AlgorithmKind {
        AlgorithmKind::LocalUpdate
    }

    fn init(&mut self, init_theta: &[f32], m: usize) -> anyhow::Result<()> {
        self.models.init(init_theta, m)
    }

    fn theta(&self) -> &[f32] {
        &self.models.theta
    }

    fn broadcast(&mut self, _ctx: &mut RoundCtx) -> anyhow::Result<()> {
        Ok(())
    }

    fn make_step(&mut self, _k: u64, w: usize, batch: Batch)
                 -> anyhow::Result<WorkerJob> {
        let (theta_w, grad) = self.models.lend(w);
        let eta = self.eta;
        Ok(Box::new(move |compute: &mut dyn Compute| {
            let mut theta_w = theta_w;
            let mut grad = grad;
            compute.grad(&theta_w, &batch, &mut grad)?;
            tensor::sgd_update(&mut theta_w, &grad, eta);
            Ok(Box::new((theta_w, grad)) as JobOut)
        }))
    }

    fn absorb_step(&mut self, ctx: &mut RoundCtx, w: usize, out: JobOut)
                   -> anyhow::Result<()> {
        let (theta_w, grad) = *out
            .downcast::<(Vec<f32>, Vec<f32>)>()
            .map_err(|_| anyhow::anyhow!(
                "fedavg: unexpected worker-job outcome type"))?;
        self.models.restore(w, theta_w, grad);
        ctx.comm.record_grad_evals(1);
        Ok(())
    }

    fn pending_uploads(&self, k: u64) -> Vec<usize> {
        self.models.pending_uploads(k)
    }

    fn aggregate(&mut self, ctx: &mut RoundCtx) -> anyhow::Result<()> {
        if self.models.averaging_round(ctx.k) {
            LocalModels::mean_local_into(&mut self.models.theta,
                                         &self.models.thetas);
        }
        Ok(())
    }

    fn server_update(&mut self, ctx: &mut RoundCtx,
                     _compute: &mut dyn Compute) -> anyhow::Result<()> {
        if self.models.averaging_round(ctx.k) {
            self.models.push_down(ctx);
        }
        Ok(())
    }
}

/// Local momentum SGD; parameters AND momentum buffers are averaged at
/// each communication round (blockwise model averaging).
pub struct LocalMomentum {
    pub eta: f32,
    pub beta: f32,
    models: LocalModels,
    /// per-worker momentum buffers
    momenta: Vec<Vec<f32>>,
    mom_avg: Vec<f32>,
}

impl LocalMomentum {
    pub fn new(eta: f32, beta: f32, h: u32) -> Self {
        LocalMomentum {
            eta,
            beta,
            models: LocalModels::new(h),
            momenta: Vec::new(),
            mom_avg: Vec::new(),
        }
    }
}

impl Algorithm for LocalMomentum {
    fn name(&self) -> &'static str {
        "local_momentum"
    }

    fn kind(&self) -> AlgorithmKind {
        AlgorithmKind::LocalUpdate
    }

    fn init(&mut self, init_theta: &[f32], m: usize) -> anyhow::Result<()> {
        self.models.init(init_theta, m)?;
        self.momenta = vec![vec![0.0; init_theta.len()]; m];
        self.mom_avg = vec![0.0; init_theta.len()];
        Ok(())
    }

    fn theta(&self) -> &[f32] {
        &self.models.theta
    }

    fn broadcast(&mut self, _ctx: &mut RoundCtx) -> anyhow::Result<()> {
        Ok(())
    }

    fn make_step(&mut self, _k: u64, w: usize, batch: Batch)
                 -> anyhow::Result<WorkerJob> {
        let (theta_w, grad) = self.models.lend(w);
        let momentum = std::mem::take(&mut self.momenta[w]);
        let (eta, beta) = (self.eta, self.beta);
        Ok(Box::new(move |compute: &mut dyn Compute| {
            let mut theta_w = theta_w;
            let mut grad = grad;
            let mut momentum = momentum;
            compute.grad(&theta_w, &batch, &mut grad)?;
            tensor::momentum_update(&mut theta_w, &mut momentum, &grad,
                                    eta, beta);
            Ok(Box::new((theta_w, grad, momentum)) as JobOut)
        }))
    }

    fn absorb_step(&mut self, ctx: &mut RoundCtx, w: usize, out: JobOut)
                   -> anyhow::Result<()> {
        let (theta_w, grad, momentum) = *out
            .downcast::<(Vec<f32>, Vec<f32>, Vec<f32>)>()
            .map_err(|_| anyhow::anyhow!(
                "local_momentum: unexpected worker-job outcome type"))?;
        self.models.restore(w, theta_w, grad);
        self.momenta[w] = momentum;
        ctx.comm.record_grad_evals(1);
        Ok(())
    }

    fn pending_uploads(&self, k: u64) -> Vec<usize> {
        self.models.pending_uploads(k)
    }

    fn aggregate(&mut self, ctx: &mut RoundCtx) -> anyhow::Result<()> {
        if self.models.averaging_round(ctx.k) {
            LocalModels::mean_local_into(&mut self.models.theta,
                                         &self.models.thetas);
            // average the momentum buffers as well
            let mparts: Vec<&[f32]> =
                self.momenta.iter().map(|u| u.as_slice()).collect();
            tensor::mean_into(&mut self.mom_avg, &mparts);
            for u in &mut self.momenta {
                u.copy_from_slice(&self.mom_avg);
            }
        }
        Ok(())
    }

    fn server_update(&mut self, ctx: &mut RoundCtx,
                     _compute: &mut dyn Compute) -> anyhow::Result<()> {
        if self.models.averaging_round(ctx.k) {
            self.models.push_down(ctx);
        }
        Ok(())
    }
}

/// FedAdam hyperparameters (Reddi et al., the FedOpt server rule).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FedAdamCfg {
    pub alpha_local: f32,
    pub alpha_server: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// averaging period H
    pub h: u32,
}

/// FedAdam: local SGD; the server applies Adam to the averaged model
/// delta every H iterations.
pub struct FedAdam {
    pub cfg: FedAdamCfg,
    models: LocalModels,
    /// server first/second moments over the pseudo-gradient
    m1: Vec<f32>,
    m2: Vec<f32>,
    /// scratch: this averaging round's mean local model
    avg: Vec<f32>,
}

impl FedAdam {
    pub fn new(cfg: FedAdamCfg) -> Self {
        FedAdam {
            models: LocalModels::new(cfg.h),
            m1: Vec::new(),
            m2: Vec::new(),
            avg: Vec::new(),
            cfg,
        }
    }
}

impl Algorithm for FedAdam {
    fn name(&self) -> &'static str {
        "fedadam"
    }

    fn kind(&self) -> AlgorithmKind {
        AlgorithmKind::LocalUpdate
    }

    fn init(&mut self, init_theta: &[f32], m: usize) -> anyhow::Result<()> {
        self.models.init(init_theta, m)?;
        self.m1 = vec![0.0; init_theta.len()];
        self.m2 = vec![0.0; init_theta.len()];
        self.avg = vec![0.0; init_theta.len()];
        Ok(())
    }

    fn theta(&self) -> &[f32] {
        &self.models.theta
    }

    fn broadcast(&mut self, _ctx: &mut RoundCtx) -> anyhow::Result<()> {
        Ok(())
    }

    fn make_step(&mut self, _k: u64, w: usize, batch: Batch)
                 -> anyhow::Result<WorkerJob> {
        let (theta_w, grad) = self.models.lend(w);
        let eta = self.cfg.alpha_local;
        Ok(Box::new(move |compute: &mut dyn Compute| {
            let mut theta_w = theta_w;
            let mut grad = grad;
            compute.grad(&theta_w, &batch, &mut grad)?;
            tensor::sgd_update(&mut theta_w, &grad, eta);
            Ok(Box::new((theta_w, grad)) as JobOut)
        }))
    }

    fn absorb_step(&mut self, ctx: &mut RoundCtx, w: usize, out: JobOut)
                   -> anyhow::Result<()> {
        let (theta_w, grad) = *out
            .downcast::<(Vec<f32>, Vec<f32>)>()
            .map_err(|_| anyhow::anyhow!(
                "fedadam: unexpected worker-job outcome type"))?;
        self.models.restore(w, theta_w, grad);
        ctx.comm.record_grad_evals(1);
        Ok(())
    }

    fn pending_uploads(&self, k: u64) -> Vec<usize> {
        self.models.pending_uploads(k)
    }

    fn aggregate(&mut self, ctx: &mut RoundCtx) -> anyhow::Result<()> {
        if self.models.averaging_round(ctx.k) {
            LocalModels::mean_local_into(&mut self.avg, &self.models.thetas);
        }
        Ok(())
    }

    fn server_update(&mut self, ctx: &mut RoundCtx,
                     _compute: &mut dyn Compute) -> anyhow::Result<()> {
        if self.models.averaging_round(ctx.k) {
            // delta = mean_m(theta_m) - theta  (the pseudo-gradient)
            let FedAdamCfg { alpha_server, beta1, beta2, eps, .. } = self.cfg;
            let theta = &mut self.models.theta;
            for i in 0..theta.len() {
                let delta = self.avg[i] - theta[i];
                self.m1[i] = beta1 * self.m1[i] + (1.0 - beta1) * delta;
                self.m2[i] =
                    beta2 * self.m2[i] + (1.0 - beta2) * delta * delta;
                theta[i] +=
                    alpha_server * self.m1[i] / (self.m2[i].sqrt() + eps);
            }
            self.models.push_down(ctx);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::Trainer;
    use crate::data::{synthetic, Dataset, Partition, PartitionScheme};
    use crate::runtime::native::NativeLogReg;
    use crate::util::rng::Rng;

    fn setup() -> (NativeLogReg, Dataset, Partition) {
        let compute = NativeLogReg::for_spec(22, 1024);
        let data = synthetic::ijcnn_like(600, 5);
        let mut rng = Rng::new(11);
        let partition =
            Partition::build(PartitionScheme::Uniform, &data, 4, &mut rng);
        (compute, data, partition)
    }

    fn train(algo: &mut dyn Algorithm, data: &Dataset,
             partition: &Partition, iters: usize, h_seed: u64,
             compute: &mut NativeLogReg) -> (crate::telemetry::Curve,
                                             crate::comm::CommStats) {
        let eval = data.gather(&(0..128).collect::<Vec<_>>());
        let mut trainer = Trainer::builder()
            .algorithm(algo)
            .dataset(data)
            .partition(partition)
            .eval_batch(eval)
            .init_theta(vec![0.0; 1024])
            .iters(iters)
            .eval_every(10)
            .upload_bytes(92)
            .seed(h_seed)
            .build()
            .unwrap();
        let curve = trainer.run(0, compute).unwrap();
        let comm = trainer.comm.clone();
        (curve, comm)
    }

    #[test]
    fn fedavg_uploads_every_h() {
        let (mut compute, data, partition) = setup();
        let mut algo = FedAvg::new(0.1, 5);
        let (_, comm) = train(&mut algo, &data, &partition, 20, 1,
                              &mut compute);
        // 20 iters, H=5 -> 4 rounds x 4 workers
        assert_eq!(comm.uploads, 16);
        assert_eq!(comm.grad_evals, 80);
        // broadcasts only on averaging rounds: 4 rounds x 4 workers
        assert_eq!(comm.downloads, 16);
        // per-worker breakdown: every worker uploaded at every round
        assert_eq!(comm.worker_uploads, vec![4; 4]);
    }

    #[test]
    fn methods_descend() {
        let (mut compute, data, partition) = setup();
        let mut algos: Vec<Box<dyn Algorithm>> = vec![
            Box::new(FedAvg::new(0.1, 5)),
            Box::new(LocalMomentum::new(0.05, 0.9, 5)),
            Box::new(FedAdam::new(FedAdamCfg {
                alpha_local: 0.1,
                alpha_server: 0.1,
                beta1: 0.9,
                beta2: 0.999,
                eps: 1e-8,
                h: 5,
            })),
        ];
        for algo in &mut algos {
            let name = algo.name();
            let (curve, _) = train(algo.as_mut(), &data, &partition, 80, 2,
                                   &mut compute);
            assert!(
                curve.final_loss() < curve.points[0].loss,
                "{name}: {} -> {}",
                curve.points[0].loss,
                curve.final_loss()
            );
        }
    }

    #[test]
    fn h1_fedavg_equals_distributed_sgd_rate() {
        // With H=1 FedAvg averages every step: equivalent to synchronous
        // SGD on the mean gradient. Its iterate after K steps must track
        // a manual implementation bit-for-bit given the same rng streams.
        let (mut compute, data, partition) = setup();
        let mut algo = FedAvg::new(0.05, 1);
        let (_, _) = train(&mut algo, &data, &partition, 30, 77,
                           &mut compute);

        // manual twin with identical rng streams
        let root = Rng::new(77);
        let mut rngs: Vec<Rng> =
            (0..4).map(|w| root.fork(w as u64 + 1)).collect();
        let mut theta = vec![0.0f32; 1024];
        let mut g = vec![0.0f32; 1024];
        for _ in 0..30 {
            let mut thetas = Vec::new();
            for w in 0..4 {
                let b = data.sample_batch(&partition.shards[w], 16,
                                          &mut rngs[w]);
                compute.grad(&theta, &b, &mut g).unwrap();
                let mut tw = theta.clone();
                tensor::sgd_update(&mut tw, &g, 0.05);
                thetas.push(tw);
            }
            let parts: Vec<&[f32]> =
                thetas.iter().map(|t| t.as_slice()).collect();
            tensor::mean_into(&mut theta, &parts);
        }
        let diff = tensor::sqnorm_diff(algo.theta(), &theta);
        assert!(diff < 1e-9, "diff {diff}");
    }
}
