//! The unified training API: one [`Algorithm`] trait covering every
//! method the paper evaluates, driven by one generic
//! [`Trainer`](trainer::Trainer) over a transport-abstracted execution
//! engine.
//!
//! # The round lifecycle
//!
//! Every distributed method in the paper — server-centric (CADA1/2, LAG,
//! distributed Adam/SGD) and local-update (local momentum SGD, FedAvg,
//! FedAdam) — fits one iteration shape, which the [`Trainer`] drives in a
//! fixed order each round `k`:
//!
//! 1. **`broadcast`** — server → workers. Server-centric methods ship
//!    theta^k to every worker (and refresh the CADA1 snapshot), freezing
//!    the round's shared state behind `Arc`s; local-update methods are a
//!    no-op here because their models were pushed down when the previous
//!    averaging round completed.
//! 2. **worker jobs** — `make_step` packages worker `w`'s computation
//!    (rule check / local SGD step on a Trainer-sampled minibatch) as a
//!    self-contained [`WorkerJob`]; the configured
//!    [`Transport`](crate::comm::Transport) executes all M jobs —
//!    sequentially in-process, or on persistent worker threads — and
//!    `absorb_step` folds each outcome back **in worker order**, which
//!    is what keeps every transport bit-identical.
//! 3. **`aggregate`** — workers → server. The engine first settles the
//!    round's upload set against the per-worker
//!    [`LinkSet`](crate::comm::LinkSet) and participation policy
//!    (fully-sync, or semi-sync "fastest K of M"); `aggregate` then folds
//!    `ctx.fresh` uploads now and re-queues `ctx.deferred` stragglers
//!    for a stale fold next round (Eq. 3, possibly delayed).
//! 4. **`server_update`** — the server step. CADA applies AMSGrad/SGD on
//!    the aggregate (Eq. 2/4) and records the drift history; FedAdam
//!    applies server Adam to the averaged pseudo-gradient; local-update
//!    methods then broadcast the new global model back down.
//!
//! The [`Trainer`] owns everything method-independent: the iteration
//! loop, per-worker RNG streams, minibatch sampling, the transport, the
//! link models and event clock, evaluation cadence,
//! [`Curve`](crate::telemetry::Curve) recording,
//! [`CommStats`](crate::comm::CommStats) and the bounded
//! [`EventTrace`](crate::comm::EventTrace). Algorithms only hold model
//! state and decide what moves over the (simulated) network, via the
//! [`RoundCtx`] handed to each lifecycle method.
//!
//! ```
//! use cada::prelude::*;
//!
//! let data = cada::data::synthetic::ijcnn_like(512, 7);
//! let mut rng = Rng::new(7);
//! let partition = Partition::build(PartitionScheme::Uniform, &data, 4,
//!                                  &mut rng);
//! let eval = data.gather(&(0..64).collect::<Vec<_>>());
//! let mut compute = cada::runtime::native::NativeLogReg::for_spec(22, 1024);
//!
//! let mut algo = Cada::new(CadaCfg::basic(
//!     RuleKind::Cada2 { c: 0.6 },
//!     Optimizer::Amsgrad {
//!         alpha: Schedule::Constant(0.01),
//!         beta1: 0.9, beta2: 0.999, eps: 1e-8,
//!         use_artifact: false,
//!     },
//! ));
//! let mut trainer = Trainer::builder()
//!     .algorithm(&mut algo)
//!     .dataset(&data)
//!     .partition(&partition)
//!     .eval_batch(eval)
//!     .init_theta(vec![0.0; 1024])
//!     .iters(40)
//!     .eval_every(10)
//!     .seed(3)
//!     .build()
//!     .unwrap();
//! let curve = trainer.run(0, &mut compute).unwrap();
//! assert!(curve.final_loss() < curve.points[0].loss);
//! ```

pub mod cada;
pub mod local;
pub mod trainer;

pub use cada::{Cada, CadaCfg};
pub use local::{FedAdam, FedAdamCfg, FedAvg, LocalMomentum};
pub use trainer::{TrainCfg, Trainer, TrainerBuilder};

use crate::comm::{CommStats, JobOut, LinkSet, RoundEvent, WorkerJob};
use crate::data::Batch;
use crate::runtime::Compute;

/// Which family a method belongs to (telemetry / driver metadata).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlgorithmKind {
    /// Parameter-server methods: broadcast every round, adaptive uploads.
    ServerCentric,
    /// Periodic-averaging methods: communicate every H rounds only.
    LocalUpdate,
}

/// Per-round context handed to every [`Algorithm`] lifecycle method.
///
/// Owned by the [`Trainer`](trainer::Trainer); algorithms use it to
/// account communication against the run's per-worker link models and
/// to learn the engine's participation verdict in `aggregate`.
pub struct RoundCtx<'c> {
    /// current iteration k
    pub k: u64,
    /// number of workers M
    pub m: usize,
    /// payload of one UPLINK gradient/innovation upload, bytes
    pub upload_bytes: usize,
    /// payload of one DOWNLINK model broadcast, bytes. Defaults to
    /// `upload_bytes` (a full model down, a full gradient up — the
    /// seed's assumption, preserved bit-for-bit), but the two are
    /// distinct quantities: wire-measured socket payloads and
    /// compressed-upload experiments (arXiv:2111.00705) diverge them.
    pub broadcast_bytes: usize,
    /// this run's per-worker link models
    pub links: &'c LinkSet,
    pub comm: &'c mut CommStats,
    /// participation verdict: uploads folded this round, worker order.
    /// Set by the engine before `aggregate`; empty in earlier phases.
    pub fresh: Vec<usize>,
    /// uploads deferred to a stale fold next round (semi-sync stragglers)
    pub deferred: Vec<usize>,
    /// this round's selected participants (sorted population slots).
    /// `0..m` under full participation — the trainer draws it once per
    /// round with [`ParticipationCfg::select`] so every transport prices
    /// the same subset.
    ///
    /// [`ParticipationCfg::select`]: crate::comm::ParticipationCfg::select
    pub selected: Vec<usize>,
}

impl RoundCtx<'_> {
    /// Count a model broadcast to this round's selected workers and
    /// advance the event clock by the slowest *selected* worker's
    /// download (broadcasts run in parallel, so the round waits for the
    /// worst participating link, not the sum; unselected workers receive
    /// nothing and must not pace the clock). Under full participation
    /// this is bit-identical to the historical broadcast-to-all
    /// accounting.
    pub fn count_broadcast(&mut self, bytes: usize) {
        self.comm.count_broadcast(self.selected.len(), bytes);
        let dt = self.links.max_download_among(&self.selected, bytes);
        self.comm.advance_clock(dt);
    }
}

/// One distributed training method, expressed as the four-phase round
/// lifecycle the [`Trainer`](trainer::Trainer) drives (see module docs).
pub trait Algorithm {
    /// Mechanism name ("cada2", "fedavg", ...; telemetry default label).
    fn name(&self) -> &'static str;

    /// Family tag (server-centric vs local-update).
    fn kind(&self) -> AlgorithmKind;

    /// Engine hint, delivered before [`Algorithm::init`]: shard the
    /// server-side parameter state into this many contiguous ranges
    /// (the `[comm] server_shards` knob, resolved to cores when 0).
    /// Sharding is a pure execution strategy — results must stay
    /// bit-identical for every shard count — so methods without server
    /// state simply ignore it (the default).
    fn set_server_shards(&mut self, shards: usize) {
        let _ = shards;
    }

    /// Engine hint, delivered before [`Algorithm::init`]: how
    /// multi-shard server rounds execute (`[comm] shard_exec` — the
    /// persistent [`ShardPool`](crate::coordinator::pool::ShardPool),
    /// or per-round scoped threads). Pure execution strategy,
    /// bit-identical either way; methods without sharded server state
    /// ignore it (the default).
    fn set_shard_exec(&mut self, exec: crate::coordinator::pool::ShardExec) {
        let _ = exec;
    }

    /// Engine hint, delivered before [`Algorithm::init`]: the upload
    /// compression config (the `[compress]` section). Lossy schemes
    /// only make sense for methods that upload innovation deltas, so
    /// the default accepts `Identity` (a no-op) and fails fast on
    /// TopK/QuantB — a clean build-time error instead of silently
    /// uncompressed uploads.
    fn set_compress(&mut self, cfg: crate::compress::CompressCfg)
                    -> anyhow::Result<()> {
        anyhow::ensure!(
            !cfg.is_lossy(),
            "algorithm '{}' does not support compressed uploads \
             (lossy schemes apply to the server-centric innovation \
             uploads; use [compress] scheme = \"identity\")",
            self.name()
        );
        Ok(())
    }

    /// Allocate all model state for `m` workers from the initial iterate.
    /// Called exactly once, by
    /// [`TrainerBuilder::build`](trainer::TrainerBuilder::build).
    fn init(&mut self, init_theta: &[f32], m: usize) -> anyhow::Result<()>;

    /// The current global model (what evaluation runs against).
    fn theta(&self) -> &[f32];

    /// Phase 1 — server → workers, at the top of round `k`.
    fn broadcast(&mut self, ctx: &mut RoundCtx) -> anyhow::Result<()>;

    /// Phase 2a — package worker `w`'s round-`k` computation as a
    /// self-contained, `Send` job: move the worker's own state and the
    /// round-frozen shared tensors (behind `Arc`s) into the closure.
    /// The transport may run it on any thread with any forked backend.
    fn make_step(&mut self, k: u64, w: usize, batch: Batch)
                 -> anyhow::Result<WorkerJob>;

    /// Phase 2b — fold worker `w`'s job outcome back into the algorithm.
    /// Called in worker order whatever the completion order was; this is
    /// where per-worker state returns home and gradient evaluations are
    /// accounted.
    fn absorb_step(&mut self, ctx: &mut RoundCtx, w: usize, out: JobOut)
                   -> anyhow::Result<()>;

    /// Phase 2b for a worker the round did *not* select: no job ran, so
    /// there is nothing to fold — but per-worker bookkeeping (CADA's
    /// staleness counters) must still advance exactly as if the worker
    /// had run and skipped its upload. Called in worker order, merged
    /// with the `absorb_step` calls for selected workers. The default
    /// no-op suits methods without per-worker round state.
    fn skip_unselected(&mut self, k: u64, w: usize) -> anyhow::Result<()> {
        let _ = (k, w);
        Ok(())
    }

    /// Workers whose round-`k` outcome requests an upload, in worker
    /// order. The engine prices these against the link models, applies
    /// the participation policy, and passes the verdict to `aggregate`
    /// via [`RoundCtx::fresh`] / [`RoundCtx::deferred`].
    fn pending_uploads(&self, k: u64) -> Vec<usize>;

    /// Phase 3 — workers → server: fold this round's settled uploads.
    fn aggregate(&mut self, ctx: &mut RoundCtx) -> anyhow::Result<()>;

    /// Phase 4 — the server-side model update closing round `k`.
    fn server_update(&mut self, ctx: &mut RoundCtx,
                     compute: &mut dyn Compute) -> anyhow::Result<()>;

    /// Telemetry snapshot of the round just finished (only requested when
    /// the trainer keeps an event trace).
    fn round_event(&self, k: u64) -> Option<RoundEvent> {
        let _ = k;
        None
    }

    /// Maximum per-worker staleness tau (0 for local-update methods).
    fn max_staleness(&self) -> u32 {
        0
    }

    /// Per-shard server-update timing of the run so far (None for
    /// methods without sharded server state).
    fn shard_stats(&self) -> Option<crate::coordinator::shard::ShardStats> {
        None
    }

    /// Socket transport, handshake: the static per-run worker config a
    /// `cada worker` process needs (rule, delay cap, parameter count).
    /// A [`WorkerJob`] is a closure and cannot cross a process
    /// boundary, so socket runs speak the serializable round protocol
    /// instead — methods that cannot express their round as wire data
    /// (the local-update family moves whole models, not rule-checked
    /// innovations) keep this default and fail fast at build time.
    fn wire_config(&self)
                   -> anyhow::Result<crate::comm::wire::WireWorkerCfg> {
        anyhow::bail!(
            "algorithm '{}' does not support the socket transport yet \
             (server-centric methods only; use transport = \"inproc\" \
             or \"threaded\")",
            self.name()
        )
    }

    /// Socket transport, phase 2a: the round's frozen server state as
    /// wire data — called after [`Algorithm::broadcast`], in place of
    /// [`Algorithm::make_step`]. The transport turns it into per-worker
    /// round headers (shipping only shard ranges the worker has not
    /// acknowledged at the current version).
    fn make_wire_step(&self, k: u64)
                      -> anyhow::Result<crate::comm::wire::WireRound> {
        let _ = k;
        anyhow::bail!(
            "algorithm '{}' does not support the socket transport yet",
            self.name()
        )
    }

    /// Socket transport, phase 2b: fold worker `w`'s wire step result —
    /// the remote mirror of [`Algorithm::absorb_step`], called in
    /// worker order.
    fn absorb_wire_step(&mut self, ctx: &mut RoundCtx, w: usize,
                        step: crate::comm::wire::WireStep)
                        -> anyhow::Result<()> {
        let _ = (ctx, w, step);
        anyhow::bail!(
            "algorithm '{}' does not support the socket transport yet",
            self.name()
        )
    }

    /// Checkpointing: append every cross-round field of the method's
    /// state to `out` (the trainer wraps it in the versioned,
    /// CRC-guarded checkpoint container — see
    /// [`crate::coordinator::checkpoint`]). A resumed run must be
    /// bit-identical to an uninterrupted one, so *everything* that
    /// influences future rounds belongs in here. Methods that have not
    /// implemented the pair fail fast at save time.
    fn export_state(&self, out: &mut Vec<u8>) -> anyhow::Result<()> {
        let _ = out;
        anyhow::bail!(
            "algorithm '{}' does not support checkpointing yet",
            self.name()
        )
    }

    /// Checkpointing: restore state exported by
    /// [`Algorithm::export_state`] into this freshly-initialised
    /// method (`init` already ran with the run's config, so buffer
    /// shapes validate the checkpoint against the run).
    fn import_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        let _ = bytes;
        anyhow::bail!(
            "algorithm '{}' does not support checkpointing yet",
            self.name()
        )
    }
}
