//! Local-update baselines: local momentum SGD [Yu et al. 2019], FedAvg
//! [McMahan et al. 2017] and FedAdam [Reddi et al. 2020] — the paper's
//! comparison methods where workers update a LOCAL model and communicate
//! only at averaging rounds (every H iterations).
//!
//! Server-centric methods (CADA, LAG, distributed Adam/SGD) live in
//! [`crate::coordinator`]; this module completes the baseline space with
//! the periodic-averaging family, sharing the same [`Compute`] backend,
//! metrics and telemetry.

use crate::comm::{CommStats, CostModel};
use crate::data::{Batch, Dataset, Partition};
use crate::runtime::Compute;
use crate::telemetry::{Curve, CurvePoint};
use crate::tensor;
use crate::util::rng::Rng;

/// Which local-update method to run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LocalMethod {
    /// Local momentum SGD; parameters AND momentum buffers are averaged
    /// at each communication round (blockwise model averaging).
    LocalMomentum { eta: f32, beta: f32 },
    /// Local SGD / FedAvg: parameter averaging only.
    FedAvg { eta: f32 },
    /// FedAdam: local SGD; the server applies Adam to the averaged model
    /// delta every H iterations (Reddi et al., Eq. FedOpt).
    FedAdam {
        alpha_local: f32,
        alpha_server: f32,
        beta1: f32,
        beta2: f32,
        eps: f32,
    },
}

impl LocalMethod {
    pub fn name(&self) -> &'static str {
        match self {
            LocalMethod::LocalMomentum { .. } => "local_momentum",
            LocalMethod::FedAvg { .. } => "fedavg",
            LocalMethod::FedAdam { .. } => "fedadam",
        }
    }
}

/// Configuration of a local-update run.
#[derive(Clone, Debug)]
pub struct LocalCfg {
    pub iters: usize,
    pub eval_every: usize,
    /// averaging period H
    pub h: u32,
    pub batch: usize,
    pub method: LocalMethod,
    pub cost_model: CostModel,
    pub upload_bytes: usize,
}

/// Kind tag shared with the CLI / experiment driver.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum AlgorithmKind {
    ServerCentric,
    LocalUpdate,
}

/// One local-update training run over `M` workers.
pub struct LocalLoop<'a> {
    pub cfg: LocalCfg,
    /// global (server) model
    pub theta: Vec<f32>,
    /// per-worker local models
    thetas: Vec<Vec<f32>>,
    /// per-worker momentum buffers (momentum method only)
    momenta: Vec<Vec<f32>>,
    /// FedAdam server moments
    m1: Vec<f32>,
    m2: Vec<f32>,
    pub comm: CommStats,
    data: &'a Dataset,
    partition: &'a Partition,
    eval_batch: Batch,
    rngs: Vec<Rng>,
    grad_scratch: Vec<f32>,
}

impl<'a> LocalLoop<'a> {
    pub fn new(
        cfg: LocalCfg,
        init_theta: Vec<f32>,
        data: &'a Dataset,
        partition: &'a Partition,
        eval_batch: Batch,
        seed: u64,
    ) -> Self {
        let m = partition.num_workers();
        let p = init_theta.len();
        let root = Rng::new(seed);
        let needs_momentum =
            matches!(cfg.method, LocalMethod::LocalMomentum { .. });
        LocalLoop {
            thetas: vec![init_theta.clone(); m],
            momenta: if needs_momentum {
                vec![vec![0.0; p]; m]
            } else {
                Vec::new()
            },
            m1: vec![0.0; p],
            m2: vec![0.0; p],
            theta: init_theta,
            comm: CommStats::default(),
            data,
            partition,
            eval_batch,
            rngs: (0..m).map(|w| root.fork(w as u64 + 1)).collect(),
            grad_scratch: vec![0.0; p],
            cfg,
        }
    }

    /// One local step on every worker; every H steps, an averaging round.
    pub fn step(&mut self, k: u64, compute: &mut dyn Compute)
                -> anyhow::Result<()> {
        let m = self.thetas.len();
        for w in 0..m {
            let batch = self.data.sample_batch(
                &self.partition.shards[w],
                self.cfg.batch,
                &mut self.rngs[w],
            );
            compute.grad(&self.thetas[w], &batch, &mut self.grad_scratch)?;
            self.comm.record_grad_evals(1);
            match self.cfg.method {
                LocalMethod::LocalMomentum { eta, beta } => {
                    tensor::momentum_update(
                        &mut self.thetas[w],
                        &mut self.momenta[w],
                        &self.grad_scratch,
                        eta,
                        beta,
                    );
                }
                LocalMethod::FedAvg { eta } => {
                    tensor::sgd_update(&mut self.thetas[w],
                                       &self.grad_scratch, eta);
                }
                LocalMethod::FedAdam { alpha_local, .. } => {
                    tensor::sgd_update(&mut self.thetas[w],
                                       &self.grad_scratch, alpha_local);
                }
            }
        }
        if (k + 1) % self.cfg.h as u64 == 0 {
            self.averaging_round()?;
        }
        Ok(())
    }

    /// Communication round: all M workers upload; server averages /
    /// Adam-steps; broadcast back.
    fn averaging_round(&mut self) -> anyhow::Result<()> {
        let m = self.thetas.len();
        for _ in 0..m {
            self.comm
                .record_upload(self.cfg.upload_bytes, &self.cfg.cost_model);
        }
        match self.cfg.method {
            LocalMethod::LocalMomentum { .. } => {
                let parts: Vec<&[f32]> =
                    self.thetas.iter().map(|t| t.as_slice()).collect();
                tensor::mean_into(&mut self.theta, &parts);
                // average momentum buffers as well
                let mut mom_avg = vec![0.0f32; self.theta.len()];
                let mparts: Vec<&[f32]> =
                    self.momenta.iter().map(|u| u.as_slice()).collect();
                tensor::mean_into(&mut mom_avg, &mparts);
                for u in &mut self.momenta {
                    u.copy_from_slice(&mom_avg);
                }
            }
            LocalMethod::FedAvg { .. } => {
                let parts: Vec<&[f32]> =
                    self.thetas.iter().map(|t| t.as_slice()).collect();
                tensor::mean_into(&mut self.theta, &parts);
            }
            LocalMethod::FedAdam {
                alpha_server, beta1, beta2, eps, ..
            } => {
                // delta = mean_m(theta_m) - theta  (the pseudo-gradient)
                let parts: Vec<&[f32]> =
                    self.thetas.iter().map(|t| t.as_slice()).collect();
                let mut avg = vec![0.0f32; self.theta.len()];
                tensor::mean_into(&mut avg, &parts);
                for i in 0..self.theta.len() {
                    let delta = avg[i] - self.theta[i];
                    self.m1[i] = beta1 * self.m1[i] + (1.0 - beta1) * delta;
                    self.m2[i] =
                        beta2 * self.m2[i] + (1.0 - beta2) * delta * delta;
                    self.theta[i] +=
                        alpha_server * self.m1[i] / (self.m2[i].sqrt() + eps);
                }
            }
        }
        // broadcast the new global model
        self.comm.record_broadcast(m, self.cfg.upload_bytes,
                                   &self.cfg.cost_model);
        for t in &mut self.thetas {
            t.copy_from_slice(&self.theta);
        }
        Ok(())
    }

    pub fn evaluate(&mut self, compute: &mut dyn Compute)
                    -> anyhow::Result<(f64, f64)> {
        let (loss, correct) = compute.eval(&self.theta, &self.eval_batch)?;
        let denom = match &self.eval_batch.arrays[..] {
            [(_, shape)] => shape[0] * (shape[1] - 1),
            arrays => arrays[0].1[0],
        } as f64;
        Ok((loss as f64, correct as f64 / denom))
    }

    pub fn run(&mut self, algo_name: &str, run: u32,
               compute: &mut dyn Compute) -> anyhow::Result<Curve> {
        let wall0 = std::time::Instant::now();
        let mut curve = Curve::new(algo_name, run);
        let (loss, acc) = self.evaluate(compute)?;
        curve.points.push(self.point(0, loss, acc, wall0));
        for k in 0..self.cfg.iters as u64 {
            self.step(k, compute)?;
            if (k + 1) % self.cfg.eval_every as u64 == 0 {
                let (loss, acc) = self.evaluate(compute)?;
                curve.points.push(self.point(k + 1, loss, acc, wall0));
            }
        }
        Ok(curve)
    }

    fn point(&self, iter: u64, loss: f64, acc: f64,
             wall0: std::time::Instant) -> CurvePoint {
        CurvePoint {
            iter,
            loss,
            accuracy: acc,
            uploads: self.comm.uploads,
            grad_evals: self.comm.grad_evals,
            sim_time_s: self.comm.sim_time_s,
            wall_s: wall0.elapsed().as_secs_f64(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synthetic, PartitionScheme};
    use crate::runtime::native::NativeLogReg;

    fn setup() -> (NativeLogReg, Dataset, Partition) {
        let compute = NativeLogReg::for_spec(22, 1024);
        let data = synthetic::ijcnn_like(600, 5);
        let mut rng = Rng::new(11);
        let partition =
            Partition::build(PartitionScheme::Uniform, &data, 4, &mut rng);
        (compute, data, partition)
    }

    fn cfg(method: LocalMethod, h: u32, iters: usize) -> LocalCfg {
        LocalCfg {
            iters,
            eval_every: 10,
            h,
            batch: 16,
            method,
            cost_model: CostModel::free(),
            upload_bytes: 92,
        }
    }

    #[test]
    fn fedavg_uploads_every_h() {
        let (mut compute, data, partition) = setup();
        let eval = data.gather(&(0..32).collect::<Vec<_>>());
        let mut lp = LocalLoop::new(
            cfg(LocalMethod::FedAvg { eta: 0.1 }, 5, 20),
            vec![0.0; 1024], &data, &partition, eval, 1);
        lp.run("fedavg", 0, &mut compute).unwrap();
        // 20 iters, H=5 -> 4 rounds x 4 workers
        assert_eq!(lp.comm.uploads, 16);
        assert_eq!(lp.comm.grad_evals, 80);
    }

    #[test]
    fn methods_descend() {
        let (mut compute, data, partition) = setup();
        let eval = data.gather(&(0..128).collect::<Vec<_>>());
        for method in [
            LocalMethod::FedAvg { eta: 0.1 },
            LocalMethod::LocalMomentum { eta: 0.05, beta: 0.9 },
            LocalMethod::FedAdam {
                alpha_local: 0.1, alpha_server: 0.1,
                beta1: 0.9, beta2: 0.999, eps: 1e-8,
            },
        ] {
            let mut lp = LocalLoop::new(cfg(method, 5, 80),
                                        vec![0.0; 1024], &data, &partition,
                                        eval.clone(), 2);
            let curve = lp.run(method.name(), 0, &mut compute).unwrap();
            assert!(
                curve.final_loss() < curve.points[0].loss,
                "{method:?}: {} -> {}",
                curve.points[0].loss,
                curve.final_loss()
            );
        }
    }

    #[test]
    fn h1_fedavg_equals_distributed_sgd_rate() {
        // With H=1 FedAvg averages every step: equivalent to synchronous
        // SGD on the mean gradient. Its loss after K steps must closely
        // track a manual implementation.
        let (mut compute, data, partition) = setup();
        let eval = data.gather(&(0..32).collect::<Vec<_>>());
        let mut lp = LocalLoop::new(
            cfg(LocalMethod::FedAvg { eta: 0.05 }, 1, 30),
            vec![0.0; 1024], &data, &partition, eval, 77);

        // manual twin with identical rng streams
        let root = Rng::new(77);
        let mut rngs: Vec<Rng> =
            (0..4).map(|w| root.fork(w as u64 + 1)).collect();
        let mut theta = vec![0.0f32; 1024];
        let mut g = vec![0.0f32; 1024];
        for _ in 0..30 {
            let mut thetas = Vec::new();
            for w in 0..4 {
                let b = data.sample_batch(&partition.shards[w], 16,
                                          &mut rngs[w]);
                compute.grad(&theta, &b, &mut g).unwrap();
                let mut tw = theta.clone();
                tensor::sgd_update(&mut tw, &g, 0.05);
                thetas.push(tw);
            }
            let parts: Vec<&[f32]> =
                thetas.iter().map(|t| t.as_slice()).collect();
            tensor::mean_into(&mut theta, &parts);
        }
        lp.run("fedavg", 0, &mut compute).unwrap();
        let diff = tensor::sqnorm_diff(&lp.theta, &theta);
        assert!(diff < 1e-9, "diff {diff}");
    }
}
