//! The generic training driver: one loop for every [`Algorithm`].
//!
//! [`Trainer`] owns the method-independent machinery that `ServerLoop`
//! and `LocalLoop` used to duplicate: the iteration loop, per-worker RNG
//! forking, minibatch sampling, evaluation, curve recording,
//! [`CommStats`] and the bounded [`EventTrace`]. It is built through
//! [`TrainerBuilder`]:
//!
//! ```ignore
//! let mut trainer = Trainer::builder()
//!     .algorithm(&mut algo)
//!     .dataset(&data)
//!     .partition(&partition)
//!     .eval_batch(eval)
//!     .init_theta(init)
//!     .cost_model(CostModel::default())
//!     .eval_every(25)
//!     .build()?;
//! let curve = trainer.run(0, &mut compute)?;
//! ```
//!
//! The trainer is generic over the algorithm (`Trainer<'_, Cada>` gives
//! tests typed access to server/worker state via [`Trainer::algo`]);
//! drivers that pick the method at runtime use `&mut dyn Algorithm`.

use std::time::Instant;

use super::{Algorithm, RoundCtx};
use crate::comm::{CommStats, CostModel, EventTrace};
use crate::config::toml::{Doc, Value};
use crate::data::{Batch, Dataset, Partition};
use crate::runtime::Compute;
use crate::telemetry::{Curve, CurvePoint};
use crate::util::rng::Rng;

/// Method-independent run configuration — the union of what the old
/// `LoopCfg` and `LocalCfg` carried, minus the method-specific knobs
/// (those live in [`CadaCfg`](super::CadaCfg) /
/// [`FedAdamCfg`](super::FedAdamCfg) / the local methods' fields).
#[derive(Clone, Debug, PartialEq)]
pub struct TrainCfg {
    pub iters: usize,
    /// record a curve point every this many iterations
    pub eval_every: usize,
    /// per-worker minibatch size (must equal the grad artifact's batch)
    pub batch: usize,
    /// base seed; worker streams are forked as `Rng::new(seed).fork(w+1)`
    pub seed: u64,
    pub cost_model: CostModel,
    /// bytes of one gradient/model upload (manifest: 4 * p live floats)
    pub upload_bytes: usize,
    /// keep at most this many round events in the trace (0 disables)
    pub trace_cap: usize,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            iters: 100,
            eval_every: 25,
            batch: 16,
            seed: 0,
            cost_model: CostModel::free(),
            upload_bytes: 0,
            trace_cap: 0,
        }
    }
}

impl TrainCfg {
    /// Render as a `[train]` TOML section (round-trips through
    /// [`TrainCfg::from_doc`]). Seeds above 2^53 lose precision (TOML
    /// numbers are f64 in our subset parser).
    pub fn to_toml(&self) -> String {
        format!(
            "[train]\n\
             iters = {}\n\
             eval_every = {}\n\
             batch = {}\n\
             seed = {}\n\
             upload_bytes = {}\n\
             trace_cap = {}\n\
             \n\
             [train.cost_model]\n\
             latency_s = {}\n\
             down_bw = {}\n\
             asymmetry = {}\n",
            self.iters,
            self.eval_every,
            self.batch,
            self.seed,
            self.upload_bytes,
            self.trace_cap,
            self.cost_model.latency_s,
            self.cost_model.down_bw,
            self.cost_model.asymmetry,
        )
    }

    /// Parse a `[train]` (+ optional `[train.cost_model]`) section,
    /// starting from defaults; unknown keys, non-numbers, and negative
    /// or fractional integer fields are errors (a `-100` saturating
    /// silently to 0 would otherwise turn a typo into an empty run).
    pub fn from_doc(doc: &Doc) -> anyhow::Result<TrainCfg> {
        let mut cfg = TrainCfg::default();
        if let Some(section) = doc.sections.get("train") {
            for (key, value) in section {
                let int = |v: &Value| -> anyhow::Result<f64> {
                    let n = v.as_f64().ok_or_else(|| {
                        anyhow::anyhow!("[train] {key} must be a number")
                    })?;
                    anyhow::ensure!(
                        n >= 0.0 && n.fract() == 0.0,
                        "[train] {key} must be a non-negative integer, \
                         got {n}"
                    );
                    Ok(n)
                };
                match key.as_str() {
                    "iters" => cfg.iters = int(value)? as usize,
                    "eval_every" => cfg.eval_every = int(value)? as usize,
                    "batch" => cfg.batch = int(value)? as usize,
                    "seed" => cfg.seed = int(value)? as u64,
                    "upload_bytes" => {
                        cfg.upload_bytes = int(value)? as usize
                    }
                    "trace_cap" => cfg.trace_cap = int(value)? as usize,
                    other => {
                        anyhow::bail!("unknown [train] key '{other}'")
                    }
                }
            }
        }
        if let Some(section) = doc.sections.get("train.cost_model") {
            for (key, value) in section {
                let num = value.as_f64().ok_or_else(|| {
                    anyhow::anyhow!("[train.cost_model] {key} must be a \
                                     number")
                })?;
                match key.as_str() {
                    "latency_s" => cfg.cost_model.latency_s = num,
                    "down_bw" => cfg.cost_model.down_bw = num,
                    "asymmetry" => cfg.cost_model.asymmetry = num,
                    other => anyhow::bail!(
                        "unknown [train.cost_model] key '{other}'"),
                }
            }
        }
        Ok(cfg)
    }
}

/// One training run: an [`Algorithm`] plus the workload it trains on.
pub struct Trainer<'a, A: Algorithm + ?Sized> {
    pub cfg: TrainCfg,
    algo: &'a mut A,
    data: &'a Dataset,
    partition: &'a Partition,
    eval_batch: Batch,
    label: String,
    rngs: Vec<Rng>,
    pub comm: CommStats,
    pub trace: EventTrace,
}

impl<'a, A: Algorithm + ?Sized> Trainer<'a, A> {
    pub fn builder() -> TrainerBuilder<'a, A> {
        TrainerBuilder {
            cfg: TrainCfg::default(),
            algo: None,
            data: None,
            partition: None,
            eval_batch: None,
            init_theta: None,
            label: None,
        }
    }

    /// The algorithm under training (typed when `A` is concrete).
    pub fn algo(&self) -> &A {
        self.algo
    }

    pub fn algo_mut(&mut self) -> &mut A {
        self.algo
    }

    /// The current global model.
    pub fn theta(&self) -> &[f32] {
        self.algo.theta()
    }

    /// Maximum per-worker staleness (0 for local-update methods).
    pub fn max_staleness(&self) -> u32 {
        self.algo.max_staleness()
    }

    /// Drive one full round `k` through the four lifecycle phases.
    pub fn step(&mut self, k: u64, compute: &mut dyn Compute)
                -> anyhow::Result<()> {
        let m = self.rngs.len();
        let mut ctx = RoundCtx {
            k,
            m,
            upload_bytes: self.cfg.upload_bytes,
            cost_model: &self.cfg.cost_model,
            comm: &mut self.comm,
        };
        self.algo.broadcast(&mut ctx)?;
        for w in 0..m {
            let batch = self.data.sample_batch(
                &self.partition.shards[w],
                self.cfg.batch,
                &mut self.rngs[w],
            );
            self.algo.local_step(&mut ctx, w, &batch, compute)?;
        }
        self.algo.aggregate(&mut ctx)?;
        self.algo.server_update(&mut ctx, compute)?;
        if self.cfg.trace_cap > 0 {
            if let Some(ev) = self.algo.round_event(k) {
                self.trace.push(ev);
            }
        }
        Ok(())
    }

    /// Evaluate (loss, accuracy) of the global model on the held-out
    /// eval batch.
    pub fn evaluate(&mut self, compute: &mut dyn Compute)
                    -> anyhow::Result<(f64, f64)> {
        let (loss, correct) =
            compute.eval(self.algo.theta(), &self.eval_batch)?;
        let denom = eval_examples(&self.eval_batch) as f64;
        Ok((loss as f64, correct as f64 / denom))
    }

    /// Run the full loop, recording a curve point every `eval_every`
    /// iterations (plus the initial point).
    pub fn run(&mut self, run: u32, compute: &mut dyn Compute)
               -> anyhow::Result<Curve> {
        let wall0 = Instant::now();
        let mut curve = Curve::new(&self.label, run);
        let (loss, acc) = self.evaluate(compute)?;
        curve.points.push(self.point(0, loss, acc, wall0));
        for k in 0..self.cfg.iters as u64 {
            self.step(k, compute)?;
            if (k + 1) % self.cfg.eval_every as u64 == 0 {
                let (loss, acc) = self.evaluate(compute)?;
                curve.points.push(self.point(k + 1, loss, acc, wall0));
            }
        }
        Ok(curve)
    }

    fn point(&self, iter: u64, loss: f64, acc: f64, wall0: Instant)
             -> CurvePoint {
        CurvePoint {
            iter,
            loss,
            accuracy: acc,
            uploads: self.comm.uploads,
            grad_evals: self.comm.grad_evals,
            sim_time_s: self.comm.sim_time_s,
            wall_s: wall0.elapsed().as_secs_f64(),
        }
    }
}

/// Number of examples in an eval batch (token batches count predicted
/// positions, matching the eval artifact's `correct` semantics).
fn eval_examples(batch: &Batch) -> usize {
    match &batch.arrays[..] {
        [(_, shape)] => shape[0] * (shape[1] - 1), // tokens: B * S targets
        arrays => arrays[0].1[0],                  // labeled: batch dim
    }
}

/// Builder for [`Trainer`] — see the module docs for the full shape.
pub struct TrainerBuilder<'a, A: Algorithm + ?Sized> {
    cfg: TrainCfg,
    algo: Option<&'a mut A>,
    data: Option<&'a Dataset>,
    partition: Option<&'a Partition>,
    eval_batch: Option<Batch>,
    init_theta: Option<Vec<f32>>,
    label: Option<String>,
}

impl<'a, A: Algorithm + ?Sized> TrainerBuilder<'a, A> {
    pub fn algorithm(mut self, algo: &'a mut A) -> Self {
        self.algo = Some(algo);
        self
    }

    pub fn dataset(mut self, data: &'a Dataset) -> Self {
        self.data = Some(data);
        self
    }

    pub fn partition(mut self, partition: &'a Partition) -> Self {
        self.partition = Some(partition);
        self
    }

    pub fn eval_batch(mut self, batch: Batch) -> Self {
        self.eval_batch = Some(batch);
        self
    }

    pub fn init_theta(mut self, theta: Vec<f32>) -> Self {
        self.init_theta = Some(theta);
        self
    }

    /// Curve label (defaults to the algorithm's mechanism name; the
    /// experiment driver overrides it with the configured algo name,
    /// e.g. "adam" for the `Always` rule under AMSGrad).
    pub fn label(mut self, label: impl Into<String>) -> Self {
        self.label = Some(label.into());
        self
    }

    /// Replace the whole [`TrainCfg`] at once (individual setters below
    /// still apply on top).
    pub fn cfg(mut self, cfg: TrainCfg) -> Self {
        self.cfg = cfg;
        self
    }

    pub fn iters(mut self, iters: usize) -> Self {
        self.cfg.iters = iters;
        self
    }

    pub fn eval_every(mut self, eval_every: usize) -> Self {
        self.cfg.eval_every = eval_every;
        self
    }

    pub fn batch(mut self, batch: usize) -> Self {
        self.cfg.batch = batch;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    pub fn cost_model(mut self, cost_model: CostModel) -> Self {
        self.cfg.cost_model = cost_model;
        self
    }

    pub fn upload_bytes(mut self, bytes: usize) -> Self {
        self.cfg.upload_bytes = bytes;
        self
    }

    pub fn trace_cap(mut self, cap: usize) -> Self {
        self.cfg.trace_cap = cap;
        self
    }

    /// Validate, allocate the algorithm's state and the per-worker RNG
    /// streams, and hand back a ready [`Trainer`].
    pub fn build(self) -> anyhow::Result<Trainer<'a, A>> {
        let algo = self
            .algo
            .ok_or_else(|| anyhow::anyhow!("Trainer needs .algorithm(...)"))?;
        let data = self
            .data
            .ok_or_else(|| anyhow::anyhow!("Trainer needs .dataset(...)"))?;
        let partition = self.partition.ok_or_else(|| {
            anyhow::anyhow!("Trainer needs .partition(...)")
        })?;
        let eval_batch = self.eval_batch.ok_or_else(|| {
            anyhow::anyhow!("Trainer needs .eval_batch(...)")
        })?;
        let init_theta = self.init_theta.ok_or_else(|| {
            anyhow::anyhow!("Trainer needs .init_theta(...)")
        })?;
        anyhow::ensure!(!init_theta.is_empty(), "init_theta is empty");
        anyhow::ensure!(self.cfg.eval_every >= 1, "eval_every must be >= 1");
        anyhow::ensure!(self.cfg.batch >= 1, "batch must be >= 1");
        let m = partition.num_workers();
        anyhow::ensure!(m >= 1, "partition has no workers");
        algo.init(&init_theta, m)?;
        let root = Rng::new(self.cfg.seed);
        let rngs = (0..m).map(|w| root.fork(w as u64 + 1)).collect();
        let label = self
            .label
            .unwrap_or_else(|| algo.name().to_string());
        Ok(Trainer {
            trace: EventTrace::new(self.cfg.trace_cap),
            cfg: self.cfg,
            algo,
            data,
            partition,
            eval_batch,
            label,
            rngs,
            comm: CommStats::default(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{Cada, CadaCfg, FedAvg};
    use crate::config::{toml, Schedule};
    use crate::coordinator::rules::RuleKind;
    use crate::coordinator::server::Optimizer;
    use crate::data::{synthetic, PartitionScheme};
    use crate::runtime::native::NativeLogReg;

    fn workload() -> (NativeLogReg, Dataset, Partition) {
        let compute = NativeLogReg::for_spec(22, 1024);
        let data = synthetic::ijcnn_like(400, 3);
        let mut rng = Rng::new(5);
        let partition =
            Partition::build(PartitionScheme::Uniform, &data, 3, &mut rng);
        (compute, data, partition)
    }

    fn amsgrad() -> Optimizer {
        Optimizer::Amsgrad {
            alpha: Schedule::Constant(0.02),
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            use_artifact: false,
        }
    }

    #[test]
    fn builder_rejects_missing_pieces() {
        let (_, data, partition) = workload();
        let mut algo = FedAvg::new(0.1, 2);
        let err = Trainer::builder()
            .algorithm(&mut algo)
            .dataset(&data)
            .partition(&partition)
            .eval_batch(data.gather(&[0, 1]))
            .build()
            .err()
            .unwrap();
        assert!(err.to_string().contains("init_theta"), "{err}");
        let err = Trainer::<FedAvg>::builder()
            .dataset(&data)
            .partition(&partition)
            .build()
            .err()
            .unwrap();
        assert!(err.to_string().contains("algorithm"), "{err}");
    }

    #[test]
    fn eval_cadence_and_label() {
        let (mut compute, data, partition) = workload();
        let mut algo = Cada::new(CadaCfg::basic(RuleKind::Always, amsgrad()));
        let mut trainer = Trainer::builder()
            .algorithm(&mut algo)
            .dataset(&data)
            .partition(&partition)
            .eval_batch(data.gather(&(0..64).collect::<Vec<_>>()))
            .init_theta(vec![0.0; 1024])
            .iters(20)
            .eval_every(5)
            .label("adam")
            .build()
            .unwrap();
        let curve = trainer.run(0, &mut compute).unwrap();
        assert_eq!(curve.algo, "adam");
        // initial point + 20/5 evals
        assert_eq!(curve.points.len(), 5);
        assert_eq!(curve.points.last().unwrap().iter, 20);
    }

    #[test]
    fn default_label_is_algorithm_name() {
        let (mut compute, data, partition) = workload();
        let mut algo = FedAvg::new(0.1, 2);
        let mut trainer = Trainer::builder()
            .algorithm(&mut algo)
            .dataset(&data)
            .partition(&partition)
            .eval_batch(data.gather(&[0, 1, 2, 3]))
            .init_theta(vec![0.0; 1024])
            .iters(4)
            .eval_every(2)
            .build()
            .unwrap();
        let curve = trainer.run(0, &mut compute).unwrap();
        assert_eq!(curve.algo, "fedavg");
    }

    #[test]
    fn train_cfg_toml_roundtrip() {
        let cfg = TrainCfg {
            iters: 1_500,
            eval_every: 25,
            batch: 92,
            seed: 2021,
            cost_model: CostModel::default(),
            upload_bytes: 4 * 23,
            trace_cap: 128,
        };
        let text = cfg.to_toml();
        let doc = toml::parse(&text).unwrap();
        let back = TrainCfg::from_doc(&doc).unwrap();
        assert_eq!(back, cfg);
        // defaults survive an empty doc
        let empty = TrainCfg::from_doc(&toml::parse("").unwrap()).unwrap();
        assert_eq!(empty, TrainCfg::default());
        // unknown keys are rejected
        let bad = toml::parse("[train]\nitters = 3\n").unwrap();
        assert!(TrainCfg::from_doc(&bad).is_err());
        // negative / fractional integer fields are rejected, not
        // saturated or truncated
        for src in ["[train]\niters = -100\n", "[train]\nbatch = 2.7\n",
                    "[train]\nseed = -1\n"] {
            let doc = toml::parse(src).unwrap();
            let err = TrainCfg::from_doc(&doc).err().unwrap();
            assert!(err.to_string().contains("non-negative integer"),
                    "{src}: {err}");
        }
    }
}
