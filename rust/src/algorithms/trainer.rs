//! The generic training driver: one engine loop for every [`Algorithm`].
//!
//! [`Trainer`] owns the method-independent machinery: the iteration
//! loop, per-worker RNG forking, minibatch sampling, the
//! [`Transport`](crate::comm::Transport) that executes worker jobs, the
//! per-worker [`LinkSet`] + event clock, the participation policy,
//! evaluation, curve recording, [`CommStats`] and the bounded
//! [`EventTrace`]. It is built through [`TrainerBuilder`]:
//!
//! ```ignore
//! let mut trainer = Trainer::builder()
//!     .algorithm(&mut algo)
//!     .dataset(&data)
//!     .partition(&partition)
//!     .eval_batch(eval)
//!     .init_theta(init)
//!     .cost_model(CostModel::default())
//!     .transport(TransportKind::Threaded)   // InProc (default) /
//!                                           // Threaded / Socket
//!     .server_shards(4)                     // shard the server state
//!     .semi_sync_k(8)                       // fastest 8 of M quorum
//!     .jitter(0.5, 7)                       // straggler jitter (sigma, seed)
//!     .eval_every(25)
//!     .build()?;
//! let curve = trainer.run(0, &mut compute)?;
//! ```
//!
//! The trainer is generic over the algorithm (`Trainer<'_, Cada>` gives
//! tests typed access to server/worker state via [`Trainer::algo`]);
//! drivers that pick the method at runtime use `&mut dyn Algorithm`.
//!
//! # One round through the engine
//!
//! 1. `broadcast` (phase 1) — the algorithm freezes the round's shared
//!    state and accounts the downlink against the slowest link.
//! 2. The trainer samples every worker's minibatch from its own RNG
//!    stream, asks the algorithm for M self-contained jobs
//!    ([`Algorithm::make_step`]), and hands them to the transport —
//!    inline, or fanned out to persistent worker threads. Outcomes come
//!    back in worker order and fold via [`Algorithm::absorb_step`].
//! 3. The engine prices the round's requested uploads against the
//!    [`LinkSet`] (heterogeneous links, seeded straggler jitter),
//!    applies the participation policy (fully-sync, or semi-sync
//!    "fastest K of M" for server-centric methods), counts the uploads,
//!    and advances the event clock by the slowest AWAITED upload.
//! 4. `aggregate` folds the settled uploads (stragglers stale-fold next
//!    round); `server_update` closes the round.

use std::path::Path;
use std::time::Instant;

use super::{Algorithm, AlgorithmKind, RoundCtx};
use crate::comm::{
    wire, CommCfg, CommStats, CostModel, EventTrace, FaultPlan, InProc,
    LinkSet, Participation, ParticipationCfg, SelectPolicy, SocketServer,
    Threaded, Transport, TransportKind, WireStats, WorkerJob,
};
use crate::compress::{CompressCfg, Scheme};
use crate::config::toml::{Doc, Value};
use crate::coordinator::checkpoint::{self, CheckpointCfg};
use crate::coordinator::pool::ShardExec;
use crate::data::{Batch, Dataset, Partition};
use crate::runtime::Compute;
use crate::telemetry::{Curve, CurvePoint};
use crate::util::rng::Rng;

/// Method-independent run configuration: the `[train]` knobs plus the
/// `[comm]` engine section ([`CommCfg`]).
#[derive(Clone, Debug, PartialEq)]
pub struct TrainCfg {
    pub iters: usize,
    /// record a curve point every this many iterations
    pub eval_every: usize,
    /// per-worker minibatch size (must equal the grad artifact's batch)
    pub batch: usize,
    /// base seed; worker streams are forked as `Rng::new(seed).fork(w+1)`
    pub seed: u64,
    /// base link cost model (per-worker links derive from it via
    /// `[comm.links]` multipliers)
    pub cost_model: CostModel,
    /// bytes of one UPLINK gradient/innovation upload (manifest:
    /// 4 * p live floats)
    pub upload_bytes: usize,
    /// bytes of one DOWNLINK model broadcast; 0 (the default) means
    /// "same as `upload_bytes`" — the seed's symmetric-payload
    /// assumption, preserved bit-for-bit. Compressed-upload experiments
    /// and wire-measured socket runs set it explicitly to diverge the
    /// two honestly.
    pub broadcast_bytes: usize,
    /// keep at most this many round events in the trace (0 disables)
    pub trace_cap: usize,
    /// execution engine configuration (`[comm]` / `[comm.links]`)
    pub comm: CommCfg,
    /// upload compression (`[compress]`): how the innovation uploads
    /// CADA does not skip are shrunk on the wire. `Identity` (default)
    /// is bit-identical to no compression at all.
    pub compress: CompressCfg,
    /// deterministic fault injection (`[fault]`): drops, corruption,
    /// truncation, delays, and scheduled kills on the socket wire. The
    /// default ([`FaultPlan::none`]) injects nothing and is
    /// bit-identical to the pre-fault engine.
    pub fault: FaultPlan,
    /// checkpoint/resume (`[checkpoint]`): atomic round-state saves
    /// and crash recovery. Disabled by default.
    pub checkpoint: CheckpointCfg,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            iters: 100,
            eval_every: 25,
            batch: 16,
            seed: 0,
            cost_model: CostModel::free(),
            upload_bytes: 0,
            broadcast_bytes: 0,
            trace_cap: 0,
            comm: CommCfg::default(),
            compress: CompressCfg::default(),
            fault: FaultPlan::none(),
            checkpoint: CheckpointCfg::default(),
        }
    }
}

fn fmt_f64_array(v: &[f64]) -> String {
    let items: Vec<String> = v.iter().map(|x| format!("{x}")).collect();
    format!("[{}]", items.join(", "))
}

impl TrainCfg {
    /// Render as `[train]` / `[train.cost_model]` / `[comm]` (+ optional
    /// `[comm.links]`) TOML sections; round-trips exactly through
    /// [`TrainCfg::from_doc`]. `seed` is emitted and parsed as an exact
    /// integer token, so seeds above 2^53 survive unharmed.
    pub fn to_toml(&self) -> String {
        let mut out = format!(
            "[train]\n\
             iters = {}\n\
             eval_every = {}\n\
             batch = {}\n\
             seed = {}\n\
             upload_bytes = {}\n\
             broadcast_bytes = {}\n\
             trace_cap = {}\n\
             \n\
             [train.cost_model]\n\
             latency_s = {}\n\
             down_bw = {}\n\
             asymmetry = {}\n\
             compute_s = {}\n\
             \n\
             [comm]\n\
             transport = \"{}\"\n\
             server_shards = {}\n\
             shard_exec = \"{}\"\n\
             semi_sync_k = {}\n\
             jitter_sigma = {}\n\
             jitter_seed = {}\n",
            self.iters,
            self.eval_every,
            self.batch,
            self.seed,
            self.upload_bytes,
            self.broadcast_bytes,
            self.trace_cap,
            self.cost_model.latency_s,
            self.cost_model.down_bw,
            self.cost_model.asymmetry,
            self.cost_model.compute_s,
            self.comm.transport.name(),
            self.comm.server_shards,
            self.comm.shard_exec.name(),
            self.comm.participation.quorum,
            self.comm.jitter_sigma,
            self.comm.jitter_seed,
        );
        // participation knobs beyond the quorum only appear when set,
        // so the default output (and every pre-selection golden config)
        // is byte-identical; semi_sync_k stays the quorum's spelling
        // for config continuity
        let p = &self.comm.participation;
        if p.population != 0 {
            out.push_str(&format!("population = {}\n", p.population));
        }
        if p.selected != 0 {
            out.push_str(&format!("select_s = {}\n", p.selected));
        }
        if p.policy != SelectPolicy::default() {
            out.push_str(&format!("select_policy = \"{}\"\n",
                                  p.policy.as_str()));
        }
        if p.seed != 0 {
            out.push_str(&format!("select_seed = {}\n", p.seed));
        }
        if p.churn {
            out.push_str("churn = true\n");
        }
        if p.min_live != 0 {
            out.push_str(&format!("min_live = {}\n", p.min_live));
        }
        if p.socket_timeout_s != 0 {
            out.push_str(&format!("socket_timeout_s = {}\n",
                                  p.socket_timeout_s));
        }
        if p.connect_retry_s != 0 {
            out.push_str(&format!("connect_retry_s = {}\n",
                                  p.connect_retry_s));
        }
        // socket addresses only appear when set, so the default output
        // (and every pre-socket golden config) is byte-identical
        if !self.comm.listen.is_empty() {
            out.push_str(&format!("listen = \"{}\"\n", self.comm.listen));
        }
        if !self.comm.connect.is_empty() {
            out.push_str(&format!("connect = \"{}\"\n",
                                  self.comm.connect));
        }
        let links = [
            ("latency_mult", &self.comm.latency_mult),
            ("bw_mult", &self.comm.bw_mult),
            ("asymmetry_mult", &self.comm.asymmetry_mult),
            ("compute_mult", &self.comm.compute_mult),
        ];
        if links.iter().any(|(_, v)| !v.is_empty()) {
            out.push_str("\n[comm.links]\n");
            for (key, v) in links {
                if !v.is_empty() {
                    out.push_str(&format!("{key} = {}\n",
                                          fmt_f64_array(v)));
                }
            }
        }
        // the [compress] section only appears when it deviates from the
        // Identity default, so every pre-compression golden config is
        // byte-identical
        if self.compress != CompressCfg::default() {
            out.push_str(&format!(
                "\n[compress]\n\
                 scheme = \"{}\"\n\
                 topk_frac = {}\n\
                 bits = {}\n\
                 seed = {}\n",
                self.compress.scheme.name(),
                self.compress.topk_frac,
                self.compress.bits,
                self.compress.seed,
            ));
        }
        // the [fault] section only appears when a plan is armed, so
        // every fault-free golden config stays byte-identical
        if self.fault != FaultPlan::none() {
            out.push_str(&format!(
                "\n[fault]\n\
                 seed = {}\n\
                 drop_p = {}\n\
                 corrupt_p = {}\n\
                 truncate_p = {}\n\
                 delay_p = {}\n\
                 delay_ms = {}\n",
                self.fault.seed,
                self.fault.drop_p,
                self.fault.corrupt_p,
                self.fault.truncate_p,
                self.fault.delay_p,
                self.fault.delay_ms,
            ));
            if !self.fault.kill_workers.is_empty() {
                // parallel arrays: kill_rounds[i] says WHEN worker
                // kill_ids[i] dies
                let rounds: Vec<String> = self
                    .fault
                    .kill_workers
                    .iter()
                    .map(|(k, _)| format!("{k}"))
                    .collect();
                let ids: Vec<String> = self
                    .fault
                    .kill_workers
                    .iter()
                    .map(|(_, w)| format!("{w}"))
                    .collect();
                out.push_str(&format!(
                    "kill_rounds = [{}]\nkill_ids = [{}]\n",
                    rounds.join(", "),
                    ids.join(", ")
                ));
            }
            if let Some(at) = self.fault.kill_server_at {
                out.push_str(&format!("kill_server_at = {at}\n"));
            }
        }
        if self.checkpoint != CheckpointCfg::default() {
            out.push_str(&format!(
                "\n[checkpoint]\n\
                 dir = \"{}\"\n\
                 every = {}\n\
                 resume = \"{}\"\n",
                self.checkpoint.dir,
                self.checkpoint.every,
                self.checkpoint.resume,
            ));
        }
        out
    }

    /// Parse the `[train]` (+ optional `[train.cost_model]`, `[comm]`,
    /// `[comm.links]`) sections, starting from defaults. Unknown keys
    /// and non-numbers are errors; integer fields reject negative,
    /// fractional, AND precision-losing float tokens (a seed written as
    /// `1e300` or a `-100` silently saturating would otherwise corrupt a
    /// run instead of failing it).
    pub fn from_doc(doc: &Doc) -> anyhow::Result<TrainCfg> {
        let mut cfg = TrainCfg::default();
        if let Some(section) = doc.sections.get("train") {
            for (key, value) in section {
                let int = |v: &Value| -> anyhow::Result<u64> {
                    v.as_u64().ok_or_else(|| {
                        anyhow::anyhow!(
                            "[train] {key} must be a non-negative integer \
                             representable without precision loss, got \
                             {v:?}"
                        )
                    })
                };
                match key.as_str() {
                    "iters" => cfg.iters = int(value)? as usize,
                    "eval_every" => {
                        cfg.eval_every = int(value)? as usize
                    }
                    "batch" => cfg.batch = int(value)? as usize,
                    "seed" => cfg.seed = int(value)?,
                    "upload_bytes" => {
                        cfg.upload_bytes = int(value)? as usize
                    }
                    "broadcast_bytes" => {
                        cfg.broadcast_bytes = int(value)? as usize
                    }
                    "trace_cap" => cfg.trace_cap = int(value)? as usize,
                    other => {
                        anyhow::bail!("unknown [train] key '{other}'")
                    }
                }
            }
        }
        if let Some(section) = doc.sections.get("train.cost_model") {
            for (key, value) in section {
                let num = value.as_f64().ok_or_else(|| {
                    anyhow::anyhow!("[train.cost_model] {key} must be a \
                                     number")
                })?;
                match key.as_str() {
                    "latency_s" => cfg.cost_model.latency_s = num,
                    "down_bw" => cfg.cost_model.down_bw = num,
                    "asymmetry" => cfg.cost_model.asymmetry = num,
                    "compute_s" => cfg.cost_model.compute_s = num,
                    other => anyhow::bail!(
                        "unknown [train.cost_model] key '{other}'"),
                }
            }
        }
        if let Some(section) = doc.sections.get("comm") {
            for (key, value) in section {
                match key.as_str() {
                    "transport" => {
                        let s = value.as_str().ok_or_else(|| {
                            anyhow::anyhow!(
                                "[comm] transport must be a string")
                        })?;
                        cfg.comm.transport = TransportKind::parse(s)?;
                    }
                    "server_shards" => {
                        cfg.comm.server_shards =
                            value.as_u64().ok_or_else(|| {
                                anyhow::anyhow!("[comm] server_shards must \
                                                 be a non-negative integer \
                                                 (0 = one shard per core)")
                            })? as usize;
                    }
                    "shard_exec" => {
                        let s = value.as_str().ok_or_else(|| {
                            anyhow::anyhow!(
                                "[comm] shard_exec must be a string")
                        })?;
                        cfg.comm.shard_exec = ShardExec::parse(s)?;
                    }
                    "semi_sync_k" => {
                        cfg.comm.participation.quorum =
                            value.as_u64().ok_or_else(|| {
                                anyhow::anyhow!("[comm] semi_sync_k must \
                                                 be a non-negative integer")
                            })? as usize;
                    }
                    "population" => {
                        cfg.comm.participation.population =
                            value.as_u64().ok_or_else(|| {
                                anyhow::anyhow!("[comm] population must \
                                                 be a non-negative integer")
                            })? as usize;
                    }
                    "select_s" => {
                        cfg.comm.participation.selected =
                            value.as_u64().ok_or_else(|| {
                                anyhow::anyhow!("[comm] select_s must be \
                                                 a non-negative integer")
                            })? as usize;
                    }
                    "select_policy" => {
                        let s = value.as_str().ok_or_else(|| {
                            anyhow::anyhow!("[comm] select_policy must be \
                                             a string (uniform|grouped)")
                        })?;
                        cfg.comm.participation.policy =
                            SelectPolicy::parse(s)?;
                    }
                    "select_seed" => {
                        cfg.comm.participation.seed =
                            value.as_u64().ok_or_else(|| {
                                anyhow::anyhow!("[comm] select_seed must \
                                                 be an exact non-negative \
                                                 integer")
                            })?;
                    }
                    "churn" => {
                        cfg.comm.participation.churn =
                            value.as_bool().ok_or_else(|| {
                                anyhow::anyhow!("[comm] churn must be a \
                                                 boolean")
                            })?;
                    }
                    "min_live" => {
                        cfg.comm.participation.min_live =
                            value.as_u64().ok_or_else(|| {
                                anyhow::anyhow!("[comm] min_live must be \
                                                 a non-negative integer")
                            })? as usize;
                    }
                    "socket_timeout_s" => {
                        cfg.comm.participation.socket_timeout_s =
                            value.as_u64().ok_or_else(|| {
                                anyhow::anyhow!("[comm] socket_timeout_s \
                                                 must be a non-negative \
                                                 integer")
                            })?;
                    }
                    "connect_retry_s" => {
                        cfg.comm.participation.connect_retry_s =
                            value.as_u64().ok_or_else(|| {
                                anyhow::anyhow!("[comm] connect_retry_s \
                                                 must be a non-negative \
                                                 integer")
                            })?;
                    }
                    "jitter_sigma" => {
                        cfg.comm.jitter_sigma =
                            value.as_f64().ok_or_else(|| {
                                anyhow::anyhow!("[comm] jitter_sigma must \
                                                 be a number")
                            })?;
                    }
                    "jitter_seed" => {
                        cfg.comm.jitter_seed =
                            value.as_u64().ok_or_else(|| {
                                anyhow::anyhow!("[comm] jitter_seed must \
                                                 be an exact non-negative \
                                                 integer")
                            })?;
                    }
                    "listen" => {
                        cfg.comm.listen = value
                            .as_str()
                            .ok_or_else(|| {
                                anyhow::anyhow!("[comm] listen must be a \
                                                 string (host:port)")
                            })?
                            .to_string();
                    }
                    "connect" => {
                        cfg.comm.connect = value
                            .as_str()
                            .ok_or_else(|| {
                                anyhow::anyhow!("[comm] connect must be a \
                                                 string (host:port)")
                            })?
                            .to_string();
                    }
                    other => anyhow::bail!("unknown [comm] key '{other}'"),
                }
            }
        }
        if let Some(section) = doc.sections.get("compress") {
            for (key, value) in section {
                match key.as_str() {
                    "scheme" => {
                        let s = value.as_str().ok_or_else(|| {
                            anyhow::anyhow!(
                                "[compress] scheme must be a string \
                                 (identity / topk / quant)")
                        })?;
                        cfg.compress.scheme = Scheme::parse(s)?;
                    }
                    "topk_frac" => {
                        cfg.compress.topk_frac =
                            value.as_f64().ok_or_else(|| {
                                anyhow::anyhow!("[compress] topk_frac \
                                                 must be a number")
                            })?;
                    }
                    "bits" => {
                        cfg.compress.bits =
                            value.as_u64().ok_or_else(|| {
                                anyhow::anyhow!("[compress] bits must be \
                                                 a non-negative integer")
                            })? as u32;
                    }
                    "seed" => {
                        cfg.compress.seed =
                            value.as_u64().ok_or_else(|| {
                                anyhow::anyhow!("[compress] seed must be \
                                                 an exact non-negative \
                                                 integer")
                            })?;
                    }
                    other => {
                        anyhow::bail!("unknown [compress] key '{other}'")
                    }
                }
            }
            cfg.compress.validate()?;
        }
        if let Some(section) = doc.sections.get("comm.links") {
            for (key, value) in section {
                let arr = match value {
                    Value::Arr(items) => items
                        .iter()
                        .map(|v| {
                            v.as_f64().ok_or_else(|| {
                                anyhow::anyhow!(
                                    "[comm.links] {key} must be an array \
                                     of numbers"
                                )
                            })
                        })
                        .collect::<anyhow::Result<Vec<f64>>>()?,
                    _ => anyhow::bail!(
                        "[comm.links] {key} must be an array of numbers"),
                };
                match key.as_str() {
                    "latency_mult" => cfg.comm.latency_mult = arr,
                    "bw_mult" => cfg.comm.bw_mult = arr,
                    "asymmetry_mult" => cfg.comm.asymmetry_mult = arr,
                    "compute_mult" => cfg.comm.compute_mult = arr,
                    other => anyhow::bail!(
                        "unknown [comm.links] key '{other}'"),
                }
            }
        }
        if let Some(section) = doc.sections.get("fault") {
            let mut kill_rounds: Vec<u64> = Vec::new();
            let mut kill_ids: Vec<u64> = Vec::new();
            for (key, value) in section {
                let prob = |v: &Value| -> anyhow::Result<f64> {
                    v.as_f64().ok_or_else(|| {
                        anyhow::anyhow!("[fault] {key} must be a number")
                    })
                };
                let int = |v: &Value| -> anyhow::Result<u64> {
                    v.as_u64().ok_or_else(|| {
                        anyhow::anyhow!("[fault] {key} must be an exact \
                                         non-negative integer")
                    })
                };
                let ints = |v: &Value| -> anyhow::Result<Vec<u64>> {
                    match v {
                        Value::Arr(items) => items
                            .iter()
                            .map(|x| {
                                x.as_u64().ok_or_else(|| {
                                    anyhow::anyhow!(
                                        "[fault] {key} must be an array \
                                         of non-negative integers"
                                    )
                                })
                            })
                            .collect(),
                        _ => anyhow::bail!(
                            "[fault] {key} must be an array of \
                             non-negative integers"),
                    }
                };
                match key.as_str() {
                    "seed" => cfg.fault.seed = int(value)?,
                    "drop_p" => cfg.fault.drop_p = prob(value)?,
                    "corrupt_p" => cfg.fault.corrupt_p = prob(value)?,
                    "truncate_p" => cfg.fault.truncate_p = prob(value)?,
                    "delay_p" => cfg.fault.delay_p = prob(value)?,
                    "delay_ms" => cfg.fault.delay_ms = int(value)?,
                    "kill_rounds" => kill_rounds = ints(value)?,
                    "kill_ids" => kill_ids = ints(value)?,
                    "kill_server_at" => {
                        cfg.fault.kill_server_at = Some(int(value)?)
                    }
                    other => {
                        anyhow::bail!("unknown [fault] key '{other}'")
                    }
                }
            }
            anyhow::ensure!(
                kill_rounds.len() == kill_ids.len(),
                "[fault] kill_rounds ({}) and kill_ids ({}) are parallel \
                 arrays and must have the same length",
                kill_rounds.len(),
                kill_ids.len()
            );
            cfg.fault.kill_workers = kill_rounds
                .into_iter()
                .zip(kill_ids)
                .map(|(k, w)| {
                    anyhow::ensure!(
                        w <= u32::MAX as u64,
                        "[fault] kill_ids entry {w} does not fit a \
                         worker id"
                    );
                    Ok((k, w as u32))
                })
                .collect::<anyhow::Result<Vec<(u64, u32)>>>()?;
            cfg.fault.validate()?;
        }
        if let Some(section) = doc.sections.get("checkpoint") {
            for (key, value) in section {
                match key.as_str() {
                    "dir" => {
                        cfg.checkpoint.dir = value
                            .as_str()
                            .ok_or_else(|| {
                                anyhow::anyhow!("[checkpoint] dir must \
                                                 be a string")
                            })?
                            .to_string();
                    }
                    "every" => {
                        cfg.checkpoint.every =
                            value.as_u64().ok_or_else(|| {
                                anyhow::anyhow!("[checkpoint] every must \
                                                 be a non-negative \
                                                 integer")
                            })?;
                    }
                    "resume" => {
                        cfg.checkpoint.resume = value
                            .as_str()
                            .ok_or_else(|| {
                                anyhow::anyhow!("[checkpoint] resume \
                                                 must be a string")
                            })?
                            .to_string();
                    }
                    other => {
                        anyhow::bail!("unknown [checkpoint] key '{other}'")
                    }
                }
            }
            cfg.checkpoint.validate()?;
        }
        cfg.comm.validate()?;
        Ok(cfg)
    }

    /// Fingerprint of the trajectory-defining configuration: FNV-1a 64
    /// over the canonical TOML rendering with the `[fault]` and
    /// `[checkpoint]` sections cleared — a resumed incarnation
    /// legitimately changes those (dropping a scheduled kill, pointing
    /// `resume` at the save dir) without changing the trajectory it
    /// must reproduce.
    pub fn fingerprint(&self) -> u64 {
        let mut clean = self.clone();
        clean.fault = FaultPlan::none();
        clean.checkpoint = CheckpointCfg::default();
        checkpoint::fnv64(clean.to_toml().as_bytes())
    }

    /// The downlink broadcast payload this config means: the explicit
    /// `broadcast_bytes`, or `upload_bytes` when left at the 0 default
    /// (the seed's symmetric assumption).
    pub fn effective_broadcast_bytes(&self) -> usize {
        if self.broadcast_bytes == 0 {
            self.upload_bytes
        } else {
            self.broadcast_bytes
        }
    }
}

/// One training run: an [`Algorithm`] plus the workload it trains on.
pub struct Trainer<'a, A: Algorithm + ?Sized> {
    pub cfg: TrainCfg,
    algo: &'a mut A,
    data: &'a Dataset,
    partition: &'a Partition,
    eval_batch: Batch,
    label: String,
    rngs: Vec<Rng>,
    links: LinkSet,
    /// lazily constructed on the first step (the threaded transport
    /// forks per-worker backends off the compute handed to `step`/`run`)
    transport: Option<Box<dyn Transport>>,
    /// socket transport: the server endpoint, bound at build time (so a
    /// caller can read [`Trainer::wire_addr`] and launch the worker
    /// processes before the first step blocks on the handshake)
    wire: Option<SocketServer>,
    /// socket transport: the static handshake config
    wire_cfg: Option<wire::WireWorkerCfg>,
    /// bytes one compressed upload occupies in the simulated accounting
    /// (payload sizes are data-independent, so this is one constant per
    /// run); equals `cfg.upload_bytes` when compression is off
    sim_upload_bytes: usize,
    /// resolved per-round selection seed (`[comm] select_seed`, or the
    /// train seed when left 0)
    select_seed: u64,
    /// per-worker nominal round seconds, frozen at build: the
    /// deterministic speed ranking [`SelectPolicy::Grouped`] partitions
    /// by (pure config, no jitter, no round index)
    speed_s: Vec<f64>,
    /// set when a round errors: worker state may have been moved into a
    /// job that never came home, so further steps must not run
    poisoned: bool,
    pub comm: CommStats,
    pub trace: EventTrace,
}

impl<'a, A: Algorithm + ?Sized> Trainer<'a, A> {
    pub fn builder() -> TrainerBuilder<'a, A> {
        TrainerBuilder {
            cfg: TrainCfg::default(),
            algo: None,
            data: None,
            partition: None,
            eval_batch: None,
            init_theta: None,
            label: None,
        }
    }

    /// The algorithm under training (typed when `A` is concrete).
    pub fn algo(&self) -> &A {
        self.algo
    }

    pub fn algo_mut(&mut self) -> &mut A {
        self.algo
    }

    /// The current global model.
    pub fn theta(&self) -> &[f32] {
        self.algo.theta()
    }

    /// This run's per-worker link models.
    pub fn links(&self) -> &LinkSet {
        &self.links
    }

    /// Socket transport: the bound listen address (the actual port when
    /// `[comm] listen` asked for port 0). `None` on in-process
    /// transports.
    pub fn wire_addr(&self) -> Option<std::net::SocketAddr> {
        self.wire.as_ref().and_then(|w| w.local_addr().ok())
    }

    /// Socket transport: the bytes that actually crossed the wire —
    /// measured upload/broadcast sizes, as opposed to the simulated
    /// `upload_bytes` constant. `None` on in-process transports.
    pub fn wire_stats(&self) -> Option<&WireStats> {
        self.wire.as_ref().map(|w| w.stats())
    }

    /// Maximum per-worker staleness (0 for local-update methods).
    pub fn max_staleness(&self) -> u32 {
        self.algo.max_staleness()
    }

    fn ensure_transport(&mut self, compute: &mut dyn Compute)
                        -> anyhow::Result<()> {
        if self.transport.is_some() {
            return Ok(());
        }
        let m = self.rngs.len();
        let transport: Box<dyn Transport> = match self.cfg.comm.transport {
            TransportKind::Socket => anyhow::bail!(
                "the socket transport is driven by the wire engine, not \
                 a Transport impl (internal error)"
            ),
            TransportKind::InProc => Box::new(InProc),
            TransportKind::Threaded => {
                let mut backends = Vec::with_capacity(m);
                for _ in 0..m {
                    backends.push(compute.fork().ok_or_else(|| {
                        anyhow::anyhow!(
                            "backend '{}' cannot fork per-worker \
                             instances; the threaded transport needs one \
                             backend per worker thread (use transport = \
                             \"inproc\")",
                            compute.backend_name()
                        )
                    })?);
                }
                Box::new(Threaded::spawn(backends)?)
            }
        };
        self.transport = Some(transport);
        Ok(())
    }

    /// Drive one full round `k` through the engine (see module docs).
    ///
    /// After a round errors, the trainer is poisoned: the failed round's
    /// worker state was moved into jobs that never folded back, so
    /// retrying would compute on zero-length placeholders. Build a fresh
    /// `Trainer` instead.
    pub fn step(&mut self, k: u64, compute: &mut dyn Compute)
                -> anyhow::Result<()> {
        anyhow::ensure!(
            !self.poisoned,
            "a previous round failed mid-flight and tore down worker \
             state; this Trainer cannot continue — build a fresh one"
        );
        let result = self.step_inner(k, compute);
        if result.is_err() {
            self.poisoned = true;
        }
        result
    }

    /// This round's participant subset — a pure function of
    /// `(select_seed, k)` plus the frozen speed ranking, so every
    /// transport (and every rerun) draws the identical set.
    fn round_selection(&self, k: u64) -> Vec<usize> {
        self.cfg.comm.participation.select(
            self.rngs.len(), self.select_seed, k, &self.speed_s)
    }

    fn step_inner(&mut self, k: u64, compute: &mut dyn Compute)
                  -> anyhow::Result<()> {
        let m = self.rngs.len();
        let selected = self.round_selection(k);
        let selection_active =
            self.cfg.comm.participation.selection_active(m);
        self.comm.count_selected(&selected);
        if self.cfg.comm.transport == TransportKind::Socket {
            // phases 1 + 2 run over the wire: serializable round
            // headers out to the worker processes, step results back
            self.wire_phases(k, &selected)?;
        } else {
            self.ensure_transport(compute)?;
            // phase 1 — server -> workers
            {
                let mut ctx = round_ctx(&self.cfg, &self.links,
                                        &mut self.comm, k, m,
                                        Vec::new(), Vec::new(),
                                        selected.clone());
                self.algo.broadcast(&mut ctx)?;
            }
            // phase 2 — sample minibatches (worker-private RNG streams),
            // build the self-contained jobs, execute them on the
            // transport. Only SELECTED workers sample and run: an
            // unselected worker's RNG stream must not advance, so the
            // batches it sees when next selected are independent of how
            // often it sat out (and match the socket transport, which
            // physically ships it nothing)
            let mut jobs: Vec<(usize, WorkerJob)> =
                Vec::with_capacity(selected.len());
            for &w in &selected {
                let batch = self.data.sample_batch(
                    &self.partition.shards[w],
                    self.cfg.batch,
                    &mut self.rngs[w],
                );
                jobs.push((w, self.algo.make_step(k, w, batch)?));
            }
            let outcomes = self
                .transport
                .as_mut()
                .expect("transport initialised above")
                .execute(jobs, compute)?;
            {
                let mut ctx = round_ctx(&self.cfg, &self.links,
                                        &mut self.comm, k, m,
                                        Vec::new(), Vec::new(),
                                        selected.clone());
                // outcomes arrive sorted by worker id: the fold order
                // (and therefore every float) is transport-independent.
                // Unselected workers fold as explicit skips, merged in
                // the same worker order, so their staleness advances
                // exactly where a remote skip would land.
                let mut outcomes = outcomes.into_iter().peekable();
                for w in 0..m {
                    match outcomes.peek() {
                        Some(&(ow, _)) if ow == w => {
                            let (_, out) = outcomes.next()
                                .expect("peeked outcome");
                            self.algo.absorb_step(&mut ctx, w, out)?;
                        }
                        _ => self.algo.skip_unselected(k, w)?,
                    }
                }
            }
        }
        // settle the round's uploads: price against the links, apply the
        // participation policy, advance the event clock
        let pending = self.algo.pending_uploads(k);
        let policy = if self.algo.kind() == AlgorithmKind::LocalUpdate {
            // model averaging needs every local model: always fully sync
            Participation::Full
        } else {
            self.cfg.comm.participation()
        };
        // compressed uploads are priced (and clocked) at their on-wire
        // size; the raw dense size feeds the per-worker compression
        // ratio. Identity keeps both equal to `upload_bytes` exactly.
        // Under per-round selection only the selected workers bound the
        // round (the fully-sync compute floor must not wait on a device
        // the round never touched).
        let verdict = if selection_active {
            self.links.settle_uploads_among(
                k, &pending, self.sim_upload_bytes, policy, &selected)
        } else {
            self.links.settle_uploads(
                k, &pending, self.sim_upload_bytes, policy)
        };
        for &(w, t) in &verdict.arrival_s {
            self.comm.count_upload_sized(
                w, self.sim_upload_bytes, self.cfg.upload_bytes, t);
        }
        // dead-link uploads were transmitted (counted + charged above,
        // with their non-finite time kept out of the per-worker
        // seconds); the lost column records where they went
        for &w in &verdict.lost {
            self.comm.mark_lost(w);
        }
        self.comm.stale_uploads += verdict.deferred.len() as u64;
        self.comm.lost_uploads += verdict.lost.len() as u64;
        self.comm.advance_clock(verdict.upload_dt_s);
        // phases 3 + 4 — aggregate the settled uploads, server step
        {
            let mut ctx = round_ctx(&self.cfg, &self.links,
                                    &mut self.comm, k, m,
                                    verdict.fresh, verdict.deferred,
                                    selected.clone());
            self.algo.aggregate(&mut ctx)?;
            self.algo.server_update(&mut ctx, compute)?;
        }
        if self.cfg.trace_cap > 0 {
            if let Some(mut ev) = self.algo.round_event(k) {
                // the trainer owns the participant draw, so it stamps
                // the selection (kept empty — meaning "all" — under
                // full participation, as the trace always has)
                if selection_active {
                    ev.selected = selected;
                }
                self.trace.push(ev);
            }
        }
        Ok(())
    }

    /// Socket-transport phases 1 + 2 of round `k`: handshake the worker
    /// processes on first use, freeze the round server-side, ship each
    /// SELECTED worker its header (batch indices + unacknowledged
    /// theta/snapshot ranges), and fold the wire step results back in
    /// worker order (unselected workers fold as skips). Simulated
    /// accounting (links, jitter, participation) is untouched — it
    /// stays a pure function of the round — so a loopback socket run is
    /// bit-identical to `InProc`.
    fn wire_phases(&mut self, k: u64, selected: &[usize])
                   -> anyhow::Result<()> {
        let m = self.rngs.len();
        let wire_ready = self
            .wire
            .as_ref()
            .expect("socket server bound in build")
            .needs_handshake();
        if wire_ready {
            // fingerprinting hashes the whole dataset: once per run,
            // not per round
            let data_fp = self.data.fingerprint();
            let data_len = self.data.len();
            let wcfg =
                self.wire_cfg.as_ref().expect("wire cfg set in build");
            self.wire
                .as_mut()
                .expect("socket server bound in build")
                .handshake(wcfg, self.cfg.batch, data_len, data_fp)?;
        }
        // phase 1 — server -> workers
        {
            let mut ctx = round_ctx(&self.cfg, &self.links,
                                    &mut self.comm, k, m,
                                    Vec::new(), Vec::new(),
                                    selected.to_vec());
            self.algo.broadcast(&mut ctx)?;
        }
        // phase 2 — the server samples each SELECTED worker's minibatch
        // INDICES from the same per-worker RNG streams the in-process
        // transports feed into `sample_batch`, and ships them in the
        // round headers; workers gather from their own dataset copy, so
        // the batches are bit-identical without batch payloads crossing
        // the wire. Unselected streams stay untouched, mirroring the
        // in-process path exactly.
        let round = self.algo.make_wire_step(k)?;
        let mut batches: Vec<Vec<u32>> =
            Vec::with_capacity(selected.len());
        for &w in selected {
            let picks = self.data.sample_picks(
                &self.partition.shards[w],
                self.cfg.batch,
                &mut self.rngs[w],
            );
            batches.push(picks.into_iter().map(|i| i as u32).collect());
        }
        let outcome = self
            .wire
            .as_mut()
            .expect("socket server bound in build")
            .run_round(&round, selected, &batches)?;
        // participation bookkeeping: dropped frames and mid-run
        // (re)admissions land in the per-worker columns
        for &w in &outcome.rejected {
            self.comm.count_rejected(w);
        }
        for &w in &outcome.rejoined {
            self.comm.count_rejoin(w);
        }
        {
            let mut ctx = round_ctx(&self.cfg, &self.links,
                                    &mut self.comm, k, m,
                                    Vec::new(), Vec::new(),
                                    selected.to_vec());
            // the socket server returns steps in selected order, so the
            // merged fold below visits workers in worker order whatever
            // the physical arrival order was; folding by POSITION (not
            // by the step's self-reported id) lets the algorithm's
            // step.w-vs-slot check catch a misordered drain. A vacated
            // slot's synthesized skip folds like a remote skip; workers
            // the round never selected fold as local skips.
            let mut steps = outcome.steps.into_iter();
            let mut sel = selected.iter().peekable();
            for w in 0..m {
                if sel.peek() == Some(&&w) {
                    sel.next();
                    let step = steps
                        .next()
                        .expect("one wire step per selected worker");
                    self.algo.absorb_wire_step(&mut ctx, w, step)?;
                } else {
                    self.algo.skip_unselected(k, w)?;
                }
            }
        }
        Ok(())
    }

    /// Evaluate (loss, accuracy) of the global model on the held-out
    /// eval batch.
    pub fn evaluate(&mut self, compute: &mut dyn Compute)
                    -> anyhow::Result<(f64, f64)> {
        let (loss, correct) =
            compute.eval(self.algo.theta(), &self.eval_batch)?;
        let denom = eval_examples(&self.eval_batch) as f64;
        Ok((loss as f64, correct as f64 / denom))
    }

    /// Run the full loop, recording a curve point every `eval_every`
    /// iterations (plus the initial point).
    ///
    /// With `[checkpoint]` armed, the full round state (RNG streams,
    /// comm ledger, algorithm state) is persisted atomically every
    /// `every` rounds; with `[checkpoint] resume` set, the loop picks
    /// up from the newest checkpoint and reproduces the uninterrupted
    /// trajectory bit-for-bit (evaluation consumes no RNG, so the
    /// resumed curve's tail matches; pre-crash points and the bounded
    /// event trace are not replayed). A `[fault] kill_server_at = R`
    /// schedule saves the pre-round state at R, silences the socket
    /// listener, and surfaces a distinctive error.
    pub fn run(&mut self, run: u32, compute: &mut dyn Compute)
               -> anyhow::Result<Curve> {
        let wall0 = Instant::now();
        let mut curve = Curve::new(&self.label, run);
        let start_k = self.restore(run)?;
        if start_k == 0 {
            let (loss, acc) = self.evaluate(compute)?;
            curve.points.push(self.point(0, loss, acc, wall0));
        }
        let ck_every = self.cfg.checkpoint.every;
        for k in start_k..self.cfg.iters as u64 {
            // scheduled crash: persist the pre-round state, go silent
            // (no Shutdown goodbyes on the wire), and fail loudly. A
            // kill scheduled exactly at the resume round already
            // happened in the previous incarnation.
            if self.cfg.fault.server_killed_at(k)
                && !(start_k > 0 && k == start_k)
            {
                if !self.cfg.checkpoint.dir.is_empty() {
                    let path = self.save_checkpoint(run, k)?;
                    crate::info!(
                        "fault injection: pre-crash state saved to {}",
                        path.display()
                    );
                }
                if let Some(server) = self.wire.as_mut() {
                    server.kill();
                }
                anyhow::bail!(
                    "fault injection: server killed before round {k} \
                     ([fault] kill_server_at)"
                );
            }
            self.step(k, compute)?;
            if (k + 1) % self.cfg.eval_every as u64 == 0 {
                let (loss, acc) = self.evaluate(compute)?;
                curve.points.push(self.point(k + 1, loss, acc, wall0));
            }
            if ck_every > 0 && (k + 1) % ck_every == 0 {
                self.save_checkpoint(run, k + 1)?;
            }
        }
        Ok(curve)
    }

    /// Resume from the newest checkpoint under `[checkpoint] resume`,
    /// if any: restores the per-worker RNG streams, the simulated comm
    /// ledger, and the algorithm's exported state, and returns the
    /// round to continue from (0 = fresh start). Run id, round cursor,
    /// config fingerprint, and every buffer shape are verified before
    /// anything is overwritten.
    fn restore(&mut self, run: u32) -> anyhow::Result<u64> {
        if self.cfg.checkpoint.resume.is_empty() {
            return Ok(0);
        }
        let dir = Path::new(&self.cfg.checkpoint.resume);
        let Some((next_k, path)) = checkpoint::latest(dir)? else {
            crate::info!(
                "resume: no checkpoint under {}, starting fresh",
                dir.display()
            );
            return Ok(0);
        };
        let body = checkpoint::load(&path)?;
        let mut dec = checkpoint::Dec::new(&body);
        let ckpt_run = dec.take_u32()?;
        anyhow::ensure!(
            ckpt_run == run,
            "checkpoint {} belongs to run {ckpt_run}, resuming run {run}",
            path.display()
        );
        let k = dec.take_u64()?;
        anyhow::ensure!(
            k == next_k,
            "checkpoint {} is named for round {next_k} but its body \
             resumes at {k}",
            path.display()
        );
        anyhow::ensure!(
            k <= self.cfg.iters as u64,
            "checkpoint {} resumes at round {k}, past this run's {} \
             iterations",
            path.display(),
            self.cfg.iters
        );
        let fp = dec.take_u64()?;
        let want = self.cfg.fingerprint();
        anyhow::ensure!(
            fp == want,
            "checkpoint {} was taken under a different run config \
             (fingerprint {fp:#018x}, this run's {want:#018x}) — \
             resuming would not reproduce the uninterrupted trajectory",
            path.display()
        );
        let m = dec.take_u64()? as usize;
        anyhow::ensure!(
            m == self.rngs.len(),
            "checkpoint {} holds {m} worker RNG streams, the run has {}",
            path.display(),
            self.rngs.len()
        );
        for rng in &mut self.rngs {
            *rng = Rng::from_state(dec.take_rng_state()?);
        }
        let comm = dec.take_comm_stats()?;
        anyhow::ensure!(
            comm.worker_uploads.len() == m,
            "checkpoint {} comm ledger covers {} workers, the run has \
             {m}",
            path.display(),
            comm.worker_uploads.len()
        );
        self.comm = comm;
        let blob = dec.take_bytes()?;
        dec.done()?;
        self.algo.import_state(&blob)?;
        crate::info!("resumed from {} at round {k}", path.display());
        Ok(k)
    }

    /// Persist the full server-side round state as the checkpoint that
    /// resumes at `next_k` — atomically, then prune old saves down to
    /// [`checkpoint::KEEP`].
    fn save_checkpoint(&self, run: u32, next_k: u64)
                       -> anyhow::Result<std::path::PathBuf> {
        let mut body = Vec::new();
        checkpoint::put_u32(&mut body, run);
        checkpoint::put_u64(&mut body, next_k);
        checkpoint::put_u64(&mut body, self.cfg.fingerprint());
        checkpoint::put_u64(&mut body, self.rngs.len() as u64);
        for rng in &self.rngs {
            checkpoint::put_rng_state(&mut body, &rng.state());
        }
        checkpoint::put_comm_stats(&mut body, &self.comm);
        let mut blob = Vec::new();
        self.algo.export_state(&mut blob)?;
        checkpoint::put_bytes(&mut body, &blob);
        let dir = Path::new(&self.cfg.checkpoint.dir);
        let path = checkpoint::save(dir, next_k, &body)?;
        checkpoint::prune(dir, checkpoint::KEEP);
        Ok(path)
    }

    fn point(&self, iter: u64, loss: f64, acc: f64, wall0: Instant)
             -> CurvePoint {
        CurvePoint {
            iter,
            loss,
            accuracy: acc,
            uploads: self.comm.uploads,
            grad_evals: self.comm.grad_evals,
            sim_time_s: self.comm.sim_time_s,
            wall_s: wall0.elapsed().as_secs_f64(),
        }
    }
}

/// Build one phase's [`RoundCtx`]: the single definition of how the
/// run's config maps onto a round context, shared by every phase of
/// both the in-process and the wire step paths (a method taking `&mut
/// self` would conflict with the disjoint field borrows the call sites
/// rely on).
fn round_ctx<'c>(cfg: &TrainCfg, links: &'c LinkSet,
                 comm: &'c mut CommStats, k: u64, m: usize,
                 fresh: Vec<usize>, deferred: Vec<usize>,
                 selected: Vec<usize>) -> RoundCtx<'c> {
    RoundCtx {
        k,
        m,
        upload_bytes: cfg.upload_bytes,
        broadcast_bytes: cfg.effective_broadcast_bytes(),
        links,
        comm,
        fresh,
        deferred,
        selected,
    }
}

/// Number of examples in an eval batch (token batches count predicted
/// positions, matching the eval artifact's `correct` semantics).
fn eval_examples(batch: &Batch) -> usize {
    match &batch.arrays[..] {
        [(_, shape)] => shape[0] * (shape[1] - 1), // tokens: B * S targets
        arrays => arrays[0].1[0],                  // labeled: batch dim
    }
}

/// Builder for [`Trainer`] — see the module docs for the full shape.
pub struct TrainerBuilder<'a, A: Algorithm + ?Sized> {
    cfg: TrainCfg,
    algo: Option<&'a mut A>,
    data: Option<&'a Dataset>,
    partition: Option<&'a Partition>,
    eval_batch: Option<Batch>,
    init_theta: Option<Vec<f32>>,
    label: Option<String>,
}

impl<'a, A: Algorithm + ?Sized> TrainerBuilder<'a, A> {
    pub fn algorithm(mut self, algo: &'a mut A) -> Self {
        self.algo = Some(algo);
        self
    }

    pub fn dataset(mut self, data: &'a Dataset) -> Self {
        self.data = Some(data);
        self
    }

    pub fn partition(mut self, partition: &'a Partition) -> Self {
        self.partition = Some(partition);
        self
    }

    pub fn eval_batch(mut self, batch: Batch) -> Self {
        self.eval_batch = Some(batch);
        self
    }

    pub fn init_theta(mut self, theta: Vec<f32>) -> Self {
        self.init_theta = Some(theta);
        self
    }

    /// Curve label (defaults to the algorithm's mechanism name; the
    /// experiment driver overrides it with the configured algo name,
    /// e.g. "adam" for the `Always` rule under AMSGrad).
    pub fn label(mut self, label: impl Into<String>) -> Self {
        self.label = Some(label.into());
        self
    }

    /// Replace the whole [`TrainCfg`] at once (individual setters below
    /// still apply on top).
    pub fn cfg(mut self, cfg: TrainCfg) -> Self {
        self.cfg = cfg;
        self
    }

    pub fn iters(mut self, iters: usize) -> Self {
        self.cfg.iters = iters;
        self
    }

    pub fn eval_every(mut self, eval_every: usize) -> Self {
        self.cfg.eval_every = eval_every;
        self
    }

    pub fn batch(mut self, batch: usize) -> Self {
        self.cfg.batch = batch;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.cfg.seed = seed;
        self
    }

    pub fn cost_model(mut self, cost_model: CostModel) -> Self {
        self.cfg.cost_model = cost_model;
        self
    }

    pub fn upload_bytes(mut self, bytes: usize) -> Self {
        self.cfg.upload_bytes = bytes;
        self
    }

    /// Downlink broadcast payload (0, the default, means "same as
    /// `upload_bytes`" — the seed's symmetric assumption).
    pub fn broadcast_bytes(mut self, bytes: usize) -> Self {
        self.cfg.broadcast_bytes = bytes;
        self
    }

    /// Socket transport: the `host:port` the server listens on (port 0
    /// binds an ephemeral port — read it back via
    /// [`Trainer::wire_addr`]).
    pub fn listen(mut self, addr: impl Into<String>) -> Self {
        self.cfg.comm.listen = addr.into();
        self
    }

    pub fn trace_cap(mut self, cap: usize) -> Self {
        self.cfg.trace_cap = cap;
        self
    }

    /// Replace the whole `[comm]` engine config at once.
    pub fn comm(mut self, comm: CommCfg) -> Self {
        self.cfg.comm = comm;
        self
    }

    /// Select the execution transport (default: `InProc`).
    pub fn transport(mut self, transport: TransportKind) -> Self {
        self.cfg.comm.transport = transport;
        self
    }

    /// Shard the server's parameter state across this many contiguous
    /// ranges, each folded and updated on its own thread
    /// (default 1 = sequential; 0 = one shard per available core).
    /// Bit-identical for every shard count.
    pub fn server_shards(mut self, shards: usize) -> Self {
        self.cfg.comm.server_shards = shards;
        self
    }

    /// How multi-shard server rounds execute: the persistent shard pool
    /// (default; spawn-free, profitable from mid-sized p) or per-round
    /// scoped threads (the PR 3 reference). Bit-identical either way.
    pub fn shard_exec(mut self, exec: ShardExec) -> Self {
        self.cfg.comm.shard_exec = exec;
        self
    }

    /// Semi-sync quorum: the server proceeds after the fastest `k`
    /// uploads of a round (0 = wait for everyone). Sugar for setting
    /// [`ParticipationCfg::quorum`] alone.
    pub fn semi_sync_k(mut self, k: usize) -> Self {
        self.cfg.comm.participation.quorum = k;
        self
    }

    /// Replace the whole participation config at once: population,
    /// per-round selection, quorum, churn tolerance, socket timeouts.
    pub fn participation(mut self, p: ParticipationCfg) -> Self {
        self.cfg.comm.participation = p;
        self
    }

    /// Log-normal upload straggler jitter (`sigma` = 0 disables).
    pub fn jitter(mut self, sigma: f64, seed: u64) -> Self {
        self.cfg.comm.jitter_sigma = sigma;
        self.cfg.comm.jitter_seed = seed;
        self
    }

    /// Upload compression (`[compress]`; default [`Scheme::Identity`],
    /// bit-identical to no compression).
    pub fn compress(mut self, compress: CompressCfg) -> Self {
        self.cfg.compress = compress;
        self
    }

    /// Deterministic fault injection plan (`[fault]`; default
    /// [`FaultPlan::none`], which injects nothing).
    pub fn fault(mut self, plan: FaultPlan) -> Self {
        self.cfg.fault = plan;
        self
    }

    /// Checkpoint/resume configuration (`[checkpoint]`; disabled by
    /// default).
    pub fn checkpoint(mut self, ck: CheckpointCfg) -> Self {
        self.cfg.checkpoint = ck;
        self
    }

    /// Validate, allocate the algorithm's state, the per-worker RNG
    /// streams and link models, and hand back a ready [`Trainer`].
    pub fn build(self) -> anyhow::Result<Trainer<'a, A>> {
        let algo = self
            .algo
            .ok_or_else(|| anyhow::anyhow!("Trainer needs .algorithm(...)"))?;
        let data = self
            .data
            .ok_or_else(|| anyhow::anyhow!("Trainer needs .dataset(...)"))?;
        let partition = self.partition.ok_or_else(|| {
            anyhow::anyhow!("Trainer needs .partition(...)")
        })?;
        let eval_batch = self.eval_batch.ok_or_else(|| {
            anyhow::anyhow!("Trainer needs .eval_batch(...)")
        })?;
        let init_theta = self.init_theta.ok_or_else(|| {
            anyhow::anyhow!("Trainer needs .init_theta(...)")
        })?;
        anyhow::ensure!(!init_theta.is_empty(), "init_theta is empty");
        anyhow::ensure!(self.cfg.eval_every >= 1, "eval_every must be >= 1");
        anyhow::ensure!(self.cfg.batch >= 1, "batch must be >= 1");
        let m = partition.num_workers();
        anyhow::ensure!(m >= 1, "partition has no workers");
        self.cfg.comm.validate()?;
        self.cfg.fault.validate()?;
        self.cfg.checkpoint.validate()?;
        {
            // wire-level faults need a wire; the scheduled server kill
            // is the only fault the in-process transports can honour
            let f = &self.cfg.fault;
            anyhow::ensure!(
                self.cfg.comm.transport == TransportKind::Socket
                    || (f.drop_p == 0.0
                        && f.corrupt_p == 0.0
                        && f.truncate_p == 0.0
                        && f.delay_p == 0.0
                        && f.kill_workers.is_empty()),
                "wire fault injection (drop/corrupt/truncate/delay/\
                 kill_workers) needs transport = \"socket\"; only \
                 kill_server_at applies to in-process transports"
            );
        }
        let part = &self.cfg.comm.participation;
        // the trainer runs exactly one simulated slot per partition
        // shard, so a registered population must match the worker count
        // (population > M — spare capacity for churn — is socket-server
        // territory the trainer does not model yet)
        anyhow::ensure!(
            part.population == 0 || part.population == m,
            "[comm] population ({}) must be 0 or equal the run's worker \
             count ({m})",
            part.population
        );
        anyhow::ensure!(
            algo.kind() != AlgorithmKind::LocalUpdate
                || !part.selection_active(m),
            "per-round selection (select_s = {}) does not apply to \
             model-averaging methods: '{}' needs every worker's local \
             model each averaging round",
            part.selected,
            algo.name()
        );
        // resolve the server-shard count (0 = one shard per core) and
        // hand it to the algorithm before it allocates server state
        let shards = match self.cfg.comm.server_shards {
            0 => std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1),
            n => n,
        };
        algo.set_server_shards(shards);
        algo.set_shard_exec(self.cfg.comm.shard_exec);
        // hand the algorithm the compression config before init so the
        // worker states allocate their error-feedback residuals;
        // algorithms without compressed-upload support reject lossy
        // schemes here rather than silently ignoring them
        algo.set_compress(self.cfg.compress)?;
        algo.init(&init_theta, m)?;
        // payload sizes are data-independent, so one constant covers
        // every simulated upload of the run
        let sim_upload_bytes = self
            .cfg
            .compress
            .sim_upload_bytes(init_theta.len(), self.cfg.upload_bytes);
        let root = Rng::new(self.cfg.seed);
        let rngs = (0..m).map(|w| root.fork(w as u64 + 1)).collect();
        let links = self.cfg.comm.build_links(m, &self.cfg.cost_model);
        let label = self
            .label
            .unwrap_or_else(|| algo.name().to_string());
        // socket transport: verify the algorithm can speak the wire
        // protocol and bind the listener NOW, so the caller can read
        // the bound address (port 0 -> ephemeral) and launch worker
        // processes before the first step blocks on the handshake
        let (wire, wire_cfg) =
            if self.cfg.comm.transport == TransportKind::Socket {
                anyhow::ensure!(
                    !self.cfg.comm.listen.is_empty(),
                    "transport = \"socket\" needs a listen address \
                     ([comm] listen / --listen / \
                     TrainerBuilder::listen)"
                );
                let wcfg = algo.wire_config()?;
                anyhow::ensure!(
                    data.len() <= u32::MAX as usize,
                    "the socket transport ships u32 batch indices; the \
                     dataset has {} samples",
                    data.len()
                );
                (Some(SocketServer::builder(&self.cfg.comm.listen)
                          .participation(&self.cfg.comm.participation, m)
                          .fault(self.cfg.fault.clone())
                          .build()?),
                 Some(wcfg))
            } else {
                (None, None)
            };
        // selection is a pure function of (seed, round): resolve the
        // seed once (0 = follow the train seed) and freeze the
        // deterministic speed ranking the grouped policy partitions by
        let select_seed = if self.cfg.comm.participation.seed == 0 {
            self.cfg.seed
        } else {
            self.cfg.comm.participation.seed
        };
        let speed_s = links.nominal_speeds(sim_upload_bytes);
        Ok(Trainer {
            trace: EventTrace::new(self.cfg.trace_cap),
            comm: CommStats::for_workers(m),
            cfg: self.cfg,
            algo,
            data,
            partition,
            eval_batch,
            label,
            rngs,
            links,
            transport: None,
            wire,
            wire_cfg,
            sim_upload_bytes,
            select_seed,
            speed_s,
            poisoned: false,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{Cada, CadaCfg, FedAvg};
    use crate::config::{toml, Schedule};
    use crate::coordinator::rules::RuleKind;
    use crate::coordinator::server::Optimizer;
    use crate::data::{synthetic, PartitionScheme};
    use crate::runtime::native::NativeLogReg;

    fn workload() -> (NativeLogReg, Dataset, Partition) {
        let compute = NativeLogReg::for_spec(22, 1024);
        let data = synthetic::ijcnn_like(400, 3);
        let mut rng = Rng::new(5);
        let partition =
            Partition::build(PartitionScheme::Uniform, &data, 3, &mut rng);
        (compute, data, partition)
    }

    fn amsgrad() -> Optimizer {
        Optimizer::Amsgrad {
            alpha: Schedule::Constant(0.02),
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            use_artifact: false,
        }
    }

    #[test]
    fn builder_rejects_missing_pieces() {
        let (_, data, partition) = workload();
        let mut algo = FedAvg::new(0.1, 2);
        let err = Trainer::builder()
            .algorithm(&mut algo)
            .dataset(&data)
            .partition(&partition)
            .eval_batch(data.gather(&[0, 1]))
            .build()
            .err()
            .unwrap();
        assert!(err.to_string().contains("init_theta"), "{err}");
        let err = Trainer::<FedAvg>::builder()
            .dataset(&data)
            .partition(&partition)
            .build()
            .err()
            .unwrap();
        assert!(err.to_string().contains("algorithm"), "{err}");
    }

    #[test]
    fn builder_rejects_clock_corrupting_comm_cfg() {
        let (_, data, partition) = workload();
        let mut algo = FedAvg::new(0.1, 2);
        let err = Trainer::builder()
            .algorithm(&mut algo)
            .dataset(&data)
            .partition(&partition)
            .eval_batch(data.gather(&[0, 1]))
            .init_theta(vec![0.0; 1024])
            .jitter(-0.5, 3)
            .build()
            .err()
            .unwrap();
        assert!(err.to_string().contains("jitter_sigma"), "{err}");
        // and from_doc rejects NaN/negative multipliers
        let doc = toml::parse("[comm.links]\nlatency_mult = [1, -2]\n")
            .unwrap();
        let err = TrainCfg::from_doc(&doc).err().unwrap();
        assert!(err.to_string().contains("finite and >= 0"), "{err}");
    }

    #[test]
    fn eval_cadence_and_label() {
        let (mut compute, data, partition) = workload();
        let mut algo = Cada::new(CadaCfg::basic(RuleKind::Always, amsgrad()));
        let mut trainer = Trainer::builder()
            .algorithm(&mut algo)
            .dataset(&data)
            .partition(&partition)
            .eval_batch(data.gather(&(0..64).collect::<Vec<_>>()))
            .init_theta(vec![0.0; 1024])
            .iters(20)
            .eval_every(5)
            .label("adam")
            .build()
            .unwrap();
        let curve = trainer.run(0, &mut compute).unwrap();
        assert_eq!(curve.algo, "adam");
        // initial point + 20/5 evals
        assert_eq!(curve.points.len(), 5);
        assert_eq!(curve.points.last().unwrap().iter, 20);
    }

    #[test]
    fn default_label_is_algorithm_name() {
        let (mut compute, data, partition) = workload();
        let mut algo = FedAvg::new(0.1, 2);
        let mut trainer = Trainer::builder()
            .algorithm(&mut algo)
            .dataset(&data)
            .partition(&partition)
            .eval_batch(data.gather(&[0, 1, 2, 3]))
            .init_theta(vec![0.0; 1024])
            .iters(4)
            .eval_every(2)
            .build()
            .unwrap();
        let curve = trainer.run(0, &mut compute).unwrap();
        assert_eq!(curve.algo, "fedavg");
    }

    #[test]
    fn train_cfg_toml_roundtrip() {
        let cfg = TrainCfg {
            iters: 1_500,
            eval_every: 25,
            batch: 92,
            seed: 2021,
            cost_model: CostModel { compute_s: 0.125,
                                    ..CostModel::default() },
            upload_bytes: 4 * 23,
            broadcast_bytes: 4 * 19,
            trace_cap: 128,
            comm: CommCfg {
                transport: TransportKind::Socket,
                listen: "127.0.0.1:7700".into(),
                connect: "cada-server:7700".into(),
                server_shards: 4,
                shard_exec: ShardExec::Scoped,
                participation: ParticipationCfg {
                    population: 12,
                    selected: 9,
                    quorum: 7,
                    policy: SelectPolicy::Grouped,
                    seed: 31,
                    churn: true,
                    min_live: 3,
                    socket_timeout_s: 15,
                    connect_retry_s: 4,
                },
                jitter_sigma: 0.5,
                jitter_seed: 11,
                latency_mult: vec![1.0, 2.0, 4.0],
                bw_mult: vec![1.0, 0.5],
                asymmetry_mult: Vec::new(),
                compute_mult: vec![1.0, 8.0],
            },
            compress: CompressCfg {
                scheme: Scheme::TopK,
                topk_frac: 0.1,
                bits: 5,
                seed: 9,
            },
            fault: FaultPlan {
                seed: 99,
                drop_p: 0.05,
                corrupt_p: 0.01,
                truncate_p: 0.02,
                delay_p: 0.25,
                delay_ms: 3,
                kill_workers: vec![(7, 2), (9, 0)],
                kill_server_at: Some(40),
            },
            checkpoint: CheckpointCfg {
                dir: "ckpts".into(),
                every: 10,
                resume: "ckpts".into(),
            },
        };
        let text = cfg.to_toml();
        let doc = toml::parse(&text).unwrap();
        let back = TrainCfg::from_doc(&doc).unwrap();
        assert_eq!(back, cfg);
        // the default Identity config emits no [compress] section at
        // all, so pre-compression golden configs stay byte-identical
        assert!(!TrainCfg::default().to_toml().contains("[compress]"));
        // likewise [fault]/[checkpoint]: absent until armed, so every
        // fault-free golden config is byte-identical — and the
        // fingerprint ignores both sections (a resume incarnation may
        // drop the kill schedule without invalidating its checkpoint)
        assert!(!TrainCfg::default().to_toml().contains("[fault]"));
        assert!(!TrainCfg::default().to_toml().contains("[checkpoint]"));
        assert_eq!(cfg.fingerprint(), {
            let mut clean = cfg.clone();
            clean.fault = FaultPlan::none();
            clean.checkpoint = CheckpointCfg::default();
            clean.fingerprint()
        });
        assert_ne!(cfg.fingerprint(), TrainCfg::default().fingerprint());
        // fault/checkpoint parse errors are loud: unknown keys,
        // out-of-range probabilities, and mismatched kill arrays
        let bad = toml::parse("[fault]\ndropp = 0.5\n").unwrap();
        assert!(TrainCfg::from_doc(&bad).is_err());
        let bad = toml::parse("[fault]\ndrop_p = 1.5\n").unwrap();
        assert!(TrainCfg::from_doc(&bad).is_err());
        let bad = toml::parse(
            "[fault]\nkill_rounds = [1, 2]\nkill_ids = [0]\n").unwrap();
        assert!(TrainCfg::from_doc(&bad).is_err());
        let bad = toml::parse("[checkpoint]\nevery = 5\n").unwrap();
        assert!(TrainCfg::from_doc(&bad).is_err());
        let bad = toml::parse("[checkpoint]\npath = \"x\"\n").unwrap();
        assert!(TrainCfg::from_doc(&bad).is_err());
        // defaults survive an empty doc
        let empty = TrainCfg::from_doc(&toml::parse("").unwrap()).unwrap();
        assert_eq!(empty, TrainCfg::default());
        // unknown keys are rejected
        let bad = toml::parse("[train]\nitters = 3\n").unwrap();
        assert!(TrainCfg::from_doc(&bad).is_err());
        let bad = toml::parse("[comm]\ntransporter = \"beam\"\n").unwrap();
        assert!(TrainCfg::from_doc(&bad).is_err());
        let bad = toml::parse("[comm]\ntransport = \"beam\"\n").unwrap();
        assert!(TrainCfg::from_doc(&bad).is_err());
        let bad = toml::parse("[comm]\nshard_exec = \"forkbomb\"\n")
            .unwrap();
        assert!(TrainCfg::from_doc(&bad).is_err());
        // participation knobs validate at parse time: a non-boolean
        // churn, an unknown policy, and a quorum exceeding the
        // selection are config errors, not run surprises
        let bad = toml::parse("[comm]\nchurn = 1\n").unwrap();
        assert!(TrainCfg::from_doc(&bad).is_err());
        let bad = toml::parse("[comm]\nselect_policy = \"fastest\"\n")
            .unwrap();
        assert!(TrainCfg::from_doc(&bad).is_err());
        let bad =
            toml::parse("[comm]\nselect_s = 5\nsemi_sync_k = 6\n").unwrap();
        assert!(TrainCfg::from_doc(&bad).is_err());
        let bad =
            toml::parse("[comm]\npopulation = 3\nselect_s = 5\n").unwrap();
        assert!(TrainCfg::from_doc(&bad).is_err());
        let bad = toml::parse("[comm.links]\nlatency_mult = 3\n").unwrap();
        assert!(TrainCfg::from_doc(&bad).is_err());
        let bad = toml::parse("[compress]\nschema = \"topk\"\n").unwrap();
        assert!(TrainCfg::from_doc(&bad).is_err());
        let bad = toml::parse("[compress]\nscheme = \"gzip\"\n").unwrap();
        assert!(TrainCfg::from_doc(&bad).is_err());
        // lossy configs are validated at parse time: 9-bit quantization
        // and a zero top-k density are config errors, not run surprises
        let bad = toml::parse(
            "[compress]\nscheme = \"quant\"\nbits = 9\n").unwrap();
        assert!(TrainCfg::from_doc(&bad).is_err());
        let bad = toml::parse(
            "[compress]\nscheme = \"topk\"\ntopk_frac = 0\n").unwrap();
        assert!(TrainCfg::from_doc(&bad).is_err());
        // compute multipliers validate like the other link multipliers
        let bad = toml::parse("[comm.links]\ncompute_mult = [1, -1]\n")
            .unwrap();
        assert!(TrainCfg::from_doc(&bad).is_err());
        // negative / fractional integer fields are rejected, not
        // saturated or truncated
        for src in ["[train]\niters = -100\n", "[train]\nbatch = 2.7\n",
                    "[train]\nseed = -1\n"] {
            let doc = toml::parse(src).unwrap();
            let err = TrainCfg::from_doc(&doc).err().unwrap();
            assert!(err.to_string().contains("non-negative integer"),
                    "{src}: {err}");
        }
    }

    #[test]
    fn broadcast_bytes_default_follows_upload_bytes() {
        // the 0 default keeps the seed's symmetric-payload assumption
        // (and every golden run) intact; explicit values diverge the
        // uplink and downlink honestly
        let cfg = TrainCfg { upload_bytes: 92, ..TrainCfg::default() };
        assert_eq!(cfg.effective_broadcast_bytes(), 92);
        let split = TrainCfg {
            upload_bytes: 92,
            broadcast_bytes: 40,
            ..TrainCfg::default()
        };
        assert_eq!(split.effective_broadcast_bytes(), 40);
    }

    #[test]
    fn socket_transport_validates_at_build() {
        let (_, data, partition) = workload();
        // a missing listen address fails before any bind
        let mut algo = Cada::new(CadaCfg::basic(RuleKind::Always,
                                                amsgrad()));
        let err = Trainer::builder()
            .algorithm(&mut algo)
            .dataset(&data)
            .partition(&partition)
            .eval_batch(data.gather(&[0, 1]))
            .init_theta(vec![0.0; 1024])
            .transport(TransportKind::Socket)
            .build()
            .err()
            .unwrap();
        assert!(err.to_string().contains("listen"), "{err}");
        // local-update methods say so clearly instead of hanging a run
        let mut algo = FedAvg::new(0.1, 2);
        let err = Trainer::builder()
            .algorithm(&mut algo)
            .dataset(&data)
            .partition(&partition)
            .eval_batch(data.gather(&[0, 1]))
            .init_theta(vec![0.0; 1024])
            .transport(TransportKind::Socket)
            .listen("127.0.0.1:0")
            .build()
            .err()
            .unwrap();
        assert!(err.to_string().contains("socket"), "{err}");
        assert!(err.to_string().contains("fedavg"), "{err}");
    }

    #[test]
    fn wire_faults_require_the_socket_transport() {
        // drop/corrupt/truncate/delay act on real frames; an in-process
        // run silently ignoring them would be a lying chaos test
        let (_, data, partition) = workload();
        let mut algo = Cada::new(CadaCfg::basic(RuleKind::Always,
                                                amsgrad()));
        let err = Trainer::builder()
            .algorithm(&mut algo)
            .dataset(&data)
            .partition(&partition)
            .eval_batch(data.gather(&[0, 1]))
            .init_theta(vec![0.0; 1024])
            .fault(FaultPlan { drop_p: 0.1, ..FaultPlan::none() })
            .build()
            .err()
            .unwrap();
        assert!(err.to_string().contains("socket"), "{err}");
        // the scheduled server kill is transport-independent
        let mut algo = Cada::new(CadaCfg::basic(RuleKind::Always,
                                                amsgrad()));
        assert!(Trainer::builder()
            .algorithm(&mut algo)
            .dataset(&data)
            .partition(&partition)
            .eval_batch(data.gather(&[0, 1]))
            .init_theta(vec![0.0; 1024])
            .fault(FaultPlan {
                kill_server_at: Some(3),
                ..FaultPlan::none()
            })
            .build()
            .is_ok());
    }

    #[test]
    fn lossy_compression_is_rejected_by_unsupporting_algorithms() {
        // local-update methods never route through the innovation
        // upload path, so a lossy scheme on them must fail at build
        // time with a clear message, not silently train uncompressed
        let (_, data, partition) = workload();
        let mut algo = FedAvg::new(0.1, 2);
        let err = Trainer::builder()
            .algorithm(&mut algo)
            .dataset(&data)
            .partition(&partition)
            .eval_batch(data.gather(&[0, 1]))
            .init_theta(vec![0.0; 1024])
            .compress(CompressCfg {
                scheme: Scheme::TopK,
                topk_frac: 0.1,
                bits: 4,
                seed: 0,
            })
            .build()
            .err()
            .unwrap();
        assert!(err.to_string().contains("compressed uploads"), "{err}");
        assert!(err.to_string().contains("fedavg"), "{err}");
        // Identity is fine everywhere
        let mut algo = FedAvg::new(0.1, 2);
        assert!(Trainer::builder()
            .algorithm(&mut algo)
            .dataset(&data)
            .partition(&partition)
            .eval_batch(data.gather(&[0, 1]))
            .init_theta(vec![0.0; 1024])
            .compress(CompressCfg::default())
            .build()
            .is_ok());
    }

    #[test]
    fn lossy_compression_shrinks_simulated_upload_bytes() {
        // the simulated accounting prices compressed uploads at their
        // data-independent on-wire size; the raw dense size lands in
        // the per-worker ratio columns
        let (mut compute, data, partition) = workload();
        let compress = CompressCfg {
            scheme: Scheme::TopK,
            topk_frac: 0.05,
            bits: 4,
            seed: 3,
        };
        let mut algo = Cada::new(CadaCfg::basic(RuleKind::Always,
                                                amsgrad()));
        let mut trainer = Trainer::builder()
            .algorithm(&mut algo)
            .dataset(&data)
            .partition(&partition)
            .eval_batch(data.gather(&(0..32).collect::<Vec<_>>()))
            .init_theta(vec![0.0; 1024])
            .iters(4)
            .upload_bytes(4 * 1024)
            .compress(compress)
            .build()
            .unwrap();
        trainer.run(0, &mut compute).unwrap();
        // Always uploads every round: 4 rounds x 3 workers
        assert_eq!(trainer.comm.uploads, 12);
        let per_upload =
            crate::compress::Payload::sparse_bytes(compress.topk_k(1024));
        assert_eq!(trainer.comm.upload_bytes, 12 * per_upload as u64);
        assert_eq!(trainer.comm.worker_raw_bytes, vec![4 * 4096; 3]);
        assert_eq!(trainer.comm.worker_wire_bytes,
                   vec![4 * per_upload as u64; 3]);
        // >= 4x measured reduction at 5% density
        assert!(4 * per_upload <= 4096,
                "per-upload {per_upload} bytes not >= 4x under 4096");
    }

    #[test]
    fn seed_above_2_pow_53_roundtrips_exactly() {
        // the seed used to be routed through f64 and silently lost its
        // low bits; it must now survive to_toml -> parse -> from_doc
        for seed in [(1u64 << 53) + 1, u64::MAX, u64::MAX - 12345] {
            let cfg = TrainCfg { seed, ..TrainCfg::default() };
            let doc = toml::parse(&cfg.to_toml()).unwrap();
            let back = TrainCfg::from_doc(&doc).unwrap();
            assert_eq!(back.seed, seed, "seed {seed} corrupted");
        }
        // a float-notation seed that cannot be represented exactly is an
        // error, not a silent rounding
        let doc = toml::parse("[train]\nseed = 1.00000000000000005e300\n")
            .unwrap();
        assert!(TrainCfg::from_doc(&doc).is_err());
    }
}
