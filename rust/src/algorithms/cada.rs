//! The server-centric family as one [`Algorithm`]: CADA1/2, stochastic
//! LAG, and distributed Adam/SGD (rules `Always`/`Periodic`/`Never`),
//! selected via [`RuleKind`] — Algorithm 1 of the paper mapped onto the
//! `broadcast → worker jobs → aggregate → server_update` lifecycle.
//!
//! * `broadcast` — refresh the CADA1 snapshot every D iterations, count
//!   the theta^k broadcast, freeze this round's drift threshold RHS, and
//!   freeze theta^k / the snapshot behind `Arc`s for the worker jobs.
//!   The frozen views come from double-buffered
//!   [`SnapshotBuffers`](crate::coordinator::shard::SnapshotBuffers):
//!   no per-round full-vector clone — only shard ranges dirtied since a
//!   buffer last held them are copied.
//! * `make_step`/`absorb_step` — lines 5–14: each worker job evaluates
//!   its rule LHS against the frozen RHS and decides whether to upload;
//!   jobs own their [`WorkerState`] for the duration, so any transport
//!   can run them concurrently, and outcomes fold back in worker order.
//! * `aggregate` — Eq. 3: record the settled (`ctx.fresh`) innovations
//!   for the round's fold, in worker order (queued semi-sync stragglers
//!   first, one round late); `ctx.deferred` stragglers are queued for
//!   the next round.
//! * `server_update` — one sharded pass over the server state: fold the
//!   recorded innovations delta_m/M and apply Eq. 2 (AMSGrad) or Eq. 4
//!   (SGD) per parameter shard (`[comm] server_shards` scoped threads,
//!   bit-identical for every shard count), then push the squared step
//!   norm into the drift history ring.

use std::sync::Arc;

use super::{Algorithm, AlgorithmKind, RoundCtx};
use crate::comm::{wire, JobOut, RoundEvent, WorkerJob};
use crate::compress::CompressCfg;
use crate::coordinator::checkpoint as ckpt;
use crate::coordinator::history::DeltaHistory;
use crate::coordinator::pool::ShardExec;
use crate::coordinator::rules::RuleKind;
use crate::coordinator::server::{Optimizer, ServerState};
use crate::coordinator::shard::{ShardLayout, ShardStats, SnapshotBuffers,
                                SnapshotStats};
use crate::coordinator::worker::{WorkerState, WorkerStep};
use crate::data::Batch;
use crate::runtime::Compute;

/// `b"CADA"` as a little-endian u32: leads the family's checkpoint
/// blob so a resume against a different algorithm's state fails fast.
const CADA_BLOB_TAG: u32 = u32::from_le_bytes(*b"CADA");

/// Static configuration of the server-centric family.
#[derive(Clone, Debug)]
pub struct CadaCfg {
    pub rule: RuleKind,
    /// the server step (AMSGrad for CADA/Adam, SGD for LAG)
    pub opt: Optimizer,
    /// D: max staleness AND (by default) the CADA1 snapshot refresh period
    pub max_delay: u32,
    /// CADA1 snapshot refresh period; 0 means "use max_delay" (the paper
    /// uses one constant D for both roles — this knob exists for ablations
    /// that disable the delay cap without freezing the snapshot)
    pub snapshot_every: u32,
    /// d_max: depth of the drift history ring
    pub d_max: usize,
    /// route innovation norms through the Pallas artifact
    pub use_artifact_innov: bool,
}

impl CadaCfg {
    /// Paper-default knobs (D = 50, d_max = 10, native innovation norms).
    pub fn basic(rule: RuleKind, opt: Optimizer) -> Self {
        CadaCfg {
            rule,
            opt,
            max_delay: 50,
            snapshot_every: 0,
            d_max: 10,
            use_artifact_innov: false,
        }
    }
}

/// Server-centric training state (parameter server + M rule-checking
/// workers). All state is allocated in [`Algorithm::init`].
pub struct Cada {
    pub cfg: CadaCfg,
    pub server: ServerState,
    pub workers: Vec<WorkerState>,
    pub history: DeltaHistory,
    /// server-shard count (engine hint, set before `init`; 1 = the
    /// sequential reference path)
    shards: usize,
    /// multi-shard execution mode (engine hint, set before `init`):
    /// persistent pool (default) or per-round scoped threads
    shard_exec: ShardExec,
    /// upload compression (engine hint, set before `init`); each
    /// worker's state owns the error-feedback residual, the server
    /// only needs the config to describe the wire protocol
    compress: CompressCfg,
    /// CADA1 snapshot theta-tilde (refreshed every D iterations)
    snapshot: Vec<f32>,
    /// bumped on every snapshot refresh (drives the snapshot buffers)
    snapshot_version: u64,
    /// double-buffered frozen views of theta^k / the snapshot: reused
    /// allocations, copy-on-dirty per shard range
    theta_bufs: SnapshotBuffers,
    snap_bufs: SnapshotBuffers,
    /// single-range layout for the snapshot buffers (the snapshot only
    /// changes wholesale, every D rounds)
    snap_layout: ShardLayout,
    /// round-frozen theta^k shared with the worker jobs
    round_theta: Arc<Vec<f32>>,
    /// round-frozen snapshot (CADA1 only)
    round_snapshot: Option<Arc<Vec<f32>>>,
    /// this round's frozen drift threshold
    rhs: f64,
    /// workers that decided to upload this round (|M^k| = uploaded.len())
    uploaded: Vec<usize>,
    /// semi-sync stragglers: innovations that arrived (in finite
    /// simulated time) after the quorum closed, folded stale at the next
    /// round's aggregate. This is a deliberate one-round-late
    /// simplification: a straggler whose arrival time exceeds a whole
    /// round still lands at k+1 (the event clock prices it, the fold
    /// schedule does not). Dead-link uploads (infinite arrival) never
    /// enter the queue — the engine classifies them as lost. Entries
    /// still queued when the run ends are in-flight transmissions the
    /// server never waits for — charged as uploads (the bytes were sent)
    /// but never applied, exactly like stopping a real deployment
    /// mid-round; [`Cada::stale_backlog`] exposes the tail (at most M-1
    /// entries).
    stale_queue: Vec<Vec<f32>>,
    /// this round's fold order, recorded by `aggregate` and consumed by
    /// `server_update`'s single sharded fold+step pass: stale straggler
    /// innovations first, then fresh uploads in worker order
    fold_stale: Vec<Vec<f32>>,
    fold_fresh: Vec<usize>,
    lhs_sum: f64,
    lhs_count: usize,
}

impl Cada {
    pub fn new(cfg: CadaCfg) -> Self {
        let opt = cfg.opt.clone();
        Cada {
            server: ServerState::new(Vec::new(), 1, opt),
            workers: Vec::new(),
            history: DeltaHistory::new(cfg.d_max.max(1)),
            shards: 1,
            shard_exec: ShardExec::default(),
            compress: CompressCfg::default(),
            snapshot: Vec::new(),
            snapshot_version: 0,
            theta_bufs: SnapshotBuffers::new(),
            snap_bufs: SnapshotBuffers::new(),
            snap_layout: ShardLayout::single(0),
            round_theta: Arc::new(Vec::new()),
            round_snapshot: None,
            rhs: 0.0,
            uploaded: Vec::new(),
            stale_queue: Vec::new(),
            fold_stale: Vec::new(),
            fold_fresh: Vec::new(),
            lhs_sum: 0.0,
            lhs_count: 0,
            cfg,
        }
    }

    /// Upload count of the round most recently completed.
    pub fn last_round_uploads(&self) -> usize {
        self.uploaded.len()
    }

    /// Straggler innovations currently awaiting their stale fold.
    pub fn stale_backlog(&self) -> usize {
        self.stale_queue.len()
    }

    /// Double-buffered broadcast counters: how often the frozen theta^k
    /// and CADA1-snapshot views reused a buffer vs fell back to a clone.
    pub fn snapshot_stats(&self) -> (SnapshotStats, SnapshotStats) {
        (self.theta_bufs.stats(), self.snap_bufs.stats())
    }
}

impl Algorithm for Cada {
    fn name(&self) -> &'static str {
        self.cfg.rule.name()
    }

    fn kind(&self) -> AlgorithmKind {
        AlgorithmKind::ServerCentric
    }

    fn set_server_shards(&mut self, shards: usize) {
        self.shards = shards.max(1);
    }

    fn set_shard_exec(&mut self, exec: ShardExec) {
        self.shard_exec = exec;
    }

    fn set_compress(&mut self, cfg: CompressCfg) -> anyhow::Result<()> {
        cfg.validate()?;
        self.compress = cfg;
        Ok(())
    }

    fn init(&mut self, init_theta: &[f32], m: usize) -> anyhow::Result<()> {
        anyhow::ensure!(self.cfg.d_max >= 1, "d_max must be >= 1");
        let p = init_theta.len();
        self.server = ServerState::new_sharded_with(
            init_theta.to_vec(), m, self.cfg.opt.clone(), self.shards,
            self.shard_exec);
        self.workers = (0..m)
            .map(|w| {
                let mut ws = WorkerState::new(w, p, self.cfg.rule);
                ws.set_compress(self.compress);
                ws
            })
            .collect();
        self.history = DeltaHistory::new(self.cfg.d_max);
        self.snapshot = init_theta.to_vec();
        self.snapshot_version = 0;
        // fresh buffers: held versions from a previous run must never
        // alias a new run's counters
        self.theta_bufs = SnapshotBuffers::new();
        self.snap_bufs = SnapshotBuffers::new();
        self.snap_layout = ShardLayout::single(p);
        self.stale_queue.clear();
        self.fold_stale.clear();
        self.fold_fresh.clear();
        Ok(())
    }

    fn theta(&self) -> &[f32] {
        &self.server.theta
    }

    fn broadcast(&mut self, ctx: &mut RoundCtx) -> anyhow::Result<()> {
        // Algorithm 1 line 4: refresh the CADA1 snapshot every D iterations
        let snap_period = if self.cfg.snapshot_every > 0 {
            self.cfg.snapshot_every
        } else {
            self.cfg.max_delay
        };
        if self.cfg.rule.needs_snapshot()
            && ctx.k % snap_period as u64 == 0
        {
            self.snapshot.copy_from_slice(&self.server.theta);
            self.snapshot_version += 1;
        }
        // line 3: broadcast theta^k (counted once per worker; the event
        // clock advances by the slowest download across the links)
        ctx.count_broadcast(ctx.broadcast_bytes);
        // freeze this round's shared state: every worker job compares
        // against the same RHS and reads the same theta^k/snapshot even
        // though jobs may run concurrently on worker threads. The views
        // come from the double buffers: dirty shard ranges are copied,
        // clean ones (and, between refreshes, the whole snapshot) reuse
        // the buffer the round-(k-2) jobs have since released.
        self.rhs = self.history.rhs(self.cfg.rule.c());
        self.round_theta = self.theta_bufs.freeze(
            &self.server.theta, self.server.layout(),
            self.server.versions());
        self.round_snapshot = if self.cfg.rule.needs_snapshot() {
            Some(self.snap_bufs.freeze(&self.snapshot, &self.snap_layout,
                                       &[self.snapshot_version]))
        } else {
            None
        };
        self.uploaded.clear();
        self.lhs_sum = 0.0;
        self.lhs_count = 0;
        Ok(())
    }

    fn make_step(&mut self, k: u64, w: usize, batch: Batch)
                 -> anyhow::Result<WorkerJob> {
        // the job owns the worker's state for the round; a zero-sized
        // placeholder keeps the slot until absorb_step returns it
        let state = std::mem::replace(
            &mut self.workers[w],
            WorkerState::new(w, 0, self.cfg.rule),
        );
        let theta = Arc::clone(&self.round_theta);
        let snapshot = self.round_snapshot.clone();
        let rule = self.cfg.rule;
        let max_delay = self.cfg.max_delay;
        let use_artifact_innov = self.cfg.use_artifact_innov;
        let rhs = self.rhs;
        Ok(Box::new(move |compute: &mut dyn Compute| {
            let mut state = state;
            let step = state.step(
                k,
                rule,
                max_delay,
                &theta,
                snapshot.as_ref().map(|s| s.as_slice()),
                rhs,
                &batch,
                compute,
                use_artifact_innov,
            )?;
            Ok(Box::new((state, step)) as JobOut)
        }))
    }

    fn absorb_step(&mut self, ctx: &mut RoundCtx, w: usize, out: JobOut)
                   -> anyhow::Result<()> {
        let (state, step) = *out
            .downcast::<(WorkerState, WorkerStep)>()
            .map_err(|_| anyhow::anyhow!(
                "cada: unexpected worker-job outcome type"))?;
        self.workers[w] = state;
        ctx.comm.record_grad_evals(step.grad_evals);
        if step.lhs.is_finite() {
            self.lhs_sum += step.lhs;
            self.lhs_count += 1;
        }
        if step.decision.upload {
            self.uploaded.push(w);
        }
        Ok(())
    }

    fn skip_unselected(&mut self, _k: u64, w: usize) -> anyhow::Result<()> {
        // an unselected worker never saw the round: no job, no upload —
        // but its staleness still ages, exactly as a remote skip does,
        // so the rule sees the true rounds-since-last-upload when the
        // worker is next selected (and max_delay still forces an upload
        // eventually)
        self.workers[w].absorb_remote_skip();
        Ok(())
    }

    fn pending_uploads(&self, _k: u64) -> Vec<usize> {
        self.uploaded.clone()
    }

    fn aggregate(&mut self, ctx: &mut RoundCtx) -> anyhow::Result<()> {
        // record the round's fold order; the actual folds run inside
        // `server_update`'s single per-shard pass. Semi-sync stragglers
        // from the previous round fold first (Eq. 3 one round late),
        // then the fresh uploads in worker order — elementwise the same
        // sequence as folding inline, so bit-identical.
        self.fold_stale = std::mem::take(&mut self.stale_queue);
        self.fold_fresh.clear();
        self.fold_fresh.extend_from_slice(&ctx.fresh);
        for &w in &ctx.deferred {
            self.stale_queue.push(self.workers[w].last_delta().to_vec());
        }
        Ok(())
    }

    fn server_update(&mut self, ctx: &mut RoundCtx,
                     compute: &mut dyn Compute) -> anyhow::Result<()> {
        // one sharded pass: fold the recorded innovations (Eq. 3) and
        // apply the optimizer step (Eq. 2/4) per parameter range
        let stale = std::mem::take(&mut self.fold_stale);
        let fresh = std::mem::take(&mut self.fold_fresh);
        let mut deltas: Vec<&[f32]> =
            Vec::with_capacity(stale.len() + fresh.len());
        deltas.extend(stale.iter().map(|d| d.as_slice()));
        deltas.extend(fresh.iter().map(|&w| self.workers[w].last_delta()));
        let sq_step = self.server.fold_and_step(ctx.k, &deltas, compute)?;
        self.history.push(sq_step);
        Ok(())
    }

    fn round_event(&self, k: u64) -> Option<RoundEvent> {
        Some(RoundEvent {
            iter: k,
            // the trainer owns the round's participant draw; it stamps
            // the selection onto the event after this snapshot
            selected: Vec::new(),
            uploaded: self.uploaded.clone(),
            staleness: self.workers.iter().map(|w| w.tau).collect(),
            mean_lhs: if self.lhs_count > 0 {
                self.lhs_sum / self.lhs_count as f64
            } else {
                f64::NAN
            },
            rhs: self.rhs,
        })
    }

    fn max_staleness(&self) -> u32 {
        self.workers.iter().map(|w| w.tau).max().unwrap_or(0)
    }

    fn shard_stats(&self) -> Option<ShardStats> {
        Some(self.server.shard_stats().clone())
    }

    fn wire_config(&self) -> anyhow::Result<wire::WireWorkerCfg> {
        Ok(wire::WireWorkerCfg {
            rule: self.cfg.rule,
            max_delay: self.cfg.max_delay,
            use_artifact_innov: self.cfg.use_artifact_innov,
            p: self.server.theta.len(),
            compress: self.compress,
        })
    }

    fn make_wire_step(&self, k: u64) -> anyhow::Result<wire::WireRound> {
        // the round state `broadcast` froze, as wire data: the shared
        // RHS plus the theta^k / snapshot views and the versions the
        // socket transport diffs per-worker acks against
        Ok(wire::WireRound {
            k,
            rhs: self.rhs,
            theta: Arc::clone(&self.round_theta),
            layout: self.server.layout().clone(),
            versions: self.server.versions().to_vec(),
            snapshot: self
                .round_snapshot
                .as_ref()
                .map(|s| (Arc::clone(s), self.snapshot_version)),
            // per-population-slot staleness: each selected worker's
            // round header carries its own server-tracked tau, so a
            // long-unselected (or freshly rejoined) remote worker
            // resumes with the count the InProc mirror would hold
            taus: self.workers.iter().map(|w| w.tau).collect(),
        })
    }

    fn absorb_wire_step(&mut self, ctx: &mut RoundCtx, w: usize,
                        step: wire::WireStep) -> anyhow::Result<()> {
        // the remote mirror of absorb_step: same lhs/grad-eval
        // accounting, and the shipped innovation lands in the worker
        // slot exactly where an in-process job would have left it —
        // aggregate/server_update run unchanged
        anyhow::ensure!(
            step.w == w,
            "cada: wire step for worker {} folded into slot {w}",
            step.w
        );
        ctx.comm.record_grad_evals(step.grad_evals);
        if step.lhs.is_finite() {
            self.lhs_sum += step.lhs;
            self.lhs_count += 1;
        }
        let decision = step.decision;
        if decision.upload {
            // the server folds what it received: the transport already
            // decompressed the shipped payload into a dense vector
            // (Dense for Identity — exact bytes, bit-identical to the
            // pre-compression protocol), so this is a move, not a
            // p-sized clone per upload
            let dense = step.payload.into_dense()?;
            self.workers[w].absorb_remote_upload(&dense)?;
            self.uploaded.push(w);
        } else {
            self.workers[w].absorb_remote_skip();
        }
        Ok(())
    }

    fn export_state(&self, out: &mut Vec<u8>) -> anyhow::Result<()> {
        // everything that crosses rounds: server moments + versions,
        // per-worker rule state, the drift history ring, the CADA1
        // snapshot, the semi-sync straggler queue, and the completed
        // round's summary fields. Per-round scratch (frozen Arc views,
        // fold order, snapshot double buffers) is rebuilt by the next
        // `broadcast`, so it stays out of the blob.
        ckpt::put_u32(out, CADA_BLOB_TAG);
        ckpt::put_u64(out, self.server.theta.len() as u64);
        ckpt::put_u64(out, self.workers.len() as u64);
        ckpt::put_f32s(out, &self.server.theta);
        ckpt::put_f32s(out, &self.server.h);
        ckpt::put_f32s(out, &self.server.vhat);
        ckpt::put_f32s(out, &self.server.grad_agg);
        ckpt::put_u64s(out, self.server.versions());
        for worker in &self.workers {
            let wc = worker.export_ckpt();
            ckpt::put_u32(out, wc.tau);
            ckpt::put_u64(out, wc.uploads);
            ckpt::put_f32s(out, &wc.g_stale);
            ckpt::put_opt_f32s(out, wc.dtilde_stored.as_deref());
            ckpt::put_opt_f32s(out, wc.theta_stored.as_deref());
            ckpt::put_f32s(out, &wc.delta);
            ckpt::put_f32s(out, &wc.residual);
        }
        let (ring, head, filled, sum) = self.history.export();
        ckpt::put_f64s(out, ring);
        ckpt::put_u64(out, head);
        ckpt::put_u64(out, filled);
        ckpt::put_f64(out, sum);
        ckpt::put_f32s(out, &self.snapshot);
        ckpt::put_u64(out, self.snapshot_version);
        ckpt::put_f64(out, self.rhs);
        let uploaded: Vec<u64> =
            self.uploaded.iter().map(|&w| w as u64).collect();
        ckpt::put_u64s(out, &uploaded);
        ckpt::put_u64(out, self.stale_queue.len() as u64);
        for stale in &self.stale_queue {
            ckpt::put_f32s(out, stale);
        }
        ckpt::put_f64(out, self.lhs_sum);
        ckpt::put_u64(out, self.lhs_count);
        Ok(())
    }

    fn import_state(&mut self, bytes: &[u8]) -> anyhow::Result<()> {
        // `init` already ran with the run's config, so every restored
        // buffer is validated against the freshly-built shapes
        let mut dec = ckpt::Dec::new(bytes);
        let tag = dec.take_u32()?;
        anyhow::ensure!(
            tag == CADA_BLOB_TAG,
            "checkpoint algorithm blob tag {tag:#010x} is not the \
             server-centric family's ({CADA_BLOB_TAG:#010x})"
        );
        let p = self.server.theta.len();
        let m = self.workers.len();
        let ckpt_p = dec.take_u64()? as usize;
        let ckpt_m = dec.take_u64()? as usize;
        anyhow::ensure!(
            ckpt_p == p && ckpt_m == m,
            "checkpoint was taken at p={ckpt_p}, m={ckpt_m}; this run \
             has p={p}, m={m}"
        );
        let theta = dec.take_f32s()?;
        let h = dec.take_f32s()?;
        let vhat = dec.take_f32s()?;
        let grad_agg = dec.take_f32s()?;
        let versions = dec.take_u64s()?;
        self.server.import_ckpt(theta, h, vhat, grad_agg, versions)?;
        for w in 0..m {
            let wc = crate::coordinator::worker::WorkerCkpt {
                tau: dec.take_u32()?,
                uploads: dec.take_u64()?,
                g_stale: dec.take_f32s()?,
                dtilde_stored: dec.take_opt_f32s()?,
                theta_stored: dec.take_opt_f32s()?,
                delta: dec.take_f32s()?,
                residual: dec.take_f32s()?,
            };
            self.workers[w].import_ckpt(wc)?;
        }
        let ring = dec.take_f64s()?;
        let head = dec.take_u64()?;
        let filled = dec.take_u64()?;
        let sum = dec.take_f64()?;
        self.history =
            DeltaHistory::import(self.cfg.d_max, ring, head, filled, sum)?;
        let snapshot = dec.take_f32s()?;
        anyhow::ensure!(
            snapshot.len() == p,
            "checkpoint snapshot holds {} parameters, the run has {p}",
            snapshot.len()
        );
        self.snapshot = snapshot;
        self.snapshot_version = dec.take_u64()?;
        self.rhs = dec.take_f64()?;
        let uploaded = dec.take_u64s()?;
        self.uploaded.clear();
        for w in uploaded {
            anyhow::ensure!(
                (w as usize) < m,
                "checkpoint uploaded-set names worker {w}, the run has \
                 {m} workers"
            );
            self.uploaded.push(w as usize);
        }
        let stale_len = dec.take_u64()? as usize;
        anyhow::ensure!(
            stale_len < m.max(1),
            "checkpoint straggler queue holds {stale_len} entries — \
             the semi-sync queue never exceeds M-1 = {}",
            m.saturating_sub(1)
        );
        self.stale_queue.clear();
        for _ in 0..stale_len {
            let stale = dec.take_f32s()?;
            anyhow::ensure!(
                stale.len() == p,
                "checkpoint straggler innovation holds {} parameters, \
                 the run has {p}",
                stale.len()
            );
            self.stale_queue.push(stale);
        }
        self.lhs_sum = dec.take_f64()?;
        self.lhs_count = dec.take_u64()? as usize;
        dec.done()?;
        // per-round scratch: the next broadcast rebuilds all of it
        self.fold_stale.clear();
        self.fold_fresh.clear();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::Trainer;
    use crate::config::Schedule;
    use crate::data::{synthetic, Dataset, Partition, PartitionScheme};
    use crate::runtime::native::NativeLogReg;
    use crate::util::rng::Rng;

    fn setup() -> (NativeLogReg, Dataset, Partition) {
        let compute = NativeLogReg::for_spec(22, 1024);
        let data = synthetic::ijcnn_like(800, 9);
        let mut rng = Rng::new(10);
        let partition =
            Partition::build(PartitionScheme::Uniform, &data, 5, &mut rng);
        (compute, data, partition)
    }

    fn amsgrad(alpha: f32) -> Optimizer {
        Optimizer::Amsgrad {
            alpha: Schedule::Constant(alpha),
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            use_artifact: false,
        }
    }

    #[test]
    fn adam_always_uploads_m_per_iter() {
        let (mut compute, data, partition) = setup();
        let eval = data.gather(&(0..64).collect::<Vec<_>>());
        let mut algo = Cada::new(CadaCfg::basic(RuleKind::Always,
                                                amsgrad(0.01)));
        let mut trainer = Trainer::builder()
            .algorithm(&mut algo)
            .dataset(&data)
            .partition(&partition)
            .eval_batch(eval)
            .init_theta(vec![0.0; 1024])
            .iters(20)
            .eval_every(5)
            .seed(7)
            .build()
            .unwrap();
        let curve = trainer.run(0, &mut compute).unwrap();
        assert_eq!(trainer.comm.uploads, 20 * 5);
        assert_eq!(trainer.comm.grad_evals, 20 * 5);
        // every worker shows up in the per-worker breakdown
        assert_eq!(trainer.comm.worker_uploads, vec![20; 5]);
        assert!(curve.final_loss() < curve.points[0].loss,
                "loss should decrease: {curve:?}");
    }

    #[test]
    fn cada2_saves_uploads_and_still_descends() {
        let (mut compute, data, partition) = setup();
        let eval = data.gather(&(0..64).collect::<Vec<_>>());
        let iters = 60;
        let run = |rule: RuleKind, compute: &mut NativeLogReg| {
            let mut cfg = CadaCfg::basic(rule, amsgrad(0.02));
            cfg.max_delay = 20;
            let mut algo = Cada::new(cfg);
            let mut trainer = Trainer::builder()
                .algorithm(&mut algo)
                .dataset(&data)
                .partition(&partition)
                .eval_batch(eval.clone())
                .init_theta(vec![0.0; 1024])
                .iters(iters)
                .seed(7)
                .build()
                .unwrap();
            let curve = trainer.run(0, compute).unwrap();
            (trainer.comm.uploads, curve.final_loss())
        };
        let (adam_up, adam_loss) = run(RuleKind::Always, &mut compute);
        let (cada_up, cada_loss) =
            run(RuleKind::Cada2 { c: 1.2 }, &mut compute);
        assert!(cada_up < adam_up, "cada {cada_up} vs adam {adam_up}");
        assert!(cada_loss < adam_loss * 1.5 + 0.1,
                "cada loss {cada_loss} vs adam {adam_loss}");
    }

    #[test]
    fn staleness_never_exceeds_max_delay() {
        let (mut compute, data, partition) = setup();
        let eval = data.gather(&(0..32).collect::<Vec<_>>());
        let mut cfg = CadaCfg::basic(RuleKind::Never, amsgrad(0.01));
        cfg.max_delay = 4;
        let mut algo = Cada::new(cfg);
        let mut trainer = Trainer::builder()
            .algorithm(&mut algo)
            .dataset(&data)
            .partition(&partition)
            .eval_batch(eval)
            .init_theta(vec![0.0; 1024])
            .iters(30)
            .batch(8)
            .seed(3)
            .build()
            .unwrap();
        for k in 0..30 {
            trainer.step(k, &mut compute).unwrap();
            assert!(trainer.max_staleness() <= 4);
        }
    }

    #[test]
    fn cada_c0_equals_distributed_amsgrad() {
        // c = 0 zeroes the RHS, so any nonzero innovation uploads: CADA
        // degenerates to distributed AMSGrad and must produce (nearly)
        // identical iterates given identical worker RNG streams.
        let (mut compute, data, partition) = setup();
        let eval = data.gather(&(0..32).collect::<Vec<_>>());
        let iters = 25;
        let run_theta = |rule: RuleKind, compute: &mut NativeLogReg| {
            let mut algo = Cada::new(CadaCfg::basic(rule, amsgrad(0.01)));
            let mut trainer = Trainer::builder()
                .algorithm(&mut algo)
                .dataset(&data)
                .partition(&partition)
                .eval_batch(eval.clone())
                .init_theta(vec![0.0; 1024])
                .iters(iters)
                .seed(42)
                .build()
                .unwrap();
            trainer.run(0, compute).unwrap();
            drop(trainer);
            algo.server.theta
        };
        let adam = run_theta(RuleKind::Always, &mut compute);
        let cada = run_theta(RuleKind::Cada2 { c: 0.0 }, &mut compute);
        let diff = crate::tensor::sqnorm_diff(&adam, &cada);
        assert!(diff < 1e-8, "divergence {diff}");
    }

    #[test]
    fn server_shards_are_bit_identical_and_reuse_broadcast_buffers() {
        // p = 4096 -> 4 reduction blocks, so 2/4 shards genuinely split
        // the server state; every shard count must reproduce the 1-shard
        // run exactly, and the double-buffered broadcast must stop
        // cloning after its two buffers are warm
        let mut compute = NativeLogReg::for_spec(22, 4096);
        let data = synthetic::ijcnn_like(600, 9);
        let mut rng = Rng::new(10);
        let partition =
            Partition::build(PartitionScheme::Uniform, &data, 4, &mut rng);
        let eval = data.gather(&(0..64).collect::<Vec<_>>());
        let iters = 30usize;
        let mut run = |shards: usize| {
            let mut cfg = CadaCfg::basic(RuleKind::Cada1 { c: 0.8 },
                                         amsgrad(0.02));
            cfg.max_delay = 10;
            let mut algo = Cada::new(cfg);
            let mut trainer = Trainer::builder()
                .algorithm(&mut algo)
                .dataset(&data)
                .partition(&partition)
                .eval_batch(eval.clone())
                .init_theta(vec![0.0; 4096])
                .iters(iters)
                .eval_every(5)
                .server_shards(shards)
                .seed(7)
                .build()
                .unwrap();
            let curve = trainer.run(0, &mut compute).unwrap();
            let losses: Vec<f64> =
                curve.points.iter().map(|p| p.loss).collect();
            let uploads = trainer.comm.uploads;
            drop(trainer);
            let (theta_stats, snap_stats) = algo.snapshot_stats();
            let shard_stats = algo.shard_stats().unwrap();
            (losses, uploads, algo.server.theta.clone(), theta_stats,
             snap_stats, shard_stats)
        };
        let reference = run(1);
        assert_eq!(reference.5.num_shards(), 1);
        for shards in [2usize, 4] {
            let sharded = run(shards);
            assert_eq!(reference.0, sharded.0,
                       "loss curve diverged at {shards} shards");
            assert_eq!(reference.1, sharded.1);
            assert_eq!(reference.2, sharded.2,
                       "final theta diverged at {shards} shards");
            assert_eq!(sharded.5.num_shards(), shards);
            assert_eq!(sharded.5.rounds, iters as u64);
            // p = 4096 splits into non-empty ranges for 2/4 shards, so
            // every shard must have accumulated real timed work over 30
            // rounds (a zero means its task never ran or its timing was
            // attributed to the wrong slot)
            assert!(sharded.5.shard_s.iter().all(|&s| s > 0.0),
                    "untouched shard timing: {:?}", sharded.5.shard_s);
        }
        // double buffers: two warm-up clones each, then pure reuse —
        // theta ranges copy every round (the step dirties them), the
        // CADA1 snapshot only re-copies after a refresh
        let (theta_stats, snap_stats) = (reference.3, reference.4);
        assert_eq!(theta_stats.full_clones, 2);
        assert_eq!(snap_stats.full_clones, 2);
        assert!(snap_stats.ranges_reused > 0,
                "snapshot buffer never reused: {snap_stats:?}");
    }

    #[test]
    fn checkpoint_blob_roundtrips_byte_for_byte() {
        // grow nontrivial state (snapshots, staleness, drift history),
        // export it, import into a freshly-initialised twin, and demand
        // the twin re-exports the exact same bytes — the unit-level
        // core of the resume-is-bit-identical guarantee
        let (mut compute, data, partition) = setup();
        let eval = data.gather(&(0..32).collect::<Vec<_>>());
        let mut cfg = CadaCfg::basic(RuleKind::Cada1 { c: 0.8 },
                                     amsgrad(0.02));
        cfg.max_delay = 5;
        let mut algo = Cada::new(cfg.clone());
        let mut trainer = Trainer::builder()
            .algorithm(&mut algo)
            .dataset(&data)
            .partition(&partition)
            .eval_batch(eval)
            .init_theta(vec![0.0; 1024])
            .iters(12)
            .seed(7)
            .build()
            .unwrap();
        trainer.run(0, &mut compute).unwrap();
        drop(trainer);
        let mut blob = Vec::new();
        algo.export_state(&mut blob).unwrap();
        assert!(!blob.is_empty());

        let mut twin = Cada::new(cfg);
        twin.init(&vec![0.0; 1024], 5).unwrap();
        twin.import_state(&blob).unwrap();
        let mut reblob = Vec::new();
        twin.export_state(&mut reblob).unwrap();
        assert_eq!(blob, reblob, "import/export is not a fixed point");
        assert_eq!(algo.server.theta, twin.server.theta);

        // shape mismatches must fail fast, not fold garbage
        let mut small = Cada::new(CadaCfg::basic(
            RuleKind::Cada1 { c: 0.8 }, amsgrad(0.02)));
        small.init(&vec![0.0; 512], 5).unwrap();
        assert!(small.import_state(&blob).is_err());
        assert!(twin.import_state(&blob[..blob.len() - 3]).is_err());
    }

    #[test]
    fn trace_records_upload_sets() {
        let (mut compute, data, partition) = setup();
        let eval = data.gather(&(0..32).collect::<Vec<_>>());
        let mut algo = Cada::new(CadaCfg::basic(RuleKind::Always,
                                                amsgrad(0.01)));
        let mut trainer = Trainer::builder()
            .algorithm(&mut algo)
            .dataset(&data)
            .partition(&partition)
            .eval_batch(eval)
            .init_theta(vec![0.0; 1024])
            .iters(5)
            .batch(8)
            .trace_cap(10)
            .seed(3)
            .build()
            .unwrap();
        for k in 0..5 {
            trainer.step(k, &mut compute).unwrap();
        }
        assert_eq!(trainer.trace.events.len(), 5);
        assert!(trainer.trace.iter().all(|e| e.uploaded.len() == 5));
    }
}
