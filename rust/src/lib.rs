//! # cada — Communication-Adaptive Distributed Adam
//!
//! A production-shaped reproduction of *"CADA: Communication-Adaptive
//! Distributed Adam"* (Chen, Guo, Sun, Yin, 2020) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: a
//!   parameter server + `M` workers where each worker adaptively *skips*
//!   gradient uploads using the CADA1/CADA2 variance-reduced innovation
//!   rules (paper Eqs. 7/10), plus every baseline the paper evaluates
//!   (distributed Adam, stochastic LAG, local momentum SGD, FedAvg,
//!   FedAdam).
//! * **L2 (python/compile)** — JAX models (logistic regression, MLP, CNN,
//!   transformer LM) lowered once to HLO text (`make artifacts`).
//! * **L1 (python/compile/kernels)** — Pallas kernels for the fused
//!   AMSGrad server step (Eq. 2a–2c) and the blocked innovation norm.
//!
//! Python never runs on the training path: [`runtime`] loads the AOT
//! artifacts via PJRT (the `xla` crate) and everything else is rust.
//!
//! ## Quick tour
//!
//! ```no_run
//! use cada::prelude::*;
//!
//! let manifest = cada::runtime::Manifest::load("artifacts").unwrap();
//! let engine = cada::runtime::Engine::new(&manifest, "test_logreg").unwrap();
//! ```
//!
//! See `examples/quickstart.rs` for an end-to-end training run.

pub mod algorithms;
pub mod bench;
pub mod cli;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod runtime;
pub mod telemetry;
pub mod tensor;
pub mod testing;
pub mod util;

/// Convenient glob import for examples and benches.
pub mod prelude {
    pub use crate::algorithms::{AlgorithmKind, LocalLoop, LocalMethod};
    pub use crate::comm::CommStats;
    pub use crate::coordinator::{
        rules::RuleKind, scheduler::ServerLoop, server::Optimizer,
    };
    pub use crate::data::{DatasetKind, Partition};
    pub use crate::exp::{Experiment, RunResult};
    pub use crate::runtime::{Engine, Manifest};
    pub use crate::util::rng::Rng;
}
