//! # cada — Communication-Adaptive Distributed Adam
//!
//! A production-shaped reproduction of *"CADA: Communication-Adaptive
//! Distributed Adam"* (Chen, Guo, Sun, Yin, 2020) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's coordination contribution: a
//!   parameter server + `M` workers where each worker adaptively *skips*
//!   gradient uploads using the CADA1/CADA2 variance-reduced innovation
//!   rules (paper Eqs. 7/10), plus every baseline the paper evaluates
//!   (distributed Adam, stochastic LAG, local momentum SGD, FedAvg,
//!   FedAdam).
//! * **L2 (python/compile)** — JAX models (logistic regression, MLP, CNN,
//!   transformer LM) lowered once to HLO text (`make artifacts`).
//! * **L1 (python/compile/kernels)** — Pallas kernels for the fused
//!   AMSGrad server step (Eq. 2a–2c) and the blocked innovation norm.
//!
//! Python never runs on the training path: with the `pjrt` cargo feature,
//! [`runtime`] loads the AOT artifacts via PJRT (the `xla` crate); the
//! default build uses the pure-rust [`runtime::native`] backend.
//!
//! ## Quick tour
//!
//! Every training method implements one [`algorithms::Algorithm`] trait
//! (round lifecycle `broadcast → worker jobs → aggregate →
//! server_update`), and one builder-style [`algorithms::Trainer`] drives
//! the engine for all of them: per-worker minibatch RNG streams, the
//! execution [`Transport`](comm::Transport) (sequential `InProc`, or
//! `Threaded` with one persistent thread per worker), per-worker
//! [`LinkModel`](comm::LinkModel)s with an event clock that advances by
//! the slowest participating worker, evaluation and telemetry:
//!
//! ```
//! use cada::prelude::*;
//!
//! // a synthetic ijcnn1-like workload split over 5 workers
//! let data = cada::data::synthetic::ijcnn_like(800, 9);
//! let mut rng = Rng::new(10);
//! let partition = Partition::build(PartitionScheme::Uniform, &data, 5,
//!                                  &mut rng);
//! let eval = data.gather(&(0..64).collect::<Vec<_>>());
//! let mut compute = cada::runtime::native::NativeLogReg::for_spec(22, 1024);
//!
//! // CADA2 (Eq. 10) under an AMSGrad server step ...
//! let mut algo = Cada::new(CadaCfg::basic(
//!     RuleKind::Cada2 { c: 1.2 },
//!     Optimizer::Amsgrad {
//!         alpha: Schedule::Constant(0.02),
//!         beta1: 0.9, beta2: 0.999, eps: 1e-8,
//!         use_artifact: false,
//!     },
//! ));
//! // ... driven by the one generic Trainer; swap
//! // `TransportKind::Threaded` in and the run is bit-identical, just
//! // spread over worker threads
//! let mut trainer = Trainer::builder()
//!     .algorithm(&mut algo)
//!     .dataset(&data)
//!     .partition(&partition)
//!     .eval_batch(eval)
//!     .init_theta(vec![0.0; 1024])
//!     .iters(60)
//!     .eval_every(20)
//!     .seed(7)
//!     .transport(TransportKind::InProc)
//!     .build()
//!     .unwrap();
//! let curve = trainer.run(0, &mut compute).unwrap();
//!
//! assert!(curve.final_loss() < curve.points[0].loss);
//! // the paper's headline: fewer uploads than always-upload Adam
//! assert!(trainer.comm.uploads < 60 * 5);
//! ```
//!
//! Swapping the method is one line — `FedAvg::new(0.1, 8)`,
//! `LocalMomentum::new(0.05, 0.9, 8)`, `FedAdam::new(...)` or another
//! [`RuleKind`](coordinator::rules::RuleKind) — everything else
//! (`Trainer`, metrics, experiment driver) is shared.
//!
//! ## Scenario knobs (the `[comm]` config section)
//!
//! * **transport** — `inproc` (sequential reference), `threaded`
//!   (persistent worker threads + channel mailboxes), or `socket`
//!   (real TCP across OS processes: one `cada serve` server + M `cada
//!   worker` processes speaking the hand-rolled length-prefixed
//!   [`comm::wire`] protocol — round headers carry the iteration,
//!   frozen rule RHS, server-sampled batch indices and theta/snapshot
//!   *delta broadcasts*, step results carry the upload decision and
//!   innovation payload; [`comm::WireStats`] counts the bytes that
//!   actually crossed the wire). All three are enforced bit-identical
//!   by `tests/golden_parity.rs`; the socket path covers the
//!   server-centric family (local-update methods fail fast with a
//!   clear error for now).
//! * **server sharding** — `server_shards = N` (CLI `--server-shards`,
//!   builder `.server_shards(n)`, 0 = one shard per core): the server's
//!   parameter state (theta/h/vhat/aggregate and the stale-gradient
//!   folds) splits into N contiguous block-aligned ranges
//!   ([`coordinator::shard::ShardLayout`]); innovation folds and the
//!   AMSGrad/SGD step run per shard on the persistent
//!   [`coordinator::pool::ShardPool`] — threads spawned once per run,
//!   each owning its range, parked on channel mailboxes between rounds,
//!   so the round hot path is spawn-free and shard counts > 1 pay off
//!   from mid-sized (~64k-parameter) specs (`shard_exec = "scoped"` /
//!   `--shard-exec scoped` keeps PR 3's per-round spawn+join as the
//!   reference). Worker order is preserved inside each shard and the
//!   step-norm reduced per fixed-size block, so every shard count and
//!   both execution modes are bit-identical to the 1-shard reference
//!   (golden-enforced). Broadcast views of
//!   theta^k (and the CADA1 snapshot) come from double-buffered
//!   [`coordinator::shard::SnapshotBuffers`]: no per-round full-vector
//!   clone, only dirtied shard ranges are copied. This is what lets the
//!   server keep up once the threaded transport parallelises the
//!   workers — and the shard versions double as the socket transport's
//!   delta-broadcast bookkeeping: a round header ships only the ranges
//!   a worker process has not acknowledged at the current version.
//! * **blocked gradient kernel** — the native backend computes each
//!   worker batch's gradient as a two-pass blocked kernel: all logits
//!   of a sample block first ([`tensor::gemv_block`], bit-identical to
//!   per-sample dots), then one fused exponential per sample for
//!   sigmoid + softplus ([`runtime::native::sigmoid_softplus`]) and a
//!   fixed group-of-4 residual fold ([`tensor::ger_acc`]) — on
//!   backend-owned scratch, so steady-state rounds allocate nothing.
//!   Pinned against the retained sample-at-a-time reference by the
//!   comparator tests in [`runtime::native`].
//! * **SIMD kernels** — the `simd` cargo feature dispatches the hot
//!   tensor kernels ([`tensor::dot`], [`tensor::sqnorm_diff`],
//!   [`tensor::axpy`], [`tensor::gemv_block`], [`tensor::ger_acc`],
//!   [`tensor::amsgrad_update`], the fused sigmoid+softplus, …) to
//!   explicit 8-lane implementations in [`tensor::simd`] (AVX where the
//!   CPU has it, a portable 8-lane form otherwise); `CADA_SIMD=0` (or
//!   `off`/`false`/`scalar`) opts back out at runtime. Every kernel
//!   keeps its scalar *golden twin* in [`tensor::scalar`]: elementwise
//!   kernels preserve the scalar expression tree (no FMA contraction)
//!   and are **bit-identical** across sets; reductions use one
//!   documented fixed 8-lane combine order (portable == AVX
//!   bit-for-bit) and are comparator-pinned against the scalar twin to
//!   reduction tolerance — dispatch is process-wide and uniform, so any
//!   run is self-consistent and the golden transport/shard parity
//!   suites hold under both feature configs in CI (comparator tests pin
//!   every kernel at remainder-lane edge sizes). [`tensor::simd_active`]
//!   reports what a
//!   build actually dispatches; [`comm::WireStats`] separately times
//!   the wire codec (header encode / step decode wall time) so socket
//!   runs show where round latency goes.
//! * **device compute time** — `[train.cost_model] compute_s` (base
//!   per-round device seconds) with per-worker `[comm.links]
//!   compute_mult` multipliers: an upload's simulated arrival is
//!   compute + transmission, and fully-sync rounds are floored by the
//!   slowest device even when its rule skips the upload — so the event
//!   clock and the semi-sync quorum price slow devices as well as slow
//!   links (0 = off, bit-identical to the pre-compute model).
//! * **heterogeneous links** — `[comm.links]` latency/bandwidth/
//!   asymmetry multipliers, cycled over workers; broadcasts and uploads
//!   are charged against each worker's own link and the event clock
//!   advances by the slowest participant.
//! * **upload compression** — the `[compress]` section (CLI
//!   `--compress topk|quant`, builder `.compress(...)`) runs the
//!   innovation uploads CADA does *not* skip through a lossy
//!   [`compress`] stage: `TopK` magnitude sparsification or `QuantB`
//!   b-bit stochastic quantization (seeded, a pure function of
//!   `(seed, round, worker)` like the jitter), each behind a per-worker
//!   error-feedback residual so truncated mass re-enters later rounds.
//!   The CADA1/CADA2/LAG skip-rule LHS is computed on the
//!   *decompressed* innovation — the rule reasons about what the server
//!   actually receives, so skipping and shrinking compose. Payload
//!   sizes are data-independent, so the simulated `upload_bytes`
//!   accounting and the socket transport's measured
//!   [`comm::WireStats`] agree on the compression ratio; `Identity`
//!   (the default) runs the exact pre-compression code paths and stays
//!   golden-enforced bit-identical on all three transports.
//! * **straggler jitter** — seeded log-normal multiplier on upload
//!   times; a pure function of `(seed, round, worker)`, so runs stay
//!   reproducible.
//! * **crash safety** — the `[fault]` section / `--fault-*` flags drive
//!   deterministic fault injection ([`comm::FaultPlan`]: seeded frame
//!   drops, bit-flips the CRC-checksummed v4 wire framing rejects,
//!   mid-frame truncations, delays, and scheduled worker/server kills —
//!   every event a pure function of `(fault_seed, round, worker)`), and
//!   the `[checkpoint]` section / `--checkpoint`, `--checkpoint-every`,
//!   `--resume` flags make training crash-safe: atomic temp+rename
//!   CRC-checksummed checkpoints ([`coordinator::checkpoint`]) capture
//!   the full trainer + algorithm state (RNG streams, comm accounting,
//!   the CADA server/worker/rule state), and a killed-then-resumed run
//!   is **bit-identical** to an uninterrupted one (golden-enforced by
//!   `tests/checkpoint.rs`). Socket workers with `--heal` survive a
//!   server restart by reconnecting with bounded seeded backoff and
//!   rejoining their slot. The failure model is documented in
//!   [`comm`] ("Failure model and recovery").
//! * **participation** — one [`comm::ParticipationCfg`] holds every
//!   participation knob (`[comm]` keys, `--select-*` CLI flags, builder
//!   `.participation(...)`): `semi_sync_k = K` makes the server proceed
//!   once the fastest K uploads of a round arrive, stragglers folding
//!   in stale next round; `select_s = S` draws a per-round participant
//!   subset of S workers — seeded-uniform or `select_policy =
//!   "grouped"` (ranked by each worker's deterministic nominal round
//!   time, so co-selected workers finish together) — as a pure function
//!   of `(select_seed, round)`, bit-identical on every transport, with
//!   unselected workers skipping the round entirely (server-centric
//!   methods only; `S = K = M` degenerates to the exact pre-selection
//!   run). On the socket transport, `population = N` sizes the admitted
//!   worker fleet at handshake, the nonblocking server rejects
//!   duplicate and unselected step uploads, and `churn = true` (with
//!   `min_live`, `socket_timeout_s`, `connect_retry_s`) tolerates
//!   worker disconnects mid-run: vacated slots fold as skips and a
//!   `cada worker --rejoin W` process is readmitted into slot W with a
//!   full catch-up broadcast.
//!
//! ## Invariants (machine-checked by `cada audit`)
//!
//! Every claim above rests on one property: all randomness and
//! timing-sensitive state is a pure function of `(seed, round,
//! worker)`, and every float fold has one documented order. The
//! [`analysis`] subsystem enforces that property *statically* — `cada
//! audit` scans `rust/src/**` and fails CI (the `static-analysis` job)
//! on any violation of:
//!
//! * **R1** — every `unsafe` block/fn carries a `// SAFETY:` contract
//!   on the preceding lines (the crate also sets
//!   `#![deny(unsafe_op_in_unsafe_fn)]`, so unsafe bodies stay
//!   explicit). Why: the unsafe inventory ([`coordinator::pool`]'s
//!   raw-slice reconstruction, [`tensor::simd`]'s AVX kernels) is only
//!   reviewable while each site states what makes it sound.
//! * **R2** — no `Instant::now`/`SystemTime`/`std::time` in
//!   simulated-accounting and fold paths (`algorithms/`, `compress/`,
//!   `coordinator/{shard,server,history}`, `util/rng`). Why: simulated
//!   round time must come from the [`comm::LinkModel`] event clock,
//!   never the host's — or run-to-run bit-identity dies.
//! * **R3** — no `HashMap`/`HashSet` in paths feeding folds,
//!   broadcasts, checkpoints, or wire frames. Why: hash iteration
//!   order varies per process. The crate currently holds **zero**
//!   hash-order containers anywhere: all twelve map uses
//!   (`util/json`, `config/toml`, `runtime` manifest, `cli`, `bench`)
//!   are `BTreeMap`, ordered by construction.
//! * **R4** — no `.unwrap()`/`.expect()`/`panic!` family in the
//!   non-test hostile-input decode paths (`comm/wire`, `comm/socket`,
//!   `coordinator/checkpoint`). Why: PR 9's CRC layer promises that
//!   hostile bytes surface as *errors*; a panicking decoder breaks
//!   that promise from inside. Fixed-width byte reads go through
//!   [`util::byte_array`].
//! * **R5** — RNG construction only via [`util::rng`]'s seeded
//!   constructors (no `thread_rng`/`OsRng`/`rand::` anywhere), and no
//!   ad-hoc `.sum()`/`.product()` float reductions in fold paths —
//!   reductions go through the blessed fixed-order kernels in
//!   [`tensor`] (`scalar`/`simd`).
//! * **R6** — thread creation only inside `comm/transport.rs`,
//!   `coordinator/pool.rs`, or test code. Why: those two substrates
//!   own the deterministic spawn/join discipline the parity suites
//!   pin.
//!
//! Deliberate exceptions live in `rust/src/analysis/allow.toml`, one
//! `[R#:path]` section per (rule, file) with a mandatory `why =
//! "..."` justification; stale entries fail the audit. To extend it,
//! add the section the audit's own output names and write the reason a
//! reviewer can check. Run `cada audit` locally from the repo root or
//! `rust/`; the auditor's fixtures (`analysis/fixtures/`) and
//! `tests/audit.rs` keep the rules themselves honest. The dynamic
//! twins of this lint — a Miri job over the unsafe/decoder cores and a
//! ThreadSanitizer job over the threaded parity suites — run in CI
//! next to it (see `bench/README.md`'s CI inventory).
//!
//! See `examples/quickstart.rs` for an end-to-end comparison run and
//! [`exp::Experiment`] for the paper-figure presets.

#![deny(unsafe_op_in_unsafe_fn)]

pub mod algorithms;
pub mod analysis;
pub mod bench;
pub mod cli;
pub mod comm;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod runtime;
pub mod telemetry;
pub mod tensor;
pub mod testing;
pub mod util;

/// Convenient glob import for examples and benches.
pub mod prelude {
    pub use crate::algorithms::{
        Algorithm, AlgorithmKind, Cada, CadaCfg, FedAdam, FedAdamCfg,
        FedAvg, LocalMomentum, TrainCfg, Trainer,
    };
    pub use crate::comm::{run_worker, run_worker_opts, CommCfg, CommStats,
                          CostModel, FaultPlan, LinkModel, LinkSet,
                          Participation, ParticipationCfg, SelectPolicy,
                          SocketServer, TransportKind, WireStats,
                          WorkerOpts, WorkerReport};
    pub use crate::compress::{CompressCfg, Payload, Scheme};
    pub use crate::config::Schedule;
    pub use crate::coordinator::checkpoint::CheckpointCfg;
    pub use crate::coordinator::{rules::RuleKind, server::Optimizer};
    pub use crate::coordinator::pool::{ShardExec, ShardPool};
    pub use crate::coordinator::shard::{ShardLayout, ShardStats,
                                        SnapshotBuffers, SnapshotStats};
    pub use crate::data::{Dataset, DatasetKind, Partition, PartitionScheme};
    pub use crate::exp::{Experiment, RunResult};
    pub use crate::runtime::{Compute, Engine, Manifest, SpecEntry};
    pub use crate::util::rng::Rng;
}
