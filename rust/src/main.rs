//! `cada` — launcher CLI for the CADA reproduction.
//!
//! Subcommands:
//!   train         run one experiment preset (or a single algorithm)
//!   serve         run one algorithm as the socket-transport server,
//!                 coordinating M `cada worker` processes over TCP
//!   worker        join a `cada serve` run as one worker process
//!   list          list artifact specs and experiment presets
//!   print-config  show a preset's full configuration (paper Tables 1-4)
//!   inspect       dump manifest details for one spec
//!   bench-check   gate a bench summary against the committed baseline
//!   audit         static determinism-and-safety lint over rust/src/**
//!
//! Examples:
//!   cada train --preset fig3 --iters 500 --runs 1
//!   cada train --preset fig2 --algo cada2 --out results/fig2.jsonl
//!   cada serve --preset fig3 --algo cada2 --listen 127.0.0.1:7700
//!   cada worker --preset fig3 --connect 127.0.0.1:7700
//!   cada list

use cada::cli::Args;
use cada::config;
use cada::exp::Experiment;
use cada::info;
use cada::runtime::Manifest;
use cada::telemetry;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    let cmd = args
        .positional
        .first()
        .map(String::as_str)
        .unwrap_or("help");
    match cmd {
        "train" => cmd_train(&args),
        "serve" => cmd_serve(&args),
        "worker" => cmd_worker(&args),
        "list" => cmd_list(&args),
        "print-config" => cmd_print_config(&args),
        "inspect" => cmd_inspect(&args),
        "bench-check" => cmd_bench_check(&args),
        "audit" => cmd_audit(&args),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => anyhow::bail!("unknown command '{other}'; try `cada help`"),
    }
}

const HELP: &str = r#"cada — Communication-Adaptive Distributed Adam (paper reproduction)

USAGE:
  cada train --preset <fig2|fig3|fig4|fig4_cnn|fig5|fig6|fig7> [options]
  cada serve --preset <name> --algo <name> --listen HOST:PORT [options]
  cada worker --preset <name> --connect HOST:PORT [options]
  cada list [--artifacts DIR]
  cada print-config --preset <name>
  cada inspect --spec <name> [--artifacts DIR]
  cada bench-check [--baseline FILE] [--current FILE]
                   [--max-regress R] [--summary FILE]
                   [--update-baseline]
  cada audit [--root DIR] [--allow FILE]

TRAIN OPTIONS:
  --preset NAME       experiment preset (paper figure)
  --config FILE       TOML overrides: [experiment] iters/n/workers/... and
                      the unified [train] / [train.cost_model] / [comm] /
                      [comm.links] / [compress] sections (iters,
                      eval_every, seed, trace_cap; latency_s, down_bw,
                      asymmetry; transport, semi_sync_k, population,
                      select_s, select_policy, select_seed, churn,
                      min_live, socket_timeout_s, connect_retry_s,
                      jitter_sigma, jitter_seed; per-worker latency_mult /
                      bw_mult / asymmetry_mult arrays; scheme, topk_frac,
                      bits, seed)
  --algo NAME         run only this algorithm from the preset
  --iters N           override iteration count
  --runs N            override Monte-Carlo run count
  --n N               override dataset size
  --workers M         override worker count
  --seed S            override base seed
  --target-loss X     override summary target loss
  --transport T       worker execution engine: inproc (sequential,
                      default), threaded (persistent worker threads) or
                      socket (real TCP across processes; use `cada
                      serve` + `cada worker`)
  --server-shards N   shard the server state into N contiguous parameter
                      ranges updated per shard (default 1;
                      0 = one shard per core; bit-identical always)
  --shard-exec E      multi-shard execution: pool (persistent shard
                      pool, default) or scoped (per-round spawn+join);
                      bit-identical either way
  --semi-sync-k K     server proceeds after the fastest K uploads of a
                      round's selected subset; stragglers fold in stale
                      (0 = wait for all selected)
  --select-s S        per-round participant subset size out of the
                      worker population (0 = everyone, the default)
  --select-policy P   how the subset is drawn: uniform (seeded sample,
                      default) or grouped (by measured worker speed)
  --select-seed N     seed of the selection stream (0 = the run seed)
  --select-population N
                      registered population the socket server admits at
                      handshake (0 = the run's worker count)
  --select-churn      tolerate worker disconnects mid-run: vacated
                      slots fold as skips, late rejoiners catch up
  --select-min-live N churn mode: abort when live workers drop below N
  --select-timeout-s T
                      socket round/handshake timeout in seconds
                      (default 120)
  --select-retry-s T  worker connect-retry budget in seconds
                      (default: the socket timeout)
  --jitter-sigma S    log-normal upload straggler jitter (0 = off)
  --jitter-seed N     seed of the jitter stream
  --compress S        upload compressor: identity (default, bit-identical
                      to the uncompressed paths), topk (magnitude
                      sparsification) or quant (b-bit stochastic
                      quantization); lossy schemes run per-worker error
                      feedback, CADA rules evaluate the decompressed
                      innovation
  --topk-frac F       topk: fraction of coordinates kept, in (0,1]
                      (default 0.05)
  --compress-bits B   quant: bits per coordinate, 2..=8 (default 4)
  --compress-seed N   seed of the stochastic-rounding streams
  --checkpoint DIR    save crash-safe checkpoints into DIR (atomic
                      temp+rename, CRC-checksummed; the newest 2 kept)
  --checkpoint-every N
                      checkpoint cadence in rounds (requires
                      --checkpoint)
  --resume DIR        resume from the newest checkpoint in DIR and keep
                      saving there; the resumed run is bit-identical to
                      an uninterrupted one
  --fault-seed N      seed of the deterministic fault-injection streams
  --fault-drop-p P    probability a worker upload frame is dropped
  --fault-corrupt-p P probability an upload frame is bit-flipped (the
                      CRC framing rejects it server-side)
  --fault-truncate-p P
                      probability an upload frame is cut short mid-write
  --fault-delay-p P / --fault-delay-ms MS
                      probability / duration of injected upload delays
  --fault-kill-workers "R:W,R:W"
                      kill worker W before round R (comma-separated
                      pairs); healing workers rejoin, others stay dead
  --fault-kill-server-at R
                      crash the server before round R (saves a
                      checkpoint first when --checkpoint is set); the
                      only fault knob that also works off-socket
                      (drop/corrupt/truncate/delay/kill-workers need
                      --transport socket)
  --artifacts DIR     artifacts directory (default ./artifacts)
  --out FILE          write curves as JSONL
  --quiet             less logging

SERVE OPTIONS (cada serve; accepts the TRAIN options too):
  --listen HOST:PORT  TCP address the server binds; M worker processes
                      must dial it (`cada worker --connect ...`)
  --algo NAME         required: the one algorithm to run over sockets
                      (server-centric only: adam/cada1/cada2/lag/sgd).
                      A serve run is a single Monte-Carlo run.

WORKER OPTIONS (cada worker):
  --connect HOST:PORT the `cada serve` address to join
  --preset NAME       same preset the server runs (the worker rebuilds
                      the run's dataset locally; batch indices arrive
                      over the wire)
  --n N / --seed S    must match the server's overrides, if any
  --run R             Monte-Carlo run index to regenerate (default 0)
  --rejoin W          reclaim population slot W of a churn-mode run
                      (late-joiner catch-up) instead of a fresh join
  --heal              self-heal: when the connection dies without a
                      shutdown goodbye, reconnect with bounded backoff
                      and rejoin the same slot (survives a server
                      restart under --resume)
  --fault-*           worker-side fault injection (same flags as train;
                      corrupts/truncates this worker's own uploads,
                      dies at scheduled kill rounds)
  --select-timeout-s / --select-retry-s
                      as above; must match the server's run config

BENCH-CHECK OPTIONS (the CI perf-regression gate):
  --baseline FILE     committed baseline (default bench/baseline.json;
                      entries with a null median report but never gate)
  --current FILE      fresh summary from `CADA_BENCH_JSON=... cargo
                      bench` (default BENCH_engine.json)
  --max-regress R     fail when current median > baseline * (1 + R)
                      on any bench (default 0.25)
  --summary FILE      also append the markdown delta table here (CI
                      passes $GITHUB_STEP_SUMMARY)
  --update-baseline   write the current run's medians into the baseline
                      file (arming its seed rows) instead of gating;
                      prints the delta table vs the old baseline first

AUDIT OPTIONS (the CI static-analysis gate; see the "Invariants"
section of the crate docs for rules R1-R6):
  --root DIR          source tree to audit (default: ./src or
                      ./rust/src, whichever holds lib.rs)
  --allow FILE        allowlist TOML (default: the checked-in
                      rust/src/analysis/allow.toml compiled into the
                      binary); every entry is an [R#:path] section
                      with a mandatory why = "..." justification, and
                      stale entries fail the audit
"#;

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let preset = args
        .str_opt("preset")
        .ok_or_else(|| anyhow::anyhow!("--preset required; see `cada help`"))?
        .to_string();
    let mut cfg = config::preset(&preset)?;
    if let Some(path) = args.str_opt("config") {
        let doc = config::toml::parse(&std::fs::read_to_string(path)?)?;
        config::apply_overrides(&mut cfg, &doc)?;
    }
    cfg.iters = args.usize_or("iters", cfg.iters)?;
    cfg.runs = args.u64_or("runs", cfg.runs as u64)? as u32;
    cfg.n = args.usize_or("n", cfg.n)?;
    cfg.workers = args.usize_or("workers", cfg.workers)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.target_loss = args.f64_or("target-loss", cfg.target_loss)?;
    config::apply_comm_cli_overrides(&mut cfg.comm, args)?;
    config::apply_compress_cli_overrides(&mut cfg.compress, args)?;
    config::apply_fault_cli_overrides(&mut cfg.fault, args)?;
    config::apply_checkpoint_cli_overrides(&mut cfg.checkpoint, args)?;
    if let Some(name) = args.str_opt("algo") {
        let name = name.to_string();
        cfg.algos.retain(|a| a.name() == name);
        anyhow::ensure!(!cfg.algos.is_empty(), "no algorithm named '{name}'");
    }
    let artifacts = args.str_or("artifacts", "artifacts");
    let out = args.str_opt("out").map(str::to_string);
    if args.bool("quiet") {
        cada::util::logging::set_level(cada::util::logging::Level::Warn);
    }
    args.reject_unknown()?;

    run_and_report(&cfg, &artifacts, out)
}

/// Shared tail of `cada train` / `cada serve`: load the backend, run
/// every configured algorithm, render the summary table + breakdowns,
/// optionally write the JSONL curves. One source of truth so the two
/// entry points cannot drift.
fn run_and_report(cfg: &cada::config::ExpConfig, artifacts: &str,
                  out: Option<String>) -> anyhow::Result<()> {
    info!("loading backend for spec '{}'", cfg.spec);
    let (spec, mut compute, init) =
        cada::runtime::load_backend(artifacts, &cfg.spec)?;
    info!("backend: {}", compute.backend_name());
    let experiment = Experiment::new(cfg.clone(), spec)?;
    let results = experiment.run_all(&mut *compute, &init)?;
    let rows = experiment.summarize(&results);
    print!(
        "{}",
        telemetry::render_table(&cfg.name, cfg.target_loss, &rows)
    );
    // stragglers only exist under heterogeneous/jittered links, and
    // wire traffic only on the socket transport; both render empty
    // under the uniform in-process default
    print!("{}", cada::exp::render_breakdowns(cfg, &results));
    if let Some(path) = out {
        let curves: Vec<_> = results
            .iter()
            .flat_map(|r| r.curves.iter().cloned())
            .collect();
        telemetry::write_jsonl(&path, &curves)?;
        info!("wrote curves to {path}");
    }
    Ok(())
}

/// Run one algorithm as the socket-transport server: bind `--listen`,
/// wait for the preset's M worker processes, drive the run over TCP.
fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let preset = args
        .str_opt("preset")
        .ok_or_else(|| anyhow::anyhow!("--preset required; see `cada help`"))?
        .to_string();
    let mut cfg = config::preset(&preset)?;
    if let Some(path) = args.str_opt("config") {
        let doc = config::toml::parse(&std::fs::read_to_string(path)?)?;
        config::apply_overrides(&mut cfg, &doc)?;
    }
    cfg.iters = args.usize_or("iters", cfg.iters)?;
    cfg.n = args.usize_or("n", cfg.n)?;
    cfg.workers = args.usize_or("workers", cfg.workers)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.target_loss = args.f64_or("target-loss", cfg.target_loss)?;
    config::apply_comm_cli_overrides(&mut cfg.comm, args)?;
    config::apply_compress_cli_overrides(&mut cfg.compress, args)?;
    config::apply_fault_cli_overrides(&mut cfg.fault, args)?;
    config::apply_checkpoint_cli_overrides(&mut cfg.checkpoint, args)?;
    cfg.comm.transport = cada::comm::TransportKind::Socket;
    anyhow::ensure!(
        !cfg.comm.listen.is_empty(),
        "cada serve needs --listen HOST:PORT (or [comm] listen)"
    );
    // port 0 (ephemeral) is for in-process tests that can read the
    // bound port back; worker processes dial the address printed below
    // VERBATIM, so the CLI needs a concrete port
    anyhow::ensure!(
        !cfg.comm.listen.ends_with(":0"),
        "cada serve cannot use an ephemeral port (--listen {}): worker \
         processes must dial this exact address — pick a concrete port",
        cfg.comm.listen
    );
    // one run only: reconnecting a fresh worker fleet per Monte-Carlo
    // run is a deployment concern, not a training-loop one
    let runs = args.u64_or("runs", 1)?;
    if runs != 1 {
        info!("cada serve drives exactly one Monte-Carlo run; \
               ignoring --runs {runs}");
    }
    cfg.runs = 1;
    let algo = args
        .str_opt("algo")
        .ok_or_else(|| {
            anyhow::anyhow!("cada serve needs --algo (one of the \
                             preset's server-centric algorithms)")
        })?
        .to_string();
    cfg.algos.retain(|a| a.name() == algo);
    anyhow::ensure!(!cfg.algos.is_empty(), "no algorithm named '{algo}'");
    let artifacts = args.str_or("artifacts", "artifacts");
    let out = args.str_opt("out").map(str::to_string);
    if args.bool("quiet") {
        cada::util::logging::set_level(cada::util::logging::Level::Warn);
    }
    args.reject_unknown()?;

    info!(
        "serving '{algo}' on {} — waiting for {} worker process(es) \
         (cada worker --preset {preset} --connect {})",
        cfg.comm.listen, cfg.workers, cfg.comm.listen
    );
    run_and_report(&cfg, &artifacts, out)
}

/// Join a `cada serve` run as one worker process: rebuild the run's
/// dataset locally, dial the server, and answer round headers until it
/// shuts the run down.
fn cmd_worker(args: &Args) -> anyhow::Result<()> {
    let preset = args
        .str_opt("preset")
        .ok_or_else(|| anyhow::anyhow!("--preset required; see `cada help`"))?
        .to_string();
    let mut cfg = config::preset(&preset)?;
    if let Some(path) = args.str_opt("config") {
        let doc = config::toml::parse(&std::fs::read_to_string(path)?)?;
        config::apply_overrides(&mut cfg, &doc)?;
    }
    cfg.n = args.usize_or("n", cfg.n)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    config::apply_comm_cli_overrides(&mut cfg.comm, args)?;
    config::apply_fault_cli_overrides(&mut cfg.fault, args)?;
    anyhow::ensure!(
        !cfg.comm.connect.is_empty(),
        "cada worker needs --connect HOST:PORT (or [comm] connect)"
    );
    let run = args.u64_or("run", 0)? as u32;
    let rejoin = args.str_opt("rejoin").map(str::parse::<u32>).transpose()
        .map_err(|e| anyhow::anyhow!("--rejoin: {e}"))?;
    let heal = args.bool("heal");
    let artifacts = args.str_or("artifacts", "artifacts");
    if args.bool("quiet") {
        cada::util::logging::set_level(cada::util::logging::Level::Warn);
    }
    args.reject_unknown()?;

    let (spec, mut compute, _init) =
        cada::runtime::load_backend(&artifacts, &cfg.spec)?;
    // the same dataset the server samples indices from: preset + run
    // seed pin it exactly (the handshake cross-checks the length)
    let run_seed = cada::exp::run_seed(cfg.seed, run);
    let data = cada::exp::make_dataset(cfg.dataset, &spec, cfg.n, run_seed);
    info!(
        "worker joining {} (preset {preset}, run {run}, {} samples)",
        cfg.comm.connect,
        data.len()
    );
    let opts = cada::comm::WorkerOpts {
        rejoin_slot: rejoin,
        fault: cfg.fault.clone(),
        heal,
        ..cada::comm::WorkerOpts::from_participation(
            &cfg.comm.participation)
    };
    let report = cada::comm::run_worker_opts(
        &cfg.comm.connect, &data, &mut *compute, &opts)?;
    info!(
        "worker {} done: {} rounds, {} uploads",
        report.w, report.rounds, report.uploads
    );
    Ok(())
}

fn cmd_list(args: &Args) -> anyhow::Result<()> {
    let artifacts = args.str_or("artifacts", "artifacts");
    args.reject_unknown()?;
    println!("experiment presets:");
    for p in ["fig2", "fig3", "fig4", "fig4_cnn", "fig5", "fig6", "fig7"] {
        let cfg = config::preset(p)?;
        println!(
            "  {:<10} spec={:<16} workers={:<3} iters={:<6} algos={}",
            p,
            cfg.spec,
            cfg.workers,
            cfg.iters,
            cfg.algos.len()
        );
    }
    match Manifest::load(&artifacts) {
        Ok(m) => {
            println!("\nartifact specs ({}):", m.dir.display());
            for s in &m.specs {
                println!(
                    "  {:<16} kind={:<18} p={:<8} batch={:<4} eval={}",
                    s.name, s.kind, s.p, s.batch, s.eval_batch
                );
            }
        }
        Err(e) => println!("\n(artifacts not available: {e})"),
    }
    Ok(())
}

fn cmd_print_config(args: &Args) -> anyhow::Result<()> {
    let preset = args
        .str_opt("preset")
        .ok_or_else(|| anyhow::anyhow!("--preset required"))?
        .to_string();
    let cfg = config::preset(&preset)?;
    args.reject_unknown()?;
    println!("{cfg:#?}");
    Ok(())
}

fn cmd_bench_check(args: &Args) -> anyhow::Result<()> {
    let baseline_path = args.str_or("baseline", "bench/baseline.json");
    let current_path = args.str_or("current", "BENCH_engine.json");
    let max_regress = args.f64_or("max-regress", 0.25)?;
    let summary = args.str_opt("summary").map(str::to_string);
    let update_baseline = args.bool("update-baseline");
    args.reject_unknown()?;
    anyhow::ensure!(
        max_regress >= 0.0 && max_regress.is_finite(),
        "--max-regress must be finite and >= 0"
    );
    let read = |path: &str| -> anyhow::Result<cada::util::json::Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
        cada::util::json::parse(&text)
            .map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))
    };
    // with --update-baseline a missing/empty baseline is a bootstrap,
    // not an error: the run's medians become the first armed entries
    let base = match read(&baseline_path) {
        Ok(v) => v,
        Err(e) if update_baseline => {
            eprintln!("note: starting a fresh baseline ({e})");
            cada::util::json::Json::Arr(Vec::new())
        }
        Err(e) => return Err(e),
    };
    let cur = read(&current_path)?;
    let deltas = cada::bench::compare_bench_json(&base, &cur)?;
    let table = cada::bench::render_delta_table(&deltas, max_regress);
    print!("{table}");
    if let Some(path) = summary {
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .map_err(|e| anyhow::anyhow!("opening {path}: {e}"))?;
        f.write_all(table.as_bytes())?;
    }
    if update_baseline {
        // report-only: write the run's medians over the baseline's
        // entries (arming seed rows) instead of gating against them
        let (updated, armed) =
            cada::bench::update_baseline(&base, &cur)?;
        std::fs::write(&baseline_path,
                       cada::util::json::render(&updated))
            .map_err(|e| anyhow::anyhow!(
                "writing {baseline_path}: {e}"))?;
        println!(
            "\nbaseline updated: {armed} bench medians written to \
             {baseline_path}"
        );
        return Ok(());
    }
    let missing = cada::bench::missing_armed(&deltas);
    anyhow::ensure!(
        missing.is_empty(),
        "armed baseline benches missing from the current run (renamed or \
         dropped? refresh {baseline_path} in the same PR): {}",
        missing.join(", ")
    );
    let regressed = cada::bench::regressions(&deltas, max_regress);
    anyhow::ensure!(
        regressed.is_empty(),
        "median regression beyond {:.0}% on {} bench(es):\n{}",
        max_regress * 100.0,
        regressed.len(),
        cada::bench::regression_report(&deltas, max_regress)
    );
    println!("\nbench-check ok: {} benches compared, none regressed",
             deltas.len());
    Ok(())
}

fn cmd_inspect(args: &Args) -> anyhow::Result<()> {
    let artifacts = args.str_or("artifacts", "artifacts");
    let spec = args
        .str_opt("spec")
        .ok_or_else(|| anyhow::anyhow!("--spec required"))?
        .to_string();
    args.reject_unknown()?;
    let manifest = Manifest::load(&artifacts)?;
    let s = manifest.spec(&spec)?;
    println!("{s:#?}");
    let init = s.load_init()?;
    let norm: f32 = init.iter().map(|v| v * v).sum::<f32>().sqrt();
    println!("init ||theta|| = {norm}");
    Ok(())
}

fn cmd_audit(args: &Args) -> anyhow::Result<()> {
    let root = args.str_opt("root").map(str::to_string);
    let allow_path = args.str_opt("allow").map(str::to_string);
    args.reject_unknown()?;
    let root = match root {
        Some(dir) => std::path::PathBuf::from(dir),
        None => cada::analysis::default_root()?,
    };
    let allow = match allow_path {
        Some(path) => {
            let text = std::fs::read_to_string(&path)
                .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
            cada::analysis::Allowlist::parse(&text)
                .map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?
        }
        None => cada::analysis::Allowlist::builtin(),
    };
    let report = cada::analysis::audit_tree(&root, &allow)?;
    print!("{}", report.render());
    anyhow::ensure!(
        report.clean(),
        "audit failed: {} finding(s), {} stale allowlist entr{} \
         (root {})",
        report.findings.len(),
        report.stale.len(),
        if report.stale.len() == 1 { "y" } else { "ies" },
        root.display()
    );
    Ok(())
}
