//! Run telemetry: loss-curve records, JSONL/CSV writers, and the
//! paper-style summary tables printed by the benches.

use std::io::Write as _;
use std::path::Path;

use crate::comm::CommStats;
use crate::coordinator::shard::ShardStats;
use crate::util::json::ObjWriter;

/// One evaluation point on a training curve.
#[derive(Clone, Debug)]
pub struct CurvePoint {
    pub iter: u64,
    pub loss: f64,
    pub accuracy: f64,
    /// cumulative uploads / grad evals at this iteration
    pub uploads: u64,
    pub grad_evals: u64,
    pub sim_time_s: f64,
    pub wall_s: f64,
}

/// A labelled training curve (one algorithm, one run).
#[derive(Clone, Debug, Default)]
pub struct Curve {
    pub algo: String,
    pub run: u32,
    pub points: Vec<CurvePoint>,
}

impl Curve {
    pub fn new(algo: &str, run: u32) -> Self {
        Curve {
            algo: algo.to_string(),
            run,
            points: Vec::new(),
        }
    }

    /// First iteration / upload count at which loss <= target (None if
    /// never reached). The paper's headline metric: uploads-to-target.
    pub fn first_reach(&self, target_loss: f64) -> Option<&CurvePoint> {
        self.points.iter().find(|p| p.loss <= target_loss)
    }

    pub fn final_loss(&self) -> f64 {
        self.points.last().map(|p| p.loss).unwrap_or(f64::NAN)
    }

    pub fn best_loss(&self) -> f64 {
        self.points
            .iter()
            .map(|p| p.loss)
            .fold(f64::INFINITY, f64::min)
    }

    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for p in &self.points {
            out.push_str(
                &ObjWriter::new()
                    .str("algo", &self.algo)
                    .int("run", self.run as u64)
                    .int("iter", p.iter)
                    .num("loss", p.loss)
                    .num("acc", p.accuracy)
                    .int("uploads", p.uploads)
                    .int("grad_evals", p.grad_evals)
                    .num("sim_time_s", p.sim_time_s)
                    .num("wall_s", p.wall_s)
                    .finish(),
            );
            out.push('\n');
        }
        out
    }
}

/// Average several runs of the same algorithm point-wise (the paper's
/// "averaged over 10 Monte Carlo runs"). Curves must share eval cadence.
pub fn average_curves(curves: &[Curve]) -> Curve {
    assert!(!curves.is_empty());
    let n = curves[0].points.len();
    assert!(
        curves.iter().all(|c| c.points.len() == n),
        "curves must share eval cadence"
    );
    let mut avg = Curve::new(&curves[0].algo, u32::MAX);
    for i in 0..n {
        let m = curves.len() as f64;
        avg.points.push(CurvePoint {
            iter: curves[0].points[i].iter,
            loss: curves.iter().map(|c| c.points[i].loss).sum::<f64>() / m,
            accuracy: curves.iter().map(|c| c.points[i].accuracy).sum::<f64>()
                / m,
            uploads: (curves.iter().map(|c| c.points[i].uploads).sum::<u64>()
                as f64
                / m) as u64,
            grad_evals: (curves
                .iter()
                .map(|c| c.points[i].grad_evals)
                .sum::<u64>() as f64
                / m) as u64,
            sim_time_s: curves.iter().map(|c| c.points[i].sim_time_s).sum::<f64>()
                / m,
            wall_s: curves.iter().map(|c| c.points[i].wall_s).sum::<f64>() / m,
        });
    }
    avg
}

/// Write curves as JSONL under `results/` (one file per experiment id).
pub fn write_jsonl(path: impl AsRef<Path>, curves: &[Curve])
                   -> anyhow::Result<()> {
    let path = path.as_ref();
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    for c in curves {
        f.write_all(c.to_jsonl().as_bytes())?;
    }
    Ok(())
}

/// The paper-style comparison row: communication/iteration/computation
/// cost for one algorithm to reach a target loss.
#[derive(Clone, Debug)]
pub struct SummaryRow {
    pub algo: String,
    pub reached: bool,
    pub iters: u64,
    pub uploads: u64,
    pub grad_evals: u64,
    pub final_loss: f64,
    pub final_acc: f64,
    pub comm_stats: Option<CommStats>,
}

/// Render the rows as the aligned table the benches print (who wins, by
/// what factor — the shape the paper reports).
pub fn render_table(title: &str, target_loss: f64, rows: &[SummaryRow])
                    -> String {
    let mut out = String::new();
    out.push_str(&format!("\n== {title} (target loss {target_loss}) ==\n"));
    out.push_str(&format!(
        "{:<16} {:>8} {:>10} {:>12} {:>11} {:>10}\n",
        "algorithm", "iters", "uploads", "grad_evals", "final_loss", "final_acc"
    ));
    let best_uploads = rows
        .iter()
        .filter(|r| r.reached)
        .map(|r| r.uploads)
        .min();
    for r in rows {
        let iters = if r.reached {
            format!("{}", r.iters)
        } else {
            "--".to_string()
        };
        let uploads = if r.reached {
            format!("{}", r.uploads)
        } else {
            "--".to_string()
        };
        let marker = match best_uploads {
            Some(b) if r.reached && r.uploads == b => " *",
            _ => "",
        };
        out.push_str(&format!(
            "{:<16} {:>8} {:>10} {:>12} {:>11.4} {:>10.4}{}\n",
            r.algo, iters, uploads, r.grad_evals, r.final_loss, r.final_acc,
            marker
        ));
    }
    out
}

/// Render the per-worker communication/time breakdown of a run: upload
/// counts, cumulative simulated upload seconds and dead-link losses per
/// worker, with the straggler (max upload-seconds worker) marked. The
/// seconds are finite by construction — lost uploads are counted (the
/// transmission happened) but their infinite arrival never accumulates
/// (see [`CommStats::count_upload`]), so this table stays renderable
/// under dead-link scenarios. Under per-round participant selection
/// (or socket churn) the table gains `sel` / `rej` / `rejoin` columns —
/// rounds each worker was selected for, frames the server refused
/// (duplicate or unselected uploads), and mid-run rejoins; full
/// participation keeps the exact old table. Empty string when the run
/// kept no per-worker stats.
pub fn render_worker_breakdown(algo: &str, comm: &CommStats) -> String {
    if comm.worker_uploads.is_empty() {
        return String::new();
    }
    let mut out = String::new();
    out.push_str(&format!(
        "\n-- {algo}: per-worker comm breakdown ({} stale, {} lost \
         uploads) --\n",
        comm.stale_uploads, comm.lost_uploads
    ));
    // the raw-vs-wire columns only appear when some worker's uploads
    // were actually transformed; Identity runs keep the exact old table
    let compressed = comm
        .worker_raw_bytes
        .iter()
        .zip(&comm.worker_wire_bytes)
        .any(|(r, w)| r != w);
    // the selection columns only appear when some round actually left
    // a worker out, or the socket server refused or readmitted frames;
    // full-participation runs keep the exact old table
    let selective = comm
        .worker_selected
        .iter()
        .any(|&c| c != comm.rounds)
        || comm.rejected_uploads > 0
        || comm.rejoins > 0;
    if compressed {
        out.push_str(&format!(
            "{:>8} {:>10} {:>12} {:>8} {:>12} {:>12} {:>7}",
            "worker", "uploads", "upload_s", "lost", "raw_B", "wire_B",
            "ratio"));
    } else {
        out.push_str(&format!(
            "{:>8} {:>10} {:>12} {:>8}",
            "worker", "uploads", "upload_s", "lost"));
    }
    if selective {
        out.push_str(&format!(" {:>8} {:>8} {:>8}",
                              "sel", "rej", "rejoin"));
    }
    out.push('\n');
    let slowest = comm
        .worker_upload_s
        .iter()
        .cloned()
        .fold(0.0, f64::max);
    // only a UNIQUE maximum is a straggler; under homogeneous links all
    // workers tie and marking every row would be noise
    let at_max = comm
        .worker_upload_s
        .iter()
        .filter(|&&s| s == slowest)
        .count();
    for (w, (&n, &s)) in comm
        .worker_uploads
        .iter()
        .zip(&comm.worker_upload_s)
        .enumerate()
    {
        let lost = comm.worker_lost.get(w).copied().unwrap_or(0);
        let marker = if s == slowest && slowest > 0.0 && at_max == 1 {
            "  <- straggler"
        } else {
            ""
        };
        if compressed {
            let raw = comm.worker_raw_bytes.get(w).copied().unwrap_or(0);
            let wire = comm.worker_wire_bytes.get(w).copied().unwrap_or(0);
            let ratio = if wire > 0 {
                format!("{:.1}x", raw as f64 / wire as f64)
            } else {
                "--".to_string()
            };
            out.push_str(&format!(
                "{w:>8} {n:>10} {s:>12.3} {lost:>8} {raw:>12} \
                 {wire:>12} {ratio:>7}"));
        } else {
            out.push_str(&format!(
                "{w:>8} {n:>10} {s:>12.3} {lost:>8}"));
        }
        if selective {
            let sel = comm.worker_selected.get(w).copied().unwrap_or(0);
            let rej = comm.worker_rejected.get(w).copied().unwrap_or(0);
            let rjn = comm.worker_rejoins.get(w).copied().unwrap_or(0);
            out.push_str(&format!(" {sel:>8} {rej:>8} {rjn:>8}"));
        }
        out.push_str(marker);
        out.push('\n');
    }
    out
}

/// Render a socket run's measured wire traffic: the bytes that actually
/// crossed the TCP connections (vs the simulated `upload_bytes`
/// constant), plus how many theta/snapshot ranges the delta-broadcast
/// headers shipped.
pub fn render_wire_stats(algo: &str,
                         wire: &crate::comm::WireStats) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "\n-- {algo}: measured wire traffic ({} rounds) --\n",
        wire.rounds
    ));
    out.push_str(&format!(
        "  sent (broadcast):  {:>12} B  ({} theta ranges, {} B; \
         {} snapshot ranges, {} B)\n",
        wire.bytes_sent,
        wire.theta_ranges_sent,
        wire.theta_range_bytes,
        wire.snapshot_ranges_sent,
        wire.snapshot_range_bytes,
    ));
    out.push_str(&format!(
        "  received (upload): {:>12} B\n", wire.bytes_received));
    // hostile/corrupt frames the CRC framing rejected — only seen under
    // fault injection or a genuinely broken peer, so gate on nonzero
    if wire.frames_corrupt > 0 {
        out.push_str(&format!(
            "  corrupt frames:    {:>12} rejected (CRC/framing)\n",
            wire.frames_corrupt,
        ));
    }
    // measured compression ratio of the upload payloads themselves:
    // dense innovation bytes vs what crossed the socket. Only printed
    // when a lossy compressor actually shrank something — Identity's
    // dense framing is a few bytes LARGER than raw, which is overhead,
    // not compression
    if wire.upload_raw_bytes > wire.upload_wire_bytes
        && wire.upload_wire_bytes > 0
    {
        out.push_str(&format!(
            "  upload payloads:   {:>12} B raw -> {} B on wire \
             ({:.1}x compression)\n",
            wire.upload_raw_bytes,
            wire.upload_wire_bytes,
            wire.upload_raw_bytes as f64 / wire.upload_wire_bytes as f64,
        ));
    }
    // server-side codec wall time (encode headers / decode steps),
    // separate from socket I/O: how much of a round the wire format
    // itself costs. Untouched stats (unit tests, fresh servers) render
    // nothing
    if wire.header_encode_ns > 0 || wire.step_decode_ns > 0 {
        out.push_str(&format!(
            "  codec time:        {:>12.3} ms encode headers, \
             {:.3} ms decode steps\n",
            wire.header_encode_ns as f64 / 1e6,
            wire.step_decode_ns as f64 / 1e6,
        ));
    }
    out
}

/// Render the per-shard server-update timing breakdown of a run: the
/// cumulative fold+step seconds each parameter shard's thread spent,
/// with the hottest shard marked (a skewed table means the block
/// distribution, not the work, is unbalanced). Empty string when the
/// server ran unsharded or never stepped.
pub fn render_shard_breakdown(algo: &str, stats: &ShardStats) -> String {
    if stats.num_shards() <= 1 || stats.rounds == 0 {
        return String::new();
    }
    let total: f64 = stats.shard_s.iter().sum();
    let mut out = String::new();
    out.push_str(&format!(
        "\n-- {algo}: per-shard server update breakdown ({} rounds) --\n",
        stats.rounds
    ));
    out.push_str(&format!("{:>8} {:>12} {:>8}\n", "shard", "busy_s",
                          "share"));
    let hottest = stats.shard_s.iter().cloned().fold(0.0, f64::max);
    let at_max = stats.shard_s.iter().filter(|&&s| s == hottest).count();
    for (s, &busy) in stats.shard_s.iter().enumerate() {
        let share = if total > 0.0 { busy / total * 100.0 } else { 0.0 };
        let marker = if busy == hottest && hottest > 0.0 && at_max == 1 {
            "  <- hottest"
        } else {
            ""
        };
        out.push_str(&format!("{s:>8} {busy:>12.4} {share:>7.1}%{marker}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn curve(losses: &[f64]) -> Curve {
        let mut c = Curve::new("x", 0);
        for (i, &l) in losses.iter().enumerate() {
            c.points.push(CurvePoint {
                iter: i as u64 * 10,
                loss: l,
                accuracy: 0.5,
                uploads: i as u64,
                grad_evals: i as u64 * 2,
                sim_time_s: 0.0,
                wall_s: 0.0,
            });
        }
        c
    }

    #[test]
    fn first_reach_finds_first() {
        let c = curve(&[1.0, 0.5, 0.2, 0.25]);
        let p = c.first_reach(0.3).unwrap();
        assert_eq!(p.iter, 20);
        assert!(c.first_reach(0.1).is_none());
        assert_eq!(c.best_loss(), 0.2);
        assert_eq!(c.final_loss(), 0.25);
    }

    #[test]
    fn average_is_pointwise() {
        let a = curve(&[1.0, 0.4]);
        let b = curve(&[0.0, 0.6]);
        let avg = average_curves(&[a, b]);
        assert_eq!(avg.points[0].loss, 0.5);
        assert_eq!(avg.points[1].loss, 0.5);
    }

    #[test]
    fn jsonl_parses_back() {
        let c = curve(&[0.9]);
        let line = c.to_jsonl();
        let v = crate::util::json::parse(line.trim()).unwrap();
        assert_eq!(v.get("loss").unwrap().as_f64(), Some(0.9));
    }

    #[test]
    fn worker_breakdown_marks_straggler() {
        let mut comm = CommStats::for_workers(3);
        comm.count_upload(0, 100, 1.0);
        comm.count_upload(1, 100, 9.0);
        comm.count_upload(2, 100, 2.0);
        let t = render_worker_breakdown("cada2", &comm);
        let straggler_line =
            t.lines().find(|l| l.contains("straggler")).unwrap();
        assert!(straggler_line.trim_start().starts_with('1'),
                "{straggler_line}");
        // no per-worker stats -> no table
        assert_eq!(render_worker_breakdown("x", &CommStats::default()), "");
        // homogeneous links tie every worker: nobody is THE straggler
        let mut tied = CommStats::for_workers(3);
        for w in 0..3 {
            tied.count_upload(w, 100, 2.0);
        }
        let t = render_worker_breakdown("adam", &tied);
        assert!(!t.contains("straggler"), "{t}");
    }

    #[test]
    fn worker_breakdown_shows_compression_ratio() {
        // uncompressed runs keep the legacy table exactly
        let mut plain = CommStats::for_workers(2);
        plain.count_upload(0, 400, 1.0);
        let t = render_worker_breakdown("cada2", &plain);
        assert!(!t.contains("ratio"), "{t}");
        assert!(!t.contains("wire_B"), "{t}");

        // a sized upload (raw != wire) grows the raw/wire/ratio columns
        let mut comm = CommStats::for_workers(2);
        comm.count_upload_sized(0, 100, 400, 1.0);
        comm.count_upload_sized(0, 100, 400, 1.0);
        comm.count_upload_sized(1, 100, 400, 2.0);
        let t = render_worker_breakdown("cada2", &comm);
        assert!(t.contains("ratio"), "{t}");
        let w0 = t
            .lines()
            .find(|l| l.trim_start().starts_with('0'))
            .unwrap();
        assert!(w0.contains("800"), "{w0}");
        assert!(w0.contains("200"), "{w0}");
        assert!(w0.contains("4.0x"), "{w0}");
    }

    #[test]
    fn worker_breakdown_selection_columns_gate_on_selectivity() {
        // full participation: every worker selected every round keeps
        // the exact legacy table — no sel/rej/rejoin columns
        let mut comm = CommStats::for_workers(2);
        comm.count_selected(&[0, 1]);
        comm.count_upload(0, 100, 1.0);
        let t = render_worker_breakdown("cada2", &comm);
        assert!(!t.contains("rejoin"), "{t}");
        assert!(!t.contains(" sel"), "{t}");

        // a round that leaves worker 1 out grows the selection columns
        let mut comm = CommStats::for_workers(2);
        comm.count_selected(&[0, 1]);
        comm.count_selected(&[0]);
        comm.count_upload(0, 100, 1.0);
        comm.count_rejected(1);
        comm.count_rejoin(1);
        let t = render_worker_breakdown("cada2", &comm);
        assert!(t.contains("sel"), "{t}");
        assert!(t.contains("rejoin"), "{t}");
        let w0 = t
            .lines()
            .find(|l| l.trim_start().starts_with('0'))
            .unwrap();
        // worker 0: selected both rounds, nothing rejected
        assert!(w0.split_whitespace().any(|f| f == "2"), "{w0}");
        let w1 = t
            .lines()
            .find(|l| l.trim_start().starts_with('1'))
            .unwrap();
        // worker 1: selected once, one refused frame, one rejoin
        assert!(w1.split_whitespace().filter(|f| *f == "1").count() >= 3,
                "{w1}");
    }

    #[test]
    fn worker_breakdown_stays_finite_under_dead_links() {
        // worker 1 transmits into a dead link every round: its uploads
        // count, its seconds stay finite (zero here), and the lost
        // column says where the bytes went — the straggler marker goes
        // to the slowest FINITE worker, not to infinity
        let mut comm = CommStats::for_workers(3);
        for _ in 0..4 {
            comm.count_upload(0, 100, 1.0);
            comm.count_upload(1, 100, f64::INFINITY);
            comm.mark_lost(1);
            comm.count_upload(2, 100, 3.0);
        }
        comm.lost_uploads = 4;
        let t = render_worker_breakdown("cada2", &comm);
        assert!(!t.contains("inf"), "{t}");
        assert!(t.contains("lost"), "{t}");
        assert!(t.contains("4 lost"), "{t}");
        let straggler_line =
            t.lines().find(|l| l.contains("straggler")).unwrap();
        assert!(straggler_line.trim_start().starts_with('2'),
                "{straggler_line}");
        let dead_line = t
            .lines()
            .find(|l| l.trim_start().starts_with('1'))
            .unwrap();
        assert!(dead_line.split_whitespace().any(|f| f == "4"),
                "lost count missing: {dead_line}");
    }

    #[test]
    fn wire_stats_render() {
        let wire = crate::comm::WireStats {
            rounds: 60,
            bytes_sent: 123_456,
            bytes_received: 654_321,
            theta_ranges_sent: 300,
            theta_range_bytes: 300 * 4096,
            snapshot_ranges_sent: 15,
            snapshot_range_bytes: 15 * 4096,
            upload_raw_bytes: 0,
            upload_wire_bytes: 0,
            header_encode_ns: 0,
            step_decode_ns: 0,
            steps_rejected: 0,
            rejoins: 0,
        };
        let t = render_wire_stats("cada1", &wire);
        assert!(t.contains("60 rounds"), "{t}");
        assert!(t.contains("123456"), "{t}");
        assert!(t.contains("15 snapshot ranges"), "{t}");
        // no compression -> no payload-ratio line
        assert!(!t.contains("compression"), "{t}");
        // untouched codec timers -> no codec line
        assert!(!t.contains("codec time"), "{t}");

        // measured codec wall time renders in milliseconds
        let timed = crate::comm::WireStats {
            header_encode_ns: 2_500_000,
            step_decode_ns: 750_000,
            ..wire
        };
        let t = render_wire_stats("cada1", &timed);
        assert!(t.contains("codec time"), "{t}");
        assert!(t.contains("2.500 ms encode headers"), "{t}");
        assert!(t.contains("0.750 ms decode steps"), "{t}");

        let compressed = crate::comm::WireStats {
            upload_raw_bytes: 40_000,
            upload_wire_bytes: 8_000,
            ..wire
        };
        let t = render_wire_stats("cada1", &compressed);
        assert!(t.contains("40000"), "{t}");
        assert!(t.contains("8000"), "{t}");
        assert!(t.contains("5.0x compression"), "{t}");

        // identity's dense framing overhead (wire a hair over raw) is
        // not compression and must not render as such
        let identity = crate::comm::WireStats {
            upload_raw_bytes: 40_000,
            upload_wire_bytes: 40_050,
            ..compressed
        };
        let t = render_wire_stats("cada1", &identity);
        assert!(!t.contains("compression"), "{t}");
    }

    #[test]
    fn shard_breakdown_marks_hottest_and_hides_unsharded() {
        let stats = ShardStats {
            shard_s: vec![0.5, 2.0, 0.5],
            rounds: 10,
        };
        let t = render_shard_breakdown("cada2", &stats);
        assert!(t.contains("10 rounds"), "{t}");
        let hot = t.lines().find(|l| l.contains("hottest")).unwrap();
        assert!(hot.trim_start().starts_with('1'), "{hot}");
        // unsharded runs and untouched stats render nothing
        assert_eq!(
            render_shard_breakdown("x", &ShardStats::for_shards(1)), "");
        assert_eq!(
            render_shard_breakdown("x", &ShardStats::for_shards(4)), "");
    }

    #[test]
    fn table_marks_winner() {
        let rows = vec![
            SummaryRow {
                algo: "adam".into(), reached: true, iters: 100,
                uploads: 1000, grad_evals: 1000, final_loss: 0.1,
                final_acc: 0.9, comm_stats: None,
            },
            SummaryRow {
                algo: "cada2".into(), reached: true, iters: 110,
                uploads: 120, grad_evals: 2200, final_loss: 0.1,
                final_acc: 0.9, comm_stats: None,
            },
        ];
        let t = render_table("test", 0.2, &rows);
        assert!(t.contains("cada2"));
        let winner_line =
            t.lines().find(|l| l.contains("cada2")).unwrap();
        assert!(winner_line.ends_with('*'));
    }
}
