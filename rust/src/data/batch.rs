//! In-memory dataset + minibatch assembly matching the AOT input specs.

use crate::util::rng::Rng;

/// One model input array (host side).
#[derive(Clone, Debug)]
pub enum Array {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Array {
    pub fn len(&self) -> usize {
        match self {
            Array::F32(v) => v.len(),
            Array::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A minibatch: arrays + their full shapes (leading dim = batch size).
#[derive(Clone, Debug)]
pub struct Batch {
    pub arrays: Vec<(Array, Vec<usize>)>,
}

impl Batch {
    pub fn batch_size(&self) -> usize {
        self.arrays
            .first()
            .map(|(_, shape)| shape[0])
            .unwrap_or(0)
    }
}

/// A full in-memory dataset.
#[derive(Clone, Debug)]
pub enum Dataset {
    /// (x, y) classification data; `x` row-major `[n, sample_elems]`.
    Labeled {
        x: Vec<f32>,
        /// per-sample shape, e.g. `[54]` or `[28, 28, 1]`
        sample_shape: Vec<usize>,
        y: Vec<i32>,
    },
    /// Token sequences for the LM; each sample is `seq_plus_one` tokens.
    Tokens { t: Vec<i32>, seq_plus_one: usize },
}

impl Dataset {
    pub fn len(&self) -> usize {
        match self {
            Dataset::Labeled { y, .. } => y.len(),
            Dataset::Tokens { t, seq_plus_one } => t.len() / seq_plus_one,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn sample_elems(&self) -> usize {
        match self {
            Dataset::Labeled { sample_shape, .. } => {
                sample_shape.iter().product()
            }
            Dataset::Tokens { seq_plus_one, .. } => *seq_plus_one,
        }
    }

    /// Assemble the batch for `indices` (shape `[indices.len(), ...]`).
    pub fn gather(&self, indices: &[usize]) -> Batch {
        match self {
            Dataset::Labeled { x, sample_shape, y } => {
                let elems = self.sample_elems();
                let mut xb = Vec::with_capacity(indices.len() * elems);
                let mut yb = Vec::with_capacity(indices.len());
                for &i in indices {
                    xb.extend_from_slice(&x[i * elems..(i + 1) * elems]);
                    yb.push(y[i]);
                }
                let mut xshape = vec![indices.len()];
                xshape.extend_from_slice(sample_shape);
                Batch {
                    arrays: vec![
                        (Array::F32(xb), xshape),
                        (Array::I32(yb), vec![indices.len()]),
                    ],
                }
            }
            Dataset::Tokens { t, seq_plus_one } => {
                let mut tb = Vec::with_capacity(indices.len() * seq_plus_one);
                for &i in indices {
                    tb.extend_from_slice(
                        &t[i * seq_plus_one..(i + 1) * seq_plus_one],
                    );
                }
                Batch {
                    arrays: vec![(
                        Array::I32(tb),
                        vec![indices.len(), *seq_plus_one],
                    )],
                }
            }
        }
    }

    /// Order-sensitive FNV-1a over the dataset's exact contents (f32 /
    /// i32 bit patterns plus geometry): the socket handshake's cheap
    /// whole-dataset checksum. Length alone cannot distinguish a worker
    /// regenerated from the wrong seed/run/preset — same `n`, different
    /// samples — which would silently break the transport's bit-parity
    /// contract; the fingerprint fails such a worker at connect time.
    pub fn fingerprint(&self) -> u64 {
        fn eat(mut h: u64, bytes: &[u8]) -> u64 {
            for &b in bytes {
                h = (h ^ u64::from(b)).wrapping_mul(0x100_0000_01b3);
            }
            h
        }
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        match self {
            Dataset::Labeled { x, sample_shape, y } => {
                h = eat(h, &(x.len() as u64).to_le_bytes());
                for s in sample_shape {
                    h = eat(h, &(*s as u64).to_le_bytes());
                }
                for v in x {
                    h = eat(h, &v.to_le_bytes());
                }
                for v in y {
                    h = eat(h, &v.to_le_bytes());
                }
            }
            Dataset::Tokens { t, seq_plus_one } => {
                h = eat(h, &(*seq_plus_one as u64).to_le_bytes());
                for v in t {
                    h = eat(h, &v.to_le_bytes());
                }
            }
        }
        h
    }

    /// The dataset indices one `sample_batch` call would gather
    /// (uniform, with replacement). Split out so the socket transport
    /// can ship indices across processes instead of assembled batches:
    /// `gather(sample_picks(...))` IS `sample_batch(...)` on the same
    /// RNG stream, bit-identically.
    pub fn sample_picks(&self, shard: &[usize], b: usize,
                        rng: &mut Rng) -> Vec<usize> {
        (0..b).map(|_| shard[rng.below(shard.len())]).collect()
    }

    /// Uniform with-replacement minibatch from a shard (index subset).
    pub fn sample_batch(&self, shard: &[usize], b: usize, rng: &mut Rng) -> Batch {
        let picks = self.sample_picks(shard, b, rng);
        self.gather(&picks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        Dataset::Labeled {
            x: (0..12).map(|v| v as f32).collect(), // 6 samples x 2 features
            sample_shape: vec![2],
            y: vec![0, 1, 0, 1, 0, 1],
        }
    }

    #[test]
    fn gather_layout() {
        let b = toy().gather(&[2, 0]);
        match &b.arrays[0] {
            (Array::F32(x), shape) => {
                assert_eq!(shape, &vec![2, 2]);
                assert_eq!(x, &vec![4.0, 5.0, 0.0, 1.0]);
            }
            _ => panic!("wrong array type"),
        }
        match &b.arrays[1] {
            (Array::I32(y), shape) => {
                assert_eq!(shape, &vec![2]);
                assert_eq!(y, &vec![0, 0]);
            }
            _ => panic!("wrong array type"),
        }
    }

    #[test]
    fn tokens_gather() {
        let d = Dataset::Tokens {
            t: (0..20).collect(),
            seq_plus_one: 5,
        };
        assert_eq!(d.len(), 4);
        let b = d.gather(&[3]);
        match &b.arrays[0] {
            (Array::I32(t), shape) => {
                assert_eq!(shape, &vec![1, 5]);
                assert_eq!(t, &vec![15, 16, 17, 18, 19]);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn fingerprint_separates_equal_length_datasets() {
        let a = toy();
        let b = toy();
        assert_eq!(a.fingerprint(), b.fingerprint(), "deterministic");
        // same n, one flipped label: different fingerprint
        let c = Dataset::Labeled {
            x: (0..12).map(|v| v as f32).collect(),
            sample_shape: vec![2],
            y: vec![0, 1, 0, 1, 0, 0],
        };
        assert_ne!(a.fingerprint(), c.fingerprint());
        // same n, one perturbed feature: different fingerprint
        let mut x: Vec<f32> = (0..12).map(|v| v as f32).collect();
        x[7] += 1e-3;
        let d = Dataset::Labeled { x, sample_shape: vec![2],
                                   y: vec![0, 1, 0, 1, 0, 1] };
        assert_ne!(a.fingerprint(), d.fingerprint());
        let t = Dataset::Tokens { t: (0..20).collect(), seq_plus_one: 5 };
        assert_ne!(t.fingerprint(),
                   Dataset::Tokens { t: (0..20).collect(),
                                     seq_plus_one: 4 }
                       .fingerprint());
    }

    #[test]
    fn sample_batch_from_shard_only() {
        let d = toy();
        let shard = vec![1, 3, 5];
        let mut rng = Rng::new(0);
        for _ in 0..20 {
            let b = d.sample_batch(&shard, 4, &mut rng);
            assert_eq!(b.batch_size(), 4);
            if let (Array::I32(y), _) = &b.arrays[1] {
                assert!(y.iter().all(|&v| v == 1)); // shard holds label-1 rows
            }
        }
    }
}
