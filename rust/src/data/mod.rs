//! Synthetic datasets + heterogeneous partitioning + minibatch sampling.
//!
//! The paper's datasets (covtype, ijcnn1, MNIST, CIFAR10) are not
//! available in this offline environment; DESIGN.md section 3 documents the
//! substitution: generators that preserve the property each dataset
//! contributes to the experiment (heterogeneity, class imbalance,
//! multiclass image structure, LM sequence structure).

pub mod batch;
pub mod partition;
pub mod synthetic;

pub use batch::{Array, Batch, Dataset};
pub use partition::{PartitionScheme, Partition};
pub use synthetic::DatasetKind;
