//! Synthetic dataset generators (DESIGN.md section 3 substitutions).
//!
//! Each generator preserves the property its paper counterpart contributes
//! to the experiment:
//!
//! * `covtype_like` — separable-ish binary task with label noise; used with
//!   a size-skewed partition to reproduce the paper's *heterogeneous*
//!   covtype split (M=20 workers, different sample counts).
//! * `ijcnn1_like` — class-imbalanced (~10% positive) binary task, iid.
//! * `mnist_like` / `cifar_like` — Gaussian-mixture image classes with
//!   spatially smooth class means, so convolutions have real structure to
//!   exploit.
//! * `lm_corpus` — token stream from a noisy affine automaton over the
//!   vocabulary: learnable sequence structure for the transformer driver.

use super::batch::Dataset;
use crate::util::rng::Rng;

/// Which synthetic workload to generate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetKind {
    CovtypeLike,
    IjcnnLike,
    MnistLike,
    CifarLike,
    LmCorpus,
}

impl DatasetKind {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "covtype" | "covtype_like" => DatasetKind::CovtypeLike,
            "ijcnn" | "ijcnn_like" | "ijcnn1" => DatasetKind::IjcnnLike,
            "mnist" | "mnist_like" => DatasetKind::MnistLike,
            "cifar" | "cifar_like" | "cifar10" => DatasetKind::CifarLike,
            "lm" | "lm_corpus" => DatasetKind::LmCorpus,
            other => anyhow::bail!("unknown dataset kind: {other}"),
        })
    }
}

/// Binary task: y = 1{x.w* + b* + noise > t}; `positive_rate` picks t.
fn binary_linear(
    n: usize,
    d: usize,
    positive_rate: f64,
    label_noise: f64,
    rng: &mut Rng,
) -> Dataset {
    let w: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut x = Vec::with_capacity(n * d);
    let mut scores = Vec::with_capacity(n);
    for _ in 0..n {
        let mut s = 0.0f32;
        for &wj in &w {
            let xv = rng.normal_f32(0.0, 1.0);
            x.push(xv);
            s += wj * xv;
        }
        scores.push(s + rng.normal_f32(0.0, 0.5));
    }
    // threshold at the (1 - positive_rate) quantile of the scores
    let mut sorted = scores.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let t = sorted[((1.0 - positive_rate) * (n - 1) as f64) as usize];
    let y: Vec<i32> = scores
        .iter()
        .map(|&s| {
            let mut label = (s > t) as i32;
            if rng.f64() < label_noise {
                label = 1 - label;
            }
            label
        })
        .collect();
    Dataset::Labeled {
        x,
        sample_shape: vec![d],
        y,
    }
}

/// covtype stand-in: balanced binary, 54 features, 5% label noise.
pub fn covtype_like(n: usize, seed: u64) -> Dataset {
    binary_linear(n, 54, 0.5, 0.05, &mut Rng::new(seed ^ 0xC0F7))
}

/// ijcnn1 stand-in: 22 features, ~10% positives, 2% label noise.
pub fn ijcnn_like(n: usize, seed: u64) -> Dataset {
    binary_linear(n, 22, 0.1, 0.02, &mut Rng::new(seed ^ 0x17CC))
}

/// Gaussian-mixture image classes. Means are spatially smoothed (box
/// blur passes) so conv layers see real local correlations.
pub fn image_mixture(
    n: usize,
    hw: usize,
    channels: usize,
    classes: usize,
    noise: f32,
    seed: u64,
) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x1A6E);
    let elems = hw * hw * channels;
    // class means
    let mut means = Vec::with_capacity(classes);
    for _ in 0..classes {
        let mut m: Vec<f32> =
            (0..elems).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for _ in 0..2 {
            m = blur(&m, hw, channels);
        }
        // re-normalise contrast after blurring
        let norm = (m.iter().map(|v| v * v).sum::<f32>() / elems as f32)
            .sqrt()
            .max(1e-6);
        for v in &mut m {
            *v /= norm;
        }
        means.push(m);
    }
    let mut x = Vec::with_capacity(n * elems);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let c = rng.below(classes);
        let mean = &means[c];
        for &mv in mean {
            x.push(mv + rng.normal_f32(0.0, noise));
        }
        y.push(c as i32);
    }
    Dataset::Labeled {
        x,
        sample_shape: vec![hw, hw, channels],
        y,
    }
}

/// 3x3 box blur per channel (zero padded), used to give class means
/// spatial smoothness.
fn blur(img: &[f32], hw: usize, channels: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; img.len()];
    let at = |r: isize, c: isize, ch: usize| -> f32 {
        if r < 0 || c < 0 || r >= hw as isize || c >= hw as isize {
            0.0
        } else {
            img[(r as usize * hw + c as usize) * channels + ch]
        }
    };
    for r in 0..hw {
        for c in 0..hw {
            for ch in 0..channels {
                let mut s = 0.0;
                for dr in -1..=1 {
                    for dc in -1..=1 {
                        s += at(r as isize + dr, c as isize + dc, ch);
                    }
                }
                out[(r * hw + c) * channels + ch] = s / 9.0;
            }
        }
    }
    out
}

/// MNIST stand-in: 28x28x1, 10 classes (flattenable for logreg/mlp).
pub fn mnist_like(n: usize, seed: u64) -> Dataset {
    image_mixture(n, 28, 1, 10, 0.7, seed)
}

/// Same distribution flattened to [784] for the mlp/logreg input specs.
pub fn mnist_like_flat(n: usize, seed: u64) -> Dataset {
    match mnist_like(n, seed) {
        Dataset::Labeled { x, y, .. } => Dataset::Labeled {
            x,
            sample_shape: vec![784],
            y,
        },
        _ => unreachable!(),
    }
}

/// CIFAR10 stand-in: 16x16x3, 10 classes, noisier.
pub fn cifar_like(n: usize, seed: u64) -> Dataset {
    image_mixture(n, 16, 3, 10, 1.0, seed)
}

/// Token stream: noisy affine automaton `next = (a*cur + b) mod V` with
/// escape probability, chopped into (seq_len + 1)-token samples.
pub fn lm_corpus(n_samples: usize, seq_len: usize, vocab: usize, seed: u64) -> Dataset {
    let mut rng = Rng::new(seed ^ 0x11AA);
    let a = 31usize;
    let b = 17usize;
    let spo = seq_len + 1;
    let mut t = Vec::with_capacity(n_samples * spo);
    let mut cur = rng.below(vocab);
    for _ in 0..n_samples * spo {
        t.push(cur as i32);
        cur = if rng.f64() < 0.85 {
            (a * cur + b) % vocab
        } else {
            rng.below(vocab)
        };
    }
    Dataset::Tokens {
        t,
        seq_plus_one: spo,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn labels(d: &Dataset) -> &[i32] {
        match d {
            Dataset::Labeled { y, .. } => y,
            _ => panic!(),
        }
    }

    #[test]
    fn covtype_balanced() {
        let d = covtype_like(4000, 1);
        let pos = labels(&d).iter().filter(|&&v| v == 1).count();
        assert!((1400..2600).contains(&pos), "pos={pos}");
        assert_eq!(d.sample_elems(), 54);
    }

    #[test]
    fn ijcnn_imbalanced() {
        let d = ijcnn_like(5000, 2);
        let pos = labels(&d).iter().filter(|&&v| v == 1).count();
        let rate = pos as f64 / 5000.0;
        assert!((0.06..0.18).contains(&rate), "rate={rate}");
    }

    #[test]
    fn generators_deterministic() {
        let a = covtype_like(100, 7);
        let b = covtype_like(100, 7);
        match (&a, &b) {
            (Dataset::Labeled { x: xa, y: ya, .. },
             Dataset::Labeled { x: xb, y: yb, .. }) => {
                assert_eq!(xa, xb);
                assert_eq!(ya, yb);
            }
            _ => panic!(),
        }
        let c = covtype_like(100, 8);
        match (&a, &c) {
            (Dataset::Labeled { x: xa, .. }, Dataset::Labeled { x: xc, .. }) => {
                assert_ne!(xa, xc);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn image_classes_separated() {
        // Mean within-class distance must undercut between-class distance.
        let d = image_mixture(400, 8, 1, 4, 0.5, 3);
        let (x, y) = match &d {
            Dataset::Labeled { x, y, .. } => (x, y),
            _ => panic!(),
        };
        let elems = d.sample_elems();
        let mut centroids = vec![vec![0.0f64; elems]; 4];
        let mut counts = [0usize; 4];
        for (i, &yi) in y.iter().enumerate() {
            counts[yi as usize] += 1;
            for j in 0..elems {
                centroids[yi as usize][j] += x[i * elems + j] as f64;
            }
        }
        for (c, cnt) in centroids.iter_mut().zip(counts) {
            for v in c.iter_mut() {
                *v /= cnt.max(1) as f64;
            }
        }
        let dist = |a: &[f64], b: &[f64]| -> f64 {
            a.iter().zip(b).map(|(p, q)| (p - q) * (p - q)).sum()
        };
        let between = dist(&centroids[0], &centroids[1]);
        assert!(between > 0.1, "between={between}");
    }

    #[test]
    fn lm_corpus_shapes_and_structure() {
        let d = lm_corpus(50, 16, 64, 4);
        assert_eq!(d.len(), 50);
        let t = match &d {
            Dataset::Tokens { t, .. } => t,
            _ => panic!(),
        };
        assert!(t.iter().all(|&v| (0..64).contains(&v)));
        // the automaton must dominate: count transitions following the rule
        let follows = t
            .windows(2)
            .filter(|w| (31 * w[0] as usize + 17) % 64 == w[1] as usize)
            .count();
        assert!(follows * 10 > t.len() * 6, "follows={follows}/{}", t.len());
    }
}
