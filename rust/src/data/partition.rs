//! Dataset partitioning across workers: iid, size-skewed (the paper's
//! heterogeneous covtype split) and Dirichlet label-skew (standard
//! federated-learning heterogeneity).

use super::batch::Dataset;
use crate::util::rng::Rng;

/// How to split `n` samples over `m` workers.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PartitionScheme {
    /// Shuffle, equal-size chunks (the paper's ijcnn1/MNIST setting).
    Uniform,
    /// Random per-worker sizes from a Dirichlet(alpha) over workers (the
    /// paper's covtype setting: "randomly into M=20 workers with different
    /// number of samples per worker"). Every worker keeps >= min_frac of
    /// the fair share so no shard is empty.
    SizeSkew { alpha: f64, min_frac: f64 },
    /// Dirichlet(alpha) label skew: per class, split its samples over
    /// workers with Dirichlet weights (non-iid in distribution, not just
    /// size).
    LabelSkew { alpha: f64 },
}

/// Per-worker index lists into the dataset.
#[derive(Clone, Debug)]
pub struct Partition {
    pub shards: Vec<Vec<usize>>,
}

impl Partition {
    pub fn num_workers(&self) -> usize {
        self.shards.len()
    }

    pub fn total(&self) -> usize {
        self.shards.iter().map(|s| s.len()).sum()
    }

    pub fn build(
        scheme: PartitionScheme,
        data: &Dataset,
        m: usize,
        rng: &mut Rng,
    ) -> Partition {
        assert!(m >= 1);
        let n = data.len();
        assert!(n >= m, "need at least one sample per worker");
        match scheme {
            PartitionScheme::Uniform => {
                let mut idx: Vec<usize> = (0..n).collect();
                rng.shuffle(&mut idx);
                let base = n / m;
                let mut shards = Vec::with_capacity(m);
                let mut cursor = 0;
                for w in 0..m {
                    let extra = usize::from(w < n % m);
                    let take = base + extra;
                    shards.push(idx[cursor..cursor + take].to_vec());
                    cursor += take;
                }
                Partition { shards }
            }
            PartitionScheme::SizeSkew { alpha, min_frac } => {
                let mut idx: Vec<usize> = (0..n).collect();
                rng.shuffle(&mut idx);
                let weights = rng.dirichlet(alpha, m);
                let floor = ((n as f64 / m as f64) * min_frac).max(1.0) as usize;
                // initial allocation by weight, then repair to the floor
                let mut sizes: Vec<usize> =
                    weights.iter().map(|w| (w * n as f64) as usize).collect();
                let mut assigned: usize = sizes.iter().sum();
                // distribute rounding remainder
                let mut w = 0;
                while assigned < n {
                    sizes[w % m] += 1;
                    assigned += 1;
                    w += 1;
                }
                // enforce the floor by taking from the largest shard
                for i in 0..m {
                    while sizes[i] < floor {
                        let big = (0..m)
                            .max_by_key(|&j| sizes[j])
                            .expect("nonempty");
                        assert!(sizes[big] > floor, "cannot satisfy floor");
                        sizes[big] -= 1;
                        sizes[i] += 1;
                    }
                }
                let mut shards = Vec::with_capacity(m);
                let mut cursor = 0;
                for size in sizes {
                    shards.push(idx[cursor..cursor + size].to_vec());
                    cursor += size;
                }
                Partition { shards }
            }
            PartitionScheme::LabelSkew { alpha } => {
                let y = match data {
                    Dataset::Labeled { y, .. } => y,
                    _ => panic!("label skew needs labeled data"),
                };
                let classes =
                    (y.iter().copied().max().unwrap_or(0) + 1) as usize;
                let mut by_class: Vec<Vec<usize>> = vec![Vec::new(); classes];
                for (i, &c) in y.iter().enumerate() {
                    by_class[c as usize].push(i);
                }
                let mut shards: Vec<Vec<usize>> = vec![Vec::new(); m];
                for mut members in by_class {
                    rng.shuffle(&mut members);
                    let weights = rng.dirichlet(alpha, m);
                    let mut cursor = 0;
                    for (w, weight) in weights.iter().enumerate() {
                        let take = if w + 1 == m {
                            members.len() - cursor
                        } else {
                            ((weight * members.len() as f64) as usize)
                                .min(members.len() - cursor)
                        };
                        shards[w].extend_from_slice(
                            &members[cursor..cursor + take],
                        );
                        cursor += take;
                    }
                }
                // repair empty shards (possible under extreme skew)
                for w in 0..m {
                    if shards[w].is_empty() {
                        let big = (0..m)
                            .max_by_key(|&j| shards[j].len())
                            .expect("nonempty");
                        let moved = shards[big].pop().expect("big shard");
                        shards[w].push(moved);
                    }
                }
                Partition { shards }
            }
        }
    }

    /// Size imbalance ratio max/min (1.0 == perfectly balanced).
    pub fn imbalance(&self) -> f64 {
        let max = self.shards.iter().map(Vec::len).max().unwrap_or(0);
        let min = self.shards.iter().map(Vec::len).min().unwrap_or(0);
        max as f64 / min.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;

    fn check_is_partition(p: &Partition, n: usize) {
        let mut all: Vec<usize> =
            p.shards.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..n).collect::<Vec<_>>());
    }

    #[test]
    fn uniform_covers_and_balances() {
        let d = synthetic::covtype_like(103, 0);
        let p = Partition::build(PartitionScheme::Uniform, &d, 10,
                                 &mut Rng::new(1));
        check_is_partition(&p, 103);
        assert!(p.imbalance() <= 11.0 / 10.0 + 1e-9);
    }

    #[test]
    fn size_skew_covers_and_skews() {
        let d = synthetic::covtype_like(2000, 0);
        let p = Partition::build(
            PartitionScheme::SizeSkew { alpha: 0.5, min_frac: 0.2 },
            &d, 20, &mut Rng::new(2));
        check_is_partition(&p, 2000);
        assert!(p.imbalance() > 1.5, "imbalance {}", p.imbalance());
        let floor = (2000.0 / 20.0 * 0.2) as usize;
        assert!(p.shards.iter().all(|s| s.len() >= floor));
    }

    #[test]
    fn label_skew_covers_and_is_noniid() {
        let d = synthetic::mnist_like_flat(1000, 0);
        let p = Partition::build(PartitionScheme::LabelSkew { alpha: 0.3 },
                                 &d, 10, &mut Rng::new(3));
        check_is_partition(&p, 1000);
        assert!(p.shards.iter().all(|s| !s.is_empty()));
        // at least one worker should be visibly class-skewed
        let y = match &d {
            crate::data::Dataset::Labeled { y, .. } => y,
            _ => panic!(),
        };
        let mut max_frac: f64 = 0.0;
        for shard in &p.shards {
            let mut counts = [0usize; 10];
            for &i in shard {
                counts[y[i] as usize] += 1;
            }
            let top = *counts.iter().max().unwrap();
            max_frac = max_frac.max(top as f64 / shard.len() as f64);
        }
        assert!(max_frac > 0.25, "max class fraction {max_frac}");
    }

    #[test]
    fn deterministic_given_rng_seed() {
        let d = synthetic::covtype_like(500, 0);
        let a = Partition::build(PartitionScheme::Uniform, &d, 7,
                                 &mut Rng::new(9));
        let b = Partition::build(PartitionScheme::Uniform, &d, 7,
                                 &mut Rng::new(9));
        assert_eq!(a.shards, b.shards);
    }
}
