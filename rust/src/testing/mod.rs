//! `proptest_lite`: a seeded random-input property harness (crates.io
//! proptest is unavailable offline). Generates many random cases from a
//! deterministic RNG, reports the first failing case with its seed so it
//! can be replayed, and supports simple integer shrinking.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: u32,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 128,
            seed: 0xCADA,
        }
    }
}

/// Run `prop` on `cases` random inputs drawn by `gen`. On failure, panic
/// with the case index + seed (replayable) and a Debug dump of the input.
pub fn check<T, G, P>(cfg: Config, name: &str, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let mut rng = Rng::new(cfg.seed).fork(case as u64);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case} \
                 (replay: seed={:#x}, fork={case})\ninput: {input:?}\n{msg}",
                cfg.seed
            );
        }
    }
}

/// Shrinking helper for usize inputs: find the smallest n in [lo, hi]
/// for which `fails` holds (bisection; assumes monotone-ish failures).
pub fn shrink_usize<F: FnMut(usize) -> bool>(lo: usize, hi: usize,
                                             mut fails: F) -> usize {
    let (mut lo, mut hi) = (lo, hi);
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if fails(mid) {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    lo
}

/// Common generators.
pub mod gen {
    use crate::util::rng::Rng;

    pub fn f32_vec(rng: &mut Rng, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| rng.normal_f32(0.0, scale)).collect()
    }

    pub fn usize_in(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.below(hi - lo + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check(
            Config { cases: 32, ..Config::default() },
            "sum-commutes",
            |rng| (rng.below(100), rng.below(100)),
            |&(a, b)| {
                if a + b == b + a {
                    Ok(())
                } else {
                    Err("math broke".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports() {
        check(
            Config { cases: 4, ..Config::default() },
            "always-fails",
            |rng| rng.below(10),
            |_| Err("nope".into()),
        );
    }

    #[test]
    fn shrink_finds_boundary() {
        // fails for n >= 37
        let n = shrink_usize(0, 100, |n| n >= 37);
        assert_eq!(n, 37);
    }
}
