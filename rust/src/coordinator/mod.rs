//! The paper's L3 contribution: the CADA parameter server, workers with
//! adaptive upload rules, and the round scheduler that drives them.
//!
//! Structure mirrors Algorithm 1 of the paper:
//!
//! * [`rules`]    — the communication rules: CADA1 (Eq. 7), CADA2 (Eq. 10),
//!                  stochastic LAG (Eq. 5), Always (= distributed Adam),
//!                  Periodic, Never.
//! * [`history`]  — the `d_max`-deep ring of ||theta^{k+1-d} - theta^{k-d}||^2
//!                  (the rules' right-hand side).
//! * [`worker`]   — per-worker state: staleness tau_m, stale gradient,
//!                  rule-specific stores (snapshot innovation / old iterate).
//! * [`server`]   — the aggregate-gradient recursion (Eq. 3) and the
//!                  AMSGrad/SGD update (Eq. 2a-2c), native or Pallas-artifact
//!                  backed.
//! * [`scheduler`]— the iteration loop: broadcast, worker checks, uploads,
//!                  server step, metrics, eval.

pub mod history;
pub mod rules;
pub mod scheduler;
pub mod server;
pub mod worker;
