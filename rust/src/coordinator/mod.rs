//! The paper's L3 building blocks: the CADA parameter server and the
//! workers with adaptive upload rules.
//!
//! Structure mirrors Algorithm 1 of the paper:
//!
//! * [`rules`]    — the communication rules: CADA1 (Eq. 7), CADA2 (Eq. 10),
//!                  stochastic LAG (Eq. 5), Always (= distributed Adam),
//!                  Periodic, Never.
//! * [`history`]  — the `d_max`-deep ring of ||theta^{k+1-d} - theta^{k-d}||^2
//!                  (the rules' right-hand side).
//! * [`worker`]   — per-worker state: staleness tau_m, stale gradient,
//!                  rule-specific stores (snapshot innovation / old iterate).
//! * [`server`]   — the aggregate-gradient recursion (Eq. 3) and the
//!                  AMSGrad/SGD update (Eq. 2a-2c), native or Pallas-artifact
//!                  backed.
//!
//! The iteration loop itself lives in [`crate::algorithms`]: the
//! [`Cada`](crate::algorithms::Cada) algorithm composes these pieces into
//! the `broadcast → local_step → aggregate → server_update` lifecycle and
//! the generic [`Trainer`](crate::algorithms::Trainer) drives it.

pub mod history;
pub mod rules;
pub mod server;
pub mod worker;
