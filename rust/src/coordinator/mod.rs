//! The paper's L3 building blocks: the CADA parameter server, the
//! workers with adaptive upload rules, and the server<->worker message
//! protocol of the threaded execution engine.
//!
//! Structure mirrors Algorithm 1 of the paper:
//!
//! * [`rules`]    — the communication rules: CADA1 (Eq. 7), CADA2 (Eq. 10),
//!                  stochastic LAG (Eq. 5), Always (= distributed Adam),
//!                  Periodic, Never.
//! * [`history`]  — the `d_max`-deep ring of ||theta^{k+1-d} - theta^{k-d}||^2
//!                  (the rules' right-hand side).
//! * [`worker`]   — per-worker state: staleness tau_m, stale gradient,
//!                  rule-specific stores (snapshot innovation / old iterate).
//! * [`server`]   — the aggregate-gradient recursion (Eq. 3) and the
//!                  AMSGrad/SGD update (Eq. 2a-2c), native or Pallas-artifact
//!                  backed, sharded by contiguous parameter range.
//! * [`shard`]    — the sharding substrate: block-aligned [`shard::ShardLayout`]
//!                  range partitions, the double-buffered broadcast
//!                  [`shard::SnapshotBuffers`], and per-shard timing stats.
//! * [`pool`]     — the persistent shard pool: one parked thread per
//!                  non-empty shard, spawned once per run, executing the
//!                  server's fold+step rounds spawn-free (the
//!                  [`pool::ShardExec`] knob selects it vs the per-round
//!                  scoped-thread reference; both bit-identical).
//! * [`ToWorker`] / [`FromWorker`] — the mailbox messages the
//!   [`Threaded`](crate::comm::Threaded) transport moves between the
//!   server thread and the persistent worker threads. These carry
//!   closures, so they cannot leave the process; their cross-process
//!   counterpart is the serializable round protocol of
//!   [`crate::comm::wire`], which the TCP
//!   [`socket`](crate::comm::socket) transport speaks between a `cada
//!   serve` server and `cada worker` processes.
//!
//! The iteration loop itself lives in [`crate::algorithms`]: the
//! [`Cada`](crate::algorithms::Cada) algorithm composes these pieces into
//! the `broadcast → worker jobs → aggregate → server_update` lifecycle
//! and the generic [`Trainer`](crate::algorithms::Trainer) drives it over
//! a [`Transport`](crate::comm::Transport).

pub mod checkpoint;
pub mod history;
pub mod pool;
pub mod rules;
pub mod server;
pub mod shard;
pub mod worker;

use crate::comm::transport::{JobOut, WorkerJob};

/// Server -> worker mailbox message (one per round per worker under the
/// threaded transport).
pub enum ToWorker {
    /// Execute one round job on the worker thread's own backend.
    Job(WorkerJob),
    /// Drain the mailbox and exit the worker thread.
    Shutdown,
}

/// Worker -> server completion message: the job's opaque outcome, tagged
/// with the worker id so the event-driven aggregator can re-impose
/// worker order on racy arrivals.
pub struct FromWorker {
    pub w: usize,
    pub outcome: anyhow::Result<JobOut>,
}
