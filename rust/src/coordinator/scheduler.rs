//! The round scheduler: Algorithm 1's outer loop. Broadcast theta^k,
//! run every worker's rule check, fold the uploaded innovations into the
//! server aggregate (Eq. 3), apply the server step (Eq. 2), maintain the
//! drift history and all metrics, and periodically evaluate the model.

use super::history::DeltaHistory;
use super::rules::RuleKind;
use super::server::{Optimizer, ServerState};
use super::worker::WorkerState;
use crate::comm::{CommStats, CostModel, EventTrace, RoundEvent};
use crate::data::{Batch, Dataset, Partition};
use crate::runtime::Compute;
use crate::telemetry::{Curve, CurvePoint};
use crate::util::rng::Rng;

/// Static configuration of one server-centric run.
#[derive(Clone, Debug)]
pub struct LoopCfg {
    pub iters: usize,
    pub eval_every: usize,
    pub rule: RuleKind,
    /// D: max staleness AND (by default) the CADA1 snapshot refresh period
    pub max_delay: u32,
    /// CADA1 snapshot refresh period; 0 means "use max_delay" (the paper
    /// uses one constant D for both roles — this knob exists for ablations
    /// that disable the delay cap without freezing the snapshot)
    pub snapshot_every: u32,
    /// d_max: depth of the drift history ring
    pub d_max: usize,
    /// per-worker minibatch size (must equal the grad artifact's batch)
    pub batch: usize,
    /// route the server step through the Pallas artifact
    pub use_artifact_update: bool,
    /// route innovation norms through the Pallas artifact
    pub use_artifact_innov: bool,
    pub cost_model: CostModel,
    /// keep at most this many round events in the trace
    pub trace_cap: usize,
    /// bytes of one gradient upload (manifest: 4 * p live floats)
    pub upload_bytes: usize,
}

impl LoopCfg {
    pub fn basic(rule: RuleKind, iters: usize, batch: usize) -> Self {
        LoopCfg {
            iters,
            eval_every: 25,
            rule,
            max_delay: 50,
            snapshot_every: 0,
            d_max: 10,
            batch,
            use_artifact_update: false,
            use_artifact_innov: false,
            cost_model: CostModel::free(),
            trace_cap: 0,
            upload_bytes: 0,
        }
    }
}

/// One server-centric training run (CADA1/2, LAG, distributed Adam/SGD).
pub struct ServerLoop<'a> {
    pub cfg: LoopCfg,
    pub server: ServerState,
    pub workers: Vec<WorkerState>,
    pub history: DeltaHistory,
    pub comm: CommStats,
    pub trace: EventTrace,
    data: &'a Dataset,
    partition: &'a Partition,
    eval_batch: Batch,
    /// CADA1 snapshot theta-tilde (refreshed every max_delay iterations)
    snapshot: Vec<f32>,
    rngs: Vec<Rng>,
}

impl<'a> ServerLoop<'a> {
    pub fn new(
        cfg: LoopCfg,
        init_theta: Vec<f32>,
        opt: Optimizer,
        data: &'a Dataset,
        partition: &'a Partition,
        eval_batch: Batch,
        seed: u64,
    ) -> Self {
        let m = partition.num_workers();
        let p = init_theta.len();
        let root = Rng::new(seed);
        let workers = (0..m)
            .map(|w| WorkerState::new(w, p, cfg.rule))
            .collect();
        let rngs = (0..m).map(|w| root.fork(w as u64 + 1)).collect();
        let snapshot = init_theta.clone();
        ServerLoop {
            history: DeltaHistory::new(cfg.d_max),
            trace: EventTrace::new(cfg.trace_cap),
            server: ServerState::new(init_theta, m, opt),
            workers,
            comm: CommStats::default(),
            data,
            partition,
            eval_batch,
            snapshot,
            rngs,
            cfg,
        }
    }

    /// One iteration of Algorithm 1. Returns |M^k| (upload count).
    pub fn step(&mut self, k: u64, compute: &mut dyn Compute)
                -> anyhow::Result<usize> {
        let cfg = &self.cfg;
        // line 4: refresh the CADA1 snapshot every D iterations
        let snap_period = if cfg.snapshot_every > 0 {
            cfg.snapshot_every
        } else {
            cfg.max_delay
        };
        if cfg.rule.needs_snapshot() && k % snap_period as u64 == 0 {
            self.snapshot.copy_from_slice(&self.server.theta);
        }
        // line 3: broadcast theta^k (counted once per worker)
        self.comm.record_broadcast(
            self.workers.len(),
            cfg.upload_bytes,
            &cfg.cost_model,
        );
        let rhs = self.history.rhs(cfg.rule.c());
        let mut uploaded = Vec::new();
        let mut lhs_sum = 0.0f64;
        let mut lhs_count = 0usize;
        for (w, worker) in self.workers.iter_mut().enumerate() {
            let batch = self.data.sample_batch(
                &self.partition.shards[w],
                cfg.batch,
                &mut self.rngs[w],
            );
            let snapshot = cfg
                .rule
                .needs_snapshot()
                .then_some(self.snapshot.as_slice());
            let step = worker.step(
                k,
                cfg.rule,
                cfg.max_delay,
                &self.server.theta,
                snapshot,
                rhs,
                &batch,
                compute,
                cfg.use_artifact_innov,
            )?;
            self.comm.record_grad_evals(step.grad_evals);
            if step.lhs.is_finite() {
                lhs_sum += step.lhs;
                lhs_count += 1;
            }
            if step.decision.upload {
                self.server.apply_innovation(worker.last_delta());
                self.comm
                    .record_upload(cfg.upload_bytes, &cfg.cost_model);
                uploaded.push(w);
            }
        }
        // lines 16-17: server update
        let sq_step = self.server.step(k, compute)?;
        self.history.push(sq_step);
        if self.cfg.trace_cap > 0 {
            let staleness = self.workers.iter().map(|w| w.tau).collect();
            self.trace.push(RoundEvent {
                iter: k,
                uploaded: uploaded.clone(),
                staleness,
                mean_lhs: if lhs_count > 0 {
                    lhs_sum / lhs_count as f64
                } else {
                    f64::NAN
                },
                rhs,
            });
        }
        Ok(uploaded.len())
    }

    /// Evaluate (loss, accuracy) on the held-out eval batch.
    pub fn evaluate(&mut self, compute: &mut dyn Compute)
                    -> anyhow::Result<(f64, f64)> {
        let (loss, correct) =
            compute.eval(&self.server.theta, &self.eval_batch)?;
        let denom = eval_examples(&self.eval_batch) as f64;
        Ok((loss as f64, correct as f64 / denom))
    }

    /// Run the full loop, recording a curve point every `eval_every`
    /// iterations (plus the initial point).
    pub fn run(&mut self, algo_name: &str, run: u32,
               compute: &mut dyn Compute) -> anyhow::Result<Curve> {
        let wall0 = std::time::Instant::now();
        let mut curve = Curve::new(algo_name, run);
        let (loss, acc) = self.evaluate(compute)?;
        curve.points.push(self.point(0, loss, acc, wall0));
        for k in 0..self.cfg.iters as u64 {
            self.step(k, compute)?;
            if (k + 1) % self.cfg.eval_every as u64 == 0 {
                let (loss, acc) = self.evaluate(compute)?;
                curve.points.push(self.point(k + 1, loss, acc, wall0));
            }
        }
        Ok(curve)
    }

    fn point(&self, iter: u64, loss: f64, acc: f64,
             wall0: std::time::Instant) -> CurvePoint {
        CurvePoint {
            iter,
            loss,
            accuracy: acc,
            uploads: self.comm.uploads,
            grad_evals: self.comm.grad_evals,
            sim_time_s: self.comm.sim_time_s,
            wall_s: wall0.elapsed().as_secs_f64(),
        }
    }

    /// Maximum staleness across workers (invariant: <= max_delay).
    pub fn max_staleness(&self) -> u32 {
        self.workers.iter().map(|w| w.tau).max().unwrap_or(0)
    }
}

/// Number of examples in an eval batch (token batches count predicted
/// positions, matching the eval artifact's `correct` semantics).
fn eval_examples(batch: &Batch) -> usize {
    match &batch.arrays[..] {
        [(_, shape)] => shape[0] * (shape[1] - 1), // tokens: B * S targets
        arrays => arrays[0].1[0],                  // labeled: batch dim
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Schedule;
    use crate::data::{synthetic, PartitionScheme};
    use crate::runtime::native::NativeLogReg;

    fn setup(rule: RuleKind, iters: usize)
             -> (NativeLogReg, Dataset, Partition) {
        let compute = NativeLogReg::for_spec(22, 1024);
        let data = synthetic::ijcnn_like(800, 9);
        let mut rng = Rng::new(10);
        let partition =
            Partition::build(PartitionScheme::Uniform, &data, 5, &mut rng);
        let _ = iters;
        (compute, data, partition)
    }

    fn amsgrad(alpha: f32) -> Optimizer {
        Optimizer::Amsgrad {
            alpha: Schedule::Constant(alpha),
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            use_artifact: false,
        }
    }

    #[test]
    fn adam_always_uploads_m_per_iter() {
        let (mut compute, data, partition) = setup(RuleKind::Always, 20);
        let eval = data.gather(&(0..64).collect::<Vec<_>>());
        let mut cfg = LoopCfg::basic(RuleKind::Always, 20, 16);
        cfg.eval_every = 5;
        let mut lp = ServerLoop::new(
            cfg,
            vec![0.0; 1024],
            amsgrad(0.01),
            &data,
            &partition,
            eval,
            7,
        );
        let curve = lp.run("adam", 0, &mut compute).unwrap();
        assert_eq!(lp.comm.uploads, 20 * 5);
        assert_eq!(lp.comm.grad_evals, 20 * 5);
        assert!(curve.final_loss() < curve.points[0].loss,
                "loss should decrease: {curve:?}");
    }

    #[test]
    fn cada2_saves_uploads_and_still_descends() {
        let (mut compute, data, partition) = setup(RuleKind::Always, 0);
        let eval = data.gather(&(0..64).collect::<Vec<_>>());
        let iters = 60;
        let run = |rule: RuleKind, compute: &mut NativeLogReg| {
            let mut cfg = LoopCfg::basic(rule, iters, 16);
            cfg.max_delay = 20;
            let mut lp = ServerLoop::new(
                cfg,
                vec![0.0; 1024],
                amsgrad(0.02),
                &data,
                &partition,
                eval.clone(),
                7,
            );
            let curve = lp.run(rule.name(), 0, compute).unwrap();
            (lp.comm.uploads, curve.final_loss())
        };
        let (adam_up, adam_loss) = run(RuleKind::Always, &mut compute);
        let (cada_up, cada_loss) =
            run(RuleKind::Cada2 { c: 1.2 }, &mut compute);
        assert!(cada_up < adam_up, "cada {cada_up} vs adam {adam_up}");
        assert!(cada_loss < adam_loss * 1.5 + 0.1,
                "cada loss {cada_loss} vs adam {adam_loss}");
    }

    #[test]
    fn staleness_never_exceeds_max_delay() {
        let (mut compute, data, partition) = setup(RuleKind::Never, 0);
        let eval = data.gather(&(0..32).collect::<Vec<_>>());
        let mut cfg = LoopCfg::basic(RuleKind::Never, 30, 8);
        cfg.max_delay = 4;
        let mut lp = ServerLoop::new(cfg, vec![0.0; 1024], amsgrad(0.01),
                                     &data, &partition, eval, 3);
        for k in 0..30 {
            lp.step(k, &mut compute).unwrap();
            assert!(lp.max_staleness() <= 4);
        }
    }

    #[test]
    fn cada_c0_equals_distributed_amsgrad() {
        // c = 0 zeroes the RHS, so any nonzero innovation uploads: CADA
        // degenerates to distributed AMSGrad and must produce (nearly)
        // identical iterates given identical worker RNG streams.
        let (mut compute, data, partition) = setup(RuleKind::Always, 0);
        let eval = data.gather(&(0..32).collect::<Vec<_>>());
        let iters = 25;
        let run_theta = |rule: RuleKind, compute: &mut NativeLogReg| {
            let mut lp = ServerLoop::new(
                LoopCfg::basic(rule, iters, 16),
                vec![0.0; 1024],
                amsgrad(0.01),
                &data,
                &partition,
                eval.clone(),
                42,
            );
            lp.run(rule.name(), 0, compute).unwrap();
            lp.server.theta
        };
        let adam = run_theta(RuleKind::Always, &mut compute);
        let cada = run_theta(RuleKind::Cada2 { c: 0.0 }, &mut compute);
        let diff = crate::tensor::sqnorm_diff(&adam, &cada);
        assert!(diff < 1e-8, "divergence {diff}");
    }

    #[test]
    fn trace_records_upload_sets() {
        let (mut compute, data, partition) = setup(RuleKind::Always, 0);
        let eval = data.gather(&(0..32).collect::<Vec<_>>());
        let mut cfg = LoopCfg::basic(RuleKind::Always, 5, 8);
        cfg.trace_cap = 10;
        let mut lp = ServerLoop::new(cfg, vec![0.0; 1024], amsgrad(0.01),
                                     &data, &partition, eval, 3);
        for k in 0..5 {
            lp.step(k, &mut compute).unwrap();
        }
        assert_eq!(lp.trace.events.len(), 5);
        assert!(lp.trace.events.iter().all(|e| e.uploaded.len() == 5));
    }
}
