//! Sharding of the server's parameter-range state and the
//! double-buffered broadcast snapshots that ride on top of it.
//!
//! The server folds innovations (Eq. 3) and runs the AMSGrad step
//! (Eq. 2a–2c) over flat parameter vectors; both are elementwise, so
//! splitting `theta`/`h`/`vhat`/`grad_agg` by contiguous parameter range
//! lets the update scale across cores while staying bit-identical to the
//! sequential path — every element sees the exact same sequence of
//! float operations whichever shard owns it. The one order-sensitive
//! piece is the squared step norm feeding the drift history: it is
//! reduced per [`SHARD_BLOCK`]-sized block (block boundaries are global,
//! never shard-relative) and the block partials are summed in block
//! order, so the reduction tree is identical for every shard count
//! (enforced by `tests/golden_parity.rs` and the shard-layout property
//! tests).
//!
//! * [`ShardLayout`] — contiguous, block-aligned ranges partitioning
//!   `0..p` exactly (no gap, no overlap, for any `p` and shard count).
//! * [`SnapshotBuffers`] — two reusable broadcast buffers with per-shard
//!   version tracking: `make_step` jobs freeze a round view of theta^k
//!   behind an `Arc` without the per-round full-vector clone; only
//!   ranges dirtied since the buffer last held them are copied.
//! * [`ShardStats`] — per-shard cumulative fold+step seconds, surfaced
//!   by the telemetry breakdown tables.

use std::sync::Arc;

/// Granularity of the step-norm reduction AND the shard boundary
/// alignment. Matches the AOT pipeline's tile size (p_pad is a multiple
/// of 1024), so artifact-sized specs shard into whole tiles.
pub const SHARD_BLOCK: usize = 1024;

/// Contiguous parameter ranges partitioning `0..p` across shards.
///
/// Interior boundaries are multiples of [`SHARD_BLOCK`]; blocks are
/// distributed as evenly as possible (the first `nblocks % shards`
/// shards get one extra). Degenerate sizes stay exact partitions:
/// `p < shards` leaves trailing shards empty, `p = 0` leaves all empty.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardLayout {
    p: usize,
    /// shard `s` owns blocks `block_bounds[s]..block_bounds[s + 1]`
    block_bounds: Vec<usize>,
}

impl ShardLayout {
    pub fn new(p: usize, shards: usize) -> Self {
        let shards = shards.max(1);
        let nblocks = p.div_ceil(SHARD_BLOCK);
        let q = nblocks / shards;
        let r = nblocks % shards;
        let mut block_bounds = Vec::with_capacity(shards + 1);
        let mut acc = 0usize;
        block_bounds.push(0);
        for s in 0..shards {
            acc += q + usize::from(s < r);
            block_bounds.push(acc);
        }
        ShardLayout { p, block_bounds }
    }

    /// The unsharded layout: one range covering `0..p`.
    pub fn single(p: usize) -> Self {
        Self::new(p, 1)
    }

    pub fn p(&self) -> usize {
        self.p
    }

    pub fn num_shards(&self) -> usize {
        self.block_bounds.len() - 1
    }

    /// Total number of [`SHARD_BLOCK`]-sized reduction blocks.
    pub fn num_blocks(&self) -> usize {
        *self.block_bounds.last().expect("bounds never empty")
    }

    /// Element range of shard `s` (empty for surplus shards).
    pub fn range(&self, s: usize) -> std::ops::Range<usize> {
        let lo = (self.block_bounds[s] * SHARD_BLOCK).min(self.p);
        let hi = (self.block_bounds[s + 1] * SHARD_BLOCK).min(self.p);
        lo..hi
    }

    /// Reduction-block range of shard `s`.
    pub fn block_range(&self, s: usize) -> std::ops::Range<usize> {
        self.block_bounds[s]..self.block_bounds[s + 1]
    }

    /// Iterate the element ranges in shard order.
    pub fn ranges(&self) -> impl Iterator<Item = std::ops::Range<usize>> + '_ {
        (0..self.num_shards()).map(|s| self.range(s))
    }
}

/// Counters of the double-buffered broadcast path (telemetry).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SnapshotStats {
    /// freezes that fell back to a fresh full-vector allocation (buffer
    /// still referenced by in-flight jobs, or first use)
    pub full_clones: u64,
    /// shard ranges copied because their version moved on
    pub ranges_copied: u64,
    /// shard ranges the buffer already held at the current version
    pub ranges_reused: u64,
}

/// Two reusable broadcast buffers with per-shard version tracking.
///
/// Each round the algorithm freezes a read-only view of the server's
/// `theta` (and, for CADA1, the snapshot) behind an `Arc` for the worker
/// jobs. Cloning the full vector every round is O(p) allocation +
/// copy; instead `freeze` alternates between two buffers — the round-k
/// jobs may still hold the other one — and, when the target buffer is
/// exclusively owned, copies only the shard ranges whose version counter
/// moved since the buffer last held them. Versions are bumped by the
/// server per shard per update, so an unchanged range (e.g. the CADA1
/// snapshot between refreshes) costs nothing to re-freeze.
pub struct SnapshotBuffers {
    bufs: [Arc<Vec<f32>>; 2],
    /// per-shard version each buffer holds (empty = never filled)
    held: [Vec<u64>; 2],
    active: usize,
    stats: SnapshotStats,
}

impl Default for SnapshotBuffers {
    fn default() -> Self {
        Self::new()
    }
}

impl SnapshotBuffers {
    pub fn new() -> Self {
        SnapshotBuffers {
            bufs: [Arc::new(Vec::new()), Arc::new(Vec::new())],
            held: [Vec::new(), Vec::new()],
            active: 0,
            stats: SnapshotStats::default(),
        }
    }

    pub fn stats(&self) -> SnapshotStats {
        self.stats
    }

    /// Freeze a round view of `src`: returns an `Arc` whose contents
    /// equal `src`, copying only shard ranges whose `versions[s]` differs
    /// from what the target buffer last held. Falls back to a full clone
    /// when the buffer is still referenced elsewhere or sizes changed.
    pub fn freeze(&mut self, src: &[f32], layout: &ShardLayout,
                  versions: &[u64]) -> Arc<Vec<f32>> {
        debug_assert_eq!(layout.num_shards(), versions.len());
        debug_assert_eq!(layout.p(), src.len());
        self.active ^= 1;
        let slot = self.active;
        let reused = match Arc::get_mut(&mut self.bufs[slot]) {
            Some(buf)
                if buf.len() == src.len()
                    && self.held[slot].len() == versions.len() =>
            {
                for (s, r) in layout.ranges().enumerate() {
                    if self.held[slot][s] == versions[s] {
                        self.stats.ranges_reused += 1;
                    } else {
                        buf[r.clone()].copy_from_slice(&src[r]);
                        self.held[slot][s] = versions[s];
                        self.stats.ranges_copied += 1;
                    }
                }
                true
            }
            _ => false,
        };
        if !reused {
            self.bufs[slot] = Arc::new(src.to_vec());
            self.held[slot] = versions.to_vec();
            self.stats.full_clones += 1;
        }
        Arc::clone(&self.bufs[slot])
    }
}

/// Per-shard timing of the server's fold+step work (cumulative over a
/// run; `shard_s[s]` is the wall seconds shard `s`'s slice spent in
/// innovation folds + the optimizer step + the step-norm blocks).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ShardStats {
    pub shard_s: Vec<f64>,
    pub rounds: u64,
}

impl ShardStats {
    pub fn for_shards(n: usize) -> Self {
        ShardStats { shard_s: vec![0.0; n], rounds: 0 }
    }

    pub fn num_shards(&self) -> usize {
        self.shard_s.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_partitions(p: usize, shards: usize) {
        let layout = ShardLayout::new(p, shards);
        assert_eq!(layout.num_shards(), shards.max(1), "p={p} shards={shards}");
        let mut next = 0usize;
        for s in 0..layout.num_shards() {
            let r = layout.range(s);
            assert_eq!(r.start, next,
                       "gap/overlap at shard {s} (p={p} shards={shards})");
            assert!(r.end >= r.start);
            next = r.end;
        }
        assert_eq!(next, p, "ranges must cover 0..{p} exactly");
        // block ranges partition 0..num_blocks the same way
        let mut bnext = 0usize;
        for s in 0..layout.num_shards() {
            let b = layout.block_range(s);
            assert_eq!(b.start, bnext);
            bnext = b.end;
        }
        assert_eq!(bnext, layout.num_blocks());
    }

    #[test]
    fn layout_partitions_awkward_sizes_exactly() {
        // p = 0, p < shards, p % shards != 0, p smaller/larger than a
        // block, and block-aligned p
        for &p in &[0usize, 1, 3, 7, 1023, 1024, 1025, 4096, 5000, 102_400] {
            for shards in 1..=9 {
                assert_partitions(p, shards);
            }
        }
        assert_partitions(2_739_200, 16);
    }

    #[test]
    fn layout_zero_shards_clamps_to_one() {
        let layout = ShardLayout::new(100, 0);
        assert_eq!(layout.num_shards(), 1);
        assert_eq!(layout.range(0), 0..100);
    }

    #[test]
    fn interior_boundaries_are_block_aligned() {
        let layout = ShardLayout::new(10_000, 3);
        for s in 0..layout.num_shards() {
            let r = layout.range(s);
            if r.end != layout.p() {
                assert_eq!(r.end % SHARD_BLOCK, 0, "shard {s}: {r:?}");
            }
        }
    }

    #[test]
    fn surplus_shards_are_empty_not_overlapping() {
        // p = 100 fits one block; shards 2.. get empty ranges
        let layout = ShardLayout::new(100, 4);
        assert_eq!(layout.range(0), 0..100);
        for s in 1..4 {
            assert!(layout.range(s).is_empty(), "shard {s}");
            assert_eq!(layout.range(s).start, 100);
        }
    }

    #[test]
    fn freeze_returns_src_contents_and_reuses_buffers() {
        let p = 3000;
        let layout = ShardLayout::new(p, 3);
        let mut src: Vec<f32> = (0..p).map(|i| i as f32).collect();
        let mut versions = vec![0u64; layout.num_shards()];
        let mut bufs = SnapshotBuffers::new();

        let a = bufs.freeze(&src, &layout, &versions);
        assert_eq!(a.as_slice(), src.as_slice());
        assert_eq!(bufs.stats().full_clones, 1);

        // second round: other slot, first use -> second full clone
        let b = bufs.freeze(&src, &layout, &versions);
        assert_eq!(b.as_slice(), src.as_slice());
        assert_eq!(bufs.stats().full_clones, 2);

        // drop the round-0 view; round 2 reuses slot 0 without cloning
        drop(a);
        src[1024] = -7.0;
        versions[1] += 1;
        let c = bufs.freeze(&src, &layout, &versions);
        assert_eq!(c.as_slice(), src.as_slice());
        let stats = bufs.stats();
        assert_eq!(stats.full_clones, 2, "no new allocation");
        assert_eq!(stats.ranges_copied, 1, "only the dirtied shard copies");
        assert_eq!(stats.ranges_reused, 2);

        // an outstanding reference to the target buffer forces the safe
        // full-clone fallback: the next freeze flips back to b's slot
        let _hold = b;
        drop(c);
        src[0] = 42.0;
        versions[0] += 1;
        let d = bufs.freeze(&src, &layout, &versions);
        assert_eq!(d.as_slice(), src.as_slice());
        assert_eq!(bufs.stats().full_clones, 3);
    }

    #[test]
    fn freeze_detects_stale_ranges_across_both_buffers() {
        // a shard dirtied every round must be re-copied in BOTH buffers
        // (each lags by two versions in steady state)
        let p = 2048;
        let layout = ShardLayout::new(p, 2);
        let mut src = vec![0.0f32; p];
        let mut versions = vec![0u64; 2];
        let mut bufs = SnapshotBuffers::new();
        let mut last: Option<Arc<Vec<f32>>> = None;
        for round in 0..6 {
            src[2047] = round as f32;
            versions[1] += 1;
            let view = bufs.freeze(&src, &layout, &versions);
            assert_eq!(view[2047], round as f32, "round {round}");
            assert_eq!(view.as_slice(), src.as_slice());
            last = Some(view); // hold one round view, like the algorithm
        }
        drop(last);
        // steady state: two initial clones, then range copies only
        assert_eq!(bufs.stats().full_clones, 2);
    }
}
