//! Communication rules: when may a worker SKIP uploading its gradient?
//!
//! A rule decides, per worker per iteration, whether the stale gradient
//! the server already holds is still informative enough. All adaptive
//! rules compare a squared innovation norm (LHS) against the shared
//! parameter-drift term RHS = (c/d_max) * sum_d ||theta^{k+1-d} -
//! theta^{k-d}||^2 from [`super::history::DeltaHistory`]:
//!
//! * `Lag`   (Eq. 5):  ||g(theta^k; xi^k) - g(theta^{k-tau}; xi^{k-tau})||^2
//!   — evaluated on DIFFERENT samples, so its LHS floors at the gradient
//!   variance and never vanishes (paper section 2.1): LAG stops saving.
//! * `Cada1` (Eq. 7):  ||dtilde^k - dtilde^{k-tau}||^2 where dtilde^k =
//!   g(theta^k; xi^k) - g(snapshot; xi^k) — a variance-reduced innovation
//!   (both grads share the sample xi^k; the snapshot refreshes every D).
//! * `Cada2` (Eq. 10): ||g(theta^k; xi^k) - g(theta^{k-tau}; xi^k)||^2 —
//!   two iterates, SAME sample, again variance-reduced.
//!
//! `Always` (every worker uploads, = distributed Adam/SGD), `Periodic`
//! and `Never` complete the baseline space.

/// Rule selecting the upload set M^k.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RuleKind {
    /// Fresh upload every iteration (distributed Adam / SGD).
    Always,
    /// CADA1 snapshot rule (Eq. 7).
    Cada1 { c: f32 },
    /// CADA2 same-sample rule (Eq. 10).
    Cada2 { c: f32 },
    /// Direct stochastic LAG (Eq. 5).
    Lag { c: f32 },
    /// Upload iff k % h == 0 (non-adaptive periodic skipping).
    Periodic { h: u32 },
    /// Only the max-delay refresh uploads (ablation lower bound).
    Never,
}

impl RuleKind {
    /// Threshold constant `c` (0 for non-adaptive rules).
    pub fn c(&self) -> f32 {
        match *self {
            RuleKind::Cada1 { c } | RuleKind::Cada2 { c }
            | RuleKind::Lag { c } => c,
            _ => 0.0,
        }
    }

    /// Stochastic-gradient evaluations a worker spends per iteration
    /// under this rule (the paper's "computational complexity" axis:
    /// CADA doubles the per-iteration gradient cost).
    pub fn grad_evals_per_iter(&self) -> u64 {
        match self {
            RuleKind::Cada1 { .. } | RuleKind::Cada2 { .. } => 2,
            _ => 1,
        }
    }

    /// Does this rule need the server-maintained snapshot theta-tilde?
    pub fn needs_snapshot(&self) -> bool {
        matches!(self, RuleKind::Cada1 { .. })
    }

    /// Does this rule need the worker to remember its last-upload iterate?
    pub fn needs_stored_iterate(&self) -> bool {
        matches!(self, RuleKind::Cada2 { .. })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RuleKind::Always => "always",
            RuleKind::Cada1 { .. } => "cada1",
            RuleKind::Cada2 { .. } => "cada2",
            RuleKind::Lag { .. } => "lag",
            RuleKind::Periodic { .. } => "periodic",
            RuleKind::Never => "never",
        }
    }
}

/// Skip decision for one worker at one iteration, given the rule LHS
/// (innovation sq-norm, already computed by the worker) and the history
/// RHS. Uploads are forced when staleness hits `max_delay` (Algorithm 1
/// line 10: tau_m >= D) and on the very first iteration.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Decision {
    pub upload: bool,
    /// whether the adaptive condition (as opposed to the delay cap or
    /// periodic schedule) triggered the upload — telemetry only
    pub rule_triggered: bool,
}

pub fn decide(rule: RuleKind, k: u64, lhs: f64, rhs: f64, tau: u32,
              max_delay: u32) -> Decision {
    if k == 0 || tau >= max_delay {
        return Decision { upload: true, rule_triggered: false };
    }
    match rule {
        RuleKind::Always => Decision { upload: true, rule_triggered: true },
        RuleKind::Never => Decision { upload: false, rule_triggered: false },
        RuleKind::Periodic { h } => Decision {
            upload: k % h as u64 == 0,
            rule_triggered: false,
        },
        RuleKind::Cada1 { .. } | RuleKind::Cada2 { .. }
        | RuleKind::Lag { .. } => {
            let upload = lhs > rhs;
            Decision { upload, rule_triggered: upload }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_iteration_always_uploads() {
        for rule in [RuleKind::Never, RuleKind::Cada2 { c: 1.0 },
                     RuleKind::Periodic { h: 7 }] {
            assert!(decide(rule, 0, 0.0, 1e9, 0, 100).upload, "{rule:?}");
        }
    }

    #[test]
    fn max_delay_forces_upload() {
        let d = decide(RuleKind::Never, 5, 0.0, 1e9, 50, 50);
        assert!(d.upload);
        assert!(!d.rule_triggered);
    }

    #[test]
    fn adaptive_rules_compare_lhs_rhs() {
        let r = RuleKind::Cada2 { c: 0.5 };
        assert!(decide(r, 3, 2.0, 1.0, 1, 100).upload);
        assert!(!decide(r, 3, 0.5, 1.0, 1, 100).upload);
        // c = 0 makes RHS 0 -> any positive innovation uploads
        assert!(decide(RuleKind::Cada1 { c: 0.0 }, 3, 1e-20, 0.0, 1, 100)
                .upload);
    }

    #[test]
    fn periodic_schedule() {
        let r = RuleKind::Periodic { h: 4 };
        assert!(decide(r, 4, 0.0, 0.0, 1, 100).upload);
        assert!(!decide(r, 5, 0.0, 0.0, 1, 100).upload);
        assert!(decide(r, 8, 0.0, 0.0, 1, 100).upload);
    }

    #[test]
    fn metadata() {
        assert_eq!(RuleKind::Cada1 { c: 0.3 }.grad_evals_per_iter(), 2);
        assert_eq!(RuleKind::Lag { c: 0.3 }.grad_evals_per_iter(), 1);
        assert!(RuleKind::Cada1 { c: 0.3 }.needs_snapshot());
        assert!(RuleKind::Cada2 { c: 0.3 }.needs_stored_iterate());
        assert!(!RuleKind::Lag { c: 0.3 }.needs_snapshot());
        assert_eq!(RuleKind::Always.c(), 0.0);
        assert_eq!(RuleKind::Cada2 { c: 0.7 }.c(), 0.7);
    }
}
