//! Crash-safe training checkpoints: an atomic, CRC-guarded container
//! plus the tiny binary codec the trainer and the algorithms share.
//!
//! A checkpoint is one file per save point, `ckpt_{next_k:08}.bin`,
//! laid out as
//!
//! ```text
//! [u64 LE magic][u32 LE version][u32 LE crc32(body)][body]
//! ```
//!
//! and written atomically: the bytes land in a `.tmp` sibling first and
//! are `rename`d into place, so a crash mid-save leaves either the old
//! file set or the new one — never a torn checkpoint. [`load`] verifies
//! magic, version, and CRC before handing the body back, so a truncated
//! or bit-flipped file is a clean error, not garbage state.
//!
//! The body itself is assembled by the trainer (run id, round cursor,
//! config fingerprint, RNG states, comm counters) around an opaque
//! algorithm blob produced by
//! [`Algorithm::export_state`](crate::algorithms::Algorithm::export_state).
//! Everything is little-endian and versioned through the container
//! header; the codec below ([`Dec`] and the `put_*` helpers) is the
//! only sanctioned way to read or write body bytes.

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use crate::comm::CommStats;
use crate::util::crc::crc32;
use crate::util::rng::RngState;

/// `b"CADACKPT"` as a little-endian u64.
pub const MAGIC: u64 = u64::from_le_bytes(*b"CADACKPT");

/// Container format version; bump on any body layout change.
pub const VERSION: u32 = 1;

/// Bytes before the body: magic + version + CRC.
pub const HEADER: usize = 8 + 4 + 4;

/// Checkpoints kept per directory after a save ([`prune`] removes the
/// rest, oldest first): the one just written plus its predecessor, so
/// a crash *during* a save can never leave zero loadable files.
pub const KEEP: usize = 2;

/// Checkpoint/resume knobs, carried by the trainer config.
#[derive(Clone, Debug, PartialEq, Default)]
pub struct CheckpointCfg {
    /// directory checkpoints are written into; empty = never save
    pub dir: String,
    /// save every N completed rounds; 0 = only at scheduled server
    /// kills (see `[fault] kill_server_at`)
    pub every: u64,
    /// directory to resume the run from (usually `dir`); empty =
    /// fresh start
    pub resume: String,
}

impl CheckpointCfg {
    /// True when checkpointing is fully disabled (the default).
    pub fn is_none(&self) -> bool {
        self.dir.is_empty() && self.every == 0 && self.resume.is_empty()
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.every == 0 || !self.dir.is_empty(),
            "checkpoint every = {} needs a checkpoint dir",
            self.every
        );
        Ok(())
    }
}

/// FNV-1a 64 — the config fingerprint stored in checkpoint bodies so a
/// resume against a different run config fails fast instead of folding
/// mismatched state.
pub fn fnv64(bytes: &[u8]) -> u64 {
    bytes.iter().fold(0xcbf2_9ce4_8422_2325u64, |h, &b| {
        (h ^ b as u64).wrapping_mul(0x100_0000_01b3)
    })
}

fn file_name(next_k: u64) -> String {
    format!("ckpt_{next_k:08}.bin")
}

/// Atomically persist `body` as the checkpoint that resumes at round
/// `next_k`. Creates `dir` if needed; returns the final path.
pub fn save(dir: &Path, next_k: u64, body: &[u8])
            -> anyhow::Result<PathBuf> {
    fs::create_dir_all(dir).map_err(|e| {
        anyhow::anyhow!("creating checkpoint dir {}: {e}", dir.display())
    })?;
    let final_path = dir.join(file_name(next_k));
    let tmp_path = dir.join(format!("{}.tmp", file_name(next_k)));
    let mut framed = Vec::with_capacity(HEADER + body.len());
    framed.extend_from_slice(&MAGIC.to_le_bytes());
    framed.extend_from_slice(&VERSION.to_le_bytes());
    framed.extend_from_slice(&crc32(body).to_le_bytes());
    framed.extend_from_slice(body);
    {
        let mut f = fs::File::create(&tmp_path).map_err(|e| {
            anyhow::anyhow!("creating {}: {e}", tmp_path.display())
        })?;
        f.write_all(&framed)?;
        f.sync_all()?;
    }
    fs::rename(&tmp_path, &final_path).map_err(|e| {
        anyhow::anyhow!("publishing {}: {e}", final_path.display())
    })?;
    Ok(final_path)
}

/// Load and verify a checkpoint file, returning its body bytes.
pub fn load(path: &Path) -> anyhow::Result<Vec<u8>> {
    let framed = fs::read(path).map_err(|e| {
        anyhow::anyhow!("reading checkpoint {}: {e}", path.display())
    })?;
    anyhow::ensure!(
        framed.len() >= HEADER,
        "checkpoint {} is {} bytes — shorter than its {HEADER}-byte \
         header",
        path.display(),
        framed.len()
    );
    let magic = u64::from_le_bytes(crate::util::byte_array(&framed[0..8])?);
    anyhow::ensure!(
        magic == MAGIC,
        "checkpoint {} has magic {magic:#018x}, want {MAGIC:#018x} — \
         not a checkpoint file",
        path.display()
    );
    let version =
        u32::from_le_bytes(crate::util::byte_array(&framed[8..12])?);
    anyhow::ensure!(
        version == VERSION,
        "checkpoint {} is format v{version}, this build reads \
         v{VERSION}",
        path.display()
    );
    let want =
        u32::from_le_bytes(crate::util::byte_array(&framed[12..16])?);
    let body = framed[HEADER..].to_vec();
    let got = crc32(&body);
    anyhow::ensure!(
        got == want,
        "checkpoint {} failed its CRC (stored {want:#010x}, computed \
         {got:#010x}) — truncated or corrupted on disk",
        path.display()
    );
    Ok(body)
}

/// The newest checkpoint in `dir`: `(next_k, path)` with the largest
/// round cursor, or `None` when the directory holds no checkpoints
/// (or does not exist).
pub fn latest(dir: &Path) -> anyhow::Result<Option<(u64, PathBuf)>> {
    let entries = match fs::read_dir(dir) {
        Ok(entries) => entries,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(None)
        }
        Err(e) => anyhow::bail!(
            "listing checkpoint dir {}: {e}", dir.display()),
    };
    let mut best: Option<(u64, PathBuf)> = None;
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        let Some(k) = parse_name(&name.to_string_lossy()) else {
            continue;
        };
        if best.as_ref().map_or(true, |(bk, _)| k > *bk) {
            best = Some((k, entry.path()));
        }
    }
    Ok(best)
}

/// Delete all but the newest `keep` checkpoints in `dir`. Stale `.tmp`
/// leftovers from an interrupted save are removed too. Best-effort: a
/// file that refuses to delete is skipped, never an error.
pub fn prune(dir: &Path, keep: usize) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    let mut ckpts: Vec<(u64, PathBuf)> = Vec::new();
    for entry in entries.flatten() {
        let name = entry.file_name();
        let name = name.to_string_lossy();
        if name.ends_with(".tmp") {
            let _ = fs::remove_file(entry.path());
        } else if let Some(k) = parse_name(&name) {
            ckpts.push((k, entry.path()));
        }
    }
    if ckpts.len() <= keep {
        return;
    }
    ckpts.sort_by_key(|(k, _)| *k);
    let doomed = ckpts.len() - keep;
    for (_, path) in ckpts.into_iter().take(doomed) {
        let _ = fs::remove_file(path);
    }
}

fn parse_name(name: &str) -> Option<u64> {
    name.strip_prefix("ckpt_")?
        .strip_suffix(".bin")?
        .parse::<u64>()
        .ok()
}

// ---------------------------------------------------------------------
// body codec: little-endian scalars, u64-length-prefixed slices
// ---------------------------------------------------------------------

pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_bits().to_le_bytes());
}

pub fn put_bytes(out: &mut Vec<u8>, v: &[u8]) {
    put_u64(out, v.len() as u64);
    out.extend_from_slice(v);
}

pub fn put_f32s(out: &mut Vec<u8>, v: &[f32]) {
    put_u64(out, v.len() as u64);
    for &x in v {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

pub fn put_f64s(out: &mut Vec<u8>, v: &[f64]) {
    put_u64(out, v.len() as u64);
    for &x in v {
        out.extend_from_slice(&x.to_bits().to_le_bytes());
    }
}

pub fn put_u64s(out: &mut Vec<u8>, v: &[u64]) {
    put_u64(out, v.len() as u64);
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

pub fn put_opt_f32s(out: &mut Vec<u8>, v: Option<&[f32]>) {
    match v {
        Some(v) => {
            put_u8(out, 1);
            put_f32s(out, v);
        }
        None => put_u8(out, 0),
    }
}

pub fn put_rng_state(out: &mut Vec<u8>, state: &RngState) {
    for &word in &state.s {
        put_u64(out, word);
    }
    match state.spare_normal {
        Some(z) => {
            put_u8(out, 1);
            put_f64(out, z);
        }
        None => put_u8(out, 0),
    }
}

/// The simulated communication ledger, field by field. Every counter in
/// [`CommStats`] is event-clock simulated (never wall time), so
/// persisting and restoring it keeps a resumed run's reported
/// uploads/bytes/sim-seconds identical to an uninterrupted one.
pub fn put_comm_stats(out: &mut Vec<u8>, comm: &CommStats) {
    put_u64(out, comm.uploads);
    put_u64(out, comm.upload_bytes);
    put_u64(out, comm.downloads);
    put_u64(out, comm.download_bytes);
    put_u64(out, comm.grad_evals);
    put_f64(out, comm.sim_time_s);
    put_u64(out, comm.stale_uploads);
    put_u64(out, comm.lost_uploads);
    put_f64s(out, &comm.worker_upload_s);
    put_u64s(out, &comm.worker_uploads);
    put_u64s(out, &comm.worker_lost);
    put_u64s(out, &comm.worker_raw_bytes);
    put_u64s(out, &comm.worker_wire_bytes);
    put_u64(out, comm.rounds);
    put_u64s(out, &comm.worker_selected);
    put_u64s(out, &comm.worker_rejected);
    put_u64s(out, &comm.worker_rejoins);
    put_u64(out, comm.rejected_uploads);
    put_u64(out, comm.rejoins);
}

/// Cursor over checkpoint body bytes; every `take_*` bounds-checks, so
/// a mislaid layout surfaces as an error instead of a silent misread.
pub struct Dec<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        Dec { bytes, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.bytes.len() - self.pos
    }

    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            self.remaining() >= n,
            "checkpoint body underrun: need {n} bytes at offset {}, \
             {} left",
            self.pos,
            self.remaining()
        );
        let out = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn take_u8(&mut self) -> anyhow::Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn take_u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(crate::util::byte_array(self.take(4)?)?))
    }

    pub fn take_u64(&mut self) -> anyhow::Result<u64> {
        Ok(u64::from_le_bytes(crate::util::byte_array(self.take(8)?)?))
    }

    pub fn take_f64(&mut self) -> anyhow::Result<f64> {
        Ok(f64::from_bits(self.take_u64()?))
    }

    fn take_len(&mut self, elem: usize) -> anyhow::Result<usize> {
        let len = self.take_u64()? as usize;
        anyhow::ensure!(
            len.checked_mul(elem).map_or(false, |b| b <= self.remaining()),
            "checkpoint body declares {len} x {elem}-byte elements with \
             only {} bytes left",
            self.remaining()
        );
        Ok(len)
    }

    pub fn take_bytes(&mut self) -> anyhow::Result<Vec<u8>> {
        let len = self.take_len(1)?;
        Ok(self.take(len)?.to_vec())
    }

    pub fn take_f32s(&mut self) -> anyhow::Result<Vec<f32>> {
        let len = self.take_len(4)?;
        let raw = self.take(len * 4)?;
        let mut out = Vec::with_capacity(len);
        for c in raw.chunks_exact(4) {
            out.push(f32::from_bits(u32::from_le_bytes(
                crate::util::byte_array(c)?,
            )));
        }
        Ok(out)
    }

    pub fn take_f64s(&mut self) -> anyhow::Result<Vec<f64>> {
        let len = self.take_len(8)?;
        let raw = self.take(len * 8)?;
        let mut out = Vec::with_capacity(len);
        for c in raw.chunks_exact(8) {
            out.push(f64::from_bits(u64::from_le_bytes(
                crate::util::byte_array(c)?,
            )));
        }
        Ok(out)
    }

    pub fn take_u64s(&mut self) -> anyhow::Result<Vec<u64>> {
        let len = self.take_len(8)?;
        let raw = self.take(len * 8)?;
        let mut out = Vec::with_capacity(len);
        for c in raw.chunks_exact(8) {
            out.push(u64::from_le_bytes(crate::util::byte_array(c)?));
        }
        Ok(out)
    }

    pub fn take_opt_f32s(&mut self) -> anyhow::Result<Option<Vec<f32>>> {
        match self.take_u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.take_f32s()?)),
            flag => anyhow::bail!(
                "checkpoint body option flag {flag} (want 0 or 1)"),
        }
    }

    pub fn take_rng_state(&mut self) -> anyhow::Result<RngState> {
        let mut s = [0u64; 4];
        for word in &mut s {
            *word = self.take_u64()?;
        }
        let spare_normal = match self.take_u8()? {
            0 => None,
            1 => Some(self.take_f64()?),
            flag => anyhow::bail!(
                "checkpoint rng spare flag {flag} (want 0 or 1)"),
        };
        Ok(RngState { s, spare_normal })
    }

    pub fn take_comm_stats(&mut self) -> anyhow::Result<CommStats> {
        let mut comm = CommStats::default();
        comm.uploads = self.take_u64()?;
        comm.upload_bytes = self.take_u64()?;
        comm.downloads = self.take_u64()?;
        comm.download_bytes = self.take_u64()?;
        comm.grad_evals = self.take_u64()?;
        comm.sim_time_s = self.take_f64()?;
        comm.stale_uploads = self.take_u64()?;
        comm.lost_uploads = self.take_u64()?;
        comm.worker_upload_s = self.take_f64s()?;
        comm.worker_uploads = self.take_u64s()?;
        comm.worker_lost = self.take_u64s()?;
        comm.worker_raw_bytes = self.take_u64s()?;
        comm.worker_wire_bytes = self.take_u64s()?;
        comm.rounds = self.take_u64()?;
        comm.worker_selected = self.take_u64s()?;
        comm.worker_rejected = self.take_u64s()?;
        comm.worker_rejoins = self.take_u64s()?;
        comm.rejected_uploads = self.take_u64()?;
        comm.rejoins = self.take_u64()?;
        Ok(comm)
    }

    /// Assert the body is fully consumed — trailing bytes mean the
    /// writer and reader disagree about the layout.
    pub fn done(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.remaining() == 0,
            "checkpoint body has {} unread trailing bytes",
            self.remaining()
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "cada_ckpt_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn codec_roundtrips_every_shape() {
        let mut body = Vec::new();
        put_u32(&mut body, 7);
        put_u64(&mut body, u64::MAX - 3);
        put_f64(&mut body, -0.125);
        put_bytes(&mut body, b"algo blob");
        put_f32s(&mut body, &[1.5, -2.25, f32::NAN]);
        put_f64s(&mut body, &[0.1, 0.2]);
        put_u64s(&mut body, &[9, 8, 7]);
        put_opt_f32s(&mut body, None);
        put_opt_f32s(&mut body, Some(&[3.0]));
        put_rng_state(&mut body, &RngState {
            s: [1, 2, 3, 4],
            spare_normal: Some(0.5),
        });
        let mut dec = Dec::new(&body);
        assert_eq!(dec.take_u32().unwrap(), 7);
        assert_eq!(dec.take_u64().unwrap(), u64::MAX - 3);
        assert_eq!(dec.take_f64().unwrap(), -0.125);
        assert_eq!(dec.take_bytes().unwrap(), b"algo blob");
        let f = dec.take_f32s().unwrap();
        assert_eq!(f[0], 1.5);
        assert_eq!(f[1], -2.25);
        assert!(f[2].is_nan());
        assert_eq!(dec.take_f64s().unwrap(), vec![0.1, 0.2]);
        assert_eq!(dec.take_u64s().unwrap(), vec![9, 8, 7]);
        assert_eq!(dec.take_opt_f32s().unwrap(), None);
        assert_eq!(dec.take_opt_f32s().unwrap(), Some(vec![3.0]));
        let rng = dec.take_rng_state().unwrap();
        assert_eq!(rng.s, [1, 2, 3, 4]);
        assert_eq!(rng.spare_normal, Some(0.5));
        dec.done().unwrap();
    }

    #[test]
    fn decoder_rejects_underruns_and_bogus_lengths() {
        let mut dec = Dec::new(&[1, 2, 3]);
        assert!(dec.take_u64().is_err());
        // a declared length far beyond the buffer must not allocate
        let mut body = Vec::new();
        put_u64(&mut body, u64::MAX / 2);
        assert!(Dec::new(&body).take_f32s().is_err());
        // trailing bytes are an error, not a shrug
        let mut body = Vec::new();
        put_u32(&mut body, 1);
        put_u32(&mut body, 2);
        let mut dec = Dec::new(&body);
        dec.take_u32().unwrap();
        assert!(dec.done().is_err());
    }

    #[test]
    fn hostile_bytes_error_at_every_hardened_site() {
        // regression for the R4 hardening: each decode site that used
        // to `try_into().unwrap()` now routes through util::byte_array
        // and must turn short/hostile input into a clean error
        assert!(Dec::new(&[0, 1, 2]).take_u32().is_err());
        assert!(Dec::new(&[0; 7]).take_u64().is_err());
        // vector reads whose length claims outrun the buffer
        let mut body = Vec::new();
        put_u64(&mut body, 3); // claims 3 f64s, holds none
        assert!(Dec::new(&body).take_f64s().is_err());
        let mut body = Vec::new();
        put_u64(&mut body, 2);
        body.extend_from_slice(&7u64.to_le_bytes()); // 1 of 2 u64s
        assert!(Dec::new(&body).take_u64s().is_err());
        // load(): a header-sized file of garbage fails on the magic
        // check via the hardened slice reads, never a panic
        let dir = scratch_dir("hostile");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt_garbage.bin");
        fs::write(&path, vec![0xA5u8; HEADER]).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("magic"), "{err}");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn save_load_roundtrip_and_corruption_detection() {
        let dir = scratch_dir("roundtrip");
        let body = b"round state goes here".to_vec();
        let path = save(&dir, 42, &body).unwrap();
        assert_eq!(path.file_name().unwrap().to_str().unwrap(),
                   "ckpt_00000042.bin");
        assert_eq!(load(&path).unwrap(), body);
        // flip one body byte on disk: the CRC must catch it
        let mut framed = fs::read(&path).unwrap();
        let last = framed.len() - 1;
        framed[last] ^= 0x40;
        fs::write(&path, &framed).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("CRC"), "{err}");
        // truncation below the header is caught too
        fs::write(&path, &framed[..HEADER - 2]).unwrap();
        assert!(load(&path).is_err());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn latest_finds_newest_and_prune_keeps_two() {
        let dir = scratch_dir("latest");
        assert!(latest(&dir).unwrap().is_none());
        for k in [5u64, 12, 9] {
            save(&dir, k, format!("body {k}").as_bytes()).unwrap();
        }
        // a stale tmp from a torn save must be ignored and pruned
        fs::write(dir.join("ckpt_00000099.bin.tmp"), b"torn").unwrap();
        let (k, path) = latest(&dir).unwrap().unwrap();
        assert_eq!(k, 12);
        assert_eq!(load(&path).unwrap(), b"body 12");
        prune(&dir, KEEP);
        let names: Vec<String> = fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name().to_string_lossy().into_owned())
            .collect();
        let mut names = names;
        names.sort();
        assert_eq!(names,
                   vec!["ckpt_00000009.bin", "ckpt_00000012.bin"]);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn cfg_validation() {
        assert!(CheckpointCfg::default().is_none());
        CheckpointCfg::default().validate().unwrap();
        let cfg = CheckpointCfg {
            dir: String::new(),
            every: 5,
            resume: String::new(),
        };
        assert!(cfg.validate().is_err());
    }
}
