//! Worker-side state and the per-iteration check of Algorithm 1
//! (lines 5–14): compute the rule-specific gradients, evaluate the
//! LHS innovation norm, decide, and (on upload) produce the gradient
//! innovation delta_m^k = g(theta^k; xi^k) - g(theta_hat; xi_hat).

use super::rules::{decide, Decision, RuleKind};
use crate::compress::{self, CompressCfg, Payload, Purpose};
use crate::data::Batch;
use crate::runtime::Compute;
use crate::tensor;

/// Outcome of one worker's iteration.
#[derive(Clone, Copy, Debug)]
pub struct WorkerStep {
    pub decision: Decision,
    /// rule LHS (innovation squared norm); NaN for non-adaptive rules
    pub lhs: f64,
    /// minibatch loss at theta^k (fresh gradient's loss)
    pub loss: f32,
    pub grad_evals: u64,
}

/// Per-worker persistent state.
pub struct WorkerState {
    pub id: usize,
    /// staleness tau_m (iterations since last upload)
    pub tau: u32,
    /// g(theta_hat_m; xi_hat_m): the gradient currently represented in the
    /// server aggregate for this worker
    pub g_stale: Vec<f32>,
    /// CADA1: stored innovation dtilde_m^{k - tau} from the last upload
    pub dtilde_stored: Option<Vec<f32>>,
    /// CADA2: theta^{k - tau_m}, the iterate at the last upload
    pub theta_stored: Option<Vec<f32>>,
    // scratch buffers (allocation-free hot path)
    g_new: Vec<f32>,
    g_aux: Vec<f32>,
    dtilde_new: Vec<f32>,
    delta: Vec<f32>,
    /// telemetry: total uploads by this worker
    pub uploads: u64,
    /// upload compression; `Identity` (the default) keeps every code
    /// path below byte-for-byte on the pre-compression route
    compress: CompressCfg,
    /// lossy only: per-worker error-feedback residual — the upload mass
    /// truncated so far, re-entering the next upload's candidate
    residual: Vec<f32>,
    /// lossy only: candidate / rule-diff scratch
    scratch: Vec<f32>,
    /// lossy only: the encoded payload of the last uploading step (the
    /// socket worker ships this instead of the dense delta)
    payload: Option<Payload>,
}

impl WorkerState {
    pub fn new(id: usize, p: usize, rule: RuleKind) -> Self {
        WorkerState {
            id,
            tau: 0,
            g_stale: vec![0.0; p],
            dtilde_stored: rule.needs_snapshot().then(|| vec![0.0; p]),
            theta_stored: rule.needs_stored_iterate().then(|| vec![0.0; p]),
            g_new: vec![0.0; p],
            g_aux: vec![0.0; p],
            dtilde_new: if rule.needs_snapshot() {
                vec![0.0; p]
            } else {
                Vec::new()
            },
            delta: vec![0.0; p],
            uploads: 0,
            compress: CompressCfg::default(),
            residual: Vec::new(),
            scratch: Vec::new(),
            payload: None,
        }
    }

    /// Install the upload compressor (default: `Identity`). Lossy
    /// schemes allocate the error-feedback residual; `Identity` keeps
    /// the worker on the exact pre-compression code paths.
    pub fn set_compress(&mut self, cfg: CompressCfg) {
        self.compress = cfg;
        let p = if cfg.is_lossy() { self.g_stale.len() } else { 0 };
        self.residual = vec![0.0; p];
        self.scratch = vec![0.0; p];
        self.payload = None;
    }

    /// Lossy compression only: the error-feedback residual (None under
    /// `Identity`). Exposed for the conservation property tests.
    pub fn ef_residual(&self) -> Option<&[f32]> {
        self.compress.is_lossy().then_some(self.residual.as_slice())
    }

    /// Rule LHS on the *decompressed* probe: what would the server
    /// actually receive if this diff were uploaded right now? Compresses
    /// `self.scratch` on the round's `Purpose::Rule` stream, decompresses
    /// it back, and returns the squared norm — so the skip rule and the
    /// compressor compose instead of the rule reasoning about truncated
    /// mass that never crosses the wire.
    fn decompressed_lhs(&self, k: u64) -> anyhow::Result<f64> {
        let dense = self
            .compress
            .compress(&self.scratch, k, self.id, Purpose::Rule)
            .decompress()?;
        Ok(tensor::sqnorm(&dense) as f64)
    }

    /// Run lines 5–14 of Algorithm 1 for this worker at iteration `k`.
    ///
    /// * `theta` — the broadcast iterate theta^k.
    /// * `snapshot` — theta-tilde (CADA1 only; refreshed by the
    ///   [`Cada`](crate::algorithms::Cada) broadcast phase every D
    ///   iterations).
    /// * `rhs` — the shared drift threshold from the history ring.
    /// * `use_artifact_innov` — route innovation norms through the Pallas
    ///   artifact instead of the native fused loop.
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &mut self,
        k: u64,
        rule: RuleKind,
        max_delay: u32,
        theta: &[f32],
        snapshot: Option<&[f32]>,
        rhs: f64,
        batch: &Batch,
        compute: &mut dyn Compute,
        use_artifact_innov: bool,
    ) -> anyhow::Result<WorkerStep> {
        // fresh stochastic gradient at theta^k on sample xi^k
        let loss = compute.grad(theta, batch, &mut self.g_new)?;
        let mut grad_evals = 1u64;

        let innov = |c: &mut dyn Compute, a: &[f32], b: &[f32]|
                     -> anyhow::Result<f64> {
            Ok(if use_artifact_innov {
                c.innov(a, b)? as f64
            } else {
                tensor::sqnorm_diff(a, b) as f64
            })
        };

        // rule-specific LHS; lossy compression swaps the raw innovation
        // norm for the norm of its decompressed probe (Identity keeps
        // the exact legacy expression)
        let lossy = self.compress.is_lossy();
        let lhs = match rule {
            RuleKind::Cada1 { .. } => {
                let snap = snapshot.expect("CADA1 requires a snapshot");
                // second gradient: same sample xi^k at the snapshot
                compute.grad(snap, batch, &mut self.g_aux)?;
                grad_evals += 1;
                tensor::sub_into(&mut self.dtilde_new, &self.g_new,
                                 &self.g_aux);
                let stored = self
                    .dtilde_stored
                    .as_ref()
                    .expect("CADA1 state allocated");
                if lossy {
                    tensor::sub_into(&mut self.scratch,
                                     &self.dtilde_new, stored);
                    self.decompressed_lhs(k)?
                } else {
                    innov(compute, &self.dtilde_new, stored)?
                }
            }
            RuleKind::Cada2 { .. } => {
                let stored = self
                    .theta_stored
                    .as_ref()
                    .expect("CADA2 state allocated");
                // second gradient: same sample xi^k at the old iterate
                compute.grad(stored, batch, &mut self.g_aux)?;
                grad_evals += 1;
                if lossy {
                    tensor::sub_into(&mut self.scratch, &self.g_new,
                                     &self.g_aux);
                    self.decompressed_lhs(k)?
                } else {
                    innov(compute, &self.g_new, &self.g_aux)?
                }
            }
            RuleKind::Lag { .. } => {
                // fresh vs STORED gradient: different iterates AND
                // different samples — the variance trap of section 2.1
                if lossy {
                    tensor::sub_into(&mut self.scratch, &self.g_new,
                                     &self.g_stale);
                    self.decompressed_lhs(k)?
                } else {
                    innov(compute, &self.g_new, &self.g_stale)?
                }
            }
            _ => f64::NAN,
        };

        let decision = decide(rule, k, lhs, rhs, self.tau, max_delay);
        if decision.upload {
            if lossy {
                // error feedback: candidate = (g_new - g_stale) +
                // residual; ship C(candidate), fold D(C(candidate)),
                // carry the truncated remainder into the next round
                for i in 0..self.scratch.len() {
                    self.scratch[i] = (self.g_new[i] - self.g_stale[i])
                        + self.residual[i];
                }
                let (payload, decomp) = compress::compress_with_feedback(
                    &self.compress,
                    &self.scratch,
                    &mut self.residual,
                    k,
                    self.id,
                    Purpose::Upload,
                )?;
                // the server folds the DECOMPRESSED innovation — the
                // in-process transports install it directly, the socket
                // worker ships `payload` and the server decompresses
                // before folding
                self.delta.copy_from_slice(&decomp);
                self.payload = Some(payload);
            } else {
                // delta_m^k = g_new - g_stale; server folds delta/M
                // (Eq. 3)
                tensor::sub_into(&mut self.delta, &self.g_new,
                                 &self.g_stale);
            }
            self.g_stale.copy_from_slice(&self.g_new);
            if let Some(d) = self.dtilde_stored.as_mut() {
                d.copy_from_slice(&self.dtilde_new);
            }
            if let Some(t) = self.theta_stored.as_mut() {
                t.copy_from_slice(theta);
            }
            self.tau = 1;
            self.uploads += 1;
        } else {
            self.tau += 1;
        }
        Ok(WorkerStep {
            decision,
            lhs,
            loss,
            grad_evals,
        })
    }

    /// The innovation payload produced by the last uploading `step`.
    pub fn last_delta(&self) -> &[f32] {
        &self.delta
    }

    /// Lossy compression: take the encoded payload of the last
    /// uploading `step` (the socket worker ships this). `None` under
    /// `Identity` — the caller ships the dense [`Self::last_delta`]
    /// exactly as before.
    pub fn take_payload(&mut self) -> Option<Payload> {
        self.payload.take()
    }

    /// Socket-transport mirror of an uploading [`WorkerState::step`]:
    /// the REMOTE worker process ran lines 5–14 and shipped this
    /// innovation delta over the wire; install it and replay the
    /// upload-side bookkeeping (tau reset, upload count) so
    /// `aggregate`/`server_update` and the staleness telemetry see
    /// exactly what an in-process step would have left behind. The
    /// gradient scratch (`g_stale` etc.) stays untouched — it lives in
    /// the worker process.
    pub fn absorb_remote_upload(&mut self, delta: &[f32])
                                -> anyhow::Result<()> {
        anyhow::ensure!(
            delta.len() == self.delta.len(),
            "worker {}: wire delta has {} elements, state holds {}",
            self.id,
            delta.len(),
            self.delta.len()
        );
        self.delta.copy_from_slice(delta);
        self.tau = 1;
        self.uploads += 1;
        Ok(())
    }

    /// Socket-transport mirror of a skipping [`WorkerState::step`].
    pub fn absorb_remote_skip(&mut self) {
        self.tau += 1;
    }

    /// Checkpoint view: every cross-round field, cloned. The scratch
    /// buffers and the staged lossy payload are per-step and rebuild
    /// themselves; everything exported here must survive a crash
    /// bit-for-bit or the resumed run diverges.
    pub fn export_ckpt(&self) -> WorkerCkpt {
        WorkerCkpt {
            tau: self.tau,
            uploads: self.uploads,
            g_stale: self.g_stale.clone(),
            dtilde_stored: self.dtilde_stored.clone(),
            theta_stored: self.theta_stored.clone(),
            delta: self.delta.clone(),
            residual: self.residual.clone(),
        }
    }

    /// Restore a checkpointed worker into this freshly-built state
    /// (`new` + `set_compress` already applied, so the buffer shapes
    /// tell us whether the checkpoint matches the run config).
    pub fn import_ckpt(&mut self, ckpt: WorkerCkpt)
                       -> anyhow::Result<()> {
        let p = self.g_stale.len();
        anyhow::ensure!(
            ckpt.g_stale.len() == p,
            "worker {} checkpoint has p = {}, the run has p = {p}",
            self.id,
            ckpt.g_stale.len()
        );
        anyhow::ensure!(
            ckpt.dtilde_stored.is_some() == self.dtilde_stored.is_some()
                && ckpt.theta_stored.is_some()
                    == self.theta_stored.is_some(),
            "worker {} checkpoint stores state for a different rule \
             family",
            self.id
        );
        anyhow::ensure!(
            ckpt.delta.len() == p
                && ckpt.residual.len() == self.residual.len(),
            "worker {} checkpoint buffers do not match the run's \
             compression config",
            self.id
        );
        self.tau = ckpt.tau;
        self.uploads = ckpt.uploads;
        self.g_stale = ckpt.g_stale;
        self.dtilde_stored = ckpt.dtilde_stored;
        self.theta_stored = ckpt.theta_stored;
        self.delta = ckpt.delta;
        self.residual = ckpt.residual;
        Ok(())
    }
}

/// The cross-round fields of one [`WorkerState`], as a checkpoint
/// carries them (see [`WorkerState::export_ckpt`]).
#[derive(Clone, Debug, PartialEq)]
pub struct WorkerCkpt {
    pub tau: u32,
    pub uploads: u64,
    pub g_stale: Vec<f32>,
    pub dtilde_stored: Option<Vec<f32>>,
    pub theta_stored: Option<Vec<f32>>,
    pub delta: Vec<f32>,
    pub residual: Vec<f32>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::runtime::native::NativeLogReg;
    use crate::util::rng::Rng;

    fn setup(rule: RuleKind) -> (NativeLogReg, Dataset, WorkerState) {
        let d = 4;
        let p = 16;
        let compute = NativeLogReg::for_spec(d, p);
        let mut rng = Rng::new(1);
        let n = 64;
        let mut x = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let mut s = 0.0;
            for j in 0..d {
                let v = rng.normal_f32(0.0, 1.0);
                x.push(v);
                s += v * (j as f32 + 1.0);
            }
            y.push((s > 0.0) as i32);
        }
        let data = Dataset::Labeled { x, sample_shape: vec![d], y };
        let worker = WorkerState::new(0, p, rule);
        (compute, data, worker)
    }

    #[test]
    fn first_iteration_uploads_full_gradient() {
        let rule = RuleKind::Cada2 { c: 1.0 };
        let (mut compute, data, mut w) = setup(rule);
        let theta = vec![0.1f32; 16];
        let batch = data.gather(&[0, 1, 2, 3]);
        let step = w
            .step(0, rule, 50, &theta, None, 0.0, &batch, &mut compute, false)
            .unwrap();
        assert!(step.decision.upload);
        assert_eq!(w.tau, 1);
        assert_eq!(step.grad_evals, 2);
        // delta == g_new since g_stale was zero
        let mut g = vec![0.0f32; 16];
        compute.grad(&theta, &batch, &mut g).unwrap();
        for (a, b) in w.last_delta().iter().zip(&g) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn cada2_skips_when_iterate_unchanged() {
        // If theta never moves, g(theta^k; xi) == g(theta_stored; xi)
        // exactly, so LHS = 0 <= RHS and the worker must skip.
        let rule = RuleKind::Cada2 { c: 1.0 };
        let (mut compute, data, mut w) = setup(rule);
        let theta = vec![0.1f32; 16];
        let mut rng = Rng::new(2);
        let shard: Vec<usize> = (0..64).collect();
        // k=0 uploads and stores theta
        let b0 = data.sample_batch(&shard, 8, &mut rng);
        w.step(0, rule, 50, &theta, None, 0.0, &b0, &mut compute, false)
            .unwrap();
        for k in 1..5 {
            let b = data.sample_batch(&shard, 8, &mut rng);
            let s = w
                .step(k, rule, 50, &theta, None, 0.0, &b, &mut compute, false)
                .unwrap();
            assert!(!s.decision.upload, "k={k} lhs={}", s.lhs);
            assert_eq!(s.lhs, 0.0);
        }
        assert_eq!(w.tau, 5);
    }

    #[test]
    fn lag_lhs_nonzero_even_when_iterate_unchanged() {
        // Same setting as above, but LAG compares different samples:
        // its LHS stays at the variance level (section 2.1).
        let rule = RuleKind::Lag { c: 1.0 };
        let (mut compute, data, mut w) = setup(rule);
        let theta = vec![0.1f32; 16];
        let mut rng = Rng::new(3);
        let shard: Vec<usize> = (0..64).collect();
        let b0 = data.sample_batch(&shard, 4, &mut rng);
        w.step(0, rule, 50, &theta, None, 0.0, &b0, &mut compute, false)
            .unwrap();
        let b1 = data.sample_batch(&shard, 4, &mut rng);
        let s = w
            .step(1, rule, 50, &theta, None, 0.0, &b1, &mut compute, false)
            .unwrap();
        assert!(s.lhs > 1e-6, "lag lhs unexpectedly {}", s.lhs);
    }

    #[test]
    fn max_delay_forces_refresh() {
        let rule = RuleKind::Never;
        let (mut compute, data, mut w) = setup(rule);
        let theta = vec![0.1f32; 16];
        let mut rng = Rng::new(4);
        let shard: Vec<usize> = (0..64).collect();
        let mut uploads = 0;
        for k in 0..7 {
            let b = data.sample_batch(&shard, 4, &mut rng);
            let s = w
                .step(k, rule, 3, &theta, None, 0.0, &b, &mut compute, false)
                .unwrap();
            if s.decision.upload {
                uploads += 1;
            }
            assert!(w.tau <= 3, "staleness invariant violated");
        }
        // k=0 (forced) then whenever tau hits 3: k=3, k=6
        assert_eq!(uploads, 3);
    }

    #[test]
    fn lossy_lhs_is_computed_on_decompressed_innovation() {
        // The acceptance-criterion assertion: with a lossy compressor
        // installed, the LAG-family LHS must equal the squared norm of
        // the DECOMPRESSED probe — not the raw innovation norm.
        use crate::compress::{CompressCfg, Purpose, Scheme};
        let rule = RuleKind::Lag { c: 1.0 };
        let (mut compute, data, mut w) = setup(rule);
        let cfg = CompressCfg {
            scheme: Scheme::TopK,
            topk_frac: 0.01, // k = 1 of 16: aggressively truncated
            ..CompressCfg::default()
        };
        w.set_compress(cfg);
        let theta = vec![0.1f32; 16];
        let ba = data.gather(&[0, 1, 2, 3]);
        let bb = data.gather(&[8, 9, 10, 11]);
        // k=0 uploads (forced): g_stale becomes grad(theta; ba)
        w.step(0, rule, 50, &theta, None, 0.0, &ba, &mut compute, false)
            .unwrap();
        let s = w
            .step(1, rule, 50, &theta, None, 1e30, &bb, &mut compute,
                  false)
            .unwrap();
        // recompute the probe independently
        let mut ga = vec![0.0f32; 16];
        let mut gb = vec![0.0f32; 16];
        compute.grad(&theta, &ba, &mut ga).unwrap();
        compute.grad(&theta, &bb, &mut gb).unwrap();
        let diff: Vec<f32> =
            gb.iter().zip(&ga).map(|(b, a)| b - a).collect();
        let probe = cfg
            .compress(&diff, 1, 0, Purpose::Rule)
            .decompress()
            .unwrap();
        let want = tensor::sqnorm(&probe) as f64;
        let raw = tensor::sqnorm(&diff) as f64;
        assert_eq!(s.lhs, want, "LHS must come from the decompressed probe");
        assert!(s.lhs < raw,
                "top-1 of 16 coords must shrink the norm: {} vs {raw}",
                s.lhs);
    }

    #[test]
    fn lossy_step_conserves_candidate_through_error_feedback() {
        // Per-round conservation through the REAL step path: the dense
        // delta the server folds plus the new residual must equal the
        // round's candidate (g_new - g_stale + old residual), exactly.
        use crate::compress::{CompressCfg, Scheme};
        for cfg in [
            CompressCfg {
                scheme: Scheme::TopK,
                topk_frac: 0.2,
                ..CompressCfg::default()
            },
            CompressCfg {
                scheme: Scheme::QuantB,
                bits: 3,
                seed: 21,
                ..CompressCfg::default()
            },
        ] {
            let rule = RuleKind::Always;
            let (mut compute, data, mut w) = setup(rule);
            w.set_compress(cfg);
            let mut rng = Rng::new(6);
            let shard: Vec<usize> = (0..64).collect();
            let mut theta = vec![0.1f32; 16];
            let mut g_stale_prev = vec![0.0f32; 16];
            for k in 0..8u64 {
                let batch = data.sample_batch(&shard, 4, &mut rng);
                let residual_before = w.ef_residual().unwrap().to_vec();
                let s = w
                    .step(k, rule, 50, &theta, None, 0.0, &batch,
                          &mut compute, false)
                    .unwrap();
                assert!(s.decision.upload);
                let mut g_new = vec![0.0f32; 16];
                compute.grad(&theta, &batch, &mut g_new).unwrap();
                let residual_after = w.ef_residual().unwrap();
                for i in 0..16 {
                    let candidate = (g_new[i] - g_stale_prev[i])
                        + residual_before[i];
                    assert_eq!(
                        w.last_delta()[i] + residual_after[i],
                        candidate,
                        "{:?} k={k} i={i}",
                        cfg.scheme
                    );
                }
                g_stale_prev.copy_from_slice(&g_new);
                // move theta so later rounds have non-trivial innovations
                for (t, g) in theta.iter_mut().zip(&g_new) {
                    *t -= 0.05 * g;
                }
            }
        }
    }

    #[test]
    fn always_rule_single_grad_eval() {
        let rule = RuleKind::Always;
        let (mut compute, data, mut w) = setup(rule);
        let theta = vec![0.1f32; 16];
        let batch = data.gather(&[0, 1]);
        let s = w
            .step(5, rule, 50, &theta, None, 0.0, &batch, &mut compute, false)
            .unwrap();
        assert!(s.decision.upload);
        assert_eq!(s.grad_evals, 1);
        assert!(s.lhs.is_nan());
    }
}
