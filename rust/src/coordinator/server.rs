//! Parameter-server state: the aggregate-gradient recursion (Eq. 3) and
//! the model update (Eq. 2a–2c for CADA/Adam, Eq. 4's SGD step for LAG).

use crate::config::Schedule;
use crate::runtime::Compute;
use crate::tensor;

/// Which update the server applies to theta each iteration.
#[derive(Clone, Debug)]
pub enum Optimizer {
    /// AMSGrad-style adaptive step (Eq. 2a–2c). `use_artifact` routes the
    /// step through the AOT Pallas kernel (`Compute::update`); otherwise
    /// the native fused rust twin runs. betas/eps must match the values
    /// baked into the artifact (taken from the manifest spec).
    Amsgrad {
        alpha: Schedule,
        beta1: f32,
        beta2: f32,
        eps: f32,
        use_artifact: bool,
    },
    /// Plain distributed SGD on the (possibly stale) aggregate — the LAG
    /// baseline's update (Eq. 4).
    Sgd { eta: Schedule },
}

impl Optimizer {
    pub fn name(&self) -> &'static str {
        match self {
            Optimizer::Amsgrad { .. } => "amsgrad",
            Optimizer::Sgd { .. } => "sgd",
        }
    }
}

/// Server-side state for one run.
pub struct ServerState {
    /// current iterate theta^k (padded flat vector)
    pub theta: Vec<f32>,
    /// momentum direction h^k (Eq. 2a)
    pub h: Vec<f32>,
    /// AMSGrad second-moment clamp vhat^k (Eq. 2b)
    pub vhat: Vec<f32>,
    /// the running aggregate nabla^k of (possibly stale) worker gradients
    pub grad_agg: Vec<f32>,
    pub opt: Optimizer,
    /// number of workers M (the 1/M in Eq. 3)
    pub m: usize,
    /// scratch: previous theta for the step-norm computation
    prev_theta: Vec<f32>,
}

impl ServerState {
    pub fn new(init_theta: Vec<f32>, m: usize, opt: Optimizer) -> Self {
        let p = init_theta.len();
        ServerState {
            prev_theta: init_theta.clone(),
            theta: init_theta,
            h: vec![0.0; p],
            vhat: vec![0.0; p],
            grad_agg: vec![0.0; p],
            opt,
            m,
        }
    }

    /// Fold one worker's gradient innovation into the aggregate:
    /// nabla^k += delta_m / M   (Eq. 3).
    pub fn apply_innovation(&mut self, delta: &[f32]) {
        tensor::axpy(&mut self.grad_agg, 1.0 / self.m as f32, delta);
    }

    /// Apply the optimizer step for iteration `k`; returns
    /// ||theta^{k+1} - theta^k||^2 for the drift history.
    pub fn step(&mut self, k: u64, compute: &mut dyn Compute)
                -> anyhow::Result<f64> {
        self.prev_theta.copy_from_slice(&self.theta);
        match self.opt.clone() {
            Optimizer::Amsgrad { alpha, beta1, beta2, eps, use_artifact } => {
                let a = alpha.at(k);
                if use_artifact {
                    compute.update(&mut self.theta, &mut self.h,
                                   &mut self.vhat, &self.grad_agg, a)?;
                } else {
                    tensor::amsgrad_update(&mut self.theta, &mut self.h,
                                           &mut self.vhat, &self.grad_agg,
                                           a, beta1, beta2, eps);
                }
            }
            Optimizer::Sgd { eta } => {
                tensor::sgd_update(&mut self.theta, &self.grad_agg,
                                   eta.at(k));
            }
        }
        Ok(tensor::sqnorm_diff(&self.theta, &self.prev_theta) as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::NativeLogReg;

    fn dummy_compute() -> NativeLogReg {
        NativeLogReg::for_spec(4, 16)
    }

    #[test]
    fn innovation_recursion_matches_direct_average() {
        // After each worker uploads delta = g_new - g_old, the aggregate
        // must equal mean(current stale gradients) — Eq. 3's invariant.
        let m = 3;
        let p = 8;
        let mut server = ServerState::new(
            vec![0.0; p], m,
            Optimizer::Sgd { eta: Schedule::Constant(0.0) });
        let mut rng = crate::util::rng::Rng::new(4);
        let mut held: Vec<Vec<f32>> = vec![vec![0.0; p]; m];
        for _round in 0..10 {
            for w in 0..m {
                if rng.f64() < 0.6 {
                    let g_new: Vec<f32> =
                        (0..p).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                    let delta: Vec<f32> = g_new
                        .iter()
                        .zip(&held[w])
                        .map(|(a, b)| a - b)
                        .collect();
                    server.apply_innovation(&delta);
                    held[w] = g_new;
                }
            }
            for i in 0..p {
                let direct: f32 =
                    held.iter().map(|g| g[i]).sum::<f32>() / m as f32;
                assert!((server.grad_agg[i] - direct).abs() < 1e-4,
                        "coord {i}: {} vs {direct}", server.grad_agg[i]);
            }
        }
    }

    #[test]
    fn sgd_step_moves_against_aggregate() {
        let mut s = ServerState::new(
            vec![1.0; 4], 1,
            Optimizer::Sgd { eta: Schedule::Constant(0.5) });
        s.grad_agg = vec![2.0; 4];
        let sq = s.step(0, &mut dummy_compute()).unwrap();
        assert!(s.theta.iter().all(|&t| (t - 0.0).abs() < 1e-6));
        assert!((sq - 4.0).abs() < 1e-6);
    }

    #[test]
    fn amsgrad_native_step_matches_tensor_kernel() {
        let p = 16;
        let mut s = ServerState::new(
            vec![0.5; p], 2,
            Optimizer::Amsgrad {
                alpha: Schedule::Constant(0.1),
                beta1: 0.9, beta2: 0.999, eps: 1e-8,
                use_artifact: false,
            });
        s.grad_agg = (0..p).map(|i| i as f32 * 0.1).collect();
        let mut theta = s.theta.clone();
        let mut h = s.h.clone();
        let mut vhat = s.vhat.clone();
        s.step(3, &mut dummy_compute()).unwrap();
        tensor::amsgrad_update(&mut theta, &mut h, &mut vhat, &s.grad_agg,
                               0.1, 0.9, 0.999, 1e-8);
        assert_eq!(s.theta, theta);
        assert_eq!(s.h, h);
        assert_eq!(s.vhat, vhat);
    }
}
