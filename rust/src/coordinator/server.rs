//! Parameter-server state: the aggregate-gradient recursion (Eq. 3) and
//! the model update (Eq. 2a–2c for CADA/Adam, Eq. 4's SGD step for LAG),
//! sharded by contiguous parameter range so both scale across cores.
//!
//! All server-side work is elementwise (innovation folds are `axpy`, the
//! AMSGrad/SGD steps touch each coordinate independently), so running it
//! per-shard — on the persistent [`ShardPool`] (default) or on per-round
//! scoped threads — is bit-identical to the sequential path: within each
//! shard the innovations fold in the same worker order, and each element
//! sees the exact same float ops whichever shard owns it. The squared step norm feeding the drift history is the one
//! reduction; it is computed per [`SHARD_BLOCK`]-sized block with the
//! block partials summed in global block order, so the reduction tree —
//! and therefore every bit of the result — is independent of the shard
//! count (`server_shards = 1` IS the reference path, enforced by
//! `tests/golden_parity.rs`).

use std::time::Instant;

use crate::config::Schedule;
use crate::coordinator::pool::{PoolRound, ShardExec, ShardPool};
use crate::coordinator::shard::{ShardLayout, ShardStats, SHARD_BLOCK};
use crate::runtime::Compute;
use crate::tensor;

/// Which update the server applies to theta each iteration.
#[derive(Clone, Debug)]
pub enum Optimizer {
    /// AMSGrad-style adaptive step (Eq. 2a–2c). `use_artifact` routes the
    /// step through the AOT Pallas kernel (`Compute::update`); otherwise
    /// the native fused rust twin runs. betas/eps must match the values
    /// baked into the artifact (taken from the manifest spec).
    Amsgrad {
        alpha: Schedule,
        beta1: f32,
        beta2: f32,
        eps: f32,
        use_artifact: bool,
    },
    /// Plain distributed SGD on the (possibly stale) aggregate — the LAG
    /// baseline's update (Eq. 4).
    Sgd { eta: Schedule },
}

impl Optimizer {
    pub fn name(&self) -> &'static str {
        match self {
            Optimizer::Amsgrad { .. } => "amsgrad",
            Optimizer::Sgd { .. } => "sgd",
        }
    }
}

/// The round-`k`-resolved update kernel a shard applies to its range.
/// `pub(crate)` so the persistent [`ShardPool`] ships it to its threads.
#[derive(Clone, Copy, Debug)]
pub(crate) enum StepKernel {
    Amsgrad { alpha: f32, beta1: f32, beta2: f32, eps: f32 },
    Sgd { eta: f32 },
}

/// The determinism-critical step-norm reduction, shared by the native
/// per-shard path and the whole-vector artifact path so the two can
/// never drift apart: per-[`SHARD_BLOCK`] f32 partials (the last block
/// may be short), one per `blocks` slot, in block order. `new`/`old`
/// must start on a global block boundary (shard ranges always do).
fn block_norms_into(new: &[f32], old: &[f32], blocks: &mut [f64]) {
    let mut lo = 0usize;
    for b in blocks.iter_mut() {
        let hi = (lo + SHARD_BLOCK).min(new.len());
        *b = tensor::sqnorm_diff(&new[lo..hi], &old[lo..hi]) as f64;
        lo = hi;
    }
}

/// One shard's slice of every parameter-sized vector, plus its step-norm
/// blocks; built per round by splitting the flat server vectors —
/// inline for one shard, on scoped threads, or on the persistent
/// [`ShardPool`]'s threads (which run this exact same code over the
/// exact same ranges, so all three execution modes are bit-identical).
pub(crate) struct ShardTask<'a> {
    pub(crate) s: usize,
    pub(crate) range: std::ops::Range<usize>,
    pub(crate) theta: &'a mut [f32],
    pub(crate) h: &'a mut [f32],
    pub(crate) vhat: &'a mut [f32],
    pub(crate) agg: &'a mut [f32],
    pub(crate) prev: &'a mut [f32],
    pub(crate) blocks: &'a mut [f64],
}

impl ShardTask<'_> {
    /// Fold the round's innovations only (in upload order) — the
    /// artifact path, whose fused update runs over the whole vector
    /// afterwards. Returns the wall seconds spent. Deltas arrive as an
    /// iterator of full-length slices so the pool threads can feed
    /// their raw-pointer reconstructions without collecting a per-round
    /// `Vec`.
    pub(crate) fn fold_only<'d>(self,
                                deltas: impl IntoIterator<Item = &'d [f32]>,
                                inv_m: f32) -> f64 {
        let t0 = Instant::now();
        for d in deltas {
            tensor::axpy(self.agg, inv_m, &d[self.range.clone()]);
        }
        t0.elapsed().as_secs_f64()
    }

    /// Fold the round's innovations (in upload order), apply the update
    /// kernel, and refresh this shard's step-norm blocks. Returns the
    /// wall seconds spent (per-shard timing breakdown). The 1-shard
    /// reference path runs this exact code over `0..p`, so sharded and
    /// sequential execution cannot drift apart.
    pub(crate) fn run<'d>(self,
                          deltas: impl IntoIterator<Item = &'d [f32]>,
                          inv_m: f32, kernel: StepKernel) -> f64 {
        let t0 = Instant::now();
        self.prev.copy_from_slice(self.theta);
        for d in deltas {
            tensor::axpy(self.agg, inv_m, &d[self.range.clone()]);
        }
        match kernel {
            StepKernel::Amsgrad { alpha, beta1, beta2, eps } => {
                tensor::amsgrad_update(self.theta, self.h, self.vhat,
                                       self.agg, alpha, beta1, beta2, eps);
            }
            StepKernel::Sgd { eta } => {
                tensor::sgd_update(self.theta, self.agg, eta);
            }
        }
        // per-block squared step norms: block boundaries are global
        // (multiples of SHARD_BLOCK, this shard starts on one), so the
        // partials are identical for every shard count
        block_norms_into(self.theta, self.prev, self.blocks);
        t0.elapsed().as_secs_f64()
    }
}

/// Server-side state for one run, sharded by contiguous parameter range.
pub struct ServerState {
    /// current iterate theta^k (padded flat vector)
    pub theta: Vec<f32>,
    /// momentum direction h^k (Eq. 2a)
    pub h: Vec<f32>,
    /// AMSGrad second-moment clamp vhat^k (Eq. 2b)
    pub vhat: Vec<f32>,
    /// the running aggregate nabla^k of (possibly stale) worker gradients
    pub grad_agg: Vec<f32>,
    pub opt: Optimizer,
    /// number of workers M (the 1/M in Eq. 3)
    pub m: usize,
    /// scratch: previous theta for the step-norm computation
    prev_theta: Vec<f32>,
    /// contiguous parameter ranges the state is sharded into
    layout: ShardLayout,
    /// per-shard version counters, bumped whenever a shard's range is
    /// updated; the broadcast double-buffers copy only moved-on ranges
    versions: Vec<u64>,
    /// scratch: per-block squared step-norm partials
    block_norms: Vec<f64>,
    /// cumulative per-shard fold+step seconds (telemetry)
    stats: ShardStats,
    /// how multi-shard rounds execute (persistent pool vs scoped)
    exec: ShardExec,
    /// the persistent shard pool, spawned lazily on the first
    /// multi-shard round and reused (parked) for the rest of the run
    pool: Option<ShardPool>,
}

impl ServerState {
    pub fn new(init_theta: Vec<f32>, m: usize, opt: Optimizer) -> Self {
        Self::new_sharded(init_theta, m, opt, 1)
    }

    /// Shard `theta`/`h`/`vhat`/`grad_agg` into `shards` contiguous
    /// ranges; folds and updates run per-shard on the default
    /// [`ShardExec`] (the persistent pool) when `shards > 1` —
    /// bit-identical to `shards = 1`.
    pub fn new_sharded(init_theta: Vec<f32>, m: usize, opt: Optimizer,
                       shards: usize) -> Self {
        Self::new_sharded_with(init_theta, m, opt, shards,
                               ShardExec::default())
    }

    /// [`ServerState::new_sharded`] with an explicit execution mode:
    /// `Pool` parks one persistent thread per non-empty shard across
    /// rounds, `Scoped` spawns+joins per round (the PR 3 reference).
    /// Both are bit-identical to each other and to one shard.
    pub fn new_sharded_with(init_theta: Vec<f32>, m: usize, opt: Optimizer,
                            shards: usize, exec: ShardExec) -> Self {
        let p = init_theta.len();
        let layout = ShardLayout::new(p, shards);
        let n = layout.num_shards();
        let nblocks = layout.num_blocks();
        ServerState {
            prev_theta: init_theta.clone(),
            theta: init_theta,
            h: vec![0.0; p],
            vhat: vec![0.0; p],
            grad_agg: vec![0.0; p],
            opt,
            m,
            versions: vec![0; n],
            block_norms: vec![0.0; nblocks],
            stats: ShardStats::for_shards(n),
            layout,
            exec,
            pool: None,
        }
    }

    pub fn layout(&self) -> &ShardLayout {
        &self.layout
    }

    /// The execution mode multi-shard rounds run under.
    pub fn shard_exec(&self) -> ShardExec {
        self.exec
    }

    /// Per-shard version counters (see [`ServerState::layout`]); the
    /// broadcast buffers use these to skip copying unchanged ranges.
    pub fn versions(&self) -> &[u64] {
        &self.versions
    }

    /// Per-shard cumulative fold+step timing.
    pub fn shard_stats(&self) -> &ShardStats {
        &self.stats
    }

    /// Checkpoint import: restore the persisted flat vectors and the
    /// per-shard versions into this freshly-built server. The export
    /// side needs no method — `theta`/`h`/`vhat`/`grad_agg` are public
    /// and [`ServerState::versions`] exposes the counters. Scratch
    /// (`prev_theta`, the step-norm blocks) and the measured timings
    /// are per-round and deliberately not restored.
    pub fn import_ckpt(&mut self, theta: Vec<f32>, h: Vec<f32>,
                       vhat: Vec<f32>, grad_agg: Vec<f32>,
                       versions: Vec<u64>) -> anyhow::Result<()> {
        let p = self.theta.len();
        anyhow::ensure!(
            theta.len() == p
                && h.len() == p
                && vhat.len() == p
                && grad_agg.len() == p,
            "checkpoint server vectors have p = {}, the run has p = {p}",
            theta.len()
        );
        anyhow::ensure!(
            versions.len() == self.versions.len(),
            "checkpoint has {} shard versions, the run's layout has {}",
            versions.len(),
            self.versions.len()
        );
        self.theta = theta;
        self.h = h;
        self.vhat = vhat;
        self.grad_agg = grad_agg;
        self.versions = versions;
        Ok(())
    }

    /// Fold one worker's gradient innovation into the aggregate:
    /// nabla^k += delta_m / M   (Eq. 3). Sequential over the full range;
    /// the round hot path folds inside [`ServerState::fold_and_step`]
    /// instead so folds and the update share one per-shard pass.
    pub fn apply_innovation(&mut self, delta: &[f32]) {
        tensor::axpy(&mut self.grad_agg, 1.0 / self.m as f32, delta);
    }

    /// Apply the optimizer step for iteration `k`; returns
    /// ||theta^{k+1} - theta^k||^2 for the drift history.
    pub fn step(&mut self, k: u64, compute: &mut dyn Compute)
                -> anyhow::Result<f64> {
        self.fold_and_step(k, &[], compute)
    }

    /// One server round over the sharded state: fold `deltas` (in upload
    /// order) into the aggregate, apply the optimizer step for iteration
    /// `k`, and return ||theta^{k+1} - theta^k||^2 for the drift history.
    /// Runs per-shard when the layout has more than one shard — on the
    /// persistent pool or per-round scoped threads per the configured
    /// [`ShardExec`] — and is bit-identical to the sequential path
    /// either way.
    pub fn fold_and_step(&mut self, k: u64, deltas: &[&[f32]],
                         compute: &mut dyn Compute) -> anyhow::Result<f64> {
        let inv_m = 1.0 / self.m as f32;
        let kernel = match self.opt.clone() {
            Optimizer::Amsgrad { alpha, beta1, beta2, eps, use_artifact } => {
                if use_artifact {
                    // the fused Pallas artifact consumes the full flat
                    // vectors; folds still shard, the step runs whole
                    // (and its time is attributed to shard 0)
                    self.run_shards(deltas, inv_m, None);
                    let t0 = Instant::now();
                    self.prev_theta.copy_from_slice(&self.theta);
                    compute.update(&mut self.theta, &mut self.h,
                                   &mut self.vhat, &self.grad_agg,
                                   alpha.at(k))?;
                    self.refresh_block_norms();
                    if let Some(t) = self.stats.shard_s.get_mut(0) {
                        *t += t0.elapsed().as_secs_f64();
                    }
                    self.close_round();
                    return Ok(self.block_norms.iter().sum());
                }
                StepKernel::Amsgrad {
                    alpha: alpha.at(k),
                    beta1,
                    beta2,
                    eps,
                }
            }
            Optimizer::Sgd { eta } => StepKernel::Sgd { eta: eta.at(k) },
        };
        self.run_shards(deltas, inv_m, Some(kernel));
        self.close_round();
        Ok(self.block_norms.iter().sum())
    }

    /// Bump every shard's version and count the round (the update writes
    /// every live range; empty surplus shards stay at version 0).
    fn close_round(&mut self) {
        for (s, v) in self.versions.iter_mut().enumerate() {
            if !self.layout.range(s).is_empty() {
                *v += 1;
            }
        }
        self.stats.rounds += 1;
    }

    /// Recompute every step-norm block sequentially (artifact path).
    fn refresh_block_norms(&mut self) {
        block_norms_into(&self.theta, &self.prev_theta,
                         &mut self.block_norms);
    }

    /// Split the state into per-shard tasks and run them — inline for a
    /// single shard, otherwise per [`ShardExec`]: on the persistent
    /// shard pool (the default — threads spawned once on the first
    /// multi-shard round, parked on mailboxes between rounds, two
    /// channel hops per shard per round) or on per-round scoped threads
    /// (the PR 3 reference; one spawn+join of ~tens of µs per shard per
    /// round, only amortised on ≥ 1M-parameter ranges). `kernel = None`
    /// folds only (artifact path applies the update afterwards). All
    /// three paths run the same [`ShardTask`] code over the same
    /// block-aligned ranges, so they are bit-identical.
    fn run_shards(&mut self, deltas: &[&[f32]], inv_m: f32,
                  kernel: Option<StepKernel>) {
        let n = self.layout.num_shards();
        if n == 1 || self.layout.num_blocks() <= 1 {
            // the reference path is literally one task spanning 0..p run
            // inline: sharded execution can never drift from it, because
            // it IS the same code. Also taken when p fits one reduction
            // block — then shard 0 owns 0..p and every other shard is
            // empty, so dispatching to threads would buy zero
            // parallelism (e.g. a small spec under `server_shards = 0`
            // on a many-core box).
            let task = ShardTask {
                s: 0,
                range: 0..self.theta.len(),
                theta: &mut self.theta,
                h: &mut self.h,
                vhat: &mut self.vhat,
                agg: &mut self.grad_agg,
                prev: &mut self.prev_theta,
                blocks: &mut self.block_norms,
            };
            let dt = match kernel {
                Some(kernel) => {
                    task.run(deltas.iter().copied(), inv_m, kernel)
                }
                None => task.fold_only(deltas.iter().copied(), inv_m),
            };
            self.stats.shard_s[0] += dt;
            return;
        }
        match self.exec {
            ShardExec::Pool => self.run_shards_pool(deltas, inv_m, kernel),
            ShardExec::Scoped => {
                self.run_shards_scoped(deltas, inv_m, kernel)
            }
        }
    }

    /// The spawn-free hot path: dispatch the round to the persistent
    /// pool (spawning it on first use) and fold the per-shard timings.
    fn run_shards_pool(&mut self, deltas: &[&[f32]], inv_m: f32,
                       kernel: Option<StepKernel>) {
        if self.pool.is_none() {
            self.pool = Some(ShardPool::spawn(&self.layout));
        }
        let pool = self.pool.as_mut().expect("spawned above");
        let timings = pool.run_round(PoolRound {
            theta: &mut self.theta,
            h: &mut self.h,
            vhat: &mut self.vhat,
            agg: &mut self.grad_agg,
            prev: &mut self.prev_theta,
            blocks: &mut self.block_norms,
            deltas,
            inv_m,
            kernel,
        });
        for (s, dt) in timings {
            self.stats.shard_s[s] += dt;
        }
    }

    /// The per-round scoped reference: safe borrow-splitting, one
    /// spawn+join per shard per round.
    fn run_shards_scoped(&mut self, deltas: &[&[f32]], inv_m: f32,
                         kernel: Option<StepKernel>) {
        let n = self.layout.num_shards();
        let mut tasks: Vec<ShardTask> = Vec::with_capacity(n);
        {
            let mut theta = self.theta.as_mut_slice();
            let mut h = self.h.as_mut_slice();
            let mut vhat = self.vhat.as_mut_slice();
            let mut agg = self.grad_agg.as_mut_slice();
            let mut prev = self.prev_theta.as_mut_slice();
            let mut blocks = self.block_norms.as_mut_slice();
            for s in 0..n {
                let range = self.layout.range(s);
                let len = range.len();
                let nb = self.layout.block_range(s).len();
                let (t_head, t_tail) =
                    std::mem::take(&mut theta).split_at_mut(len);
                theta = t_tail;
                let (h_head, h_tail) =
                    std::mem::take(&mut h).split_at_mut(len);
                h = h_tail;
                let (v_head, v_tail) =
                    std::mem::take(&mut vhat).split_at_mut(len);
                vhat = v_tail;
                let (a_head, a_tail) =
                    std::mem::take(&mut agg).split_at_mut(len);
                agg = a_tail;
                let (p_head, p_tail) =
                    std::mem::take(&mut prev).split_at_mut(len);
                prev = p_tail;
                let (b_head, b_tail) =
                    std::mem::take(&mut blocks).split_at_mut(nb);
                blocks = b_tail;
                tasks.push(ShardTask {
                    s,
                    range,
                    theta: t_head,
                    h: h_head,
                    vhat: v_head,
                    agg: a_head,
                    prev: p_head,
                    blocks: b_head,
                });
            }
        }
        let timings: Vec<(usize, f64)> = std::thread::scope(|scope| {
            let handles: Vec<_> = tasks
                .into_iter()
                .filter(|t| !t.range.is_empty())
                .map(|t| {
                    let s = t.s;
                    let handle = scope.spawn(move || match kernel {
                        Some(kernel) => {
                            t.run(deltas.iter().copied(), inv_m, kernel)
                        }
                        None => {
                            t.fold_only(deltas.iter().copied(), inv_m)
                        }
                    });
                    (s, handle)
                })
                .collect();
            handles
                .into_iter()
                .map(|(s, h)| match h.join() {
                    Ok(dt) => (s, dt),
                    Err(panic) => std::panic::resume_unwind(panic),
                })
                .collect()
        });
        for (s, dt) in timings {
            self.stats.shard_s[s] += dt;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::NativeLogReg;

    fn dummy_compute() -> NativeLogReg {
        NativeLogReg::for_spec(4, 16)
    }

    #[test]
    fn innovation_recursion_matches_direct_average() {
        // After each worker uploads delta = g_new - g_old, the aggregate
        // must equal mean(current stale gradients) — Eq. 3's invariant.
        let m = 3;
        let p = 8;
        let mut server = ServerState::new(
            vec![0.0; p], m,
            Optimizer::Sgd { eta: Schedule::Constant(0.0) });
        let mut rng = crate::util::rng::Rng::new(4);
        let mut held: Vec<Vec<f32>> = vec![vec![0.0; p]; m];
        for _round in 0..10 {
            for w in 0..m {
                if rng.f64() < 0.6 {
                    let g_new: Vec<f32> =
                        (0..p).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                    let delta: Vec<f32> = g_new
                        .iter()
                        .zip(&held[w])
                        .map(|(a, b)| a - b)
                        .collect();
                    server.apply_innovation(&delta);
                    held[w] = g_new;
                }
            }
            for i in 0..p {
                let direct: f32 =
                    held.iter().map(|g| g[i]).sum::<f32>() / m as f32;
                assert!((server.grad_agg[i] - direct).abs() < 1e-4,
                        "coord {i}: {} vs {direct}", server.grad_agg[i]);
            }
        }
    }

    #[test]
    fn sgd_step_moves_against_aggregate() {
        let mut s = ServerState::new(
            vec![1.0; 4], 1,
            Optimizer::Sgd { eta: Schedule::Constant(0.5) });
        s.grad_agg = vec![2.0; 4];
        let sq = s.step(0, &mut dummy_compute()).unwrap();
        assert!(s.theta.iter().all(|&t| (t - 0.0).abs() < 1e-6));
        assert!((sq - 4.0).abs() < 1e-6);
    }

    #[test]
    fn amsgrad_native_step_matches_tensor_kernel() {
        let p = 16;
        let mut s = ServerState::new(
            vec![0.5; p], 2,
            Optimizer::Amsgrad {
                alpha: Schedule::Constant(0.1),
                beta1: 0.9, beta2: 0.999, eps: 1e-8,
                use_artifact: false,
            });
        s.grad_agg = (0..p).map(|i| i as f32 * 0.1).collect();
        let mut theta = s.theta.clone();
        let mut h = s.h.clone();
        let mut vhat = s.vhat.clone();
        s.step(3, &mut dummy_compute()).unwrap();
        tensor::amsgrad_update(&mut theta, &mut h, &mut vhat, &s.grad_agg,
                               0.1, 0.9, 0.999, 1e-8);
        assert_eq!(s.theta, theta);
        assert_eq!(s.h, h);
        assert_eq!(s.vhat, vhat);
    }

    fn amsgrad(alpha: f32) -> Optimizer {
        Optimizer::Amsgrad {
            alpha: Schedule::Constant(alpha),
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            use_artifact: false,
        }
    }

    #[test]
    fn sharded_fold_and_step_is_bit_identical_to_single_shard() {
        // several blocks, uneven tail, random deltas: every shard count
        // must produce the exact same state AND the exact same step norm
        let p = 4096 + 513;
        let m = 3;
        let mut rng = crate::util::rng::Rng::new(11);
        let init: Vec<f32> =
            (0..p).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let rounds: Vec<Vec<Vec<f32>>> = (0..4)
            .map(|_| {
                (0..m)
                    .map(|_| {
                        (0..p).map(|_| rng.normal_f32(0.0, 0.1)).collect()
                    })
                    .collect()
            })
            .collect();
        let run = |shards: usize, exec: ShardExec| {
            let mut server = ServerState::new_sharded_with(
                init.clone(), m, amsgrad(0.05), shards, exec);
            let mut norms = Vec::new();
            for (k, deltas) in rounds.iter().enumerate() {
                let refs: Vec<&[f32]> =
                    deltas.iter().map(|d| d.as_slice()).collect();
                norms.push(
                    server
                        .fold_and_step(k as u64, &refs,
                                       &mut dummy_compute())
                        .unwrap(),
                );
            }
            (server.theta, server.h, server.vhat, server.grad_agg, norms)
        };
        let reference = run(1, ShardExec::Pool);
        for exec in [ShardExec::Pool, ShardExec::Scoped] {
            for shards in [2, 3, 4, 8, 64] {
                let label = format!("shards={shards} [{}]", exec.name());
                let sharded = run(shards, exec);
                assert_eq!(reference.0, sharded.0, "theta, {label}");
                assert_eq!(reference.1, sharded.1, "h, {label}");
                assert_eq!(reference.2, sharded.2, "vhat, {label}");
                assert_eq!(reference.3, sharded.3, "agg, {label}");
                assert_eq!(reference.4, sharded.4, "norms, {label}");
            }
        }
    }

    #[test]
    fn fold_and_step_matches_independent_reference() {
        // pin the fused pass against an INDEPENDENT inline reference
        // built straight from the tensor kernels: fold deltas/M in
        // order, one amsgrad step, and the documented step-norm
        // semantics — per-SHARD_BLOCK f32 partials summed in f64 in
        // block order (p = 2048 + 300: two full blocks and a tail, so
        // the blocked reduction genuinely differs from a flat one)
        let p = 2048 + 300;
        let mut rng = crate::util::rng::Rng::new(3);
        let init: Vec<f32> =
            (0..p).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let d0: Vec<f32> =
            (0..p).map(|_| rng.normal_f32(0.0, 0.5)).collect();
        let d1: Vec<f32> =
            (0..p).map(|_| rng.normal_f32(0.0, 0.5)).collect();

        let mut want_theta = init.clone();
        let mut want_h = vec![0.0f32; p];
        let mut want_vhat = vec![0.0f32; p];
        let mut want_agg = vec![0.0f32; p];
        tensor::axpy(&mut want_agg, 0.5, &d0);
        tensor::axpy(&mut want_agg, 0.5, &d1);
        tensor::amsgrad_update(&mut want_theta, &mut want_h,
                               &mut want_vhat, &want_agg, 0.1, 0.9,
                               0.999, 1e-8);
        let mut want_sq = 0.0f64;
        let mut lo = 0usize;
        while lo < p {
            let hi = (lo + crate::coordinator::shard::SHARD_BLOCK).min(p);
            want_sq +=
                tensor::sqnorm_diff(&want_theta[lo..hi], &init[lo..hi])
                    as f64;
            lo = hi;
        }

        // both the fused path and the two-phase (apply_innovation then
        // step) path must reproduce the reference exactly
        let mut fused = ServerState::new(init.clone(), 2, amsgrad(0.1));
        let sq_fused = fused
            .fold_and_step(5, &[&d0, &d1], &mut dummy_compute())
            .unwrap();
        assert_eq!(fused.theta, want_theta);
        assert_eq!(fused.h, want_h);
        assert_eq!(fused.vhat, want_vhat);
        assert_eq!(fused.grad_agg, want_agg);
        assert_eq!(sq_fused, want_sq);

        let mut two_phase = ServerState::new(init, 2, amsgrad(0.1));
        two_phase.apply_innovation(&d0);
        two_phase.apply_innovation(&d1);
        let sq_two = two_phase.step(5, &mut dummy_compute()).unwrap();
        assert_eq!(two_phase.theta, want_theta);
        assert_eq!(sq_two, want_sq);
    }

    #[test]
    fn versions_and_stats_track_rounds() {
        let mut s = ServerState::new_sharded(vec![0.0; 3000], 1,
                                             amsgrad(0.01), 4);
        assert_eq!(s.versions(), &[0, 0, 0, 0]);
        assert_eq!(s.layout().num_shards(), 4);
        s.step(0, &mut dummy_compute()).unwrap();
        s.step(1, &mut dummy_compute()).unwrap();
        // 3000 params = 3 blocks: shard 3 is empty and never dirties
        assert_eq!(s.versions(), &[2, 2, 2, 0]);
        assert_eq!(s.shard_stats().rounds, 2);
        assert_eq!(s.shard_stats().num_shards(), 4);
    }
}
