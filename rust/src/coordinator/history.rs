//! The rules' right-hand side: a ring buffer of the last `d_max` squared
//! parameter-step norms, RHS = (c / d_max) * sum_d ||theta^{k+1-d} -
//! theta^{k-d}||^2 (paper Eqs. 5/7/10).
//!
//! The paper initialises theta^{-D} ... theta^{-1} = theta^0, so missing
//! early entries contribute exactly zero — dividing by `d_max` (not by the
//! current fill level) reproduces that.

/// Ring buffer of squared step norms with O(1) push and O(1) sum.
#[derive(Clone, Debug)]
pub struct DeltaHistory {
    ring: Vec<f64>,
    head: usize,
    filled: usize,
    sum: f64,
    d_max: usize,
}

impl DeltaHistory {
    pub fn new(d_max: usize) -> Self {
        assert!(d_max >= 1);
        DeltaHistory {
            ring: vec![0.0; d_max],
            head: 0,
            filled: 0,
            sum: 0.0,
            d_max,
        }
    }

    /// Record ||theta^{k+1} - theta^k||^2 after a server step.
    pub fn push(&mut self, sq_step: f64) {
        debug_assert!(sq_step >= 0.0);
        self.sum -= self.ring[self.head];
        self.ring[self.head] = sq_step;
        self.sum += sq_step;
        self.head = (self.head + 1) % self.d_max;
        self.filled = (self.filled + 1).min(self.d_max);
        // fight drift: recompute exactly once per wrap
        if self.head == 0 {
            self.sum = self.ring.iter().sum();
        }
    }

    /// (c / d_max) * sum of stored squared step norms.
    pub fn rhs(&self, c: f32) -> f64 {
        c as f64 * self.sum / self.d_max as f64
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn filled(&self) -> usize {
        self.filled
    }

    pub fn d_max(&self) -> usize {
        self.d_max
    }

    /// Checkpoint view: `(ring, head, filled, sum)` — everything a
    /// [`DeltaHistory::import`] needs to resume bit-identically.
    pub fn export(&self) -> (&[f64], u64, u64, f64) {
        (&self.ring, self.head as u64, self.filled as u64, self.sum)
    }

    /// Rebuild from a checkpoint produced by [`DeltaHistory::export`]
    /// on a history with the same `d_max`.
    pub fn import(d_max: usize, ring: Vec<f64>, head: u64, filled: u64,
                  sum: f64) -> anyhow::Result<Self> {
        anyhow::ensure!(
            d_max >= 1 && ring.len() == d_max,
            "checkpoint history ring holds {} entries, the run's d_max \
             is {d_max}",
            ring.len()
        );
        anyhow::ensure!(
            (head as usize) < d_max && filled as usize <= d_max,
            "checkpoint history head {head} / fill {filled} out of \
             range for d_max {d_max}"
        );
        Ok(DeltaHistory {
            ring,
            head: head as usize,
            filled: filled as usize,
            sum,
            d_max,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_rhs_is_zero() {
        let h = DeltaHistory::new(10);
        assert_eq!(h.rhs(0.5), 0.0);
    }

    #[test]
    fn partial_fill_divides_by_dmax() {
        let mut h = DeltaHistory::new(4);
        h.push(2.0);
        // (c/d_max) * 2.0 with the three missing entries counted as 0
        assert!((h.rhs(1.0) - 0.5).abs() < 1e-12);
        assert_eq!(h.filled(), 1);
    }

    #[test]
    fn ring_evicts_oldest() {
        let mut h = DeltaHistory::new(3);
        for v in [1.0, 2.0, 3.0, 4.0] {
            h.push(v);
        }
        // window is now {2, 3, 4}
        assert!((h.sum() - 9.0).abs() < 1e-12);
        assert!((h.rhs(3.0) - 9.0).abs() < 1e-9);
    }

    #[test]
    fn matches_naive_over_long_sequence() {
        let mut h = DeltaHistory::new(7);
        let mut naive: Vec<f64> = Vec::new();
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..500 {
            let v = rng.f64();
            h.push(v);
            naive.push(v);
            let window: f64 =
                naive.iter().rev().take(7).sum();
            assert!((h.sum() - window).abs() < 1e-9);
        }
    }
}
