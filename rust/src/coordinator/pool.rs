//! Persistent shard pool: the spawn-free execution engine of the
//! sharded server round.
//!
//! PR 3's scoped threads spawned and joined one OS thread per shard per
//! round. The spawn+join pair costs tens of microseconds, so shard
//! counts > 1 only paid off on ≥ 1M-parameter ranges and mid-sized
//! specs were locked to `server_shards = 1`. Here the threads are
//! spawned ONCE (lazily, on the first multi-shard round of a
//! [`ServerState`](super::server::ServerState)), each permanently owns
//! its [`ShardLayout`] range, and between rounds they park on channel
//! mailboxes — exactly the persistent-worker design of the `Threaded`
//! transport in [`crate::comm::transport`]. A round is then two channel
//! hops per shard instead of a spawn+join, and the hot path allocates
//! nothing parameter-sized.
//!
//! Determinism: the pool runs the same
//! [`ShardTask`](super::server::ShardTask) code over the same
//! block-aligned ranges as the scoped path and the 1-shard inline
//! reference — worker order inside each shard and the fixed
//! 1024-element step-norm blocks are untouched — so all three execution
//! modes are bit-identical for every shard count, on every transport
//! (enforced by `tests/golden_parity.rs` and
//! `tests/properties.rs::prop_server_shards_bit_identical_to_one_shard`).
//!
//! # Safety
//!
//! The shard threads write through raw pointers into the server's flat
//! vectors. This is sound because [`ShardPool::run_round`]:
//!
//! 1. holds exclusive (`&mut`) borrows of every vector for the whole
//!    call, and never touches them itself between dispatch and the last
//!    completion;
//! 2. blocks until EVERY dispatched shard reports back before
//!    returning, so no thread can outlive the borrows it writes through
//!    (a panicking task still reports, via `catch_unwind`);
//! 3. hands each thread a disjoint range — [`ShardLayout`] ranges
//!    partition `0..p` exactly (property-tested), so two threads never
//!    alias.
//!
//! All `unsafe` in this crate lives in this file's two
//! `slice::from_raw_parts*` reconstructions.

use std::panic::AssertUnwindSafe;
use std::sync::mpsc;
use std::thread::JoinHandle;

use super::server::{ShardTask, StepKernel};
use super::shard::ShardLayout;
use crate::util::panic_message;

/// How the sharded server state executes its per-round fold+step pass
/// (the `[comm] shard_exec` knob / `--shard-exec`). A pure execution
/// strategy: both modes are bit-identical to the 1-shard reference.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ShardExec {
    /// Persistent shard pool: threads spawned once per run, parked on
    /// mailboxes between rounds (the default — profitable from
    /// mid-sized parameter ranges, ~64k, upward).
    #[default]
    Pool,
    /// One scoped spawn+join per shard per round (the PR 3 path, kept
    /// as the pool's correctness + perf reference; only amortised on
    /// ≥ 1M-parameter ranges).
    Scoped,
}

impl ShardExec {
    pub fn parse(s: &str) -> anyhow::Result<ShardExec> {
        match s {
            "pool" => Ok(ShardExec::Pool),
            "scoped" => Ok(ShardExec::Scoped),
            other => anyhow::bail!(
                "unknown shard_exec '{other}' (have: pool, scoped)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ShardExec::Pool => "pool",
            ShardExec::Scoped => "scoped",
        }
    }
}

/// One round's borrowed view of the full server state, handed to
/// [`ShardPool::run_round`]; shard threads carve their own fixed range
/// out of it.
pub(crate) struct PoolRound<'a> {
    pub(crate) theta: &'a mut [f32],
    pub(crate) h: &'a mut [f32],
    pub(crate) vhat: &'a mut [f32],
    pub(crate) agg: &'a mut [f32],
    pub(crate) prev: &'a mut [f32],
    pub(crate) blocks: &'a mut [f64],
    /// full-length innovation vectors, in fold (upload) order
    pub(crate) deltas: &'a [&'a [f32]],
    pub(crate) inv_m: f32,
    /// `None` folds only (artifact path applies the update afterwards)
    pub(crate) kernel: Option<StepKernel>,
}

/// Raw (pointer, len) image of [`PoolRound`], sent by value to every
/// shard thread each round. See the module-level safety argument.
#[derive(Clone, Copy)]
struct RoundRaw {
    theta: *mut f32,
    h: *mut f32,
    vhat: *mut f32,
    agg: *mut f32,
    prev: *mut f32,
    blocks: *mut f64,
    /// base pointers + lens of the round's full-length delta slices
    deltas: *const (*const f32, usize),
    n_deltas: usize,
    inv_m: f32,
    kernel: Option<StepKernel>,
}

// SAFETY: the pointers target disjoint-per-thread ranges of buffers the
// dispatching `run_round` call exclusively borrows until every thread
// reports completion (see the module docs).
unsafe impl Send for RoundRaw {}

enum ToShard {
    Round(RoundRaw),
    Shutdown,
}

struct FromShard {
    s: usize,
    /// wall seconds the shard spent, or a rendered panic payload
    outcome: Result<f64, String>,
}

/// The persistent pool: one parked thread per non-empty shard, each
/// owning its element + block range for the life of the pool.
pub struct ShardPool {
    /// `(shard id, mailbox)` for every thread-backed shard
    mailboxes: Vec<(usize, mpsc::Sender<ToShard>)>,
    results: mpsc::Receiver<FromShard>,
    handles: Vec<JoinHandle<()>>,
    /// element / reduction-block counts the spawn-time ranges index
    /// into (every round's buffers must match — safety invariant)
    p: usize,
    nblocks: usize,
}

impl ShardPool {
    /// Spawn one thread per NON-EMPTY shard of `layout` (surplus shards
    /// own no elements and would only burn a parked thread). Panics on
    /// OS thread-spawn failure — resource exhaustion at `<= 1024`
    /// validated shards is not a recoverable configuration error.
    pub fn spawn(layout: &ShardLayout) -> ShardPool {
        let (res_tx, res_rx) = mpsc::channel::<FromShard>();
        let mut mailboxes = Vec::new();
        let mut handles = Vec::new();
        for s in 0..layout.num_shards() {
            let range = layout.range(s);
            if range.is_empty() {
                continue;
            }
            let block_range = layout.block_range(s);
            let (tx, rx) = mpsc::channel::<ToShard>();
            let out = res_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("cada-shard-{s}"))
                .spawn(move || {
                    while let Ok(msg) = rx.recv() {
                        match msg {
                            ToShard::Round(raw) => {
                                let outcome = std::panic::catch_unwind(
                                    AssertUnwindSafe(|| {
                                        run_shard(s, &range, &block_range,
                                                  raw)
                                    }))
                                .map_err(|panic| {
                                    panic_message(panic.as_ref())
                                        .to_string()
                                });
                                if out.send(FromShard { s, outcome })
                                    .is_err()
                                {
                                    break; // pool side is gone
                                }
                            }
                            ToShard::Shutdown => break,
                        }
                    }
                })
                .unwrap_or_else(|e| {
                    panic!("spawning shard-pool thread {s}: {e}")
                });
            mailboxes.push((s, tx));
            handles.push(handle);
        }
        // drop the spawn-side result sender: `recv` must error (instead
        // of parking forever) if every thread is somehow gone
        drop(res_tx);
        ShardPool {
            mailboxes,
            results: res_rx,
            handles,
            p: layout.p(),
            nblocks: layout.num_blocks(),
        }
    }

    /// Number of (non-empty, thread-backed) shards.
    pub fn workers(&self) -> usize {
        self.mailboxes.len()
    }

    /// Execute one fold(+step) round across the pool and block until
    /// every shard is done. Returns `(shard, wall_seconds)` per shard,
    /// in completion order. Propagates any shard panic AFTER all other
    /// shards settled, so a failed round never leaves stale completions
    /// behind for the next one.
    pub(crate) fn run_round(&mut self, round: PoolRound<'_>)
                            -> Vec<(usize, f64)> {
        // the threads' spawn-time ranges index into these buffers: a
        // length mismatch would void the safety argument, so check it
        // here (cheap — once per round) rather than trust the caller
        assert!(round.theta.len() == self.p
                    && round.h.len() == self.p
                    && round.vhat.len() == self.p
                    && round.agg.len() == self.p
                    && round.prev.len() == self.p
                    && round.blocks.len() == self.nblocks,
                "pool round buffers disagree with the spawn layout");
        // raw images of the round's delta slices; lives until every
        // completion arrived, i.e. strictly longer than any reader
        let delta_raw: Vec<(*const f32, usize)> = round
            .deltas
            .iter()
            .map(|d| (d.as_ptr(), d.len()))
            .collect();
        let raw = RoundRaw {
            theta: round.theta.as_mut_ptr(),
            h: round.h.as_mut_ptr(),
            vhat: round.vhat.as_mut_ptr(),
            agg: round.agg.as_mut_ptr(),
            prev: round.prev.as_mut_ptr(),
            blocks: round.blocks.as_mut_ptr(),
            deltas: delta_raw.as_ptr(),
            n_deltas: delta_raw.len(),
            inv_m: round.inv_m,
            kernel: round.kernel,
        };
        let mut dispatched = 0usize;
        let mut dead: Option<usize> = None;
        for (s, tx) in &self.mailboxes {
            if tx.send(ToShard::Round(raw)).is_err() {
                // that thread already panicked out of an earlier round.
                // Stop dispatching, but KEEP the round barrier over what
                // was already sent: unwinding right here would release
                // the `&mut` borrows (and free `delta_raw`) while the
                // dispatched shards still write through the raw
                // pointers — the exact UB the safety argument forbids.
                dead = Some(*s);
                break;
            }
            dispatched += 1;
        }
        let mut timings = Vec::with_capacity(dispatched);
        let mut panicked: Option<String> = None;
        for _ in 0..dispatched {
            match self.results.recv() {
                Ok(FromShard { s, outcome }) => match outcome {
                    Ok(dt) => timings.push((s, dt)),
                    Err(msg) => panicked = Some(format!(
                        "shard-pool thread {s} panicked: {msg}")),
                },
                Err(_) => {
                    // recv only errors once every thread has exited —
                    // nothing holds the round's pointers any more
                    panicked = Some(
                        "shard-pool threads exited before completing \
                         the round"
                            .to_string(),
                    );
                    break;
                }
            }
        }
        if let Some(s) = dead {
            panic!("shard-pool thread {s} is gone");
        }
        if let Some(msg) = panicked {
            panic!("{msg}");
        }
        timings
    }
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        for (_, tx) in &self.mailboxes {
            let _ = tx.send(ToShard::Shutdown);
        }
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

/// Reconstruct shard `s`'s disjoint slices from the round image and run
/// the shared [`ShardTask`] over them. Runs on the shard's own thread.
fn run_shard(s: usize, range: &std::ops::Range<usize>,
             block_range: &std::ops::Range<usize>, raw: RoundRaw) -> f64 {
    let len = range.len();
    let nb = block_range.len();
    // SAFETY: `run_round` exclusively borrows the underlying vectors and
    // blocks until this function's completion message is received;
    // `range` / `block_range` come from the same ShardLayout for every
    // thread, and layout ranges partition 0..p (resp. 0..nblocks)
    // disjointly — so each `from_raw_parts_mut` slice is uniquely owned
    // by this thread for the duration of the call. The delta images are
    // read-only and outlive the call the same way.
    unsafe {
        let task = ShardTask {
            s,
            range: range.clone(),
            theta: std::slice::from_raw_parts_mut(
                raw.theta.add(range.start), len),
            h: std::slice::from_raw_parts_mut(raw.h.add(range.start), len),
            vhat: std::slice::from_raw_parts_mut(
                raw.vhat.add(range.start), len),
            agg: std::slice::from_raw_parts_mut(
                raw.agg.add(range.start), len),
            prev: std::slice::from_raw_parts_mut(
                raw.prev.add(range.start), len),
            blocks: std::slice::from_raw_parts_mut(
                raw.blocks.add(block_range.start), nb),
        };
        let delta_raw =
            std::slice::from_raw_parts(raw.deltas, raw.n_deltas);
        // lazily reconstruct each delta slice as the fold consumes it:
        // no per-round collection on the hot path
        let deltas = delta_raw.iter().map(|&(ptr, len)|
            // SAFETY: same argument as above — read-only images held
            // alive by `run_round` until this shard reports completion
            unsafe { std::slice::from_raw_parts(ptr, len) });
        match raw.kernel {
            Some(kernel) => task.run(deltas, raw.inv_m, kernel),
            None => task.fold_only(deltas, raw.inv_m),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_exec_parses() {
        assert_eq!(ShardExec::parse("pool").unwrap(), ShardExec::Pool);
        assert_eq!(ShardExec::parse("scoped").unwrap(), ShardExec::Scoped);
        assert!(ShardExec::parse("fork-per-round").is_err());
        assert_eq!(ShardExec::Pool.name(), "pool");
        assert_eq!(ShardExec::Scoped.name(), "scoped");
        assert_eq!(ShardExec::default(), ShardExec::Pool);
    }

    #[test]
    fn pool_spawns_only_non_empty_shards() {
        // 3000 params = 3 blocks: shard 4 of 4 owns nothing
        let layout = ShardLayout::new(3000, 4);
        let pool = ShardPool::spawn(&layout);
        assert_eq!(pool.workers(), 3);
        // p < one block: everything lives in shard 0
        let tiny = ShardPool::spawn(&ShardLayout::new(100, 8));
        assert_eq!(tiny.workers(), 1);
    }

    #[test]
    fn pool_round_folds_and_times_every_shard() {
        // a pure fold round (kernel = None) has an exact expected
        // result: agg += inv_m * (d0 + d1), elementwise, per shard
        let p = 4096 + 200;
        let layout = ShardLayout::new(p, 3);
        let mut pool = ShardPool::spawn(&layout);
        let mut theta = vec![0.0f32; p];
        let mut h = vec![0.0f32; p];
        let mut vhat = vec![0.0f32; p];
        let mut agg = vec![1.0f32; p];
        let mut prev = vec![0.0f32; p];
        let mut blocks = vec![0.0f64; layout.num_blocks()];
        let d0: Vec<f32> = (0..p).map(|i| i as f32).collect();
        let d1: Vec<f32> = (0..p).map(|i| -2.0 * i as f32).collect();
        for round in 0..3 {
            let deltas: Vec<&[f32]> = vec![&d0, &d1];
            let timings = pool.run_round(PoolRound {
                theta: &mut theta,
                h: &mut h,
                vhat: &mut vhat,
                agg: &mut agg,
                prev: &mut prev,
                blocks: &mut blocks,
                deltas: &deltas,
                inv_m: 0.5,
                kernel: None,
            });
            assert_eq!(timings.len(), 3, "round {round}");
            let mut shards: Vec<usize> =
                timings.iter().map(|&(s, _)| s).collect();
            shards.sort_unstable();
            assert_eq!(shards, vec![0, 1, 2]);
        }
        // 3 rounds of += 0.5*(i - 2i) = -0.5*i each
        for i in 0..p {
            let want = 1.0 + 3.0 * (-0.5 * i as f32);
            assert_eq!(agg[i], want, "coord {i}");
        }
    }

    #[test]
    fn pool_propagates_shard_panics_without_deadlock() {
        // an out-of-range delta makes exactly the LAST shard's
        // `&d[range]` slicing panic; run_round must drain the healthy
        // completions and re-panic with the shard's message
        let p = 2048;
        let layout = ShardLayout::new(p, 2);
        let mut pool = ShardPool::spawn(&layout);
        let mut theta = vec![0.0f32; p];
        let mut h = vec![0.0f32; p];
        let mut vhat = vec![0.0f32; p];
        let mut agg = vec![0.0f32; p];
        let mut prev = vec![0.0f32; p];
        let mut blocks = vec![0.0f64; layout.num_blocks()];
        let short = vec![0.0f32; 1024]; // covers shard 0 only
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let deltas: Vec<&[f32]> = vec![&short];
            pool.run_round(PoolRound {
                theta: &mut theta,
                h: &mut h,
                vhat: &mut vhat,
                agg: &mut agg,
                prev: &mut prev,
                blocks: &mut blocks,
                deltas: &deltas,
                inv_m: 1.0,
                kernel: None,
            });
        }));
        let payload = result.unwrap_err();
        let msg = panic_message(payload.as_ref());
        assert!(msg.contains("shard-pool thread 1 panicked"), "{msg}");
    }
}
