//! Minimal CLI flag parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments; unknown flags are an error so typos fail fast.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    /// flags that were consumed by a getter (for unknown-flag detection)
    known: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> anyhow::Result<Args> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(rest) = arg.strip_prefix("--") {
                if rest.is_empty() {
                    // `--` terminates flags
                    out.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().expect("peeked");
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> anyhow::Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.known.borrow_mut().push(key.to_string());
    }

    pub fn str_opt(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.flags.get(key).map(String::as_str)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.str_opt(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{key}={v}: {e}")),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{key}={v}: {e}")),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        match self.str_opt(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{key}={v}: {e}")),
        }
    }

    pub fn f32_or(&self, key: &str, default: f32) -> anyhow::Result<f32> {
        Ok(self.f64_or(key, default as f64)? as f32)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.str_opt(key), Some("true") | Some("1") | Some("yes"))
    }

    /// Error on any flag no getter asked about (call after all getters).
    pub fn reject_unknown(&self) -> anyhow::Result<()> {
        let known = self.known.borrow();
        for key in self.flags.keys() {
            if !known.iter().any(|k| k == key) {
                anyhow::bail!("unknown flag --{key}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn mixed_forms() {
        let a = parse(&["train", "--spec=logreg", "--iters", "100",
                        "--verbose", "--runs", "3"]);
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.str_or("spec", ""), "logreg");
        assert_eq!(a.usize_or("iters", 0).unwrap(), 100);
        assert!(a.bool("verbose"));
        assert_eq!(a.usize_or("runs", 1).unwrap(), 3);
        assert_eq!(a.usize_or("absent", 7).unwrap(), 7);
        a.reject_unknown().unwrap();
    }

    #[test]
    fn unknown_flag_rejected() {
        let a = parse(&["--tyop", "x"]);
        let _ = a.str_opt("typo");
        assert!(a.reject_unknown().is_err());
    }

    #[test]
    fn double_dash_stops_flags() {
        let a = parse(&["--a", "1", "--", "--not-a-flag"]);
        assert_eq!(a.str_or("a", ""), "1");
        assert_eq!(a.positional, vec!["--not-a-flag"]);
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&["--iters", "ten"]);
        assert!(a.usize_or("iters", 0).is_err());
    }
}
