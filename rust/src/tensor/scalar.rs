//! The scalar golden twins of every dispatched tensor kernel.
//!
//! These are the pre-SIMD kernel bodies, moved here verbatim when the
//! dispatch layer landed: 4-way unrolled loops that LLVM autovectorises,
//! with the remainder loop handling the tail. They are the *reference
//! semantics* of the crate — every [`super::simd`] kernel is pinned
//! against its twin here by the comparator tests (bit-identical for the
//! elementwise kernels, fixed-order-twin + tolerance for the
//! reductions), exactly like the PR-3/PR-4 determinism trades.
//!
//! Do not "optimise" these: their float association order is part of the
//! documented contract.

use super::GER_GROUP;

/// y += a * x
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * *xi;
    }
}

/// dot product — four f32 accumulator lanes over the 4-chunks, lanes
/// summed left to right, then the scalar tail in element order.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * b[j];
        acc[1] += a[j + 1] * b[j + 1];
        acc[2] += a[j + 2] * b[j + 2];
        acc[3] += a[j + 3] * b[j + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for j in chunks * 4..a.len() {
        s += a[j] * b[j];
    }
    s
}

/// ||x||^2
pub fn sqnorm(x: &[f32]) -> f32 {
    dot(x, x)
}

/// ||a - b||^2 — single fused pass, same lane structure as [`dot`].
pub fn sqnorm_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        let d0 = a[j] - b[j];
        let d1 = a[j + 1] - b[j + 1];
        let d2 = a[j + 2] - b[j + 2];
        let d3 = a[j + 3] - b[j + 3];
        acc[0] += d0 * d0;
        acc[1] += d1 * d1;
        acc[2] += d2 * d2;
        acc[3] += d3 * d3;
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for j in chunks * 4..a.len() {
        let d = a[j] - b[j];
        s += d * d;
    }
    s
}

/// Blocked GEMV logits pass — rows two at a time, each row accumulated
/// in [`dot`]'s exact order, so every `z[i]` is bit-identical to
/// `dot(&x[i*d..(i+1)*d], w)`.
pub fn gemv_block(z: &mut [f32], x: &[f32], w: &[f32]) {
    let d = w.len();
    assert_eq!(x.len(), z.len() * d);
    let rows = z.len();
    let chunks = d / 4;
    let mut i = 0;
    while i + 1 < rows {
        let x0 = &x[i * d..(i + 1) * d];
        let x1 = &x[(i + 1) * d..(i + 2) * d];
        let mut a0 = [0.0f32; 4];
        let mut a1 = [0.0f32; 4];
        for c in 0..chunks {
            let j = c * 4;
            a0[0] += x0[j] * w[j];
            a0[1] += x0[j + 1] * w[j + 1];
            a0[2] += x0[j + 2] * w[j + 2];
            a0[3] += x0[j + 3] * w[j + 3];
            a1[0] += x1[j] * w[j];
            a1[1] += x1[j + 1] * w[j + 1];
            a1[2] += x1[j + 2] * w[j + 2];
            a1[3] += x1[j + 3] * w[j + 3];
        }
        let mut s0 = a0[0] + a0[1] + a0[2] + a0[3];
        let mut s1 = a1[0] + a1[1] + a1[2] + a1[3];
        for j in chunks * 4..d {
            s0 += x0[j] * w[j];
            s1 += x1[j] * w[j];
        }
        z[i] = s0;
        z[i + 1] = s1;
        i += 2;
    }
    if i < rows {
        z[i] = dot(&x[i * d..(i + 1) * d], w);
    }
}

/// Blocked rank-accumulation `g += Xᵀ r` with the FIXED documented
/// order: rows fold in groups of [`GER_GROUP`] = 4 (in row order), and
/// within a group each coordinate accumulates
/// `g[j] += (r0*x0[j] + r1*x1[j]) + (r2*x2[j] + r3*x3[j])`;
/// trailing rows (< 4) fold one at a time in row order.
pub fn ger_acc(g: &mut [f32], x: &[f32], r: &[f32]) {
    let d = g.len();
    assert_eq!(x.len(), r.len() * d);
    let rows = r.len();
    let groups = rows / GER_GROUP;
    for gi in 0..groups {
        let i = gi * GER_GROUP;
        let (r0, r1, r2, r3) = (r[i], r[i + 1], r[i + 2], r[i + 3]);
        let x0 = &x[i * d..(i + 1) * d];
        let x1 = &x[(i + 1) * d..(i + 2) * d];
        let x2 = &x[(i + 2) * d..(i + 3) * d];
        let x3 = &x[(i + 3) * d..(i + 4) * d];
        for j in 0..d {
            g[j] +=
                (r0 * x0[j] + r1 * x1[j]) + (r2 * x2[j] + r3 * x3[j]);
        }
    }
    for i in groups * GER_GROUP..rows {
        let ri = r[i];
        let xi = &x[i * d..(i + 1) * d];
        for j in 0..d {
            g[j] += ri * xi[j];
        }
    }
}

/// out = a - b
pub fn sub_into(out: &mut [f32], a: &[f32], b: &[f32]) {
    assert_eq!(out.len(), a.len());
    assert_eq!(a.len(), b.len());
    for ((o, &x), &y) in out.iter_mut().zip(a.iter()).zip(b.iter()) {
        *o = x - y;
    }
}

/// x *= a
pub fn scale(x: &mut [f32], a: f32) {
    for v in x.iter_mut() {
        *v *= a;
    }
}

/// Fused AMSGrad/CADA step (paper Eq. 2a–2c), per element:
/// `h' = beta1*h + (1-beta1)*g`, `v = beta2*vhat + (1-beta2)*g*g`,
/// `vhat' = max(v, vhat)`, `theta -= alpha*h' / sqrt(eps + vhat')`.
#[allow(clippy::too_many_arguments)]
pub fn amsgrad_update(
    theta: &mut [f32],
    h: &mut [f32],
    vhat: &mut [f32],
    grad: &[f32],
    alpha: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
) {
    assert_eq!(theta.len(), h.len());
    assert_eq!(theta.len(), vhat.len());
    assert_eq!(theta.len(), grad.len());
    for i in 0..theta.len() {
        let g = grad[i];
        let h_new = beta1 * h[i] + (1.0 - beta1) * g;
        let v_new = beta2 * vhat[i] + (1.0 - beta2) * g * g;
        let vhat_new = v_new.max(vhat[i]);
        theta[i] -= alpha * h_new / (eps + vhat_new).sqrt();
        h[i] = h_new;
        vhat[i] = vhat_new;
    }
}

/// Fused logistic pair: (sigmoid(z), softplus(z)) from ONE exponential.
///
/// With `t = e^{-|z|}` (the only transcendental):
/// `softplus(z) = max(z, 0) + ln1p(t)` and `sigmoid(z) = 1/(1+t)` for
/// `z >= 0`, `t/(1+t)` for `z < 0`. For `z >= 0` the sigmoid is
/// bit-identical to the historical `1/(1+e^{-z})`; for `z < 0` it
/// differs in the last ulps (same mathematical value, better
/// conditioning), which the comparator test in `runtime::native` bounds.
#[inline]
pub fn sigmoid_softplus(z: f32) -> (f32, f32) {
    let t = (-z.abs()).exp();
    let sp = z.max(0.0) + t.ln_1p();
    let sig = if z >= 0.0 { 1.0 / (1.0 + t) } else { t / (1.0 + t) };
    (sig, sp)
}

/// Block form of [`sigmoid_softplus`]: one fused activation pair per
/// element of `z`, in element order. Bit-identical to calling the scalar
/// helper per element (it does exactly that).
pub fn sigmoid_softplus_block(z: &[f32], sig: &mut [f32], sp: &mut [f32]) {
    assert_eq!(z.len(), sig.len());
    assert_eq!(z.len(), sp.len());
    for i in 0..z.len() {
        let (s, p) = sigmoid_softplus(z[i]);
        sig[i] = s;
        sp[i] = p;
    }
}
