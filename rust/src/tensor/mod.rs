//! Flat f32 vector math for the coordinator hot path.
//!
//! Every parameter-sized object in the system is a flat `Vec<f32>` of
//! length `p_pad` (tile aligned by the AOT pipeline). These kernels are
//! the *native* counterparts of the L1 Pallas artifacts — used (a) as the
//! fast path for rule checks, (b) as an independent comparator for the
//! HLO/Pallas numerics in integration tests, and (c) by the native grad
//! backend for pure-rust sweeps.
//!
//! # Two kernel sets, one dispatch
//!
//! Each public kernel here dispatches between two implementations:
//!
//! * [`scalar`] — the golden reference: 4-way unrolled loops whose float
//!   association order is part of the documented contract. This is the
//!   DEFAULT (the `simd` cargo feature is off by default).
//! * [`simd`] — explicit 8-lane (`f32x8`) kernels: AVX intrinsics on
//!   x86_64 with a bit-identical portable emulation elsewhere. Selected
//!   only when the crate is built with `--features simd` AND the
//!   `CADA_SIMD` env knob doesn't opt out ([`simd::enabled`]).
//!
//! **Scalar-twin policy** (the PR-3/PR-4 determinism trades, extended):
//! elementwise kernels ([`axpy`], [`scale`], [`sub_into`], [`ger_acc`],
//! [`amsgrad_update`], [`sigmoid_softplus_block`]) are bit-identical
//! across the two sets; reductions ([`dot`], [`sqnorm`],
//! [`sqnorm_diff`], [`gemv_block`]'s row dots) differ — 4 accumulator
//! lanes vs a documented fixed 8-lane order — and are comparator-pinned:
//! bit-for-bit against an inline fixed-order twin, tolerance-bounded
//! against the scalar twin (see `simd`'s module docs). Dispatch is
//! process-wide and uniform, so any single run is self-consistent and
//! the golden run-vs-run parity suites (transports, shard counts) hold
//! under either kernel set.
//!
//! [`gemv_block`] / [`ger_acc`] are the batch-level kernels of the
//! native backend's blocked gradient path: one pass computing a sample
//! block's logits (bit-identical to per-row [`dot`] *of the active
//! set*), one pass folding the residuals into the gradient with a fixed,
//! documented group-of-4 accumulation order (pinned by the comparator
//! tests in [`crate::runtime::native`]).

pub mod scalar;
pub mod simd;

/// True when kernel calls dispatch to the [`simd`] set. `cfg!` makes
/// the whole check const-false (and the branch dead) without the `simd`
/// feature; with it, the cached [`simd::enabled`] knob decides once per
/// process.
#[inline]
pub fn simd_active() -> bool {
    cfg!(feature = "simd") && simd::enabled()
}

/// y += a * x
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    if simd_active() {
        simd::axpy(y, a, x)
    } else {
        scalar::axpy(y, a, x)
    }
}

/// dot product
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    if simd_active() {
        simd::dot(a, b)
    } else {
        scalar::dot(a, b)
    }
}

/// ||x||^2
pub fn sqnorm(x: &[f32]) -> f32 {
    dot(x, x)
}

/// ||a - b||^2 — the innovation norm, LHS of rules (5)/(7)/(10).
/// Single fused pass (no temporary difference vector).
pub fn sqnorm_diff(a: &[f32], b: &[f32]) -> f32 {
    if simd_active() {
        simd::sqnorm_diff(a, b)
    } else {
        scalar::sqnorm_diff(a, b)
    }
}

/// Rows per fixed accumulation group of [`ger_acc`]. The blocked
/// gradient kernel's block size must be a multiple of this so the group
/// boundaries — and therefore every bit of the accumulated gradient —
/// are independent of how the sample batch is blocked.
pub const GER_GROUP: usize = 4;

/// Blocked GEMV logits pass: `z[i] = dot(x[i*d .. (i+1)*d], w)` for
/// every row `i` of the row-major sample block `x` (`d = w.len()`).
///
/// Rows are processed two at a time so one streamed read of `w` feeds
/// two dot products, but each row's accumulation follows the active
/// set's [`dot`] exactly — rows are independent, so every `z[i]` is
/// bit-identical to `dot(&x[i*d..(i+1)*d], w)` whatever the row
/// blocking. Pinned by `gemv_block_bit_equals_per_row_dot` (which runs
/// under whichever set is dispatched).
pub fn gemv_block(z: &mut [f32], x: &[f32], w: &[f32]) {
    if simd_active() {
        simd::gemv_block(z, x, w)
    } else {
        scalar::gemv_block(z, x, w)
    }
}

/// Blocked rank-accumulation `g += Xᵀ r` over a row-major sample block
/// (`d = g.len()`, row `i` is `x[i*d .. (i+1)*d]` with residual `r[i]`).
///
/// The accumulation order is FIXED and documented — it is what the
/// comparator test in `runtime::native` pins bit-for-bit: rows fold in
/// groups of [`GER_GROUP`] = 4 (in row order), and within a group each
/// coordinate accumulates
/// `g[j] += (r0*x0[j] + r1*x1[j]) + (r2*x2[j] + r3*x3[j])`;
/// trailing rows (< 4) fold one at a time in row order. One read-write
/// pass over `g` per group instead of one per row is where the win
/// comes from; both kernel sets share this exact order (the simd set
/// vectorises across coordinates, bit-identically). NOTE: this is a
/// different float summation order than the historical sample-at-a-time
/// `axpy` loop — a deliberate PR-3-style determinism trade (the old
/// order is retained as `NativeLogReg::loss_grad_scalar` for tolerance
/// comparison).
pub fn ger_acc(g: &mut [f32], x: &[f32], r: &[f32]) {
    if simd_active() {
        simd::ger_acc(g, x, r)
    } else {
        scalar::ger_acc(g, x, r)
    }
}

/// out = a - b
pub fn sub_into(out: &mut [f32], a: &[f32], b: &[f32]) {
    if simd_active() {
        simd::sub_into(out, a, b)
    } else {
        scalar::sub_into(out, a, b)
    }
}

/// x *= a
pub fn scale(x: &mut [f32], a: f32) {
    if simd_active() {
        simd::scale(x, a)
    } else {
        scalar::scale(x, a)
    }
}

/// Native fused AMSGrad/CADA step — the rust twin of the Pallas
/// `cada_update` kernel (paper Eq. 2a–2c), used as its comparator and as
/// the fast in-process update backend. Bit-identical across kernel sets
/// for finite inputs.
#[allow(clippy::too_many_arguments)]
pub fn amsgrad_update(
    theta: &mut [f32],
    h: &mut [f32],
    vhat: &mut [f32],
    grad: &[f32],
    alpha: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
) {
    if simd_active() {
        simd::amsgrad_update(theta, h, vhat, grad, alpha, beta1, beta2, eps)
    } else {
        scalar::amsgrad_update(theta, h, vhat, grad, alpha, beta1, beta2, eps)
    }
}

/// Fused logistic pair: (sigmoid(z), softplus(z)) from ONE exponential
/// (see [`scalar::sigmoid_softplus`] for the numerics). Single-value
/// form — no dispatch (there is nothing to vectorise at width 1).
pub use scalar::sigmoid_softplus;

/// Block form of [`sigmoid_softplus`]: activation pairs for a whole
/// logits block, in element order. Bit-identical across kernel sets
/// (the transcendentals stay scalar per lane by policy).
pub fn sigmoid_softplus_block(z: &[f32], sig: &mut [f32], sp: &mut [f32]) {
    if simd_active() {
        simd::sigmoid_softplus_block(z, sig, sp)
    } else {
        scalar::sigmoid_softplus_block(z, sig, sp)
    }
}

/// Plain SGD step (LAG baseline; paper Eq. 4): theta -= eta * grad.
pub fn sgd_update(theta: &mut [f32], grad: &[f32], eta: f32) {
    axpy(theta, -eta, grad);
}

/// Heavy-ball momentum step: u = beta*u + grad; theta -= eta*u.
pub fn momentum_update(theta: &mut [f32], u: &mut [f32], grad: &[f32],
                       eta: f32, beta: f32) {
    assert_eq!(theta.len(), u.len());
    assert_eq!(theta.len(), grad.len());
    for i in 0..theta.len() {
        u[i] = beta * u[i] + grad[i];
        theta[i] -= eta * u[i];
    }
}

/// Mean of several equally-weighted vectors into `out`.
pub fn mean_into(out: &mut [f32], parts: &[&[f32]]) {
    assert!(!parts.is_empty());
    let scale_by = 1.0 / parts.len() as f32;
    out.fill(0.0);
    for part in parts {
        axpy(out, scale_by, part);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: f32, b: f32, tol: f32) {
        assert!((a - b).abs() <= tol * (1.0 + b.abs()), "{a} vs {b}");
    }

    #[test]
    fn dot_and_norms() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [5.0, 4.0, 3.0, 2.0, 1.0];
        approx(dot(&a, &b), 35.0, 1e-6);
        approx(sqnorm(&a), 55.0, 1e-6);
        approx(sqnorm_diff(&a, &b), 16.0 + 4.0 + 0.0 + 4.0 + 16.0, 1e-6);
    }

    #[test]
    fn sqnorm_diff_matches_two_pass() {
        let mut rng = crate::util::rng::Rng::new(5);
        let a: Vec<f32> = (0..1031).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let b: Vec<f32> = (0..1031).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut d = vec![0.0; a.len()];
        sub_into(&mut d, &a, &b);
        approx(sqnorm_diff(&a, &b), sqnorm(&d), 1e-5);
    }

    /// Whichever set is dispatched, the dispatched kernels agree with
    /// the scalar golden twins: exactly for the elementwise ones, to
    /// reduction tolerance for the rest. (The bit-level pins per set
    /// live in `simd::tests`.)
    #[test]
    fn dispatched_kernels_match_scalar_twins() {
        let mut rng = crate::util::rng::Rng::new(77);
        let n = 1025;
        let a: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();

        let mut y0 = b.clone();
        let mut y1 = b.clone();
        scalar::axpy(&mut y0, 0.37, &a);
        axpy(&mut y1, 0.37, &a);
        assert_eq!(y0, y1);

        let mut o0 = vec![0.0; n];
        let mut o1 = vec![0.0; n];
        scalar::sub_into(&mut o0, &a, &b);
        sub_into(&mut o1, &a, &b);
        assert_eq!(o0, o1);

        approx(dot(&a, &b), scalar::dot(&a, &b), 1e-4);
        approx(sqnorm_diff(&a, &b), scalar::sqnorm_diff(&a, &b), 1e-4);
    }

    #[test]
    fn gemv_block_bit_equals_per_row_dot() {
        // the logits pass must be bit-identical to one dot() per row for
        // every (rows, d) shape: even/odd row counts, d not a multiple
        // of 4, d < 4, d = 0
        let mut rng = crate::util::rng::Rng::new(9);
        for &(rows, d) in &[(0usize, 7usize), (1, 7), (2, 7), (5, 22),
                            (8, 3), (7, 1), (3, 0), (64, 17), (63, 16)] {
            let x: Vec<f32> = (0..rows * d)
                .map(|_| rng.normal_f32(0.0, 1.0))
                .collect();
            let w: Vec<f32> =
                (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut z = vec![0.0f32; rows];
            gemv_block(&mut z, &x, &w);
            for i in 0..rows {
                let want = dot(&x[i * d..(i + 1) * d], &w);
                assert_eq!(z[i], want,
                           "row {i} of (rows={rows}, d={d})");
            }
        }
    }

    #[test]
    fn ger_acc_matches_documented_fixed_order_bit_for_bit() {
        // independent inline reference of the documented semantics:
        // 4-row groups, pairwise within a group, trailing rows singly
        let mut rng = crate::util::rng::Rng::new(11);
        for &(rows, d) in &[(0usize, 5usize), (1, 5), (3, 5), (4, 5),
                            (5, 5), (11, 22), (64, 9), (66, 9)] {
            let x: Vec<f32> = (0..rows * d)
                .map(|_| rng.normal_f32(0.0, 1.0))
                .collect();
            let r: Vec<f32> =
                (0..rows).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let init: Vec<f32> =
                (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut g = init.clone();
            ger_acc(&mut g, &x, &r);
            let mut want = init;
            let mut i = 0;
            while i + GER_GROUP <= rows {
                for j in 0..d {
                    want[j] += (r[i] * x[i * d + j]
                        + r[i + 1] * x[(i + 1) * d + j])
                        + (r[i + 2] * x[(i + 2) * d + j]
                            + r[i + 3] * x[(i + 3) * d + j]);
                }
                i += GER_GROUP;
            }
            while i < rows {
                for j in 0..d {
                    want[j] += r[i] * x[i * d + j];
                }
                i += 1;
            }
            assert_eq!(g, want, "(rows={rows}, d={d})");
        }
    }

    #[test]
    fn ger_acc_matches_sample_at_a_time_to_tolerance() {
        // vs the historical per-row axpy order: same sum, different
        // float association — must agree to f32 accumulation tolerance
        let mut rng = crate::util::rng::Rng::new(13);
        let (rows, d) = (130usize, 22usize);
        let x: Vec<f32> =
            (0..rows * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let r: Vec<f32> =
            (0..rows).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut g = vec![0.0f32; d];
        ger_acc(&mut g, &x, &r);
        let mut want = vec![0.0f32; d];
        for i in 0..rows {
            axpy(&mut want, r[i], &x[i * d..(i + 1) * d]);
        }
        for j in 0..d {
            approx(g[j], want[j], 1e-4);
        }
    }

    #[test]
    fn amsgrad_update_hand_example() {
        // One coordinate, hand-computed.
        let mut theta = [1.0f32];
        let mut h = [0.5f32];
        let mut vhat = [0.04f32];
        amsgrad_update(&mut theta, &mut h, &mut vhat, &[2.0], 0.1, 0.9,
                       0.99, 1e-8);
        // h' = .9*.5 + .1*2 = .65 ; v = .99*.04 + .01*4 = .0796
        // vhat' = max(.0796,.04)=.0796 ; theta' = 1 - .1*.65/sqrt(.0796)
        approx(h[0], 0.65, 1e-6);
        approx(vhat[0], 0.0796, 1e-6);
        approx(theta[0], 1.0 - 0.1 * 0.65 / 0.0796f32.sqrt(), 1e-6);
    }

    #[test]
    fn amsgrad_vhat_monotone() {
        let mut rng = crate::util::rng::Rng::new(1);
        let p = 257;
        let mut theta: Vec<f32> = (0..p).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut h = vec![0.0; p];
        let mut vhat = vec![0.0; p];
        let mut prev = vhat.clone();
        for _ in 0..20 {
            let g: Vec<f32> = (0..p).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            amsgrad_update(&mut theta, &mut h, &mut vhat, &g, 0.01, 0.9,
                           0.999, 1e-8);
            assert!(vhat.iter().zip(&prev).all(|(a, b)| a >= b));
            prev.copy_from_slice(&vhat);
        }
    }

    #[test]
    fn momentum_matches_unrolled() {
        let mut theta = [0.0f32; 3];
        let mut u = [0.0f32; 3];
        let g = [1.0f32, -2.0, 0.5];
        momentum_update(&mut theta, &mut u, &g, 0.1, 0.9);
        momentum_update(&mut theta, &mut u, &g, 0.1, 0.9);
        // u1 = g, u2 = .9 g + g = 1.9 g ; theta = -.1(g) - .1(1.9 g)
        for i in 0..3 {
            approx(u[i], 1.9 * g[i], 1e-6);
            approx(theta[i], -0.1 * g[i] - 0.1 * 1.9 * g[i], 1e-6);
        }
    }

    #[test]
    fn mean_into_averages() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 6.0];
        let mut out = [0.0f32; 2];
        mean_into(&mut out, &[&a, &b]);
        approx(out[0], 2.0, 1e-6);
        approx(out[1], 4.0, 1e-6);
    }

    #[test]
    fn sgd_is_axpy() {
        let mut theta = [1.0f32, 1.0];
        sgd_update(&mut theta, &[0.5, -0.5], 0.2);
        approx(theta[0], 0.9, 1e-6);
        approx(theta[1], 1.1, 1e-6);
    }
}
