//! Flat f32 vector math for the coordinator hot path.
//!
//! Every parameter-sized object in the system is a flat `Vec<f32>` of
//! length `p_pad` (tile aligned by the AOT pipeline). These kernels are
//! the *native* counterparts of the L1 Pallas artifacts — used (a) as the
//! fast path for rule checks, (b) as an independent comparator for the
//! HLO/Pallas numerics in integration tests, and (c) by the native grad
//! backend for pure-rust sweeps.
//!
//! Loops are written 4-way unrolled over exact chunks so LLVM reliably
//! autovectorises them; the remainder loop handles the tail (p_pad is a
//! multiple of 1024, but the functions stay correct for any length).
//!
//! [`gemv_block`] / [`ger_acc`] are the batch-level kernels of the
//! native backend's blocked gradient path: one pass computing a sample
//! block's logits (bit-identical to per-row [`dot`]), one pass folding
//! the residuals into the gradient with a fixed, documented group-of-4
//! accumulation order (pinned by the comparator tests in
//! [`crate::runtime::native`]).

/// y += a * x
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    for (yi, xi) in y.iter_mut().zip(x.iter()) {
        *yi += a * *xi;
    }
}

/// dot product
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        acc[0] += a[j] * b[j];
        acc[1] += a[j + 1] * b[j + 1];
        acc[2] += a[j + 2] * b[j + 2];
        acc[3] += a[j + 3] * b[j + 3];
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for j in chunks * 4..a.len() {
        s += a[j] * b[j];
    }
    s
}

/// ||x||^2
pub fn sqnorm(x: &[f32]) -> f32 {
    dot(x, x)
}

/// ||a - b||^2 — the innovation norm, LHS of rules (5)/(7)/(10).
/// Single fused pass (no temporary difference vector).
pub fn sqnorm_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    let mut acc = [0.0f32; 4];
    let chunks = a.len() / 4;
    for i in 0..chunks {
        let j = i * 4;
        let d0 = a[j] - b[j];
        let d1 = a[j + 1] - b[j + 1];
        let d2 = a[j + 2] - b[j + 2];
        let d3 = a[j + 3] - b[j + 3];
        acc[0] += d0 * d0;
        acc[1] += d1 * d1;
        acc[2] += d2 * d2;
        acc[3] += d3 * d3;
    }
    let mut s = acc[0] + acc[1] + acc[2] + acc[3];
    for j in chunks * 4..a.len() {
        let d = a[j] - b[j];
        s += d * d;
    }
    s
}

/// Rows per fixed accumulation group of [`ger_acc`]. The blocked
/// gradient kernel's block size must be a multiple of this so the group
/// boundaries — and therefore every bit of the accumulated gradient —
/// are independent of how the sample batch is blocked.
pub const GER_GROUP: usize = 4;

/// Blocked GEMV logits pass: `z[i] = dot(x[i*d .. (i+1)*d], w)` for
/// every row `i` of the row-major sample block `x` (`d = w.len()`).
///
/// Rows are processed two at a time so one streamed read of `w` feeds
/// two dot products, but each row's accumulation follows [`dot`]'s exact
/// order (four f32 lanes over the 4-chunks, lanes summed left to right,
/// then the scalar tail) — rows are independent, so every `z[i]` is
/// bit-identical to `dot(&x[i*d..(i+1)*d], w)` whatever the row
/// blocking. Pinned by `gemv_block_bit_equals_per_row_dot`.
pub fn gemv_block(z: &mut [f32], x: &[f32], w: &[f32]) {
    let d = w.len();
    assert_eq!(x.len(), z.len() * d);
    let rows = z.len();
    let chunks = d / 4;
    let mut i = 0;
    while i + 1 < rows {
        let x0 = &x[i * d..(i + 1) * d];
        let x1 = &x[(i + 1) * d..(i + 2) * d];
        let mut a0 = [0.0f32; 4];
        let mut a1 = [0.0f32; 4];
        for c in 0..chunks {
            let j = c * 4;
            a0[0] += x0[j] * w[j];
            a0[1] += x0[j + 1] * w[j + 1];
            a0[2] += x0[j + 2] * w[j + 2];
            a0[3] += x0[j + 3] * w[j + 3];
            a1[0] += x1[j] * w[j];
            a1[1] += x1[j + 1] * w[j + 1];
            a1[2] += x1[j + 2] * w[j + 2];
            a1[3] += x1[j + 3] * w[j + 3];
        }
        let mut s0 = a0[0] + a0[1] + a0[2] + a0[3];
        let mut s1 = a1[0] + a1[1] + a1[2] + a1[3];
        for j in chunks * 4..d {
            s0 += x0[j] * w[j];
            s1 += x1[j] * w[j];
        }
        z[i] = s0;
        z[i + 1] = s1;
        i += 2;
    }
    if i < rows {
        z[i] = dot(&x[i * d..(i + 1) * d], w);
    }
}

/// Blocked rank-accumulation `g += Xᵀ r` over a row-major sample block
/// (`d = g.len()`, row `i` is `x[i*d .. (i+1)*d]` with residual `r[i]`).
///
/// The accumulation order is FIXED and documented — it is what the
/// comparator test in `runtime::native` pins bit-for-bit: rows fold in
/// groups of [`GER_GROUP`] = 4 (in row order), and within a group each
/// coordinate accumulates
/// `g[j] += (r0*x0[j] + r1*x1[j]) + (r2*x2[j] + r3*x3[j])`;
/// trailing rows (< 4) fold one at a time in row order. One read-write
/// pass over `g` per group instead of one per row is where the win
/// comes from. NOTE: this is a different float summation order than the
/// historical sample-at-a-time `axpy` loop — a deliberate PR-3-style
/// determinism trade (the old order is retained as
/// `NativeLogReg::loss_grad_scalar` for tolerance comparison).
pub fn ger_acc(g: &mut [f32], x: &[f32], r: &[f32]) {
    let d = g.len();
    assert_eq!(x.len(), r.len() * d);
    let rows = r.len();
    let groups = rows / GER_GROUP;
    for gi in 0..groups {
        let i = gi * GER_GROUP;
        let (r0, r1, r2, r3) = (r[i], r[i + 1], r[i + 2], r[i + 3]);
        let x0 = &x[i * d..(i + 1) * d];
        let x1 = &x[(i + 1) * d..(i + 2) * d];
        let x2 = &x[(i + 2) * d..(i + 3) * d];
        let x3 = &x[(i + 3) * d..(i + 4) * d];
        for j in 0..d {
            g[j] +=
                (r0 * x0[j] + r1 * x1[j]) + (r2 * x2[j] + r3 * x3[j]);
        }
    }
    for i in groups * GER_GROUP..rows {
        let ri = r[i];
        let xi = &x[i * d..(i + 1) * d];
        for j in 0..d {
            g[j] += ri * xi[j];
        }
    }
}

/// out = a - b
pub fn sub_into(out: &mut [f32], a: &[f32], b: &[f32]) {
    assert_eq!(out.len(), a.len());
    assert_eq!(a.len(), b.len());
    for ((o, &x), &y) in out.iter_mut().zip(a.iter()).zip(b.iter()) {
        *o = x - y;
    }
}

/// x *= a
pub fn scale(x: &mut [f32], a: f32) {
    for v in x.iter_mut() {
        *v *= a;
    }
}

/// Native fused AMSGrad/CADA step — the rust twin of the Pallas
/// `cada_update` kernel (paper Eq. 2a–2c), used as its comparator and as
/// the fast in-process update backend.
#[allow(clippy::too_many_arguments)]
pub fn amsgrad_update(
    theta: &mut [f32],
    h: &mut [f32],
    vhat: &mut [f32],
    grad: &[f32],
    alpha: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
) {
    assert_eq!(theta.len(), h.len());
    assert_eq!(theta.len(), vhat.len());
    assert_eq!(theta.len(), grad.len());
    for i in 0..theta.len() {
        let g = grad[i];
        let h_new = beta1 * h[i] + (1.0 - beta1) * g;
        let v_new = beta2 * vhat[i] + (1.0 - beta2) * g * g;
        let vhat_new = v_new.max(vhat[i]);
        theta[i] -= alpha * h_new / (eps + vhat_new).sqrt();
        h[i] = h_new;
        vhat[i] = vhat_new;
    }
}

/// Plain SGD step (LAG baseline; paper Eq. 4): theta -= eta * grad.
pub fn sgd_update(theta: &mut [f32], grad: &[f32], eta: f32) {
    axpy(theta, -eta, grad);
}

/// Heavy-ball momentum step: u = beta*u + grad; theta -= eta*u.
pub fn momentum_update(theta: &mut [f32], u: &mut [f32], grad: &[f32],
                       eta: f32, beta: f32) {
    assert_eq!(theta.len(), u.len());
    assert_eq!(theta.len(), grad.len());
    for i in 0..theta.len() {
        u[i] = beta * u[i] + grad[i];
        theta[i] -= eta * u[i];
    }
}

/// Mean of several equally-weighted vectors into `out`.
pub fn mean_into(out: &mut [f32], parts: &[&[f32]]) {
    assert!(!parts.is_empty());
    let scale_by = 1.0 / parts.len() as f32;
    out.fill(0.0);
    for part in parts {
        axpy(out, scale_by, part);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn approx(a: f32, b: f32, tol: f32) {
        assert!((a - b).abs() <= tol * (1.0 + b.abs()), "{a} vs {b}");
    }

    #[test]
    fn dot_and_norms() {
        let a = [1.0, 2.0, 3.0, 4.0, 5.0];
        let b = [5.0, 4.0, 3.0, 2.0, 1.0];
        approx(dot(&a, &b), 35.0, 1e-6);
        approx(sqnorm(&a), 55.0, 1e-6);
        approx(sqnorm_diff(&a, &b), 16.0 + 4.0 + 0.0 + 4.0 + 16.0, 1e-6);
    }

    #[test]
    fn sqnorm_diff_matches_two_pass() {
        let mut rng = crate::util::rng::Rng::new(5);
        let a: Vec<f32> = (0..1031).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let b: Vec<f32> = (0..1031).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut d = vec![0.0; a.len()];
        sub_into(&mut d, &a, &b);
        approx(sqnorm_diff(&a, &b), sqnorm(&d), 1e-5);
    }

    #[test]
    fn gemv_block_bit_equals_per_row_dot() {
        // the logits pass must be bit-identical to one dot() per row for
        // every (rows, d) shape: even/odd row counts, d not a multiple
        // of 4, d < 4, d = 0
        let mut rng = crate::util::rng::Rng::new(9);
        for &(rows, d) in &[(0usize, 7usize), (1, 7), (2, 7), (5, 22),
                            (8, 3), (7, 1), (3, 0), (64, 17), (63, 16)] {
            let x: Vec<f32> = (0..rows * d)
                .map(|_| rng.normal_f32(0.0, 1.0))
                .collect();
            let w: Vec<f32> =
                (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut z = vec![0.0f32; rows];
            gemv_block(&mut z, &x, &w);
            for i in 0..rows {
                let want = dot(&x[i * d..(i + 1) * d], &w);
                assert_eq!(z[i], want,
                           "row {i} of (rows={rows}, d={d})");
            }
        }
    }

    #[test]
    fn ger_acc_matches_documented_fixed_order_bit_for_bit() {
        // independent inline reference of the documented semantics:
        // 4-row groups, pairwise within a group, trailing rows singly
        let mut rng = crate::util::rng::Rng::new(11);
        for &(rows, d) in &[(0usize, 5usize), (1, 5), (3, 5), (4, 5),
                            (5, 5), (11, 22), (64, 9), (66, 9)] {
            let x: Vec<f32> = (0..rows * d)
                .map(|_| rng.normal_f32(0.0, 1.0))
                .collect();
            let r: Vec<f32> =
                (0..rows).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let init: Vec<f32> =
                (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut g = init.clone();
            ger_acc(&mut g, &x, &r);
            let mut want = init;
            let mut i = 0;
            while i + GER_GROUP <= rows {
                for j in 0..d {
                    want[j] += (r[i] * x[i * d + j]
                        + r[i + 1] * x[(i + 1) * d + j])
                        + (r[i + 2] * x[(i + 2) * d + j]
                            + r[i + 3] * x[(i + 3) * d + j]);
                }
                i += GER_GROUP;
            }
            while i < rows {
                for j in 0..d {
                    want[j] += r[i] * x[i * d + j];
                }
                i += 1;
            }
            assert_eq!(g, want, "(rows={rows}, d={d})");
        }
    }

    #[test]
    fn ger_acc_matches_sample_at_a_time_to_tolerance() {
        // vs the historical per-row axpy order: same sum, different
        // float association — must agree to f32 accumulation tolerance
        let mut rng = crate::util::rng::Rng::new(13);
        let (rows, d) = (130usize, 22usize);
        let x: Vec<f32> =
            (0..rows * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let r: Vec<f32> =
            (0..rows).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut g = vec![0.0f32; d];
        ger_acc(&mut g, &x, &r);
        let mut want = vec![0.0f32; d];
        for i in 0..rows {
            axpy(&mut want, r[i], &x[i * d..(i + 1) * d]);
        }
        for j in 0..d {
            approx(g[j], want[j], 1e-4);
        }
    }

    #[test]
    fn amsgrad_update_hand_example() {
        // One coordinate, hand-computed.
        let mut theta = [1.0f32];
        let mut h = [0.5f32];
        let mut vhat = [0.04f32];
        amsgrad_update(&mut theta, &mut h, &mut vhat, &[2.0], 0.1, 0.9,
                       0.99, 1e-8);
        // h' = .9*.5 + .1*2 = .65 ; v = .99*.04 + .01*4 = .0796
        // vhat' = max(.0796,.04)=.0796 ; theta' = 1 - .1*.65/sqrt(.0796)
        approx(h[0], 0.65, 1e-6);
        approx(vhat[0], 0.0796, 1e-6);
        approx(theta[0], 1.0 - 0.1 * 0.65 / 0.0796f32.sqrt(), 1e-6);
    }

    #[test]
    fn amsgrad_vhat_monotone() {
        let mut rng = crate::util::rng::Rng::new(1);
        let p = 257;
        let mut theta: Vec<f32> = (0..p).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut h = vec![0.0; p];
        let mut vhat = vec![0.0; p];
        let mut prev = vhat.clone();
        for _ in 0..20 {
            let g: Vec<f32> = (0..p).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            amsgrad_update(&mut theta, &mut h, &mut vhat, &g, 0.01, 0.9,
                           0.999, 1e-8);
            assert!(vhat.iter().zip(&prev).all(|(a, b)| a >= b));
            prev.copy_from_slice(&vhat);
        }
    }

    #[test]
    fn momentum_matches_unrolled() {
        let mut theta = [0.0f32; 3];
        let mut u = [0.0f32; 3];
        let g = [1.0f32, -2.0, 0.5];
        momentum_update(&mut theta, &mut u, &g, 0.1, 0.9);
        momentum_update(&mut theta, &mut u, &g, 0.1, 0.9);
        // u1 = g, u2 = .9 g + g = 1.9 g ; theta = -.1(g) - .1(1.9 g)
        for i in 0..3 {
            approx(u[i], 1.9 * g[i], 1e-6);
            approx(theta[i], -0.1 * g[i] - 0.1 * 1.9 * g[i], 1e-6);
        }
    }

    #[test]
    fn mean_into_averages() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 6.0];
        let mut out = [0.0f32; 2];
        mean_into(&mut out, &[&a, &b]);
        approx(out[0], 2.0, 1e-6);
        approx(out[1], 4.0, 1e-6);
    }

    #[test]
    fn sgd_is_axpy() {
        let mut theta = [1.0f32, 1.0];
        sgd_update(&mut theta, &[0.5, -0.5], 0.2);
        approx(theta[0], 0.9, 1e-6);
        approx(theta[1], 1.1, 1e-6);
    }
}
