//! Explicit 8-lane SIMD kernels for the tensor hot path.
//!
//! Every kernel here is the `f32x8` twin of a scalar golden reference in
//! [`super::scalar`], selected by the dispatch layer in [`super`] when
//! the `simd` cargo feature is on (runtime opt-out: `CADA_SIMD=0`). Two
//! implementations back each kernel:
//!
//! * [`avx`] (x86_64 only): `core::arch` AVX intrinsics behind
//!   `is_x86_feature_detected!("avx")` — f32 mul/add/sub/div/sqrt/max
//!   are all IEEE-754 single operations on AVX (no FMA contraction
//!   anywhere in this module), so lane arithmetic is exact.
//! * [`portable`]: plain-rust 8-lane emulation with the *same expression
//!   tree per lane*, so the two paths produce **identical bits** on any
//!   hardware — pinned by the `avx_and_portable_agree_bit_for_bit`
//!   comparator. Results never depend on which machine ran the kernel.
//!
//! # Determinism contract (the PR-3/PR-4-style trade, restated)
//!
//! **Elementwise kernels** (`axpy`, `scale`, `sub_into`, `ger_acc`,
//! `amsgrad_update`, `sigmoid_softplus_block`) keep the scalar twin's
//! per-element expression tree and are **bit-identical** to it. Caveat:
//! `amsgrad_update`'s max emulates AVX `vmaxps` (returns the second
//! operand on NaN or equality), which differs from `f32::max` only for
//! NaN gradients — outside the kernel contract (gradients are finite).
//!
//! **Reductions** (`dot`, `sqnorm`, `sqnorm_diff`, and `gemv_block`'s
//! per-row dots) necessarily change the float association order: the
//! scalar twins accumulate in 4 lanes, these kernels in 8. The 8-lane
//! order is FIXED and documented — one 8-lane accumulator `acc[l]` over
//! the 8-chunks (lane `l` takes elements `j*8 + l`), then
//! `q[l] = acc[l] + acc[l+4]` for `l = 0..4`, then
//! `((q0 + q1) + q2) + q3`, then the scalar tail folds in element
//! order — implemented identically by both backends and pinned
//! bit-for-bit by an inline fixed-order twin in the comparator tests;
//! agreement with the scalar twin is tolerance-bounded. Golden parity
//! across transports/shards is unaffected: every consumer dispatches
//! uniformly, so run-vs-run comparisons see one consistent order.
//!
//! # Unsafe policy
//!
//! The only `unsafe` here is the AVX path: `#[target_feature]` fns
//! (callers check [`avx::available`] first) doing unaligned
//! loads/stores through raw pointers whose bounds are established from
//! slice lengths immediately above each loop. Audit rule R1 (`cada
//! audit`) holds every site to a written contract: each dispatcher
//! carries a `// SAFETY:` comment discharging the AVX precondition,
//! and each `avx::*` fn states its own `# Safety` requirements; the
//! crate root's `#![deny(unsafe_op_in_unsafe_fn)]` keeps the unsafe
//! bodies explicit.

use super::GER_GROUP;
use std::sync::OnceLock;

/// SIMD vector width in f32 lanes. Both backends are exactly this wide.
pub const LANES: usize = 8;

/// Runtime dispatch knob: true unless `CADA_SIMD` is set to
/// `0`/`off`/`false`/`scalar`. Cached after the first read — flipping
/// the env var mid-process has no effect (by design: a run uses ONE
/// kernel set, keeping its floats self-consistent).
pub fn enabled() -> bool {
    static KNOB: OnceLock<bool> = OnceLock::new();
    *KNOB.get_or_init(|| knob_from(std::env::var("CADA_SIMD").ok().as_deref()))
}

fn knob_from(v: Option<&str>) -> bool {
    !matches!(
        v.unwrap_or("").trim().to_ascii_lowercase().as_str(),
        "0" | "off" | "false" | "scalar"
    )
}

/// The documented fixed reduction order for the 8 accumulator lanes:
/// pairwise fold of lane `l` with lane `l+4`, then a left-to-right sum
/// of the four partials. Shared by both backends (the AVX kernels store
/// their accumulator register and reduce through this exact function).
#[inline]
fn combine8(acc: [f32; LANES]) -> f32 {
    let q0 = acc[0] + acc[4];
    let q1 = acc[1] + acc[5];
    let q2 = acc[2] + acc[6];
    let q3 = acc[3] + acc[7];
    ((q0 + q1) + q2) + q3
}

/// `vmaxps` semantics in plain rust: returns `b` when `a <= b`, when
/// either is NaN, and on signed-zero equality — exactly what
/// `_mm256_max_ps(a, b)` does, so portable and AVX `amsgrad_update`
/// agree bit-for-bit on EVERY input, not just finite ones.
#[inline]
fn maxps(a: f32, b: f32) -> f32 {
    if a > b {
        a
    } else {
        b
    }
}

// ---------------------------------------------------------------------
// dispatched kernel surface (same signatures as the scalar twins)
// ---------------------------------------------------------------------

/// y += a * x (8-lane; bit-identical to the scalar twin)
pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
    assert_eq!(y.len(), x.len());
    #[cfg(target_arch = "x86_64")]
    if avx::available() {
        // SAFETY: available() just confirmed AVX on this CPU, and the
        // equal-length assert above establishes the slice contract.
        return unsafe { avx::axpy(y, a, x) };
    }
    portable::axpy(y, a, x);
}

/// dot product in the documented 8-lane fixed order.
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if avx::available() {
        // SAFETY: available() just confirmed AVX on this CPU, and the
        // equal-length assert above establishes the slice contract.
        return unsafe { avx::dot(a, b) };
    }
    portable::dot(a, b)
}

/// ||x||^2 in the documented 8-lane fixed order.
pub fn sqnorm(x: &[f32]) -> f32 {
    dot(x, x)
}

/// ||a - b||^2, fused single pass, documented 8-lane fixed order.
pub fn sqnorm_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if avx::available() {
        // SAFETY: available() just confirmed AVX on this CPU, and the
        // equal-length assert above establishes the slice contract.
        return unsafe { avx::sqnorm_diff(a, b) };
    }
    portable::sqnorm_diff(a, b)
}

/// Blocked GEMV logits pass; every `z[i]` is bit-identical to
/// [`dot`]`(&x[i*d..(i+1)*d], w)` of THIS module (8-lane order).
pub fn gemv_block(z: &mut [f32], x: &[f32], w: &[f32]) {
    let d = w.len();
    assert_eq!(x.len(), z.len() * d);
    #[cfg(target_arch = "x86_64")]
    if avx::available() {
        // SAFETY: available() just confirmed AVX on this CPU, and the
        // x.len() == z.len() * d assert above establishes the blocked
        // row layout avx::gemv_block requires.
        return unsafe { avx::gemv_block(z, x, w) };
    }
    portable::gemv_block(z, x, w);
}

/// Blocked `g += Xᵀ r` in the scalar twin's fixed group-of-4 order,
/// vectorised across coordinates (bit-identical to the twin).
pub fn ger_acc(g: &mut [f32], x: &[f32], r: &[f32]) {
    let d = g.len();
    assert_eq!(x.len(), r.len() * d);
    #[cfg(target_arch = "x86_64")]
    if avx::available() {
        // SAFETY: available() just confirmed AVX on this CPU, and the
        // x.len() == r.len() * d assert above establishes the blocked
        // row layout avx::ger_acc requires.
        return unsafe { avx::ger_acc(g, x, r) };
    }
    portable::ger_acc(g, x, r);
}

/// out = a - b (8-lane; bit-identical to the scalar twin)
pub fn sub_into(out: &mut [f32], a: &[f32], b: &[f32]) {
    assert_eq!(out.len(), a.len());
    assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    if avx::available() {
        // SAFETY: available() just confirmed AVX on this CPU, and the
        // equal-length asserts above establish the slice contract.
        return unsafe { avx::sub_into(out, a, b) };
    }
    portable::sub_into(out, a, b);
}

/// x *= a (8-lane; bit-identical to the scalar twin)
pub fn scale(x: &mut [f32], a: f32) {
    #[cfg(target_arch = "x86_64")]
    if avx::available() {
        // SAFETY: available() just confirmed AVX on this CPU; scale
        // has no cross-slice length precondition.
        return unsafe { avx::scale(x, a) };
    }
    portable::scale(x, a);
}

/// Fused AMSGrad step, 8 coordinates per iteration. Bit-identical to
/// the scalar twin for finite inputs (see the module docs for the
/// `vmaxps` NaN caveat).
#[allow(clippy::too_many_arguments)]
pub fn amsgrad_update(
    theta: &mut [f32],
    h: &mut [f32],
    vhat: &mut [f32],
    grad: &[f32],
    alpha: f32,
    beta1: f32,
    beta2: f32,
    eps: f32,
) {
    assert_eq!(theta.len(), h.len());
    assert_eq!(theta.len(), vhat.len());
    assert_eq!(theta.len(), grad.len());
    #[cfg(target_arch = "x86_64")]
    if avx::available() {
        // SAFETY: available() just confirmed AVX on this CPU, and the
        // equal-length asserts above establish the slice contract.
        return unsafe {
            avx::amsgrad_update(theta, h, vhat, grad, alpha, beta1, beta2, eps)
        };
    }
    portable::amsgrad_update(theta, h, vhat, grad, alpha, beta1, beta2, eps);
}

/// Block fused logistic pair. The exponential and `ln_1p` stay scalar
/// per lane — vectorising them would change the numerics, and the
/// bit-identity policy wins over speed here (the kernel is
/// transcendental-bound either way); the surrounding arithmetic is
/// 8-lane-structured for the autovectoriser. Bit-identical to the
/// scalar twin.
pub fn sigmoid_softplus_block(z: &[f32], sig: &mut [f32], sp: &mut [f32]) {
    assert_eq!(z.len(), sig.len());
    assert_eq!(z.len(), sp.len());
    portable::sigmoid_softplus_block(z, sig, sp);
}

// ---------------------------------------------------------------------
// portable 8-lane backend
// ---------------------------------------------------------------------

/// Plain-rust 8-lane emulation: the bit-exact fallback for the AVX
/// backend (and the only backend off x86_64). Per-lane expression trees
/// match [`avx`] operation for operation.
pub mod portable {
    use super::{combine8, maxps, GER_GROUP, LANES};

    pub fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
        let n = y.len();
        let chunks = n / LANES;
        for c in 0..chunks {
            let j = c * LANES;
            for l in 0..LANES {
                y[j + l] += a * x[j + l];
            }
        }
        for j in chunks * LANES..n {
            y[j] += a * x[j];
        }
    }

    pub fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let mut acc = [0.0f32; LANES];
        let chunks = n / LANES;
        for c in 0..chunks {
            let j = c * LANES;
            for l in 0..LANES {
                acc[l] += a[j + l] * b[j + l];
            }
        }
        let mut s = combine8(acc);
        for j in chunks * LANES..n {
            s += a[j] * b[j];
        }
        s
    }

    pub fn sqnorm_diff(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let mut acc = [0.0f32; LANES];
        let chunks = n / LANES;
        for c in 0..chunks {
            let j = c * LANES;
            for l in 0..LANES {
                let d = a[j + l] - b[j + l];
                acc[l] += d * d;
            }
        }
        let mut s = combine8(acc);
        for j in chunks * LANES..n {
            let d = a[j] - b[j];
            s += d * d;
        }
        s
    }

    pub fn gemv_block(z: &mut [f32], x: &[f32], w: &[f32]) {
        let d = w.len();
        let rows = z.len();
        let chunks = d / LANES;
        let mut i = 0;
        while i + 1 < rows {
            let x0 = &x[i * d..(i + 1) * d];
            let x1 = &x[(i + 1) * d..(i + 2) * d];
            let mut a0 = [0.0f32; LANES];
            let mut a1 = [0.0f32; LANES];
            for c in 0..chunks {
                let j = c * LANES;
                for l in 0..LANES {
                    a0[l] += x0[j + l] * w[j + l];
                    a1[l] += x1[j + l] * w[j + l];
                }
            }
            let mut s0 = combine8(a0);
            let mut s1 = combine8(a1);
            for j in chunks * LANES..d {
                s0 += x0[j] * w[j];
                s1 += x1[j] * w[j];
            }
            z[i] = s0;
            z[i + 1] = s1;
            i += 2;
        }
        if i < rows {
            z[i] = dot(&x[i * d..(i + 1) * d], w);
        }
    }

    pub fn ger_acc(g: &mut [f32], x: &[f32], r: &[f32]) {
        let d = g.len();
        let rows = r.len();
        let groups = rows / GER_GROUP;
        for gi in 0..groups {
            let i = gi * GER_GROUP;
            let (r0, r1, r2, r3) = (r[i], r[i + 1], r[i + 2], r[i + 3]);
            let x0 = &x[i * d..(i + 1) * d];
            let x1 = &x[(i + 1) * d..(i + 2) * d];
            let x2 = &x[(i + 2) * d..(i + 3) * d];
            let x3 = &x[(i + 3) * d..(i + 4) * d];
            for j in 0..d {
                g[j] += (r0 * x0[j] + r1 * x1[j])
                    + (r2 * x2[j] + r3 * x3[j]);
            }
        }
        for i in groups * GER_GROUP..rows {
            let ri = r[i];
            let xi = &x[i * d..(i + 1) * d];
            for j in 0..d {
                g[j] += ri * xi[j];
            }
        }
    }

    pub fn sub_into(out: &mut [f32], a: &[f32], b: &[f32]) {
        for i in 0..out.len() {
            out[i] = a[i] - b[i];
        }
    }

    pub fn scale(x: &mut [f32], a: f32) {
        for v in x.iter_mut() {
            *v *= a;
        }
    }

    #[allow(clippy::too_many_arguments)]
    pub fn amsgrad_update(
        theta: &mut [f32],
        h: &mut [f32],
        vhat: &mut [f32],
        grad: &[f32],
        alpha: f32,
        beta1: f32,
        beta2: f32,
        eps: f32,
    ) {
        let one_m_b1 = 1.0 - beta1;
        let one_m_b2 = 1.0 - beta2;
        for i in 0..theta.len() {
            let g = grad[i];
            let h_new = beta1 * h[i] + one_m_b1 * g;
            let v_new = beta2 * vhat[i] + one_m_b2 * g * g;
            let vhat_new = maxps(v_new, vhat[i]);
            theta[i] -= alpha * h_new / (eps + vhat_new).sqrt();
            h[i] = h_new;
            vhat[i] = vhat_new;
        }
    }

    pub fn sigmoid_softplus_block(z: &[f32], sig: &mut [f32],
                                  sp: &mut [f32]) {
        let n = z.len();
        let chunks = n / LANES;
        let mut t = [0.0f32; LANES];
        for c in 0..chunks {
            let j = c * LANES;
            // the only transcendentals: scalar per lane, by policy
            for l in 0..LANES {
                t[l] = (-z[j + l].abs()).exp();
            }
            for l in 0..LANES {
                sp[j + l] = z[j + l].max(0.0) + t[l].ln_1p();
                sig[j + l] = if z[j + l] >= 0.0 {
                    1.0 / (1.0 + t[l])
                } else {
                    t[l] / (1.0 + t[l])
                };
            }
        }
        for j in chunks * LANES..n {
            let (s, p) = super::super::scalar::sigmoid_softplus(z[j]);
            sig[j] = s;
            sp[j] = p;
        }
    }
}

// ---------------------------------------------------------------------
// AVX backend (x86_64)
// ---------------------------------------------------------------------

/// AVX intrinsic backend. Every fn is `#[target_feature(enable =
/// "avx")]` and must only be called after [`available`] returned
/// true (the dispatchers above guarantee this); each fn's `# Safety`
/// section states its own slice-length preconditions. All loads/stores
/// are unaligned (`loadu`/`storeu`) and bounded by the slice-length
/// arithmetic directly above each loop.
#[cfg(target_arch = "x86_64")]
pub mod avx {
    use super::{combine8, GER_GROUP, LANES};
    use std::arch::x86_64::*;

    /// Runtime CPU check (cached by std). AVX (not AVX2) suffices: every
    /// instruction used here is a 256-bit float op from the AVX set.
    #[inline]
    pub fn available() -> bool {
        std::arch::is_x86_feature_detected!("avx")
    }

    /// # Safety
    ///
    /// Caller must have confirmed AVX via [`available`] and must pass
    /// `y.len() == x.len()`.
    #[target_feature(enable = "avx")]
    pub unsafe fn axpy(y: &mut [f32], a: f32, x: &[f32]) {
        // SAFETY: every pointer offset is j + 8 <= chunks*LANES <= n,
        // in bounds of both slices by the y.len() == x.len() contract.
        unsafe {
            let n = y.len();
            let chunks = n / LANES;
            let av = _mm256_set1_ps(a);
            let yp = y.as_mut_ptr();
            let xp = x.as_ptr();
            for c in 0..chunks {
                let j = c * LANES;
                let yv = _mm256_loadu_ps(yp.add(j));
                let xv = _mm256_loadu_ps(xp.add(j));
                _mm256_storeu_ps(yp.add(j),
                                 _mm256_add_ps(yv, _mm256_mul_ps(av, xv)));
            }
            for j in chunks * LANES..n {
                y[j] += a * x[j];
            }
        }
    }

    /// # Safety
    ///
    /// Caller must have confirmed AVX via [`available`] and must pass
    /// `a.len() == b.len()`.
    #[target_feature(enable = "avx")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        // SAFETY: every pointer offset is j + 8 <= chunks*LANES <= n,
        // in bounds of both slices by the a.len() == b.len() contract;
        // the accumulator store targets a local [f32; 8].
        unsafe {
            let n = a.len();
            let chunks = n / LANES;
            let mut accv = _mm256_setzero_ps();
            let ap = a.as_ptr();
            let bp = b.as_ptr();
            for c in 0..chunks {
                let j = c * LANES;
                let av = _mm256_loadu_ps(ap.add(j));
                let bv = _mm256_loadu_ps(bp.add(j));
                accv = _mm256_add_ps(accv, _mm256_mul_ps(av, bv));
            }
            let mut acc = [0.0f32; LANES];
            _mm256_storeu_ps(acc.as_mut_ptr(), accv);
            let mut s = combine8(acc);
            for j in chunks * LANES..n {
                s += a[j] * b[j];
            }
            s
        }
    }

    /// # Safety
    ///
    /// Caller must have confirmed AVX via [`available`] and must pass
    /// `a.len() == b.len()`.
    #[target_feature(enable = "avx")]
    pub unsafe fn sqnorm_diff(a: &[f32], b: &[f32]) -> f32 {
        // SAFETY: every pointer offset is j + 8 <= chunks*LANES <= n,
        // in bounds of both slices by the a.len() == b.len() contract;
        // the accumulator store targets a local [f32; 8].
        unsafe {
            let n = a.len();
            let chunks = n / LANES;
            let mut accv = _mm256_setzero_ps();
            let ap = a.as_ptr();
            let bp = b.as_ptr();
            for c in 0..chunks {
                let j = c * LANES;
                let dv = _mm256_sub_ps(_mm256_loadu_ps(ap.add(j)),
                                       _mm256_loadu_ps(bp.add(j)));
                accv = _mm256_add_ps(accv, _mm256_mul_ps(dv, dv));
            }
            let mut acc = [0.0f32; LANES];
            _mm256_storeu_ps(acc.as_mut_ptr(), accv);
            let mut s = combine8(acc);
            for j in chunks * LANES..n {
                let d = a[j] - b[j];
                s += d * d;
            }
            s
        }
    }

    /// # Safety
    ///
    /// Caller must have confirmed AVX via [`available`] and must pass
    /// `x.len() == z.len() * w.len()` (row-major rows of width
    /// `w.len()`).
    #[target_feature(enable = "avx")]
    pub unsafe fn gemv_block(z: &mut [f32], x: &[f32], w: &[f32]) {
        // SAFETY: row base pointers x0/x1 sit at i*d with i+1 < rows,
        // so every offset j < d stays inside x by the
        // x.len() == rows*d contract; w offsets are j + 8 <= d; the
        // odd-row tail calls dot, whose AVX requirement this fn's own
        // contract already guarantees.
        unsafe {
            let d = w.len();
            let rows = z.len();
            let chunks = d / LANES;
            let wp = w.as_ptr();
            let mut i = 0;
            while i + 1 < rows {
                let x0 = x.as_ptr().add(i * d);
                let x1 = x.as_ptr().add((i + 1) * d);
                let mut acc0 = _mm256_setzero_ps();
                let mut acc1 = _mm256_setzero_ps();
                for c in 0..chunks {
                    let j = c * LANES;
                    let wv = _mm256_loadu_ps(wp.add(j));
                    acc0 = _mm256_add_ps(
                        acc0, _mm256_mul_ps(_mm256_loadu_ps(x0.add(j)), wv));
                    acc1 = _mm256_add_ps(
                        acc1, _mm256_mul_ps(_mm256_loadu_ps(x1.add(j)), wv));
                }
                let mut a0 = [0.0f32; LANES];
                let mut a1 = [0.0f32; LANES];
                _mm256_storeu_ps(a0.as_mut_ptr(), acc0);
                _mm256_storeu_ps(a1.as_mut_ptr(), acc1);
                let mut s0 = combine8(a0);
                let mut s1 = combine8(a1);
                for j in chunks * LANES..d {
                    s0 += *x0.add(j) * w[j];
                    s1 += *x1.add(j) * w[j];
                }
                z[i] = s0;
                z[i + 1] = s1;
                i += 2;
            }
            if i < rows {
                z[i] = dot(&x[i * d..(i + 1) * d], w);
            }
        }
    }

    /// # Safety
    ///
    /// Caller must have confirmed AVX via [`available`] and must pass
    /// `x.len() == r.len() * g.len()` (row-major rows of width
    /// `g.len()`).
    #[target_feature(enable = "avx")]
    pub unsafe fn ger_acc(g: &mut [f32], x: &[f32], r: &[f32]) {
        // SAFETY: row base pointers x0..x3/xi sit at i*d with
        // i + 3 < rows (grouped loop) or i < rows (tail loop), so
        // every offset j < d stays inside x by the x.len() == rows*d
        // contract; g offsets are j + 8 <= d or j < d.
        unsafe {
            let d = g.len();
            let rows = r.len();
            let groups = rows / GER_GROUP;
            let chunks = d / LANES;
            let gp = g.as_mut_ptr();
            for gi in 0..groups {
                let i = gi * GER_GROUP;
                let (r0, r1, r2, r3) = (r[i], r[i + 1], r[i + 2], r[i + 3]);
                let (r0v, r1v, r2v, r3v) =
                    (_mm256_set1_ps(r0), _mm256_set1_ps(r1),
                     _mm256_set1_ps(r2), _mm256_set1_ps(r3));
                let x0 = x.as_ptr().add(i * d);
                let x1 = x.as_ptr().add((i + 1) * d);
                let x2 = x.as_ptr().add((i + 2) * d);
                let x3 = x.as_ptr().add((i + 3) * d);
                for c in 0..chunks {
                    let j = c * LANES;
                    let t01 = _mm256_add_ps(
                        _mm256_mul_ps(r0v, _mm256_loadu_ps(x0.add(j))),
                        _mm256_mul_ps(r1v, _mm256_loadu_ps(x1.add(j))));
                    let t23 = _mm256_add_ps(
                        _mm256_mul_ps(r2v, _mm256_loadu_ps(x2.add(j))),
                        _mm256_mul_ps(r3v, _mm256_loadu_ps(x3.add(j))));
                    let gv = _mm256_loadu_ps(gp.add(j));
                    _mm256_storeu_ps(
                        gp.add(j),
                        _mm256_add_ps(gv, _mm256_add_ps(t01, t23)));
                }
                for j in chunks * LANES..d {
                    g[j] += (r0 * *x0.add(j) + r1 * *x1.add(j))
                        + (r2 * *x2.add(j) + r3 * *x3.add(j));
                }
            }
            for i in groups * GER_GROUP..rows {
                let ri = r[i];
                let riv = _mm256_set1_ps(ri);
                let xi = x.as_ptr().add(i * d);
                for c in 0..chunks {
                    let j = c * LANES;
                    let gv = _mm256_loadu_ps(gp.add(j));
                    _mm256_storeu_ps(
                        gp.add(j),
                        _mm256_add_ps(
                            gv,
                            _mm256_mul_ps(riv, _mm256_loadu_ps(xi.add(j)))));
                }
                for j in chunks * LANES..d {
                    g[j] += ri * *xi.add(j);
                }
            }
        }
    }

    /// # Safety
    ///
    /// Caller must have confirmed AVX via [`available`] and must pass
    /// `out.len() == a.len() == b.len()`.
    #[target_feature(enable = "avx")]
    pub unsafe fn sub_into(out: &mut [f32], a: &[f32], b: &[f32]) {
        // SAFETY: every pointer offset is j + 8 <= chunks*LANES <= n,
        // in bounds of all three slices by the equal-length contract.
        unsafe {
            let n = out.len();
            let chunks = n / LANES;
            let op = out.as_mut_ptr();
            let ap = a.as_ptr();
            let bp = b.as_ptr();
            for c in 0..chunks {
                let j = c * LANES;
                _mm256_storeu_ps(op.add(j),
                                 _mm256_sub_ps(_mm256_loadu_ps(ap.add(j)),
                                               _mm256_loadu_ps(bp.add(j))));
            }
            for j in chunks * LANES..n {
                out[j] = a[j] - b[j];
            }
        }
    }

    /// # Safety
    ///
    /// Caller must have confirmed AVX via [`available`]; there is no
    /// cross-slice length precondition.
    #[target_feature(enable = "avx")]
    pub unsafe fn scale(x: &mut [f32], a: f32) {
        // SAFETY: every pointer offset is j + 8 <= chunks*LANES <= n,
        // in bounds of x.
        unsafe {
            let n = x.len();
            let chunks = n / LANES;
            let av = _mm256_set1_ps(a);
            let xp = x.as_mut_ptr();
            for c in 0..chunks {
                let j = c * LANES;
                _mm256_storeu_ps(
                    xp.add(j),
                    _mm256_mul_ps(_mm256_loadu_ps(xp.add(j)), av));
            }
            for j in chunks * LANES..n {
                x[j] *= a;
            }
        }
    }

    /// # Safety
    ///
    /// Caller must have confirmed AVX via [`available`] and must pass
    /// `theta`, `h`, `vhat`, `grad` all of equal length.
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx")]
    pub unsafe fn amsgrad_update(
        theta: &mut [f32],
        h: &mut [f32],
        vhat: &mut [f32],
        grad: &[f32],
        alpha: f32,
        beta1: f32,
        beta2: f32,
        eps: f32,
    ) {
        // SAFETY: every pointer offset is j + 8 <= chunks*LANES <= n,
        // in bounds of all four slices by the equal-length contract;
        // the tail re-slices at k = chunks*LANES <= n and runs the
        // safe portable kernel.
        unsafe {
            let n = theta.len();
            let chunks = n / LANES;
            let b1v = _mm256_set1_ps(beta1);
            let b2v = _mm256_set1_ps(beta2);
            let omb1v = _mm256_set1_ps(1.0 - beta1);
            let omb2v = _mm256_set1_ps(1.0 - beta2);
            let av = _mm256_set1_ps(alpha);
            let ev = _mm256_set1_ps(eps);
            let tp = theta.as_mut_ptr();
            let hp = h.as_mut_ptr();
            let vp = vhat.as_mut_ptr();
            let gp = grad.as_ptr();
            for c in 0..chunks {
                let j = c * LANES;
                let gv = _mm256_loadu_ps(gp.add(j));
                let hv = _mm256_loadu_ps(hp.add(j));
                let vv = _mm256_loadu_ps(vp.add(j));
                // h' = beta1*h + (1-beta1)*g
                let h_new = _mm256_add_ps(_mm256_mul_ps(b1v, hv),
                                          _mm256_mul_ps(omb1v, gv));
                // v = beta2*vhat + ((1-beta2)*g)*g  (left-assoc, as scalar)
                let v_new = _mm256_add_ps(
                    _mm256_mul_ps(b2v, vv),
                    _mm256_mul_ps(_mm256_mul_ps(omb2v, gv), gv));
                // vhat' = vmaxps(v, vhat)
                let vhat_new = _mm256_max_ps(v_new, vv);
                // theta -= (alpha*h') / sqrt(eps + vhat')
                let step = _mm256_div_ps(
                    _mm256_mul_ps(av, h_new),
                    _mm256_sqrt_ps(_mm256_add_ps(ev, vhat_new)));
                let tv = _mm256_sub_ps(_mm256_loadu_ps(tp.add(j)), step);
                _mm256_storeu_ps(tp.add(j), tv);
                _mm256_storeu_ps(hp.add(j), h_new);
                _mm256_storeu_ps(vp.add(j), vhat_new);
            }
            // tail: the portable per-element path (identical expressions)
            let k = chunks * LANES;
            super::portable::amsgrad_update(&mut theta[k..], &mut h[k..],
                                            &mut vhat[k..], &grad[k..], alpha,
                                            beta1, beta2, eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::scalar;
    use super::*;
    use crate::util::rng::Rng;

    /// Odd lengths + remainder-lane edge cases around the 8-lane width
    /// and the 4-lane scalar-twin width, plus the bench size.
    const SIZES: &[usize] = &[0, 1, 7, 8, 9, 63, 64, 65, 1023, 1024,
                              1025, 65536];

    fn vecs(n: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let a = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let b = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        (a, b)
    }

    #[test]
    fn knob_parsing() {
        assert!(knob_from(None));
        assert!(knob_from(Some("")));
        assert!(knob_from(Some("1")));
        assert!(knob_from(Some("avx")));
        assert!(!knob_from(Some("0")));
        assert!(!knob_from(Some("off")));
        assert!(!knob_from(Some("OFF ")));
        assert!(!knob_from(Some("false")));
        assert!(!knob_from(Some("scalar")));
        // cached value is stable across calls
        assert_eq!(enabled(), enabled());
    }

    #[test]
    fn elementwise_kernels_bit_equal_scalar_twins() {
        for (si, &n) in SIZES.iter().enumerate() {
            let (a, b) = vecs(n, 40 + si as u64);
            let s = 0.73f32;

            let mut y0 = b.clone();
            let mut y1 = b.clone();
            scalar::axpy(&mut y0, s, &a);
            axpy(&mut y1, s, &a);
            assert_eq!(y0, y1, "axpy n={n}");

            let mut x0 = a.clone();
            let mut x1 = a.clone();
            scalar::scale(&mut x0, s);
            scale(&mut x1, s);
            assert_eq!(x0, x1, "scale n={n}");

            let mut o0 = vec![0.0; n];
            let mut o1 = vec![0.0; n];
            scalar::sub_into(&mut o0, &a, &b);
            sub_into(&mut o1, &a, &b);
            assert_eq!(o0, o1, "sub_into n={n}");

            let mut sg0 = vec![0.0; n];
            let mut sp0 = vec![0.0; n];
            let mut sg1 = vec![0.0; n];
            let mut sp1 = vec![0.0; n];
            scalar::sigmoid_softplus_block(&a, &mut sg0, &mut sp0);
            sigmoid_softplus_block(&a, &mut sg1, &mut sp1);
            assert_eq!(sg0, sg1, "sigmoid block n={n}");
            assert_eq!(sp0, sp1, "softplus block n={n}");
        }
    }

    #[test]
    fn amsgrad_bit_equals_scalar_twin() {
        for (si, &n) in SIZES.iter().enumerate() {
            let (theta, grad) = vecs(n, 60 + si as u64);
            let (h, vh) = vecs(n, 90 + si as u64);
            let vh: Vec<f32> = vh.iter().map(|v| v.abs()).collect();

            let mut t0 = theta.clone();
            let mut h0 = h.clone();
            let mut v0 = vh.clone();
            scalar::amsgrad_update(&mut t0, &mut h0, &mut v0, &grad, 0.05,
                                   0.9, 0.999, 1e-8);
            let mut t1 = theta.clone();
            let mut h1 = h.clone();
            let mut v1 = vh.clone();
            amsgrad_update(&mut t1, &mut h1, &mut v1, &grad, 0.05, 0.9,
                           0.999, 1e-8);
            assert_eq!(t0, t1, "theta n={n}");
            assert_eq!(h0, h1, "h n={n}");
            assert_eq!(v0, v1, "vhat n={n}");
        }
    }

    #[test]
    fn ger_acc_bit_equals_scalar_twin() {
        let mut rng = Rng::new(71);
        for &(rows, d) in &[(0usize, 7usize), (1, 7), (3, 9), (4, 9),
                            (5, 16), (11, 65), (64, 63), (66, 1024)] {
            let x: Vec<f32> =
                (0..rows * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let r: Vec<f32> =
                (0..rows).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let init: Vec<f32> =
                (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut g0 = init.clone();
            let mut g1 = init;
            scalar::ger_acc(&mut g0, &x, &r);
            ger_acc(&mut g1, &x, &r);
            assert_eq!(g0, g1, "(rows={rows}, d={d})");
        }
    }

    /// The 8-lane reductions against an INDEPENDENT inline twin of the
    /// documented fixed order — bit-for-bit, both backends.
    #[test]
    fn reductions_match_documented_8lane_fixed_order_bit_for_bit() {
        fn fixed_order_dot(a: &[f32], b: &[f32]) -> f32 {
            let mut acc = [0.0f32; LANES];
            let chunks = a.len() / LANES;
            for c in 0..chunks {
                for l in 0..LANES {
                    acc[l] += a[c * LANES + l] * b[c * LANES + l];
                }
            }
            let q = [acc[0] + acc[4], acc[1] + acc[5], acc[2] + acc[6],
                     acc[3] + acc[7]];
            let mut s = ((q[0] + q[1]) + q[2]) + q[3];
            for j in chunks * LANES..a.len() {
                s += a[j] * b[j];
            }
            s
        }
        for (si, &n) in SIZES.iter().enumerate() {
            let (a, b) = vecs(n, 120 + si as u64);
            assert_eq!(dot(&a, &b), fixed_order_dot(&a, &b), "dot n={n}");
            assert_eq!(sqnorm(&a), fixed_order_dot(&a, &a), "sqnorm n={n}");
            let d: Vec<f32> =
                a.iter().zip(&b).map(|(x, y)| x - y).collect();
            assert_eq!(sqnorm_diff(&a, &b), fixed_order_dot(&d, &d),
                       "sqnorm_diff n={n}");
        }
    }

    /// And against the scalar golden twin: same sum, different float
    /// association — tolerance-bounded, like every reduction-order trade
    /// in this repo.
    #[test]
    fn reductions_match_scalar_twin_to_tolerance() {
        for (si, &n) in SIZES.iter().enumerate() {
            let (a, b) = vecs(n, 150 + si as u64);
            let tol = 1e-5 * (n.max(1) as f32).sqrt();
            let ds = scalar::dot(&a, &b);
            assert!((dot(&a, &b) - ds).abs() <= tol * (1.0 + ds.abs()),
                    "dot n={n}");
            let qs = scalar::sqnorm_diff(&a, &b);
            assert!((sqnorm_diff(&a, &b) - qs).abs()
                        <= tol * (1.0 + qs.abs()),
                    "sqnorm_diff n={n}");
        }
    }

    #[test]
    fn gemv_rows_bit_equal_simd_dot() {
        let mut rng = Rng::new(171);
        for &(rows, d) in &[(0usize, 7usize), (1, 7), (2, 7), (5, 22),
                            (8, 3), (7, 1), (3, 0), (63, 16), (64, 65),
                            (9, 1025)] {
            let x: Vec<f32> =
                (0..rows * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let w: Vec<f32> =
                (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let mut z = vec![0.0f32; rows];
            gemv_block(&mut z, &x, &w);
            for i in 0..rows {
                assert_eq!(z[i], dot(&x[i * d..(i + 1) * d], &w),
                           "row {i} of (rows={rows}, d={d})");
            }
        }
    }

    /// The hardware-independence pin: on an AVX machine, the portable
    /// backend must reproduce the intrinsic backend bit-for-bit for
    /// every kernel (elsewhere this test is vacuous and the portable
    /// backend IS the simd path).
    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx_and_portable_agree_bit_for_bit() {
        if !avx::available() {
            return;
        }
        for (si, &n) in SIZES.iter().enumerate() {
            let (a, b) = vecs(n, 200 + si as u64);
            let s = -1.17f32;
            unsafe {
                assert_eq!(portable::dot(&a, &b), avx::dot(&a, &b),
                           "dot n={n}");
                assert_eq!(portable::sqnorm_diff(&a, &b),
                           avx::sqnorm_diff(&a, &b), "sqnorm_diff n={n}");

                let mut y0 = b.clone();
                let mut y1 = b.clone();
                portable::axpy(&mut y0, s, &a);
                avx::axpy(&mut y1, s, &a);
                assert_eq!(y0, y1, "axpy n={n}");

                let mut x0 = a.clone();
                let mut x1 = a.clone();
                portable::scale(&mut x0, s);
                avx::scale(&mut x1, s);
                assert_eq!(x0, x1, "scale n={n}");

                let mut o0 = vec![0.0; n];
                let mut o1 = vec![0.0; n];
                portable::sub_into(&mut o0, &a, &b);
                avx::sub_into(&mut o1, &a, &b);
                assert_eq!(o0, o1, "sub_into n={n}");

                let vh: Vec<f32> = a.iter().map(|v| v.abs()).collect();
                let mut t0 = a.clone();
                let mut h0 = b.clone();
                let mut v0 = vh.clone();
                portable::amsgrad_update(&mut t0, &mut h0, &mut v0, &b,
                                         0.05, 0.9, 0.999, 1e-8);
                let mut t1 = a.clone();
                let mut h1 = b.clone();
                let mut v1 = vh;
                avx::amsgrad_update(&mut t1, &mut h1, &mut v1, &b, 0.05,
                                    0.9, 0.999, 1e-8);
                assert_eq!(t0, t1, "amsgrad theta n={n}");
                assert_eq!(h0, h1, "amsgrad h n={n}");
                assert_eq!(v0, v1, "amsgrad vhat n={n}");
            }
        }
        let mut rng = Rng::new(231);
        for &(rows, d) in &[(5usize, 22usize), (64, 63), (7, 1024),
                            (66, 65)] {
            let x: Vec<f32> =
                (0..rows * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let w: Vec<f32> =
                (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let r: Vec<f32> =
                (0..rows).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            unsafe {
                let mut z0 = vec![0.0f32; rows];
                let mut z1 = vec![0.0f32; rows];
                portable::gemv_block(&mut z0, &x, &w);
                avx::gemv_block(&mut z1, &x, &w);
                assert_eq!(z0, z1, "gemv (rows={rows}, d={d})");

                let mut g0 = w.clone();
                let mut g1 = w.clone();
                portable::ger_acc(&mut g0, &x, &r);
                avx::ger_acc(&mut g1, &x, &r);
                assert_eq!(g0, g1, "ger_acc (rows={rows}, d={d})");
            }
        }
    }
}
