//! Lossy upload compression: shrink the innovation uploads CADA does
//! not skip.
//!
//! CADA's contribution is *skipping* uploads; this layer is the
//! complementary axis — making the uploads that do happen smaller. A
//! [`CompressCfg`] selects one of three [`Scheme`]s:
//!
//! * **`Identity`** — the exact pre-compression path. Workers run the
//!   same code they always ran; every golden parity suite stays
//!   bit-identical (enforced by `tests/golden_parity.rs`).
//! * **`TopK`** — magnitude sparsification: keep the `ceil(frac * p)`
//!   largest-|x| coordinates as (index, value) pairs, drop the rest.
//! * **`QuantB`** — b-bit stochastic quantization onto a symmetric
//!   uniform grid (`2^b - 1` levels scaled by the vector's max-|x|).
//!   The rounding randomness is a pure function of
//!   `(seed, round, worker, purpose)` — the same construction as the
//!   `LinkSet` straggler jitter — so a run is reproducible and the
//!   server and worker sides of the socket transport agree without any
//!   extra wire traffic.
//!
//! Both lossy schemes sit behind per-worker **error feedback**: the mass
//! a round truncates is kept in a residual accumulator and added back
//! into the next round's candidate, so compression delays gradient
//! information instead of destroying it. The compressors are built so
//! that the conservation law
//!
//! ```text
//! decompress(compress(candidate)) + residual' == candidate   (exact, f32)
//! ```
//!
//! holds *exactly*, elementwise, every round: `TopK` keeps exact values
//! and drops the rest into the residual; `QuantB` snaps any coordinate
//! whose rounding would not reconstruct exactly to the zero code (both
//! ends see the snapped code, so they still agree). The property test
//! below asserts `==`, not a tolerance.
//!
//! Composition with the CADA rules: the CADA1/CADA2/LAG skip-rule LHS is
//! computed on the *decompressed* innovation (see
//! [`crate::coordinator::worker::WorkerState`]), i.e. on what the server
//! would actually receive, so the skip logic and the compressor compose
//! instead of the rule reasoning about bytes that never cross the wire.
//!
//! Payload sizes are a pure function of `(scheme, p)` — never of the
//! data — which is what lets the simulated `upload_bytes` accounting and
//! the measured socket `WireStats` agree on the compression ratio.

use crate::util::rng::Rng;

/// Which compressor runs on the upload path.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Scheme {
    /// no compression: the exact pre-compression code path
    #[default]
    Identity,
    /// top-k magnitude sparsification (index + value pairs)
    TopK,
    /// b-bit stochastic quantization (seeded, symmetric grid)
    QuantB,
}

impl Scheme {
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Identity => "identity",
            Scheme::TopK => "topk",
            Scheme::QuantB => "quant",
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Scheme> {
        match s {
            "identity" | "none" => Ok(Scheme::Identity),
            "topk" => Ok(Scheme::TopK),
            "quant" | "quantb" => Ok(Scheme::QuantB),
            other => anyhow::bail!(
                "unknown compression scheme '{other}' (expected \
                 identity | topk | quant)"
            ),
        }
    }
}

/// The `[compress]` config section: scheme + knobs + RNG seed.
///
/// `Copy` because the socket handshake ships it inside the by-value
/// [`crate::comm::wire::WireWorkerCfg`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CompressCfg {
    pub scheme: Scheme,
    /// `TopK`: fraction of coordinates kept, in (0, 1]
    pub topk_frac: f64,
    /// `QuantB`: bits per coordinate, in 2..=8
    pub bits: u32,
    /// seed of the stochastic-rounding streams (pure function of
    /// `(seed, round, worker, purpose)`, like the `LinkSet` jitter)
    pub seed: u64,
}

impl Default for CompressCfg {
    fn default() -> Self {
        CompressCfg {
            scheme: Scheme::Identity,
            topk_frac: 0.05,
            bits: 4,
            seed: 0,
        }
    }
}

/// Decorrelation tags for the two compression uses inside one round:
/// the rule-LHS probe and the actual upload must not share a stream.
#[derive(Clone, Copy, Debug)]
pub enum Purpose {
    Rule,
    Upload,
}

impl Purpose {
    fn tag(self) -> u64 {
        match self {
            Purpose::Rule => 1,
            Purpose::Upload => 2,
        }
    }
}

impl CompressCfg {
    /// True when uploads are actually transformed (TopK / QuantB).
    /// `Identity` runs the exact pre-compression code paths.
    pub fn is_lossy(&self) -> bool {
        self.scheme != Scheme::Identity
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if !self.is_lossy() {
            return Ok(());
        }
        anyhow::ensure!(
            self.topk_frac.is_finite()
                && self.topk_frac > 0.0
                && self.topk_frac <= 1.0,
            "[compress] topk_frac must be in (0, 1], got {}",
            self.topk_frac
        );
        anyhow::ensure!(
            (2..=8).contains(&self.bits),
            "[compress] bits must be in 2..=8, got {}",
            self.bits
        );
        Ok(())
    }

    /// `TopK`: coordinates kept for a p-dimensional vector.
    pub fn topk_k(&self, p: usize) -> usize {
        ((self.topk_frac * p as f64).ceil() as usize).clamp(1, p.max(1))
    }

    /// Simulated uplink payload of one upload: `dense_bytes` (the
    /// configured nominal) under `Identity` — byte-identical to the
    /// pre-compression accounting — or the deterministic encoded size
    /// of the lossy payload. Sizes are data-independent, so the event
    /// clock stays a pure function of the round.
    pub fn sim_upload_bytes(&self, p: usize, dense_bytes: usize) -> usize {
        match self.scheme {
            Scheme::Identity => dense_bytes,
            Scheme::TopK => Payload::sparse_bytes(self.topk_k(p)) as usize,
            Scheme::QuantB => Payload::quant_bytes(p, self.bits) as usize,
        }
    }

    /// The seeded RNG stream of `(round k, worker w, purpose)` — the
    /// `LinkSet` jitter construction plus a purpose fork.
    pub fn stream(&self, k: u64, w: usize, purpose: Purpose) -> Rng {
        let stream = k
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(w as u64 + 1)
            .wrapping_mul(0xA24BAED4963EE407);
        Rng::new(self.seed ^ stream).fork(purpose.tag())
    }

    /// Compress `x` for `(round k, worker w, purpose)`. Pure function of
    /// its arguments — both ends of a socket run compute identical
    /// payloads without coordination.
    pub fn compress(&self, x: &[f32], k: u64, w: usize, purpose: Purpose)
                    -> Payload {
        match self.scheme {
            Scheme::Identity => Payload::Dense(x.to_vec()),
            Scheme::TopK => top_k(x, self.topk_k(x.len())),
            Scheme::QuantB => {
                quantize(x, self.bits as u8,
                         &mut self.stream(k, w, purpose))
            }
        }
    }
}

/// One compressed upload: what crosses the wire in a
/// [`crate::comm::wire::WireStep`], and what the in-process transports
/// decompress locally.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// uncompressed f32 innovation (also the skip-round empty payload)
    Dense(Vec<f32>),
    /// top-k sparsification: strictly increasing indices + their values
    Sparse { p: u32, idx: Vec<u32>, val: Vec<f32> },
    /// b-bit quantization: `ceil(p * bits / 8)` packed little-endian
    /// codes on the grid `(code - bias) * scale`
    Quant { p: u32, bits: u8, scale: f32, codes: Vec<u8> },
}

impl Payload {
    /// Encoded size of a sparse payload with k entries (wire framing:
    /// tag + p + k + k * (u32 idx + f32 val)).
    pub fn sparse_bytes(k: usize) -> u64 {
        1 + 4 + 4 + 8 * k as u64
    }

    /// Encoded size of a b-bit quant payload of dimension p (wire
    /// framing: tag + p + bits + scale + count + packed codes).
    pub fn quant_bytes(p: usize, bits: u32) -> u64 {
        1 + 4 + 1 + 4 + 4 + (p as u64 * bits as u64).div_ceil(8)
    }

    /// The dense dimension this payload decompresses to.
    pub fn dim(&self) -> usize {
        match self {
            Payload::Dense(v) => v.len(),
            Payload::Sparse { p, .. } => *p as usize,
            Payload::Quant { p, .. } => *p as usize,
        }
    }

    /// Bytes of the dense f32 vector this payload stands for.
    pub fn raw_bytes(&self) -> u64 {
        4 * self.dim() as u64
    }

    /// Bytes this payload occupies inside a wire Step frame.
    pub fn encoded_bytes(&self) -> u64 {
        match self {
            Payload::Dense(v) => 1 + 4 + 4 * v.len() as u64,
            Payload::Sparse { idx, .. } => Payload::sparse_bytes(idx.len()),
            Payload::Quant { p, bits, .. } => {
                Payload::quant_bytes(*p as usize, *bits as u32)
            }
        }
    }

    /// Structural validity: index bounds/order, code-buffer sizing.
    /// Wire decoding calls this so a hostile frame cannot smuggle an
    /// out-of-range index into the fold.
    pub fn validate(&self) -> anyhow::Result<()> {
        match self {
            Payload::Dense(_) => Ok(()),
            Payload::Sparse { p, idx, val } => {
                anyhow::ensure!(
                    idx.len() == val.len(),
                    "sparse payload: {} indices vs {} values",
                    idx.len(),
                    val.len()
                );
                anyhow::ensure!(
                    idx.len() <= *p as usize,
                    "sparse payload: {} entries in dimension {p}",
                    idx.len()
                );
                let mut prev: Option<u32> = None;
                for &i in idx {
                    anyhow::ensure!(
                        i < *p,
                        "sparse payload: index {i} out of range (p={p})"
                    );
                    anyhow::ensure!(
                        prev.map_or(true, |q| i > q),
                        "sparse payload: indices must be strictly \
                         increasing"
                    );
                    prev = Some(i);
                }
                Ok(())
            }
            Payload::Quant { p, bits, scale, codes } => {
                anyhow::ensure!(
                    (1..=8).contains(bits),
                    "quant payload: bits {bits} out of range"
                );
                anyhow::ensure!(
                    scale.is_finite(),
                    "quant payload: non-finite scale"
                );
                let want = (*p as u64 * *bits as u64).div_ceil(8);
                anyhow::ensure!(
                    codes.len() as u64 == want,
                    "quant payload: {} code bytes for p={p}, bits={bits} \
                     (want {want})",
                    codes.len()
                );
                Ok(())
            }
        }
    }

    /// Decompress to the dense innovation the server folds.
    /// Deterministic: both transports and both ends of the socket see
    /// identical floats.
    pub fn decompress(&self) -> anyhow::Result<Vec<f32>> {
        self.validate()?;
        Ok(match self {
            Payload::Dense(v) => v.clone(),
            Payload::Sparse { p, idx, val } => {
                let mut out = vec![0.0f32; *p as usize];
                for (&i, &v) in idx.iter().zip(val) {
                    out[i as usize] = v;
                }
                out
            }
            Payload::Quant { p, bits, scale, codes } => {
                let bias = quant_bias(*bits);
                let mut out = Vec::with_capacity(*p as usize);
                for i in 0..*p as usize {
                    let code = read_code(codes, *bits, i);
                    out.push((code as f32 - bias) * scale);
                }
                out
            }
        })
    }

    /// Like [`Payload::decompress`], but consumes the payload: a dense
    /// payload gives back its vector by move (the server absorb path —
    /// no clone of a p-sized buffer per upload), the compressed forms
    /// decompress as usual.
    pub fn into_dense(self) -> anyhow::Result<Vec<f32>> {
        match self {
            Payload::Dense(v) => Ok(v),
            other => other.decompress(),
        }
    }

    /// Borrow this payload for zero-copy wire encoding.
    pub fn as_payload_ref(&self) -> PayloadRef<'_> {
        match self {
            Payload::Dense(v) => PayloadRef::Dense(v),
            Payload::Sparse { p, idx, val } => {
                PayloadRef::Sparse { p: *p, idx, val }
            }
            Payload::Quant { p, bits, scale, codes } => PayloadRef::Quant {
                p: *p,
                bits: *bits,
                scale: *scale,
                codes,
            },
        }
    }
}

/// A borrowed [`Payload`]: what the wire encoder writes from. Workers
/// build one straight over their innovation/compressor buffers
/// (`PayloadRef::Dense(state.last_delta())` for identity uploads), so
/// encoding a step frame never copies a p-sized vector first. The wire
/// encoder guarantees byte-identity with encoding the equivalent owned
/// [`Payload`].
#[derive(Clone, Copy, Debug)]
pub enum PayloadRef<'a> {
    /// uncompressed f32 innovation (also the skip-round empty payload)
    Dense(&'a [f32]),
    /// top-k sparsification: strictly increasing indices + their values
    Sparse { p: u32, idx: &'a [u32], val: &'a [f32] },
    /// b-bit quantization, packed codes borrowed from the compressor
    Quant { p: u32, bits: u8, scale: f32, codes: &'a [u8] },
}

impl PayloadRef<'_> {
    /// Bytes this payload occupies inside a wire Step frame (mirrors
    /// [`Payload::encoded_bytes`]).
    pub fn encoded_bytes(&self) -> u64 {
        match self {
            PayloadRef::Dense(v) => 1 + 4 + 4 * v.len() as u64,
            PayloadRef::Sparse { idx, .. } => {
                Payload::sparse_bytes(idx.len())
            }
            PayloadRef::Quant { p, bits, .. } => {
                Payload::quant_bytes(*p as usize, *bits as u32)
            }
        }
    }

    /// Clone into an owned [`Payload`] (tests / non-hot paths).
    pub fn to_payload(&self) -> Payload {
        match self {
            PayloadRef::Dense(v) => Payload::Dense(v.to_vec()),
            PayloadRef::Sparse { p, idx, val } => Payload::Sparse {
                p: *p,
                idx: idx.to_vec(),
                val: val.to_vec(),
            },
            PayloadRef::Quant { p, bits, scale, codes } => Payload::Quant {
                p: *p,
                bits: *bits,
                scale: *scale,
                codes: codes.to_vec(),
            },
        }
    }
}

/// Keep the k largest-|x| coordinates. Ties break toward the lower
/// index, so selection is a deterministic total order.
fn top_k(x: &[f32], k: usize) -> Payload {
    let k = k.min(x.len());
    let mut order: Vec<u32> = (0..x.len() as u32).collect();
    let key = |i: u32| {
        // NaN sorts as smallest-magnitude so it is dropped (and then
        // carried by the residual) rather than crowning the selection
        let a = x[i as usize].abs();
        if a.is_nan() { f32::NEG_INFINITY } else { a }
    };
    if k < order.len() {
        // total_cmp orders identically to the old partial_cmp here —
        // key() never yields NaN (mapped to NEG_INFINITY) or -0.0
        // (abs) — but has no panic path (audit rule R4 hygiene)
        order.select_nth_unstable_by(k - 1, |&a, &b| {
            key(b).total_cmp(&key(a)).then(a.cmp(&b))
        });
        order.truncate(k);
    }
    order.sort_unstable();
    let val = order.iter().map(|&i| x[i as usize]).collect();
    Payload::Sparse { p: x.len() as u32, idx: order, val }
}

/// Center code of the symmetric (2^b - 1)-level grid. `pub(crate)` so
/// the wire decode view can unpack quant codes in place without first
/// copying them into an owned [`Payload`].
pub(crate) fn quant_bias(bits: u8) -> f32 {
    ((1u32 << bits) - 2) as f32 / 2.0
}

pub(crate) fn read_code(codes: &[u8], bits: u8, i: usize) -> u32 {
    let bit = i * bits as usize;
    let (byte, off) = (bit / 8, bit % 8);
    let lo = (codes[byte] as u32) >> off;
    let hi = if off + bits as usize > 8 {
        (*codes.get(byte + 1).unwrap_or(&0) as u32) << (8 - off)
    } else {
        0
    };
    (lo | hi) & ((1u32 << bits) - 1)
}

fn write_code(codes: &mut [u8], bits: u8, i: usize, code: u32) {
    let bit = i * bits as usize;
    let (byte, off) = (bit / 8, bit % 8);
    let mask = (1u32 << bits) - 1;
    codes[byte] &= !((mask << off) as u8);
    codes[byte] |= ((code & mask) << off) as u8;
    if off + bits as usize > 8 {
        let spill = 8 - off;
        codes[byte + 1] &= !((mask >> spill) as u8);
        codes[byte + 1] |= ((code & mask) >> spill) as u8;
    }
}

/// b-bit stochastic quantization onto the symmetric grid
/// `(code - bias) * scale`, `scale = max|x| / bias`. Each coordinate
/// rounds up with probability equal to its fractional position
/// (unbiased); any coordinate whose grid value would not reconstruct
/// exactly under error feedback (`fl(x - q) + q != x`) snaps to the
/// zero code, which keeps the conservation law exact without the two
/// ends of the wire ever disagreeing.
fn quantize(x: &[f32], bits: u8, rng: &mut Rng) -> Payload {
    let p = x.len();
    let bias = quant_bias(bits);
    let top = ((1u32 << bits) - 2) as f32; // largest usable code
    let max_abs = x
        .iter()
        .map(|v| v.abs())
        .filter(|v| v.is_finite())
        .fold(0.0f32, f32::max);
    let scale = if max_abs > 0.0 { max_abs / bias } else { 0.0 };
    let mut codes =
        vec![0u8; ((p as u64 * bits as u64).div_ceil(8)) as usize];
    let zero_code = bias as u32;
    for (i, &v) in x.iter().enumerate() {
        let code = if scale == 0.0 || !v.is_finite() {
            zero_code
        } else {
            let t = (v / scale + bias).clamp(0.0, top);
            let floor = t.floor();
            let up = rng.f64() < (t - floor) as f64;
            let c = (floor as u32 + up as u32).min(top as u32);
            // exact-reconstruction guard: if the residual would lose
            // bits, ship zero instead and carry all of v in the residual
            let q = (c as f32 - bias) * scale;
            if (v - q) + q == v { c } else { zero_code }
        };
        write_code(&mut codes, bits, i, code);
    }
    Payload::Quant { p: p as u32, bits, scale, codes }
}

/// One error-feedback round on a candidate vector: compress, measure
/// what survived, and fold the truncated mass into `residual` for the
/// next round. Returns the payload and its decompressed (server-side)
/// view. Exact conservation: `decomp[i] + residual[i] == candidate[i]`
/// for every finite coordinate.
pub fn compress_with_feedback(
    cfg: &CompressCfg,
    candidate: &[f32],
    residual: &mut [f32],
    k: u64,
    w: usize,
    purpose: Purpose,
) -> anyhow::Result<(Payload, Vec<f32>)> {
    let payload = cfg.compress(candidate, k, w, purpose);
    let decomp = payload.decompress()?;
    for ((r, &c), &d) in
        residual.iter_mut().zip(candidate).zip(&decomp)
    {
        *r = c - d;
    }
    Ok((payload, decomp))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randv(p: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..p).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn identity_is_dense_and_exact() {
        let cfg = CompressCfg::default();
        assert!(!cfg.is_lossy());
        let x = randv(33, 1);
        let payload = cfg.compress(&x, 5, 2, Purpose::Upload);
        assert_eq!(payload, Payload::Dense(x.clone()));
        assert_eq!(payload.decompress().unwrap(), x);
        assert_eq!(cfg.sim_upload_bytes(33, 4 * 33), 4 * 33);
    }

    #[test]
    fn topk_keeps_largest_magnitudes() {
        let cfg = CompressCfg {
            scheme: Scheme::TopK,
            topk_frac: 0.25,
            ..CompressCfg::default()
        };
        let x = vec![0.1, -5.0, 0.2, 3.0, -0.3, 0.0, 1.0, 0.4];
        let payload = cfg.compress(&x, 0, 0, Purpose::Upload);
        match &payload {
            Payload::Sparse { p, idx, val } => {
                assert_eq!(*p, 8);
                assert_eq!(idx, &[1, 3]); // |-5| and |3|
                assert_eq!(val, &[-5.0, 3.0]);
            }
            other => panic!("expected sparse, got {other:?}"),
        }
        let dense = payload.decompress().unwrap();
        assert_eq!(dense, vec![0.0, -5.0, 0.0, 3.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn topk_tie_break_is_deterministic() {
        let cfg = CompressCfg {
            scheme: Scheme::TopK,
            topk_frac: 0.5,
            ..CompressCfg::default()
        };
        // all-equal magnitudes: the lower indices win, stably
        let x = vec![1.0f32; 6];
        match cfg.compress(&x, 0, 0, Purpose::Upload) {
            Payload::Sparse { idx, .. } => assert_eq!(idx, vec![0, 1, 2]),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn quant_roundtrip_is_bounded_and_seeded() {
        let cfg = CompressCfg {
            scheme: Scheme::QuantB,
            bits: 4,
            seed: 9,
            ..CompressCfg::default()
        };
        let x = randv(257, 3);
        let payload = cfg.compress(&x, 7, 1, Purpose::Upload);
        let max_abs = x.iter().fold(0.0f32, |a, v| a.max(v.abs()));
        let step = max_abs / quant_bias(4);
        let dense = payload.decompress().unwrap();
        for (a, b) in x.iter().zip(&dense) {
            // one grid cell of error at most (zero-snapped coords can
            // err by |a| <= max_abs, still bounded by the grid range)
            assert!((a - b).abs() <= max_abs + step, "{a} vs {b}");
        }
        // pure function of (seed, k, w, purpose)
        let again = cfg.compress(&x, 7, 1, Purpose::Upload);
        assert_eq!(payload, again);
        let other_round = cfg.compress(&x, 8, 1, Purpose::Upload);
        assert_ne!(payload, other_round);
        let other_worker = cfg.compress(&x, 7, 2, Purpose::Upload);
        assert_ne!(payload, other_worker);
        let other_purpose = cfg.compress(&x, 7, 1, Purpose::Rule);
        assert_ne!(payload, other_purpose);
    }

    #[test]
    fn quant_rounding_is_unbiased_in_expectation() {
        let cfg = CompressCfg {
            scheme: Scheme::QuantB,
            bits: 2,
            seed: 17,
            ..CompressCfg::default()
        };
        // a coordinate exactly halfway between grid points should round
        // up about half the time across rounds
        let x = vec![0.5f32, 1.0];
        let mut ups = 0;
        for k in 0..2000 {
            let dense = cfg
                .compress(&x, k, 0, Purpose::Upload)
                .decompress()
                .unwrap();
            if dense[0] == 1.0 {
                ups += 1;
            } else {
                assert_eq!(dense[0], 0.0);
            }
        }
        assert!((800..1200).contains(&ups), "ups = {ups}");
    }

    #[test]
    fn error_feedback_conserves_exactly() {
        // the satellite property test: (decompressed delta + residual)
        // == candidate, EXACTLY, for both lossy schemes, many rounds
        for cfg in [
            CompressCfg {
                scheme: Scheme::TopK,
                topk_frac: 0.1,
                ..CompressCfg::default()
            },
            CompressCfg {
                scheme: Scheme::QuantB,
                bits: 3,
                seed: 5,
                ..CompressCfg::default()
            },
        ] {
            let p = 513;
            let mut residual = vec![0.0f32; p];
            let mut rng = Rng::new(99);
            for k in 0..50 {
                let g: Vec<f32> =
                    (0..p).map(|_| rng.normal_f32(0.0, 0.3)).collect();
                let candidate: Vec<f32> = g
                    .iter()
                    .zip(&residual)
                    .map(|(&g, &r)| g + r)
                    .collect();
                let (_, decomp) = compress_with_feedback(
                    &cfg, &candidate, &mut residual, k, 0,
                    Purpose::Upload,
                )
                .unwrap();
                for i in 0..p {
                    assert_eq!(
                        decomp[i] + residual[i],
                        candidate[i],
                        "{:?} round {k} coord {i}",
                        cfg.scheme
                    );
                }
            }
        }
    }

    #[test]
    fn payload_sizes_match_their_formulas() {
        let topk = CompressCfg {
            scheme: Scheme::TopK,
            topk_frac: 0.05,
            ..CompressCfg::default()
        };
        let p = 1024;
        let x = randv(p, 4);
        let payload = topk.compress(&x, 0, 0, Purpose::Upload);
        assert_eq!(payload.encoded_bytes(),
                   topk.sim_upload_bytes(p, 4 * p) as u64);
        assert_eq!(payload.raw_bytes(), 4 * p as u64);
        // >= 4x reduction at 5% density
        assert!(payload.encoded_bytes() * 4 <= payload.raw_bytes());

        let quant = CompressCfg {
            scheme: Scheme::QuantB,
            bits: 4,
            ..CompressCfg::default()
        };
        let payload = quant.compress(&x, 0, 0, Purpose::Upload);
        assert_eq!(payload.encoded_bytes(),
                   quant.sim_upload_bytes(p, 4 * p) as u64);
        assert!(payload.encoded_bytes() * 4 <= payload.raw_bytes());
    }

    #[test]
    fn payload_validation_rejects_malformed() {
        // out-of-range index
        let bad = Payload::Sparse { p: 4, idx: vec![4], val: vec![1.0] };
        assert!(bad.decompress().is_err());
        // unsorted indices
        let bad =
            Payload::Sparse { p: 4, idx: vec![2, 1], val: vec![1.0, 2.0] };
        assert!(bad.decompress().is_err());
        // duplicate indices
        let bad =
            Payload::Sparse { p: 4, idx: vec![1, 1], val: vec![1.0, 2.0] };
        assert!(bad.decompress().is_err());
        // mismatched lengths
        let bad = Payload::Sparse { p: 4, idx: vec![1], val: vec![] };
        assert!(bad.decompress().is_err());
        // wrong code-buffer size
        let bad = Payload::Quant {
            p: 16,
            bits: 4,
            scale: 1.0,
            codes: vec![0; 7],
        };
        assert!(bad.decompress().is_err());
        // non-finite scale
        let bad = Payload::Quant {
            p: 2,
            bits: 4,
            scale: f32::NAN,
            codes: vec![0; 1],
        };
        assert!(bad.decompress().is_err());
        // bits out of range
        let bad =
            Payload::Quant { p: 2, bits: 9, scale: 1.0, codes: vec![0; 3] };
        assert!(bad.decompress().is_err());
    }

    #[test]
    fn code_packing_roundtrips_all_widths() {
        for bits in 1u8..=8 {
            let n = 67;
            let mut codes =
                vec![0u8; (n * bits as usize).div_ceil(8)];
            let mask = (1u32 << bits) - 1;
            let vals: Vec<u32> =
                (0..n).map(|i| (i as u32 * 2654435761) & mask).collect();
            for (i, &v) in vals.iter().enumerate() {
                write_code(&mut codes, bits, i, v);
            }
            for (i, &v) in vals.iter().enumerate() {
                assert_eq!(read_code(&codes, bits, i), v,
                           "bits={bits} i={i}");
            }
        }
    }

    #[test]
    fn cfg_validation_and_parsing() {
        assert!(CompressCfg::default().validate().is_ok());
        let bad = CompressCfg {
            scheme: Scheme::TopK,
            topk_frac: 0.0,
            ..CompressCfg::default()
        };
        assert!(bad.validate().is_err());
        let bad = CompressCfg {
            scheme: Scheme::TopK,
            topk_frac: 1.5,
            ..CompressCfg::default()
        };
        assert!(bad.validate().is_err());
        let bad = CompressCfg {
            scheme: Scheme::QuantB,
            bits: 1,
            ..CompressCfg::default()
        };
        assert!(bad.validate().is_err());
        let bad = CompressCfg {
            scheme: Scheme::QuantB,
            bits: 9,
            ..CompressCfg::default()
        };
        assert!(bad.validate().is_err());
        assert_eq!(Scheme::parse("topk").unwrap(), Scheme::TopK);
        assert_eq!(Scheme::parse("quant").unwrap(), Scheme::QuantB);
        assert_eq!(Scheme::parse("identity").unwrap(), Scheme::Identity);
        assert!(Scheme::parse("gzip").is_err());
        for s in [Scheme::Identity, Scheme::TopK, Scheme::QuantB] {
            assert_eq!(Scheme::parse(s.name()).unwrap(), s);
        }
    }

    #[test]
    fn topk_k_bounds() {
        let cfg = CompressCfg {
            scheme: Scheme::TopK,
            topk_frac: 0.05,
            ..CompressCfg::default()
        };
        assert_eq!(cfg.topk_k(1024), 52); // ceil(51.2)
        assert_eq!(cfg.topk_k(3), 1);     // floor of 1
        let all = CompressCfg {
            topk_frac: 1.0,
            ..cfg
        };
        assert_eq!(all.topk_k(10), 10);
    }
}
