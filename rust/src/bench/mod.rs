//! Micro/End-to-end bench harness (criterion is unavailable offline).
//!
//! `cargo bench` drives `[[bench]] harness = false` targets that call
//! [`Runner::bench`] for timed sections and print paper-style tables for
//! the figure reproductions. Timing method: warmup iterations, then
//! batched timed iterations until both a minimum duration and a minimum
//! iteration count are reached; reports mean/median/p95 and throughput.

use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats::percentile;

/// Result of one timed benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    /// optional bytes processed per iteration (for GB/s reporting)
    pub bytes_per_iter: Option<u64>,
}

impl BenchResult {
    pub fn throughput_gbs(&self) -> Option<f64> {
        self.bytes_per_iter
            .map(|b| b as f64 / self.mean_ns)
    }

    /// The JSON object this result contributes to a bench summary file.
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("iters".to_string(), Json::Num(self.iters as f64));
        m.insert("mean_ns".to_string(), Json::Num(self.mean_ns));
        m.insert("median_ns".to_string(), Json::Num(self.median_ns));
        m.insert("p95_ns".to_string(), Json::Num(self.p95_ns));
        if let Some(b) = self.bytes_per_iter {
            m.insert("bytes_per_iter".to_string(), Json::Num(b as f64));
        }
        Json::Obj(m)
    }

    pub fn render(&self) -> String {
        let tp = match self.throughput_gbs() {
            Some(t) => format!("  {t:8.2} GB/s"),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12} {:>12} {:>12}  x{}{}",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p95_ns),
            self.iters,
            tp
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Bench runner with global knobs (overridable via env for quick runs).
pub struct Runner {
    pub warmup: Duration,
    pub min_time: Duration,
    pub min_iters: u64,
    pub results: Vec<BenchResult>,
}

impl Default for Runner {
    fn default() -> Self {
        let scale: f64 = std::env::var("CADA_BENCH_SCALE")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1.0);
        Runner {
            warmup: Duration::from_secs_f64(0.3 * scale),
            min_time: Duration::from_secs_f64(1.0 * scale),
            min_iters: 10,
            results: Vec::new(),
        }
    }
}

impl Runner {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f`, which performs ONE iteration per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.bench_with_bytes(name, None, &mut f)
    }

    pub fn bench_bytes<F: FnMut()>(&mut self, name: &str, bytes: u64,
                                   mut f: F) -> &BenchResult {
        self.bench_with_bytes(name, Some(bytes), &mut f)
    }

    fn bench_with_bytes(&mut self, name: &str, bytes: Option<u64>,
                        f: &mut dyn FnMut()) -> &BenchResult {
        // warmup
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            f();
        }
        // timed
        let mut samples_ns: Vec<f64> = Vec::new();
        let timed_start = Instant::now();
        while timed_start.elapsed() < self.min_time
            || (samples_ns.len() as u64) < self.min_iters
        {
            let t0 = Instant::now();
            f();
            samples_ns.push(t0.elapsed().as_nanos() as f64);
            if samples_ns.len() > 5_000_000 {
                break;
            }
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples_ns.iter().sum::<f64>() / samples_ns.len() as f64;
        let result = BenchResult {
            name: name.to_string(),
            iters: samples_ns.len() as u64,
            mean_ns: mean,
            median_ns: percentile(&samples_ns, 50.0),
            p95_ns: percentile(&samples_ns, 95.0),
            bytes_per_iter: bytes,
        };
        println!("{}", result.render());
        self.results.push(result);
        self.results.last().expect("just pushed")
    }

    pub fn header(&self, title: &str) {
        println!("\n### {title}");
        println!(
            "{:<44} {:>12} {:>12} {:>12}",
            "benchmark", "mean", "median", "p95"
        );
    }

    /// Write every recorded result as a JSON array (the CI perf artifact
    /// — `BENCH_engine.json` — feeds the cross-PR regression gate).
    ///
    /// Merges by bench name into an existing file: multi-invocation
    /// bench runs (several `cargo bench` targets, or re-runs of one)
    /// update their own entries and leave everything else in place
    /// instead of clobbering the whole file. A current result replaces a
    /// same-named entry; an unparseable existing file is overwritten.
    pub fn write_json(&self, path: impl AsRef<std::path::Path>)
                      -> anyhow::Result<()> {
        let path = path.as_ref();
        let mut entries: Vec<(String, Json)> = Vec::new();
        if let Ok(text) = std::fs::read_to_string(path) {
            match crate::util::json::parse(&text) {
                Ok(Json::Arr(old)) => {
                    for v in old {
                        if let Some(name) =
                            v.get("name").and_then(|n| n.as_str())
                        {
                            let name = name.to_string();
                            entries.push((name, v));
                        }
                    }
                }
                _ => eprintln!(
                    "warning: {} held no bench array; overwriting",
                    path.display()
                ),
            }
        }
        for r in &self.results {
            let v = r.to_json();
            match entries.iter_mut().find(|(n, _)| n == &r.name) {
                Some(slot) => slot.1 = v,
                None => entries.push((r.name.clone(), v)),
            }
        }
        let merged =
            Json::Arr(entries.into_iter().map(|(_, v)| v).collect());
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, crate::util::json::render(&merged))?;
        Ok(())
    }
}

/// One bench's baseline-vs-current comparison (by name, median ns).
#[derive(Clone, Debug)]
pub struct BenchDelta {
    pub name: String,
    /// None = baseline entry seeded without a timing (or bench is new)
    pub baseline_ns: Option<f64>,
    /// None = bench missing from the current run. Once the baseline
    /// entry is armed this FAILS the gate (see [`missing_armed`]) —
    /// which is why baselines must only be refreshed from artifacts of
    /// the same CI job that gates them: a baseline containing benches
    /// the gate job cannot run (e.g. PJRT-only ones) would fail forever.
    pub current_ns: Option<f64>,
}

impl BenchDelta {
    /// current/baseline median ratio; None unless both sides timed.
    pub fn ratio(&self) -> Option<f64> {
        match (self.baseline_ns, self.current_ns) {
            (Some(b), Some(c)) if b > 0.0 => Some(c / b),
            _ => None,
        }
    }

    /// Does this bench regress beyond `max_regress` (e.g. 0.25 = +25%)?
    pub fn regressed(&self, max_regress: f64) -> bool {
        self.ratio().is_some_and(|r| r > 1.0 + max_regress)
    }
}

fn median_of(v: &Json) -> Option<f64> {
    v.get("median_ns").and_then(|m| m.as_f64())
}

/// Compare two bench-summary JSON arrays (as written by
/// [`Runner::write_json`]) by bench name. Baseline order is kept, new
/// benches append; entries whose baseline median is `null` are "seeded"
/// rows that report but never gate (how a fresh baseline bootstraps).
pub fn compare_bench_json(baseline: &Json, current: &Json)
                          -> anyhow::Result<Vec<BenchDelta>> {
    let base = baseline
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("baseline is not a JSON array"))?;
    let cur = current
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("current is not a JSON array"))?;
    let name_of = |v: &Json| -> anyhow::Result<String> {
        Ok(v.req("name")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("bench name must be a string"))?
            .to_string())
    };
    let mut deltas = Vec::new();
    for b in base {
        let name = name_of(b)?;
        let current_ns = cur
            .iter()
            .find(|c| c.get("name").and_then(|n| n.as_str())
                == Some(name.as_str()))
            .and_then(median_of);
        deltas.push(BenchDelta {
            baseline_ns: median_of(b),
            current_ns,
            name,
        });
    }
    for c in cur {
        let name = name_of(c)?;
        if !deltas.iter().any(|d| d.name == name) {
            deltas.push(BenchDelta {
                name,
                baseline_ns: None,
                current_ns: median_of(c),
            });
        }
    }
    Ok(deltas)
}

/// Merge a current run's medians into a baseline array (the
/// `bench-check --update-baseline` write path): every bench in
/// `current` that carries a NUMERIC `median_ns` gets an ARMED
/// `{name, median_ns}` row — replacing its existing baseline row, seed
/// note and all — while baseline-only rows are preserved untouched
/// (they keep gating whatever job armed them). Entries without a
/// numeric median are skipped, never written as null: feeding the
/// command a seed-row file (say, the baseline itself by argument
/// mix-up) must not silently disarm the gate. Returns the new baseline
/// array and how many rows were armed.
pub fn update_baseline(baseline: &Json, current: &Json)
                       -> anyhow::Result<(Json, usize)> {
    let cur = current
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("current is not a JSON array"))?;
    let mut entries: Vec<(String, Json)> = Vec::new();
    if let Some(base) = baseline.as_arr() {
        for v in base {
            if let Some(name) = v.get("name").and_then(|n| n.as_str()) {
                entries.push((name.to_string(), v.clone()));
            }
        }
    }
    let mut armed = 0usize;
    for c in cur {
        let name = c
            .req("name")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("bench name must be a string"))?
            .to_string();
        let Some(median) = c.get("median_ns").and_then(|m| m.as_f64())
        else {
            continue;
        };
        let mut row = std::collections::BTreeMap::new();
        row.insert("name".to_string(), Json::Str(name.clone()));
        row.insert("median_ns".to_string(), Json::Num(median));
        let row = Json::Obj(row);
        armed += 1;
        match entries.iter_mut().find(|(n, _)| n == &name) {
            Some(slot) => slot.1 = row,
            None => entries.push((name, row)),
        }
    }
    Ok((Json::Arr(entries.into_iter().map(|(_, v)| v).collect()), armed))
}

/// Names of the benches regressing beyond `max_regress`.
pub fn regressions(deltas: &[BenchDelta], max_regress: f64) -> Vec<String> {
    deltas
        .iter()
        .filter(|d| d.regressed(max_regress))
        .map(|d| d.name.clone())
        .collect()
}

/// Detailed gate-failure lines for the regressing benches: one row per
/// offender, naming the bench with its baseline/current medians and the
/// measured delta — a red CI job points at the exact kernel rows at
/// fault without anyone re-reading the full delta table.
pub fn regression_report(deltas: &[BenchDelta], max_regress: f64)
                         -> String {
    deltas
        .iter()
        .filter(|d| d.regressed(max_regress))
        .map(|d| {
            format!(
                "  {}: {} -> {} ({:+.1}%, gate is +{:.0}%)",
                d.name,
                fmt_ns(d.baseline_ns.unwrap_or(f64::NAN)),
                fmt_ns(d.current_ns.unwrap_or(f64::NAN)),
                (d.ratio().unwrap_or(1.0) - 1.0) * 100.0,
                max_regress * 100.0
            )
        })
        .collect::<Vec<_>>()
        .join("\n")
}

/// Benches the baseline gates on (non-null median) that the current run
/// never produced. A rename or an accidentally dropped bench would
/// otherwise silently disarm the gate, so the checker fails on these
/// too — renames must refresh the baseline in the same PR.
pub fn missing_armed(deltas: &[BenchDelta]) -> Vec<String> {
    deltas
        .iter()
        .filter(|d| d.baseline_ns.is_some() && d.current_ns.is_none())
        .map(|d| d.name.clone())
        .collect()
}

/// Render the per-bench delta table (markdown, for the CI job summary).
pub fn render_delta_table(deltas: &[BenchDelta], max_regress: f64)
                          -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "## micro_hotpath vs baseline (gate: median +{:.0}%)\n\n",
        max_regress * 100.0
    ));
    out.push_str("| bench | baseline | current | delta | status |\n");
    out.push_str("|---|---:|---:|---:|---|\n");
    for d in deltas {
        let fmt = |ns: Option<f64>| match ns {
            Some(ns) => fmt_ns(ns),
            None => "—".to_string(),
        };
        let (delta, status) = match d.ratio() {
            Some(r) => (
                format!("{:+.1}%", (r - 1.0) * 100.0),
                if d.regressed(max_regress) {
                    "REGRESSED"
                } else {
                    "ok"
                },
            ),
            None => (
                "—".to_string(),
                match (d.baseline_ns, d.current_ns) {
                    (None, Some(_)) => "seeded/new",
                    (_, None) => "not run",
                    _ => "—",
                },
            ),
        };
        out.push_str(&format!(
            "| `{}` | {} | {} | {} | {} |\n",
            d.name.trim(),
            fmt(d.baseline_ns),
            fmt(d.current_ns),
            delta,
            status
        ));
    }
    out
}

/// Prevent the optimiser from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut r = Runner {
            warmup: Duration::from_millis(5),
            min_time: Duration::from_millis(20),
            min_iters: 5,
            results: Vec::new(),
        };
        let mut acc = 0u64;
        r.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        let res = &r.results[0];
        assert!(res.iters >= 5);
        assert!(res.mean_ns > 0.0);
        assert!(res.median_ns <= res.p95_ns * 1.0001);
    }

    #[test]
    fn write_json_emits_parseable_array() {
        let mut r = Runner {
            warmup: Duration::from_millis(1),
            min_time: Duration::from_millis(5),
            min_iters: 3,
            results: Vec::new(),
        };
        r.bench_bytes("k", 64, || {});
        let path = std::env::temp_dir().join("cada_bench_summary.json");
        // write_json merges into an existing file by design; start clean
        // so a leftover from an aborted earlier run cannot leak in
        let _ = std::fs::remove_file(&path);
        r.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = crate::util::json::parse(&text).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("name").unwrap().as_str(), Some("k"));
        assert!(arr[0].get("mean_ns").unwrap().as_f64().unwrap() > 0.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn write_json_merges_by_name_across_invocations() {
        let path = std::env::temp_dir().join("cada_bench_merge.json");
        let _ = std::fs::remove_file(&path);
        let quick = || Runner {
            warmup: Duration::from_millis(1),
            min_time: Duration::from_millis(5),
            min_iters: 3,
            results: Vec::new(),
        };
        // first invocation writes benches a + b
        let mut r1 = quick();
        r1.bench("a", || {});
        r1.bench("b", || {});
        r1.write_json(&path).unwrap();
        // second invocation re-times b and adds c: a must survive, b
        // must be replaced (not duplicated), c must append
        let mut r2 = quick();
        r2.bench("b", || {});
        r2.bench("c", || {});
        r2.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let arr_val = crate::util::json::parse(&text).unwrap();
        let arr = arr_val.as_arr().unwrap();
        let names: Vec<&str> = arr
            .iter()
            .map(|v| v.get("name").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(names, vec!["a", "b", "c"]);
        let b_median = arr[1].get("median_ns").unwrap().as_f64().unwrap();
        let r2_b = r2.results.iter().find(|r| r.name == "b").unwrap();
        assert_eq!(b_median, r2_b.median_ns, "b must hold the re-run");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn compare_flags_regressions_and_skips_seeded_rows() {
        let baseline = crate::util::json::parse(
            r#"[{"name":"fast","median_ns":100},
                {"name":"slow","median_ns":100},
                {"name":"seeded","median_ns":null},
                {"name":"gone","median_ns":50}]"#,
        )
        .unwrap();
        let current = crate::util::json::parse(
            r#"[{"name":"fast","median_ns":110},
                {"name":"slow","median_ns":200},
                {"name":"seeded","median_ns":900},
                {"name":"fresh","median_ns":5}]"#,
        )
        .unwrap();
        let deltas = compare_bench_json(&baseline, &current).unwrap();
        assert_eq!(deltas.len(), 5);
        // +10% passes a 25% gate, +100% fails it
        assert_eq!(regressions(&deltas, 0.25), vec!["slow".to_string()]);
        // the same +10% fails a 5% gate
        assert_eq!(regressions(&deltas, 0.05),
                   vec!["fast".to_string(), "slow".to_string()]);
        // the failure report names exactly the offending rows, with
        // both medians and the measured delta
        let report = regression_report(&deltas, 0.25);
        assert!(report.contains("slow"), "{report}");
        assert!(report.contains("+100.0%"), "{report}");
        assert!(report.contains("100 ns -> 200 ns"), "{report}");
        assert!(!report.contains("fast"), "{report}");
        assert!(regression_report(&deltas, 2.0).is_empty());
        // null-seeded baselines and benches absent from one side never
        // gate, whatever their numbers
        let seeded = deltas.iter().find(|d| d.name == "seeded").unwrap();
        assert!(seeded.ratio().is_none());
        assert!(!seeded.regressed(0.0));
        let gone = deltas.iter().find(|d| d.name == "gone").unwrap();
        assert!(gone.current_ns.is_none() && !gone.regressed(0.0));
        // ...but an ARMED baseline bench missing from the current run is
        // flagged separately, so renames cannot silently disarm the gate
        // (seeded rows are exempt: they gate nothing yet)
        assert_eq!(missing_armed(&deltas), vec!["gone".to_string()]);
        let table = render_delta_table(&deltas, 0.25);
        assert!(table.contains("REGRESSED"), "{table}");
        assert!(table.contains("seeded/new"), "{table}");
        assert!(table.contains("not run"), "{table}");
        assert!(table.contains("| `fast` |"), "{table}");
        // malformed inputs error instead of silently passing the gate
        let bad = crate::util::json::parse("{}").unwrap();
        assert!(compare_bench_json(&bad, &current).is_err());
    }

    #[test]
    fn update_baseline_arms_seed_rows_and_keeps_strangers() {
        let baseline = crate::util::json::parse(
            r#"[{"name":"a","median_ns":null,"note":"seeded"},
                {"name":"pjrt-only","median_ns":123},
                {"name":"b","median_ns":50}]"#,
        )
        .unwrap();
        let current = crate::util::json::parse(
            r#"[{"name":"a","median_ns":10,"mean_ns":11},
                {"name":"b","median_ns":60},
                {"name":"pjrt-only","median_ns":null},
                {"name":"fresh","median_ns":5}]"#,
        )
        .unwrap();
        let (updated, armed) =
            update_baseline(&baseline, &current).unwrap();
        // the null-median row is SKIPPED, not written: a seed-row file
        // fed back in by mistake must never disarm existing medians
        assert_eq!(armed, 3);
        let arr = updated.as_arr().unwrap();
        let names: Vec<&str> = arr
            .iter()
            .map(|v| v.get("name").unwrap().as_str().unwrap())
            .collect();
        // baseline order kept, new benches append
        assert_eq!(names, vec!["a", "pjrt-only", "b", "fresh"]);
        // seed row armed (note dropped), existing row refreshed
        assert_eq!(arr[0].get("median_ns").unwrap().as_f64(), Some(10.0));
        assert!(arr[0].get("note").is_none());
        assert_eq!(arr[2].get("median_ns").unwrap().as_f64(), Some(60.0));
        // a bench the current run cannot produce survives untouched
        assert_eq!(arr[1].get("median_ns").unwrap().as_f64(), Some(123.0));
        assert_eq!(arr[3].get("median_ns").unwrap().as_f64(), Some(5.0));
        // the armed file round-trips straight back into the gate
        let deltas = compare_bench_json(&updated, &current).unwrap();
        assert!(regressions(&deltas, 0.0).is_empty());
        // malformed current is an error, not an empty write
        let bad = crate::util::json::parse("{}").unwrap();
        assert!(update_baseline(&baseline, &bad).is_err());
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
