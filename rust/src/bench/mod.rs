//! Micro/End-to-end bench harness (criterion is unavailable offline).
//!
//! `cargo bench` drives `[[bench]] harness = false` targets that call
//! [`Runner::bench`] for timed sections and print paper-style tables for
//! the figure reproductions. Timing method: warmup iterations, then
//! batched timed iterations until both a minimum duration and a minimum
//! iteration count are reached; reports mean/median/p95 and throughput.

use std::time::{Duration, Instant};

use crate::util::stats::percentile;

/// Result of one timed benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    /// optional bytes processed per iteration (for GB/s reporting)
    pub bytes_per_iter: Option<u64>,
}

impl BenchResult {
    pub fn throughput_gbs(&self) -> Option<f64> {
        self.bytes_per_iter
            .map(|b| b as f64 / self.mean_ns)
    }

    pub fn render(&self) -> String {
        let tp = match self.throughput_gbs() {
            Some(t) => format!("  {t:8.2} GB/s"),
            None => String::new(),
        };
        format!(
            "{:<44} {:>12} {:>12} {:>12}  x{}{}",
            self.name,
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p95_ns),
            self.iters,
            tp
        )
    }
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Bench runner with global knobs (overridable via env for quick runs).
pub struct Runner {
    pub warmup: Duration,
    pub min_time: Duration,
    pub min_iters: u64,
    pub results: Vec<BenchResult>,
}

impl Default for Runner {
    fn default() -> Self {
        let scale: f64 = std::env::var("CADA_BENCH_SCALE")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(1.0);
        Runner {
            warmup: Duration::from_secs_f64(0.3 * scale),
            min_time: Duration::from_secs_f64(1.0 * scale),
            min_iters: 10,
            results: Vec::new(),
        }
    }
}

impl Runner {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time `f`, which performs ONE iteration per call.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        self.bench_with_bytes(name, None, &mut f)
    }

    pub fn bench_bytes<F: FnMut()>(&mut self, name: &str, bytes: u64,
                                   mut f: F) -> &BenchResult {
        self.bench_with_bytes(name, Some(bytes), &mut f)
    }

    fn bench_with_bytes(&mut self, name: &str, bytes: Option<u64>,
                        f: &mut dyn FnMut()) -> &BenchResult {
        // warmup
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            f();
        }
        // timed
        let mut samples_ns: Vec<f64> = Vec::new();
        let timed_start = Instant::now();
        while timed_start.elapsed() < self.min_time
            || (samples_ns.len() as u64) < self.min_iters
        {
            let t0 = Instant::now();
            f();
            samples_ns.push(t0.elapsed().as_nanos() as f64);
            if samples_ns.len() > 5_000_000 {
                break;
            }
        }
        samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = samples_ns.iter().sum::<f64>() / samples_ns.len() as f64;
        let result = BenchResult {
            name: name.to_string(),
            iters: samples_ns.len() as u64,
            mean_ns: mean,
            median_ns: percentile(&samples_ns, 50.0),
            p95_ns: percentile(&samples_ns, 95.0),
            bytes_per_iter: bytes,
        };
        println!("{}", result.render());
        self.results.push(result);
        self.results.last().expect("just pushed")
    }

    pub fn header(&self, title: &str) {
        println!("\n### {title}");
        println!(
            "{:<44} {:>12} {:>12} {:>12}",
            "benchmark", "mean", "median", "p95"
        );
    }

    /// Write every recorded result as a JSON array (the CI perf artifact
    /// — `BENCH_engine.json` — starts the cross-PR perf trajectory).
    pub fn write_json(&self, path: impl AsRef<std::path::Path>)
                      -> anyhow::Result<()> {
        use crate::util::json::ObjWriter;
        let mut out = String::from("[");
        for (i, r) in self.results.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let mut obj = ObjWriter::new()
                .str("name", &r.name)
                .int("iters", r.iters)
                .num("mean_ns", r.mean_ns)
                .num("median_ns", r.median_ns)
                .num("p95_ns", r.p95_ns);
            if let Some(b) = r.bytes_per_iter {
                obj = obj.int("bytes_per_iter", b);
            }
            out.push_str(&obj.finish());
        }
        out.push(']');
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, out)?;
        Ok(())
    }
}

/// Prevent the optimiser from discarding a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_stats() {
        let mut r = Runner {
            warmup: Duration::from_millis(5),
            min_time: Duration::from_millis(20),
            min_iters: 5,
            results: Vec::new(),
        };
        let mut acc = 0u64;
        r.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        let res = &r.results[0];
        assert!(res.iters >= 5);
        assert!(res.mean_ns > 0.0);
        assert!(res.median_ns <= res.p95_ns * 1.0001);
    }

    #[test]
    fn write_json_emits_parseable_array() {
        let mut r = Runner {
            warmup: Duration::from_millis(1),
            min_time: Duration::from_millis(5),
            min_iters: 3,
            results: Vec::new(),
        };
        r.bench_bytes("k", 64, || {});
        let path = std::env::temp_dir().join("cada_bench_summary.json");
        r.write_json(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let parsed = crate::util::json::parse(&text).unwrap();
        let arr = parsed.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].get("name").unwrap().as_str(), Some("k"));
        assert!(arr[0].get("mean_ns").unwrap().as_f64().unwrap() > 0.0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn fmt_ns_units() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}
