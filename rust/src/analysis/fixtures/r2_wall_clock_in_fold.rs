//@ audit-path: algorithms/bad_timer.rs
//! Known-bad fixture for R2: wall-clock reads inside a
//! simulated-accounting path. Round timing must be a pure function of
//! (seed, round, worker) — `Instant::now()` makes it a function of
//! the host machine.

use std::time::Instant;

pub fn round_cost() -> f64 {
    let t0 = Instant::now();
    t0.elapsed().as_secs_f64()
}
