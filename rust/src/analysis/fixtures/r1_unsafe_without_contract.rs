//@ audit-path: tensor/bad_kernel.rs
//! Known-bad fixture for R1: an unsafe dereference whose comments
//! never state the contract the caller must uphold.

/// Reads the first element without bounds checks.
// fast path, the caller probably checked the length already
pub fn first_unchecked(x: &[f32]) -> f32 {
    unsafe { *x.as_ptr() }
}
