//@ audit-path: comm/wire.rs
//! Known-bad fixture for R4: a decode path that panics on hostile
//! bytes instead of surfacing an error. A truncated frame from the
//! network must never take the server down.

pub fn decode_len(frame: &[u8]) -> u32 {
    u32::from_le_bytes(frame[0..4].try_into().unwrap())
}
