//@ audit-path: coordinator/bad_fold.rs
//! Known-bad fixture for R3: a HashMap iterated inside a fold path.
//! Hash iteration order varies per process, so the fold result would
//! depend on the run, not on (seed, round, worker).

use std::collections::HashMap;

pub fn fold(uploads: &HashMap<usize, Vec<f32>>) -> f32 {
    let mut acc = 0.0;
    for (_, delta) in uploads {
        acc += delta[0];
    }
    acc
}
