//@ audit-path: exp/bad_spawn.rs
//! Known-bad fixture for R6: thread creation outside the transport
//! and pool substrates. Rogue threads dodge the deterministic join
//! order those two modules guarantee.

pub fn run_detached<F: FnOnce() + Send + 'static>(work: F) {
    std::thread::spawn(work);
}
