//@ audit-path: algorithms/bad_random.rs
//! Known-bad fixture for R5, both halves: an ambient OS-seeded RNG
//! (not a pure function of (seed, round, worker)) and an ad-hoc float
//! reduction that bypasses the blessed fixed-order tensor kernels.

pub fn noisy_norm(x: &[f32]) -> f32 {
    let _rng = rand::thread_rng();
    x.iter().map(|v| v * v).sum::<f32>()
}
