//! Hand-rolled line scanner feeding the audit rules.
//!
//! Not a Rust parser: one pass over each source file classifies every
//! character as **code**, **comment**, or **literal**, so the rules in
//! [`super::rules`] can pattern-match on code without tripping over
//! tokens quoted in strings or prose, and can read `// SAFETY:`
//! contracts out of the comment channel. A second line-level pass
//! tracks `#[cfg(test)]` / `#[test]` item regions by brace depth so
//! test code is exempt from the hostile-input and wall-clock rules.
//!
//! The lexer understands exactly the token shapes that could confuse a
//! substring match: line and (nested) block comments, string / raw
//! string / byte-string literals, char literals vs lifetimes. Anything
//! else passes through as code verbatim.

use std::path::Path;

/// One source line, split into its code and comment channels.
#[derive(Debug)]
pub struct Line {
    /// 1-based line number.
    pub number: usize,
    /// Code with literals blanked to spaces and comments stripped;
    /// pattern matches against this never hit quoted text.
    pub code: String,
    /// Comment text on the line (line, doc, or block comments).
    pub comment: String,
    /// True inside the braces of a `#[cfg(test)]` / `#[test]` item.
    pub in_test: bool,
}

/// A scanned file: its path relative to the scan root (forward
/// slashes), plus the classified lines.
#[derive(Debug)]
pub struct SourceFile {
    pub rel: String,
    pub lines: Vec<Line>,
}

/// Scan one file's text under its root-relative path. Public so the
/// fixture self-tests can scan known-bad snippets under the *pretend*
/// path their `//@ audit-path:` directive declares.
pub fn scan_source(rel: &str, text: &str) -> SourceFile {
    let mut lines = classify(text);
    mark_test_regions(&mut lines);
    SourceFile { rel: rel.to_string(), lines }
}

/// Walk `root` and scan every `.rs` file, in sorted path order.
/// `analysis/fixtures/` is skipped: it holds deliberately-bad snippets
/// that every rule must trip on — in their own self-tests, not in the
/// live-tree audit.
pub fn scan_tree(root: &Path) -> anyhow::Result<Vec<SourceFile>> {
    let mut files = Vec::new();
    walk(root, root, &mut files)?;
    files.sort_by(|a, b| a.rel.cmp(&b.rel));
    Ok(files)
}

fn walk(
    root: &Path,
    dir: &Path,
    out: &mut Vec<SourceFile>,
) -> anyhow::Result<()> {
    let entries = std::fs::read_dir(dir).map_err(|e| {
        anyhow::anyhow!("auditing {}: {e}", dir.display())
    })?;
    let mut paths: Vec<_> = Vec::new();
    for entry in entries {
        paths.push(entry?.path());
    }
    paths.sort();
    for path in paths {
        let rel = rel_path(root, &path);
        if path.is_dir() {
            if rel == "analysis/fixtures" {
                continue;
            }
            walk(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let text = std::fs::read_to_string(&path).map_err(|e| {
                anyhow::anyhow!("auditing {}: {e}", path.display())
            })?;
            out.push(scan_source(&rel, &text));
        }
    }
    Ok(())
}

fn rel_path(root: &Path, path: &Path) -> String {
    let rel = path.strip_prefix(root).unwrap_or(path);
    // normalise to forward slashes so rule scopes and allowlist keys
    // are platform-independent
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy())
        .collect::<Vec<_>>()
        .join("/")
}

// ------------------------------------------------------------- lexer

enum Mode {
    Code,
    /// Rust block comments nest.
    BlockComment { depth: usize },
    Str,
    RawStr { hashes: usize },
}

/// Split `text` into per-line `(code, comment)` channels.
fn classify(text: &str) -> Vec<Line> {
    let chars: Vec<char> = text.chars().collect();
    let mut out = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut mode = Mode::Code;
    // the last code character emitted, to tell `r"..."` raw strings
    // from identifiers that merely end in `r`
    let mut last_code: char = ' ';
    let mut i = 0;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            out.push(Line {
                number: out.len() + 1,
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
                in_test: false,
            });
            i += 1;
            continue;
        }
        match mode {
            Mode::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    while i < chars.len() && chars[i] != '\n' {
                        comment.push(chars[i]);
                        i += 1;
                    }
                    continue;
                }
                if c == '/' && next == Some('*') {
                    mode = Mode::BlockComment { depth: 1 };
                    comment.push_str("/*");
                    i += 2;
                    continue;
                }
                if c == '"' {
                    mode = Mode::Str;
                    code.push(' ');
                    i += 1;
                    continue;
                }
                // raw (byte) strings: r"..", r#".."#, br".., b handled
                // by the plain-'"' arm above when not followed by `r`
                let raw_at = if c == 'r' && !is_ident(last_code) {
                    Some(i)
                } else if c == 'b'
                    && next == Some('r')
                    && !is_ident(last_code)
                {
                    Some(i + 1)
                } else {
                    None
                };
                if let Some(r) = raw_at {
                    let mut h = 0;
                    while chars.get(r + 1 + h) == Some(&'#') {
                        h += 1;
                    }
                    if chars.get(r + 1 + h) == Some(&'"') {
                        for _ in i..=r + 1 + h {
                            code.push(' ');
                        }
                        i = r + 2 + h;
                        mode = Mode::RawStr { hashes: h };
                        last_code = ' ';
                        continue;
                    }
                }
                if c == '\'' {
                    // lifetime/label ('a, 'static, '_) vs char literal
                    // ('x', '\n', 'b' as in b'x' handled here too since
                    // the b was emitted as code)
                    let n1 = chars.get(i + 1).copied();
                    let lifetime = n1.is_some_and(|n| {
                        (n.is_alphanumeric() || n == '_')
                            && chars.get(i + 2) != Some(&'\'')
                    });
                    if lifetime {
                        code.push(c);
                        last_code = c;
                        i += 1;
                        continue;
                    }
                    // char literal: blank through the closing quote
                    code.push(' ');
                    i += 1;
                    while i < chars.len()
                        && chars[i] != '\''
                        && chars[i] != '\n'
                    {
                        code.push(' ');
                        i += if chars[i] == '\\' { 2 } else { 1 };
                    }
                    if chars.get(i) == Some(&'\'') {
                        code.push(' ');
                        i += 1;
                    }
                    last_code = ' ';
                    continue;
                }
                code.push(c);
                if !c.is_whitespace() {
                    last_code = c;
                }
                i += 1;
            }
            Mode::BlockComment { depth } => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('*') {
                    mode = Mode::BlockComment { depth: depth + 1 };
                    comment.push_str("/*");
                    i += 2;
                } else if c == '*' && next == Some('/') {
                    comment.push_str("*/");
                    i += 2;
                    mode = if depth == 1 {
                        Mode::Code
                    } else {
                        Mode::BlockComment { depth: depth - 1 }
                    };
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' {
                    code.push(' ');
                    if chars.get(i + 1) == Some(&'\n') {
                        i += 1; // keep the newline for the line split
                    } else {
                        i += 2;
                    }
                } else if c == '"' {
                    mode = Mode::Code;
                    code.push(' ');
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            Mode::RawStr { hashes } => {
                if c == '"'
                    && (1..=hashes)
                        .all(|k| chars.get(i + k) == Some(&'#'))
                {
                    for _ in 0..=hashes {
                        code.push(' ');
                    }
                    i += 1 + hashes;
                    mode = Mode::Code;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        out.push(Line {
            number: out.len() + 1,
            code,
            comment,
            in_test: false,
        });
    }
    out
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Mark the lines inside `#[cfg(test)]` / `#[test]` items. A pending
/// flag set by the attribute latches onto the next `{` (the item
/// body); the region ends when brace depth drops back below the
/// body's. `#[cfg(not(test))]` and `cfg!(test)` never set the flag; an
/// attribute followed by a braceless item (`#[cfg(test)] use ...;`)
/// is cancelled by the `;`.
fn mark_test_regions(lines: &mut [Line]) {
    let mut depth: usize = 0;
    let mut pending = false;
    let mut pending_depth = 0usize;
    let mut test_depth: Option<usize> = None;
    for line in lines.iter_mut() {
        line.in_test = test_depth.is_some();
        let code = &line.code;
        let is_test_attr = (code.contains("#[cfg(")
            && code.contains("test")
            && !code.contains("not("))
            || code.contains("#[test]");
        if is_test_attr && test_depth.is_none() {
            pending = true;
            pending_depth = depth;
            line.in_test = true;
        }
        for c in code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    if pending && test_depth.is_none() {
                        test_depth = Some(depth);
                        pending = false;
                        line.in_test = true;
                    }
                }
                '}' => {
                    depth = depth.saturating_sub(1);
                    if test_depth.is_some_and(|d| depth < d) {
                        test_depth = None;
                    }
                }
                ';' if pending && depth == pending_depth => {
                    pending = false;
                }
                _ => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_leave_the_code_channel() {
        let f = scan_source(
            "x.rs",
            "let s = \"unsafe .unwrap() HashMap\"; // Instant::now\n\
             let c = 'u'; /* SystemTime */ let l: &'static str = s;\n",
        );
        assert!(!f.lines[0].code.contains("unsafe"));
        assert!(!f.lines[0].code.contains(".unwrap()"));
        assert!(!f.lines[0].code.contains("HashMap"));
        assert!(f.lines[0].comment.contains("Instant::now"));
        assert!(!f.lines[1].code.contains('u'), "{}", f.lines[1].code);
        assert!(f.lines[1].comment.contains("SystemTime"));
        // the lifetime survives as code, the char literal does not
        assert!(f.lines[1].code.contains("'static"));
    }

    #[test]
    fn raw_strings_are_blanked() {
        let f = scan_source(
            "x.rs",
            "let a = r\"unsafe\"; let b = r#\"say \"unsafe\"\"#;\n\
             let c = br\"unsafe\"; let r = 1; let br = 2;\n",
        );
        assert!(!f.lines[0].code.contains("unsafe"), "{}", f.lines[0].code);
        assert!(!f.lines[1].code.contains("unsafe"), "{}", f.lines[1].code);
        // identifiers named r/br don't start raw strings
        assert!(f.lines[1].code.contains("let r = 1"));
    }

    #[test]
    fn nested_block_comments_and_multiline_strings() {
        let f = scan_source(
            "x.rs",
            "/* outer /* inner */ still comment */ let x = 1;\n\
             let s = \"line one\nline two unsafe\";\nlet y = 2;\n",
        );
        assert!(f.lines[0].code.contains("let x = 1"));
        assert!(f.lines[0].comment.contains("inner"));
        assert!(!f.lines[1].code.contains("unsafe"));
        assert!(f.lines[2].code.contains("let y = 2"));
    }

    #[test]
    fn cfg_test_regions_are_marked() {
        let f = scan_source(
            "x.rs",
            "fn live() { x.unwrap(); }\n\
             #[cfg(test)]\n\
             mod tests {\n\
                 fn t() { y.unwrap(); }\n\
             }\n\
             fn live_again() {}\n",
        );
        assert!(!f.lines[0].in_test);
        assert!(f.lines[1].in_test);
        assert!(f.lines[2].in_test);
        assert!(f.lines[3].in_test);
        assert!(f.lines[4].in_test);
        assert!(!f.lines[5].in_test);
    }

    #[test]
    fn cfg_not_test_and_braceless_items_stay_live() {
        let f = scan_source(
            "x.rs",
            "#[cfg(not(test))]\n\
             fn prod() { x.unwrap(); }\n\
             #[cfg(test)]\n\
             use something::Gone;\n\
             fn still_live() {}\n",
        );
        assert!(!f.lines[1].in_test, "not(test) must stay live");
        // the braceless use is attribute-marked, but the fn after it
        // must NOT inherit the pending flag
        assert!(!f.lines[4].in_test);
    }
}
