//! `cada audit` — the static determinism-and-safety lint over
//! `rust/src/**`.
//!
//! Every claim the repo makes (honest wire accounting, bit-identical
//! crash-resume, reproducible soaks) rests on invariants that used to
//! be enforced only by golden tests *after* a violation shipped. This
//! subsystem checks them before: a hand-rolled scanner
//! ([`scan`]) splits source into code/comment channels, the rules
//! ([`rules`]) pattern-match the written invariants R1–R6, and a
//! checked-in allowlist (`analysis/allow.toml`) names the justification
//! for every deliberate exception. See the "Invariants" section of the
//! crate docs ([`crate`]) for the rule statements.
//!
//! Three properties keep the allowlist honest:
//!
//! * every entry is `[R#:path]` with a mandatory non-empty `why` —
//!   an exception nobody can justify in a sentence does not ship;
//! * entries are per-(rule, file), never global — a new violation in
//!   an un-allowlisted file always fails the audit;
//! * **stale entries fail the audit** — when the code an entry excused
//!   goes away, the entry must go with it, so the list only ever
//!   shrinks to match reality.
//!
//! The deliberately-bad snippets under `analysis/fixtures/` (one per
//! rule) are the auditor's own regression suite: each must trip its
//! rule, and the live tree must audit clean (`rust/tests/audit.rs`).

pub mod rules;
pub mod scan;

pub use rules::{Finding, Rule};
pub use scan::{scan_source, scan_tree, SourceFile};

use std::collections::{BTreeMap, BTreeSet};
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// The checked-in exceptions: allow key (`"R#:rel/path.rs"`) → the
/// written justification.
#[derive(Debug, Default)]
pub struct Allowlist {
    entries: BTreeMap<String, String>,
}

impl Allowlist {
    /// No exceptions — what the fixture self-tests audit against.
    pub fn empty() -> Allowlist {
        Allowlist::default()
    }

    /// The checked-in `analysis/allow.toml`, compiled into the binary
    /// so `cada audit` needs no path plumbing to self-host.
    pub fn builtin() -> Allowlist {
        Allowlist::parse(include_str!("allow.toml"))
            .expect("checked-in analysis/allow.toml must parse")
    }

    /// Parse and validate allowlist TOML: every section is
    /// `[R#:path]` with exactly one key, a non-empty `why` string.
    pub fn parse(text: &str) -> anyhow::Result<Allowlist> {
        let doc = crate::config::toml::parse(text)?;
        let mut entries = BTreeMap::new();
        for (name, section) in &doc.sections {
            if name.is_empty() {
                anyhow::ensure!(
                    section.is_empty(),
                    "allowlist: top-level keys are not allowed; \
                     every entry is an [R#:path] section"
                );
                continue;
            }
            let (rule_id, rel) =
                name.split_once(':').ok_or_else(|| {
                    anyhow::anyhow!(
                        "allowlist entry [{name}] is not R#:path"
                    )
                })?;
            anyhow::ensure!(
                Rule::from_id(rule_id).is_some(),
                "allowlist entry [{name}] names unknown rule \
                 `{rule_id}`"
            );
            anyhow::ensure!(
                !rel.is_empty(),
                "allowlist entry [{name}] has an empty path"
            );
            for key in section.keys() {
                anyhow::ensure!(
                    key == "why",
                    "allowlist entry [{name}]: unexpected key \
                     `{key}` (only `why` is allowed)"
                );
            }
            let why = section
                .get("why")
                .and_then(|v| v.as_str())
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "allowlist entry [{name}] is missing its \
                         `why = \"...\"` justification"
                    )
                })?;
            anyhow::ensure!(
                !why.trim().is_empty(),
                "allowlist entry [{name}] has an empty `why`"
            );
            entries.insert(name.clone(), why.to_string());
        }
        Ok(Allowlist { entries })
    }

    pub fn contains(&self, key: &str) -> bool {
        self.entries.contains_key(key)
    }

    pub fn why(&self, key: &str) -> Option<&str> {
        self.entries.get(key).map(String::as_str)
    }

    pub fn keys(&self) -> impl Iterator<Item = &String> {
        self.entries.keys()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// The outcome of one audit run.
#[derive(Debug)]
pub struct Report {
    /// Unsuppressed rule hits, in (file, line) order.
    pub findings: Vec<Finding>,
    /// Hits excused by an allowlist entry.
    pub suppressed: usize,
    /// Allowlist keys that suppressed nothing — dead entries that
    /// must be removed (they fail the audit).
    pub stale: Vec<String>,
    /// Files scanned.
    pub files: usize,
}

impl Report {
    pub fn clean(&self) -> bool {
        self.findings.is_empty() && self.stale.is_empty()
    }

    /// Human-readable report: one `file:line [R#]` line per finding
    /// with the allow key that would suppress it, stale-entry lines,
    /// a legend for every rule that fired, and a summary line.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            let _ = writeln!(
                out,
                "src/{}:{} [{}] {}  (allow key: {})",
                f.rel,
                f.line,
                f.rule.id(),
                f.what,
                f.allow_key()
            );
        }
        for key in &self.stale {
            let _ = writeln!(
                out,
                "stale allowlist entry [{key}] suppresses nothing \
                 — remove it from rust/src/analysis/allow.toml"
            );
        }
        let fired: BTreeSet<Rule> =
            self.findings.iter().map(|f| f.rule).collect();
        for rule in fired {
            let _ = writeln!(
                out,
                "  {}: {} — exceptions go in \
                 rust/src/analysis/allow.toml with a `why`",
                rule.id(),
                rule.summary()
            );
        }
        let _ = writeln!(
            out,
            "audit: {} files, {} finding(s), {} suppressed, \
             {} stale allowlist entr{}",
            self.files,
            self.findings.len(),
            self.suppressed,
            self.stale.len(),
            if self.stale.len() == 1 { "y" } else { "ies" }
        );
        out
    }
}

/// Run every rule over already-scanned files and fold the allowlist
/// in: suppressed hits consume their entry, unconsumed entries are
/// reported stale.
pub fn audit_files(
    files: &[SourceFile],
    allow: &Allowlist,
) -> Report {
    let mut raw = Vec::new();
    for file in files {
        rules::check_file(file, &mut raw);
    }
    let mut used: BTreeSet<&str> = BTreeSet::new();
    let mut findings = Vec::new();
    let mut suppressed = 0;
    for f in raw {
        let key = f.allow_key();
        if allow.contains(&key) {
            suppressed += 1;
            if let Some(k) = allow.entries.get_key_value(&key) {
                used.insert(k.0.as_str());
            }
        } else {
            findings.push(f);
        }
    }
    let stale: Vec<String> = allow
        .keys()
        .filter(|k| !used.contains(k.as_str()))
        .cloned()
        .collect();
    Report { findings, suppressed, stale, files: files.len() }
}

/// Audit a single source text under a root-relative path — how the
/// fixture self-tests run known-bad snippets under the pretend path
/// their `//@ audit-path:` directive declares.
pub fn audit_source(
    rel: &str,
    text: &str,
    allow: &Allowlist,
) -> Report {
    audit_files(&[scan_source(rel, text)], allow)
}

/// Scan and audit every `.rs` file under `root`.
pub fn audit_tree(
    root: &Path,
    allow: &Allowlist,
) -> anyhow::Result<Report> {
    let files = scan_tree(root)?;
    Ok(audit_files(&files, allow))
}

/// Locate the crate source tree from the current directory: `src/`
/// when invoked from `rust/`, `rust/src/` from the repo root.
pub fn default_root() -> anyhow::Result<PathBuf> {
    for cand in ["src", "rust/src"] {
        let p = PathBuf::from(cand);
        if p.join("lib.rs").is_file() {
            return Ok(p);
        }
    }
    anyhow::bail!(
        "cannot find the crate source tree (looked for src/lib.rs \
         and rust/src/lib.rs); pass --root"
    )
}

/// The pretend path a fixture audits under: its first line must be
/// `//@ audit-path: <rel>`, placing the snippet inside the scoped
/// rules' jurisdiction even though the file lives in
/// `analysis/fixtures/` (which the tree scan skips).
pub fn fixture_rel(text: &str) -> Option<String> {
    let first = text.lines().next()?;
    let rel = first.strip_prefix("//@ audit-path:")?.trim();
    (!rel.is_empty()).then(|| rel.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_allowlist_parses_with_justifications() {
        let allow = Allowlist::builtin();
        assert!(!allow.is_empty());
        for key in allow.keys() {
            let why = allow.why(key).unwrap();
            assert!(
                why.split_whitespace().count() >= 3,
                "[{key}] needs a real justification, got: {why}"
            );
        }
    }

    #[test]
    fn allowlist_rejects_malformed_entries() {
        // unknown rule id
        assert!(Allowlist::parse("[R9:x.rs]\nwhy = \"z z z\"\n")
            .is_err());
        // not R#:path shaped
        assert!(Allowlist::parse("[wat]\nwhy = \"z z z\"\n")
            .is_err());
        // missing / empty why
        assert!(Allowlist::parse("[R2:x.rs]\n").is_err());
        assert!(Allowlist::parse("[R2:x.rs]\nwhy = \"  \"\n")
            .is_err());
        // keys other than why
        assert!(Allowlist::parse(
            "[R2:x.rs]\nwhy = \"z z z\"\nalso = 1\n"
        )
        .is_err());
        // top-level keys
        assert!(Allowlist::parse("loose = 1\n").is_err());
        // the empty document is a valid empty allowlist
        assert!(Allowlist::parse("# nothing\n").unwrap().is_empty());
    }

    #[test]
    fn suppression_consumes_entries_and_stale_ones_fail() {
        let src = "let t = Instant::now();\n";
        let allow = Allowlist::parse(
            "[R2:coordinator/server.rs]\n\
             why = \"test: excused wall clock\"\n",
        )
        .unwrap();
        let hit = audit_source("coordinator/server.rs", src, &allow);
        assert!(hit.clean(), "{}", hit.render());
        assert_eq!(hit.suppressed, 1);

        // same allowlist over a file that never trips R2: the entry
        // is stale and the audit is not clean
        let idle = audit_source(
            "coordinator/server.rs",
            "let x = 1;\n",
            &allow,
        );
        assert!(!idle.clean());
        assert_eq!(idle.stale, vec!["R2:coordinator/server.rs"]);
        assert!(idle.render().contains("stale allowlist entry"));
    }

    #[test]
    fn report_names_file_line_rule_and_key() {
        let rep = audit_source(
            "comm/wire.rs",
            "fn d() { x.unwrap(); }\n",
            &Allowlist::empty(),
        );
        assert_eq!(rep.findings.len(), 1);
        let text = rep.render();
        assert!(
            text.contains("src/comm/wire.rs:1 [R4]"),
            "{text}"
        );
        assert!(text.contains("allow key: R4:comm/wire.rs"), "{text}");
        assert!(!rep.clean());
    }

    #[test]
    fn fixture_directive_parses() {
        assert_eq!(
            fixture_rel("//@ audit-path: comm/wire.rs\nfn x() {}\n"),
            Some("comm/wire.rs".to_string())
        );
        assert_eq!(fixture_rel("fn x() {}\n"), None);
        assert_eq!(fixture_rel("//@ audit-path:\n"), None);
    }
}
