//! The audit rules R1–R6: the repo's written-but-previously-unchecked
//! determinism and safety invariants as machine-checked pattern rules
//! over scanned source (see [`super::scan`]).
//!
//! Every rule is a deliberate *approximation* — a lexer cannot see
//! through type inference — tuned so the live tree's legitimate code
//! either passes structurally or carries a justified allowlist entry
//! (`analysis/allow.toml`). The bias is always toward false positives
//! in protected paths: a hit that is actually fine gets an allowlist
//! entry with a written `why`, never a weakening of the rule.

use super::scan::SourceFile;

/// Rule identifiers, ordered by id.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    R1,
    R2,
    R3,
    R4,
    R5,
    R6,
}

/// All rules, in id order (fixture self-tests iterate this).
pub const ALL: [Rule; 6] =
    [Rule::R1, Rule::R2, Rule::R3, Rule::R4, Rule::R5, Rule::R6];

impl Rule {
    pub fn id(self) -> &'static str {
        match self {
            Rule::R1 => "R1",
            Rule::R2 => "R2",
            Rule::R3 => "R3",
            Rule::R4 => "R4",
            Rule::R5 => "R5",
            Rule::R6 => "R6",
        }
    }

    pub fn from_id(id: &str) -> Option<Rule> {
        ALL.into_iter().find(|r| r.id() == id)
    }

    /// One-line statement of the invariant, shown with every hit.
    pub fn summary(self) -> &'static str {
        match self {
            Rule::R1 => {
                "every `unsafe` site carries a SAFETY contract on the \
                 preceding lines"
            }
            Rule::R2 => {
                "no wall-clock reads in simulated-accounting/fold paths \
                 (timing must be a pure fn of (seed, round, worker))"
            }
            Rule::R3 => {
                "no HashMap/HashSet in paths feeding folds, broadcasts, \
                 checkpoints, or wire frames (iteration order is \
                 nondeterministic; use BTreeMap or an explicit sort)"
            }
            Rule::R4 => {
                "no unwrap/expect/panics in non-test wire/checkpoint \
                 decode paths (hostile bytes must surface as errors)"
            }
            Rule::R5 => {
                "RNG only via util::rng seeded constructors; float \
                 reductions only via the blessed fixed-order kernels \
                 in tensor::{scalar,simd}"
            }
            Rule::R6 => {
                "thread creation only inside comm/transport.rs, \
                 coordinator/pool.rs, or test code"
            }
        }
    }
}

/// One rule hit at a specific line.
#[derive(Debug)]
pub struct Finding {
    pub rel: String,
    pub line: usize,
    pub rule: Rule,
    /// What matched (the offending token or missing contract).
    pub what: String,
}

impl Finding {
    /// The allowlist key that would suppress this finding.
    pub fn allow_key(&self) -> String {
        format!("{}:{}", self.rule.id(), self.rel)
    }
}

/// Run every rule over one scanned file.
pub fn check_file(file: &SourceFile, out: &mut Vec<Finding>) {
    r1_unsafe_contracts(file, out);
    r2_wall_clock(file, out);
    r3_hash_containers(file, out);
    r4_panicking_decodes(file, out);
    r5_rng_and_reductions(file, out);
    r6_thread_spawns(file, out);
}

/// How far above an `unsafe` token R1 looks for its contract: enough
/// for a `/// # Safety` doc section or a multi-line `// SAFETY:`
/// comment above the attributes of a fn.
const R1_LOOKBACK: usize = 16;

/// R1 — every `unsafe` token (block, fn, or impl) must have a comment
/// containing "SAFETY" (matched case-insensitively, so `/// # Safety`
/// doc headings count) on its own line or the lines directly above.
fn r1_unsafe_contracts(file: &SourceFile, out: &mut Vec<Finding>) {
    for (i, line) in file.lines.iter().enumerate() {
        if line.in_test || !has_word(&line.code, "unsafe") {
            continue;
        }
        let lo = i.saturating_sub(R1_LOOKBACK);
        let contracted = file.lines[lo..=i].iter().any(|l| {
            l.comment.to_ascii_uppercase().contains("SAFETY")
        });
        if !contracted {
            out.push(Finding {
                rel: file.rel.clone(),
                line: line.number,
                rule: Rule::R1,
                what: format!(
                    "`unsafe` without a SAFETY contract in the {} \
                     preceding lines",
                    R1_LOOKBACK
                ),
            });
        }
    }
}

/// The modules whose accounting must be a pure function of
/// (seed, round, worker): the algorithms' fold paths, the server's
/// sharded fold/step, the drift history ring, the compressors, and the
/// RNG substrate itself.
fn r2_in_scope(rel: &str) -> bool {
    rel.starts_with("algorithms/")
        || rel.starts_with("compress/")
        || rel == "coordinator/shard.rs"
        || rel == "coordinator/server.rs"
        || rel == "coordinator/history.rs"
        || rel == "util/rng.rs"
}

/// R2 — no wall-clock reads in simulated-accounting and fold paths.
/// Telemetry-only wall timing in these files needs an allowlist entry
/// naming its justification; socket deadlines and bench timing live in
/// modules outside this scope by design.
fn r2_wall_clock(file: &SourceFile, out: &mut Vec<Finding>) {
    if !r2_in_scope(&file.rel) {
        return;
    }
    for line in &file.lines {
        if line.in_test {
            continue;
        }
        let hit = if line.code.contains("std::time") {
            Some("std::time")
        } else if has_word(&line.code, "Instant") {
            Some("Instant")
        } else if has_word(&line.code, "SystemTime") {
            Some("SystemTime")
        } else {
            None
        };
        if let Some(tok) = hit {
            out.push(Finding {
                rel: file.rel.clone(),
                line: line.number,
                rule: Rule::R2,
                what: format!("wall-clock token `{tok}` in a \
                               simulated-accounting path"),
            });
        }
    }
}

/// Everything that feeds a fold, broadcast, checkpoint, or wire frame.
fn r3_in_scope(rel: &str) -> bool {
    rel.starts_with("algorithms/")
        || rel.starts_with("coordinator/")
        || rel.starts_with("compress/")
        || rel.starts_with("comm/")
}

/// R3 — no hash-order containers in deterministic paths. The scanner
/// cannot see *iteration* through type inference, so any mention is
/// flagged: lookup-only uses would need an allowlist entry, but the
/// crate-wide policy is simpler — these paths hold no HashMap at all
/// (config/JSON/CLI maps are `BTreeMap`, ordered by construction).
fn r3_hash_containers(file: &SourceFile, out: &mut Vec<Finding>) {
    if !r3_in_scope(&file.rel) {
        return;
    }
    const TOKENS: [&str; 5] =
        ["HashMap", "HashSet", "hash_map", "hash_set", "RandomState"];
    for line in &file.lines {
        if line.in_test {
            continue;
        }
        if let Some(tok) =
            TOKENS.iter().find(|t| has_word(&line.code, t))
        {
            out.push(Finding {
                rel: file.rel.clone(),
                line: line.number,
                rule: Rule::R3,
                what: format!("hash-order container `{tok}` in a \
                               deterministic path"),
            });
        }
    }
}

/// The hostile-input decode surfaces: wire frames from the network,
/// checkpoint bytes from disk.
fn r4_in_scope(rel: &str) -> bool {
    matches!(
        rel,
        "comm/wire.rs" | "comm/socket.rs" | "coordinator/checkpoint.rs"
    )
}

/// R4 — hostile bytes must surface as errors, never panics. Indexing
/// panics are invisible to a lexer; the explicit panic family below is
/// the enforceable surface (bounds-checked cursors like `Reader::take`
/// and `Dec::take` handle the indexing half by construction).
fn r4_panicking_decodes(file: &SourceFile, out: &mut Vec<Finding>) {
    if !r4_in_scope(&file.rel) {
        return;
    }
    const TOKENS: [&str; 6] = [
        ".unwrap()",
        ".expect(",
        "panic!(",
        "unreachable!(",
        "todo!(",
        "unimplemented!(",
    ];
    for line in &file.lines {
        if line.in_test {
            continue;
        }
        if let Some(tok) =
            TOKENS.iter().find(|t| line.code.contains(*t))
        {
            out.push(Finding {
                rel: file.rel.clone(),
                line: line.number,
                rule: Rule::R4,
                what: format!("panicking `{tok}` in a hostile-input \
                               decode path"),
            });
        }
    }
}

/// Where ad-hoc float reductions would break the one-documented-order
/// invariant: the fold paths plus the sharded server step.
fn r5_reduction_scope(rel: &str) -> bool {
    rel.starts_with("algorithms/")
        || rel.starts_with("compress/")
        || rel == "coordinator/server.rs"
        || rel == "coordinator/shard.rs"
        || rel == "coordinator/history.rs"
        || rel == "coordinator/pool.rs"
}

/// R5 — two halves. (a) crate-wide: no ambient/OS RNG; every stream
/// must come from `util::rng`'s seeded constructors so randomness is a
/// pure function of (seed, round, worker). (b) in fold paths: no
/// ad-hoc `.sum()`/`.product()` — float reductions go through the
/// blessed fixed-order kernels in `tensor::{scalar,simd}`, and the
/// few legitimate fixed-order folds carry allowlist entries.
fn r5_rng_and_reductions(file: &SourceFile, out: &mut Vec<Finding>) {
    const RNG_TOKENS: [&str; 6] = [
        "thread_rng",
        "from_entropy",
        "OsRng",
        "StdRng",
        "SmallRng",
        "getrandom",
    ];
    for line in &file.lines {
        if line.in_test {
            continue;
        }
        if file.rel != "util/rng.rs" {
            let rng_hit = RNG_TOKENS
                .iter()
                .find(|t| has_word(&line.code, t))
                .copied()
                .or_else(|| {
                    line.code.contains("rand::").then_some("rand::")
                });
            if let Some(tok) = rng_hit {
                out.push(Finding {
                    rel: file.rel.clone(),
                    line: line.number,
                    rule: Rule::R5,
                    what: format!(
                        "RNG `{tok}` outside util::rng's seeded \
                         constructors"
                    ),
                });
                continue;
            }
        }
        if r5_reduction_scope(&file.rel) {
            let red = [".sum::<", ".sum()", ".product"]
                .into_iter()
                .find(|t| line.code.contains(t));
            if let Some(tok) = red {
                out.push(Finding {
                    rel: file.rel.clone(),
                    line: line.number,
                    rule: Rule::R5,
                    what: format!(
                        "ad-hoc reduction `{tok}` in a fold path \
                         (use the fixed-order tensor kernels)"
                    ),
                });
            }
        }
    }
}

/// R6 — thread creation is confined to the two engine substrates
/// (worker transport, shard pool); everything else must go through
/// them or carry an allowlist entry. `thread::sleep`/`JoinHandle`/
/// `available_parallelism` are not creation and do not match.
fn r6_thread_spawns(file: &SourceFile, out: &mut Vec<Finding>) {
    if matches!(file.rel.as_str(), "comm/transport.rs"
        | "coordinator/pool.rs")
    {
        return;
    }
    const TOKENS: [&str; 3] =
        ["thread::spawn", "thread::Builder", "thread::scope"];
    for line in &file.lines {
        if line.in_test {
            continue;
        }
        if let Some(tok) =
            TOKENS.iter().find(|t| line.code.contains(*t))
        {
            out.push(Finding {
                rel: file.rel.clone(),
                line: line.number,
                rule: Rule::R6,
                what: format!("thread creation `{tok}` outside the \
                               transport/pool substrates"),
            });
        }
    }
}

/// Substring match with identifier boundaries on both sides, so
/// `unsafe` never matches inside `unsafe_op_in_unsafe_fn` and
/// `Instant` never matches inside `Instantiate`.
fn has_word(code: &str, word: &str) -> bool {
    let bytes = code.as_bytes();
    let mut from = 0;
    while let Some(at) = code[from..].find(word) {
        let start = from + at;
        let end = start + word.len();
        let pre_ok = start == 0 || !is_ident_byte(bytes[start - 1]);
        let post_ok =
            end == bytes.len() || !is_ident_byte(bytes[end]);
        if pre_ok && post_ok {
            return true;
        }
        from = start + 1;
    }
    false
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::scan::scan_source;

    fn findings(rel: &str, src: &str) -> Vec<Finding> {
        let file = scan_source(rel, src);
        let mut out = Vec::new();
        check_file(&file, &mut out);
        out
    }

    #[test]
    fn word_boundaries() {
        assert!(has_word("unsafe {", "unsafe"));
        assert!(!has_word("deny(unsafe_op_in_unsafe_fn)", "unsafe"));
        assert!(has_word("Instant::now()", "Instant"));
        assert!(!has_word("Instantiate the thing", "Instant"));
    }

    #[test]
    fn r1_accepts_contracts_and_doc_headings() {
        let ok = "// SAFETY: ptr is in bounds\nunsafe { *p }\n";
        assert!(findings("tensor/simd.rs", ok).is_empty());
        let doc = "/// # Safety\n/// Caller checks AVX.\n\
                   pub unsafe fn go() {}\n";
        assert!(findings("tensor/simd.rs", doc).is_empty());
        let bad = "let v = unsafe { *p };\n";
        let f = findings("tensor/simd.rs", bad);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, Rule::R1);
        assert_eq!(f[0].allow_key(), "R1:tensor/simd.rs");
    }

    #[test]
    fn r2_only_fires_in_scope() {
        let src = "let t0 = std::time::Instant::now();\n";
        assert!(!findings("coordinator/server.rs", src).is_empty());
        assert!(!findings("algorithms/trainer.rs", src).is_empty());
        // telemetry/bench/socket wall timing is out of scope by design
        assert!(findings("telemetry/mod.rs", src).is_empty());
        assert!(findings("comm/socket.rs", src).is_empty());
        assert!(findings("bench/mod.rs", src).is_empty());
    }

    #[test]
    fn r4_exempts_test_modules() {
        let src = "#[cfg(test)]\nmod tests {\n\
                   fn t() { frame().unwrap(); }\n}\n";
        assert!(findings("comm/wire.rs", src).is_empty());
        let live = "fn d(b: &[u8]) -> u32 { b[0] as u32 }\n\
                    fn e(b: &[u8]) { b.first().unwrap(); }\n";
        let f = findings("comm/wire.rs", live);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, Rule::R4);
        assert_eq!(f[0].line, 2);
    }

    #[test]
    fn r5_rng_is_crate_wide_but_reductions_are_scoped() {
        let rng = "let r = rand::thread_rng();\n";
        assert!(!findings("telemetry/mod.rs", rng).is_empty());
        let sum = "let s: f32 = xs.iter().sum();\n";
        assert!(!findings("coordinator/server.rs", sum).is_empty());
        // stats/telemetry means over counters are not fold paths
        assert!(findings("util/stats.rs", sum).is_empty());
    }

    #[test]
    fn r6_allows_the_substrates_and_sleep() {
        let spawn = "std::thread::spawn(|| {});\n";
        assert!(findings("comm/transport.rs", spawn).is_empty());
        assert!(findings("coordinator/pool.rs", spawn).is_empty());
        assert!(!findings("exp/mod.rs", spawn).is_empty());
        let sleep = "std::thread::sleep(d);\n";
        assert!(findings("comm/socket.rs", sleep).is_empty());
    }
}
