//! Compute backends behind the [`Compute`] trait, so the coordinator and
//! [`Trainer`](crate::algorithms::Trainer) stay backend-agnostic:
//!
//! * [`pjrt`] (cargo feature `pjrt`) — loads the AOT HLO-text artifacts
//!   emitted by `make artifacts` and executes them on the CPU PJRT
//!   client via the `xla` crate. Python never runs on the training path.
//!   Without the feature, [`Engine`] is a stub whose constructor reports
//!   how to enable the real backend.
//! * [`native`] — a pure-rust comparator backend (logistic regression
//!   with the exact flat layout of the JAX model) used by tests, CI and
//!   fast sweeps; always available.
//!
//! [`load_backend`] picks the PJRT engine when artifacts + the feature
//! are available and falls back to the native backend for logreg specs,
//! so the CLI, benches and examples run end-to-end in a pure-rust build.

pub mod manifest;
pub mod native;

pub use manifest::{Dtype, InputSpec, Manifest, SpecEntry};

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::Engine;

#[cfg(not(feature = "pjrt"))]
mod pjrt_stub;
#[cfg(not(feature = "pjrt"))]
pub use pjrt_stub::Engine;

use crate::data::Batch;

/// Model-compute abstraction used by the coordinator (L3 hot path).
///
/// `grad` writes the padded flat gradient into `out_grad` (allocation-free
/// hot path) and returns the minibatch loss. `update` applies the fused
/// AMSGrad/CADA step (Eq. 2a-2c) in place. `innov` is the squared-L2
/// innovation norm of rules (5)/(7)/(10).
pub trait Compute {
    fn p_pad(&self) -> usize;

    fn grad(&mut self, theta: &[f32], batch: &Batch, out_grad: &mut [f32])
        -> anyhow::Result<f32>;

    /// Returns (mean loss, correct count) over the eval batch.
    fn eval(&mut self, theta: &[f32], batch: &Batch)
        -> anyhow::Result<(f32, f32)>;

    fn update(&mut self, theta: &mut [f32], h: &mut [f32], vhat: &mut [f32],
              grad: &[f32], alpha: f32) -> anyhow::Result<()>;

    fn innov(&mut self, g1: &[f32], g2: &[f32]) -> anyhow::Result<f32>;

    /// Human-readable backend name (telemetry).
    fn backend_name(&self) -> &'static str;

    /// Fork an independent instance of this backend for a worker thread:
    /// the [`Threaded`](crate::comm::Threaded) transport gives each
    /// persistent worker its own backend. Stateless native backends
    /// return a clone; backends tied to one runtime/device (PJRT) keep
    /// the default `None`, and the engine reports that the threaded
    /// transport is unavailable for them.
    fn fork(&self) -> Option<Box<dyn Compute + Send>> {
        None
    }
}

/// Resolve (spec, compute backend, initial theta) for `spec_name`.
///
/// Tries the artifact-backed PJRT engine first; if artifacts or the
/// `pjrt` feature are missing, falls back to the native backend for
/// logistic-regression specs (zero-initialised theta, matching the AOT
/// pipeline's logreg init).
pub fn load_backend(
    artifacts: impl AsRef<std::path::Path>,
    spec_name: &str,
) -> anyhow::Result<(SpecEntry, Box<dyn Compute>, Vec<f32>)> {
    let engine_err = match Manifest::load(artifacts) {
        Ok(manifest) => match Engine::new(&manifest, spec_name) {
            Ok(engine) => {
                let init = engine.init_theta()?;
                let spec = engine.spec.clone();
                return Ok((spec, Box::new(engine), init));
            }
            Err(e) => {
                // In a real PJRT build with artifacts on disk, a broken
                // artifact set must not silently degrade to a different
                // backend (and a different init theta) — surface it.
                if cfg!(feature = "pjrt") {
                    return Err(e);
                }
                e
            }
        },
        Err(e) => e,
    };
    let spec = SpecEntry::builtin_logreg(spec_name).map_err(|_| {
        anyhow::anyhow!(
            "no PJRT backend for spec '{spec_name}' ({engine_err:#}) and \
             no native fallback exists for it; run `make artifacts` and \
             build with `--features pjrt`"
        )
    })?;
    crate::info!(
        "PJRT unavailable for '{spec_name}' ({engine_err}); using the \
         native rust backend"
    );
    let compute = native::NativeLogReg::for_spec(spec.feature_dim(),
                                                 spec.p_pad);
    let init = vec![0.0; spec.p_pad];
    Ok((spec, Box::new(compute), init))
}
