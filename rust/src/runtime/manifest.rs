//! `artifacts/manifest.json` — the single source of truth emitted by the
//! AOT pipeline (python/compile/aot.py). Describes, per experiment spec,
//! the HLO artifact filenames, parameter dimensions, input shapes/dtypes
//! and the baked Adam hyper-parameters.

use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

/// Element type of a model input.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => anyhow::bail!("unknown dtype in manifest: {other}"),
        }
    }

    pub fn size_bytes(self) -> usize {
        4
    }
}

/// Shape + dtype of one model input (beyond the theta vector).
#[derive(Clone, Debug, PartialEq)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl InputSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(v: &Json) -> anyhow::Result<Self> {
        let shape = v
            .req("shape")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("shape not an array"))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| anyhow::anyhow!("bad dim")))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let dtype = Dtype::parse(
            v.req("dtype")?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("dtype not a string"))?,
        )?;
        Ok(InputSpec { shape, dtype })
    }
}

/// One experiment spec: model + batch geometry + artifact files.
#[derive(Clone, Debug)]
pub struct SpecEntry {
    pub name: String,
    pub kind: String,
    /// live (unpadded) parameter count
    pub p: usize,
    /// tile-aligned padded parameter count — the length of every flat vector
    pub p_pad: usize,
    pub batch: usize,
    pub eval_batch: usize,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub grad_inputs: Vec<InputSpec>,
    pub eval_inputs: Vec<InputSpec>,
    pub grad_hlo: PathBuf,
    pub eval_hlo: PathBuf,
    pub update_hlo: PathBuf,
    pub innov_hlo: PathBuf,
    pub init_bin: PathBuf,
    /// model config needed by data generators (features, classes, ...)
    pub cfg: Json,
}

impl SpecEntry {
    fn parse(dir: &Path, v: &Json) -> anyhow::Result<Self> {
        let s = |key: &str| -> anyhow::Result<String> {
            Ok(v.req(key)?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("{key} not a string"))?
                .to_string())
        };
        let n = |key: &str| -> anyhow::Result<f64> {
            v.req(key)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("{key} not a number"))
        };
        let inputs = |key: &str| -> anyhow::Result<Vec<InputSpec>> {
            v.req(key)?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("{key} not an array"))?
                .iter()
                .map(InputSpec::parse)
                .collect()
        };
        Ok(SpecEntry {
            name: s("name")?,
            kind: s("kind")?,
            p: n("p")? as usize,
            p_pad: n("p_pad")? as usize,
            batch: n("batch")? as usize,
            eval_batch: n("eval_batch")? as usize,
            beta1: n("beta1")? as f32,
            beta2: n("beta2")? as f32,
            eps: n("eps")? as f32,
            grad_inputs: inputs("grad_inputs")?,
            eval_inputs: inputs("eval_inputs")?,
            grad_hlo: dir.join(s("grad_hlo")?),
            eval_hlo: dir.join(s("eval_hlo")?),
            update_hlo: dir.join(s("update_hlo")?),
            innov_hlo: dir.join(s("innov_hlo")?),
            init_bin: dir.join(s("init_bin")?),
            cfg: v.req("cfg")?.clone(),
        })
    }

    /// Read the initial padded flat parameter vector.
    pub fn load_init(&self) -> anyhow::Result<Vec<f32>> {
        let raw = std::fs::read(&self.init_bin)?;
        anyhow::ensure!(
            raw.len() == 4 * self.p_pad,
            "init bin {} has {} bytes, expected {}",
            self.init_bin.display(),
            raw.len(),
            4 * self.p_pad
        );
        Ok(raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect())
    }

    /// Upload payload of one gradient (innovation) vector, in bytes —
    /// what a worker sends to the server on a communication round.
    pub fn upload_bytes(&self) -> usize {
        4 * self.p
    }

    /// Feature dimension of the model input (last axis of the first grad
    /// input; e.g. 22 for the ijcnn1-like logreg spec).
    pub fn feature_dim(&self) -> usize {
        self.grad_inputs
            .first()
            .and_then(|i| i.shape.last().copied())
            .unwrap_or(0)
    }

    /// Artifact-free builtin spec for the binary-logreg workloads, with
    /// the same geometry the AOT pipeline bakes into the real artifacts
    /// (python/compile/specs.py). Lets the native backend, tests and CI
    /// run without `make artifacts`.
    pub fn builtin_logreg(name: &str) -> anyhow::Result<SpecEntry> {
        // (features, per-worker batch, eval batch) per spec
        let (d, batch, eval_batch) = match name {
            "logreg_covtype" => (54, 32, 4096),
            "logreg_ijcnn" => (22, 92, 4096),
            "test_logreg" => (8, 16, 64),
            other => anyhow::bail!(
                "no builtin spec named '{other}' (have logreg_covtype, \
                 logreg_ijcnn, test_logreg)"
            ),
        };
        let inputs = |b: usize| {
            vec![
                InputSpec { shape: vec![b, d], dtype: Dtype::F32 },
                InputSpec { shape: vec![b], dtype: Dtype::I32 },
            ]
        };
        let mut cfg = std::collections::BTreeMap::new();
        cfg.insert("num_features".to_string(), Json::Num(d as f64));
        Ok(SpecEntry {
            name: name.to_string(),
            kind: "logreg_binary".to_string(),
            p: d + 1,
            p_pad: 1024,
            batch,
            eval_batch,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            grad_inputs: inputs(batch),
            eval_inputs: inputs(eval_batch),
            grad_hlo: PathBuf::new(),
            eval_hlo: PathBuf::new(),
            update_hlo: PathBuf::new(),
            innov_hlo: PathBuf::new(),
            init_bin: PathBuf::new(),
            cfg: Json::Obj(cfg),
        })
    }
}

/// The parsed artifact manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub specs: Vec<SpecEntry>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} ({e}); run `make artifacts` first",
                path.display()
            )
        })?;
        let root = json::parse(&text)?;
        let version = root.req("version")?.as_f64().unwrap_or(0.0) as u32;
        anyhow::ensure!(version == 1, "unsupported manifest version {version}");
        let specs = root
            .req("specs")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("specs not an array"))?
            .iter()
            .map(|v| SpecEntry::parse(&dir, v))
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Manifest { dir, specs })
    }

    pub fn spec(&self, name: &str) -> anyhow::Result<&SpecEntry> {
        self.specs
            .iter()
            .find(|s| s.name == name)
            .ok_or_else(|| {
                let known: Vec<_> =
                    self.specs.iter().map(|s| s.name.as_str()).collect();
                anyhow::anyhow!("spec '{name}' not in manifest; have {known:?}")
            })
    }

    /// Default artifacts directory: $CADA_ARTIFACTS or ./artifacts.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("CADA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> String {
        r#"{
          "version": 1,
          "specs": [{
            "name": "t", "kind": "logreg_binary", "p": 9, "p_pad": 1024,
            "batch": 16, "eval_batch": 64,
            "beta1": 0.9, "beta2": 0.999, "eps": 1e-8, "seed": 0,
            "cfg": {"num_features": 8}, "tags": ["test"],
            "grad_inputs": [
              {"shape": [16, 8], "dtype": "f32"},
              {"shape": [16], "dtype": "i32"}],
            "eval_inputs": [
              {"shape": [64, 8], "dtype": "f32"},
              {"shape": [64], "dtype": "i32"}],
            "grad_hlo": "t.grad.hlo.txt", "eval_hlo": "t.eval.hlo.txt",
            "update_hlo": "u.hlo.txt", "innov_hlo": "i.hlo.txt",
            "init_bin": "t.init.bin"
          }]
        }"#
        .to_string()
    }

    #[test]
    fn parse_sample() {
        let dir = std::env::temp_dir().join("cada_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample_manifest()).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let s = m.spec("t").unwrap();
        assert_eq!(s.p, 9);
        assert_eq!(s.p_pad, 1024);
        assert_eq!(s.grad_inputs.len(), 2);
        assert_eq!(s.grad_inputs[0].dtype, Dtype::F32);
        assert_eq!(s.grad_inputs[1].shape, vec![16]);
        assert_eq!(s.upload_bytes(), 36);
        assert!(m.spec("nope").is_err());
    }

    #[test]
    fn builtin_logreg_specs_are_consistent() {
        for name in ["logreg_covtype", "logreg_ijcnn", "test_logreg"] {
            let s = SpecEntry::builtin_logreg(name).unwrap();
            assert_eq!(s.name, name);
            assert_eq!(s.p, s.feature_dim() + 1);
            assert!(s.p_pad >= s.p);
            assert_eq!(s.grad_inputs[0].shape, vec![s.batch, s.feature_dim()]);
            assert_eq!(s.eval_inputs[0].shape,
                       vec![s.eval_batch, s.feature_dim()]);
            assert_eq!(s.upload_bytes(), 4 * s.p);
            assert_eq!(s.cfg.get("num_features").unwrap().as_usize(),
                       Some(s.feature_dim()));
        }
        assert!(SpecEntry::builtin_logreg("cnn_cifar").is_err());
    }

    #[test]
    fn init_length_checked() {
        let dir = std::env::temp_dir().join("cada_manifest_test2");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), sample_manifest()).unwrap();
        std::fs::write(dir.join("t.init.bin"), vec![0u8; 8]).unwrap();
        let m = Manifest::load(&dir).unwrap();
        assert!(m.spec("t").unwrap().load_init().is_err());
        std::fs::write(dir.join("t.init.bin"), vec![0u8; 4 * 1024]).unwrap();
        let init = m.spec("t").unwrap().load_init().unwrap();
        assert_eq!(init.len(), 1024);
    }
}
