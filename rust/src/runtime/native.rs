//! Pure-rust [`Compute`] backend: binary logistic regression with the same
//! flat layout and loss as the JAX model (python/compile/models/logreg.py).
//!
//! Purpose: (a) an independent numerical comparator for the HLO/Pallas
//! artifacts (integration tests assert native == pjrt to f32 tolerance);
//! (b) a fast in-process backend for wide parameter sweeps where per-call
//! PJRT overhead on tiny models would dominate (ablated in the
//! micro_hotpath bench).
//!
//! Flat layout note: `jax.flatten_util.ravel_pytree` flattens dict keys in
//! sorted order, so for `{"w": f32[d], "b": f32[]}` the flat vector is
//! `[b, w_0, ..., w_{d-1}]`, padded with zeros to `p_pad`. This backend
//! reproduces exactly that layout.

use super::Compute;
use crate::data::{Array, Batch};
use crate::tensor;

/// Numerically stable softplus: ln(1 + e^z).
#[inline]
fn softplus(z: f32) -> f32 {
    z.max(0.0) + (-z.abs()).exp().ln_1p()
}

#[inline]
fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

/// Binary logistic regression with l2 regularisation, flat layout
/// `[b, w...]` padded to `p_pad`.
#[derive(Clone)]
pub struct NativeLogReg {
    pub d: usize,
    pub p_pad: usize,
    pub lam: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl NativeLogReg {
    pub fn new(d: usize, p_pad: usize, lam: f32, beta1: f32, beta2: f32,
               eps: f32) -> Self {
        assert!(p_pad >= d + 1);
        NativeLogReg { d, p_pad, lam, beta1, beta2, eps }
    }

    /// Matches the python spec defaults (lam=1e-5, Adam betas).
    pub fn for_spec(d: usize, p_pad: usize) -> Self {
        Self::new(d, p_pad, 1e-5, 0.9, 0.999, 1e-8)
    }

    fn unpack_batch<'a>(&self, batch: &'a Batch)
                        -> anyhow::Result<(&'a [f32], &'a [i32])> {
        anyhow::ensure!(batch.arrays.len() == 2, "logreg batch needs (x, y)");
        let x = match &batch.arrays[0].0 {
            Array::F32(v) => v.as_slice(),
            _ => anyhow::bail!("x must be f32"),
        };
        let y = match &batch.arrays[1].0 {
            Array::I32(v) => v.as_slice(),
            _ => anyhow::bail!("y must be i32"),
        };
        anyhow::ensure!(x.len() == y.len() * self.d, "bad batch geometry");
        Ok((x, y))
    }

    /// loss + optional gradient accumulation (shared fwd/bwd core).
    fn loss_grad(&self, theta: &[f32], x: &[f32], y: &[i32],
                 mut grad: Option<&mut [f32]>) -> (f32, f32) {
        let b = theta[0];
        let w = &theta[1..1 + self.d];
        let n = y.len();
        let inv_n = 1.0 / n as f32;
        if let Some(g) = grad.as_deref_mut() {
            g.fill(0.0);
        }
        let mut loss = 0.0f32;
        let mut correct = 0.0f32;
        for (i, &yi) in y.iter().enumerate() {
            let xi = &x[i * self.d..(i + 1) * self.d];
            let z = tensor::dot(xi, w) + b;
            let yf = yi as f32;
            loss += softplus(z) - yf * z;
            if ((z > 0.0) as i32) == yi {
                correct += 1.0;
            }
            if let Some(g) = grad.as_deref_mut() {
                let r = (sigmoid(z) - yf) * inv_n;
                g[0] += r;
                tensor::axpy(&mut g[1..1 + self.d], r, xi);
            }
        }
        loss *= inv_n;
        // l2 over all live params (w AND b), matching the jax _l2 helper
        let live = &theta[..1 + self.d];
        loss += 0.5 * self.lam * tensor::sqnorm(live);
        if let Some(g) = grad.as_deref_mut() {
            tensor::axpy(&mut g[..1 + self.d], self.lam, live);
        }
        (loss, correct)
    }
}

impl Compute for NativeLogReg {
    fn p_pad(&self) -> usize {
        self.p_pad
    }

    fn grad(&mut self, theta: &[f32], batch: &Batch, out_grad: &mut [f32])
            -> anyhow::Result<f32> {
        let (x, y) = self.unpack_batch(batch)?;
        let (loss, _) = self.loss_grad(theta, x, y, Some(out_grad));
        Ok(loss)
    }

    fn eval(&mut self, theta: &[f32], batch: &Batch)
            -> anyhow::Result<(f32, f32)> {
        let (x, y) = self.unpack_batch(batch)?;
        let (loss, correct) = self.loss_grad(theta, x, y, None);
        Ok((loss, correct))
    }

    fn update(&mut self, theta: &mut [f32], h: &mut [f32], vhat: &mut [f32],
              grad: &[f32], alpha: f32) -> anyhow::Result<()> {
        tensor::amsgrad_update(theta, h, vhat, grad, alpha, self.beta1,
                               self.beta2, self.eps);
        Ok(())
    }

    fn innov(&mut self, g1: &[f32], g2: &[f32]) -> anyhow::Result<f32> {
        Ok(tensor::sqnorm_diff(g1, g2))
    }

    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn fork(&self) -> Option<Box<dyn Compute + Send>> {
        // stateless: a worker-thread clone computes bit-identical floats
        Some(Box::new(self.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::util::rng::Rng;

    fn toy_data(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let w: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut x = Vec::with_capacity(n * d);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let mut z = 0.0;
            for &wj in &w {
                let v = rng.normal_f32(0.0, 1.0);
                x.push(v);
                z += wj * v;
            }
            y.push((z > 0.0) as i32);
        }
        Dataset::Labeled { x, sample_shape: vec![d], y }
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let d = 6;
        let mut m = NativeLogReg::for_spec(d, 16);
        let data = toy_data(32, d, 1);
        let batch = data.gather(&(0..32).collect::<Vec<_>>());
        let mut rng = Rng::new(2);
        let mut theta = vec![0.0f32; 16];
        for t in theta[..d + 1].iter_mut() {
            *t = rng.normal_f32(0.0, 0.3);
        }
        let mut g = vec![0.0f32; 16];
        m.grad(&theta, &batch, &mut g).unwrap();
        let eps = 1e-3f32;
        for i in 0..d + 1 {
            let mut tp = theta.clone();
            tp[i] += eps;
            let mut tm = theta.clone();
            tm[i] -= eps;
            let mut scratch = vec![0.0f32; 16];
            let lp = m.grad(&tp, &batch, &mut scratch).unwrap();
            let lm = m.grad(&tm, &batch, &mut scratch).unwrap();
            let fd = (lp - lm) / (2.0 * eps);
            assert!((g[i] - fd).abs() < 2e-3 * (1.0 + fd.abs()),
                    "coord {i}: {} vs {}", g[i], fd);
        }
        // padding carries zero gradient
        assert!(g[d + 1..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn adam_descends() {
        let d = 8;
        let p = 1024;
        let mut m = NativeLogReg::for_spec(d, p);
        let data = toy_data(256, d, 3);
        let all: Vec<usize> = (0..256).collect();
        let batch = data.gather(&all);
        let mut theta = vec![0.0f32; p];
        let mut h = vec![0.0f32; p];
        let mut vhat = vec![0.0f32; p];
        let mut g = vec![0.0f32; p];
        let loss0 = m.grad(&theta, &batch, &mut g).unwrap();
        for _ in 0..80 {
            m.grad(&theta, &batch, &mut g).unwrap();
            m.update(&mut theta, &mut h, &mut vhat, &g, 0.05).unwrap();
        }
        let loss1 = m.grad(&theta, &batch, &mut g).unwrap();
        assert!(loss1 < 0.5 * loss0, "{loss0} -> {loss1}");
    }

    #[test]
    fn eval_counts_match_manual() {
        let d = 2;
        let mut m = NativeLogReg::for_spec(d, 8);
        // theta = [b=0, w=(1,0)] -> z = x0
        let theta = [0.0f32, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let data = Dataset::Labeled {
            x: vec![2.0, 0.0, -2.0, 0.0, 3.0, 0.0, -1.0, 0.0],
            sample_shape: vec![2],
            y: vec![1, 0, 0, 0],
        };
        let batch = data.gather(&[0, 1, 2, 3]);
        let (_, correct) = m.eval(&theta, &batch).unwrap();
        assert_eq!(correct, 3.0);
    }
}
