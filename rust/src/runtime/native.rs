//! Pure-rust [`Compute`] backend: binary logistic regression with the same
//! flat layout and loss as the JAX model (python/compile/models/logreg.py).
//!
//! Purpose: (a) an independent numerical comparator for the HLO/Pallas
//! artifacts (integration tests assert native == pjrt to f32 tolerance);
//! (b) a fast in-process backend for wide parameter sweeps where per-call
//! PJRT overhead on tiny models would dominate (ablated in the
//! micro_hotpath bench).
//!
//! The gradient — the dominant compute of every worker round (two grad
//! evals per round for CADA1/CADA2) — runs as a two-pass **blocked
//! kernel** over [`GRAD_BLOCK`]-sample blocks: pass 1 computes the whole
//! block's logits `z = X·w + b` ([`tensor::gemv_block`], bit-identical
//! to per-sample dots), pass 2 derives sigmoid AND softplus from **one**
//! exponential per sample ([`sigmoid_softplus`]) and folds the residuals
//! into the gradient with a fixed group-of-4 accumulation order
//! ([`tensor::ger_acc`]). The scratch buffers live on the backend, so a
//! steady-state round allocates nothing. The pre-blocked sample-at-a-time
//! path is retained as [`NativeLogReg::loss_grad_scalar`] — the
//! comparator tests pin the blocked kernel against it (tolerance) and
//! against an independent reference of the documented accumulation order
//! (bit-for-bit, PR-3-style).
//!
//! Flat layout note: `jax.flatten_util.ravel_pytree` flattens dict keys in
//! sorted order, so for `{"w": f32[d], "b": f32[]}` the flat vector is
//! `[b, w_0, ..., w_{d-1}]`, padded with zeros to `p_pad`. This backend
//! reproduces exactly that layout.

use super::Compute;
use crate::data::{Array, Batch};
use crate::tensor;

/// Samples per block of the blocked gradient kernel. A multiple of
/// [`tensor::GER_GROUP`], so the gradient's fixed 4-row accumulation
/// groups fall on the same sample boundaries whatever the block size —
/// the accumulated bits depend only on the sample order, never on
/// `GRAD_BLOCK`.
const GRAD_BLOCK: usize = 64;

/// Numerically stable softplus: ln(1 + e^z).
#[inline]
fn softplus(z: f32) -> f32 {
    z.max(0.0) + (-z.abs()).exp().ln_1p()
}

/// The historical sigmoid (its own exponential); retained for the
/// sample-at-a-time reference path.
#[inline]
fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

/// Fused logistic pair: (sigmoid(z), softplus(z)) from ONE exponential.
/// The kernel now lives in [`tensor::scalar`] (it is the scalar twin of
/// the dispatched block form [`tensor::sigmoid_softplus_block`], which
/// the blocked gradient path below uses); re-exported here because this
/// backend is its historical home and the comparator tests pin it here.
pub use crate::tensor::sigmoid_softplus;

/// Binary logistic regression with l2 regularisation, flat layout
/// `[b, w...]` padded to `p_pad`.
#[derive(Clone)]
pub struct NativeLogReg {
    pub d: usize,
    pub p_pad: usize,
    pub lam: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// scratch: one block of logits (blocked gradient kernel; owned by
    /// the backend so steady-state rounds allocate nothing)
    z_buf: Vec<f32>,
    /// scratch: one block of residuals `(sigmoid(z) - y) / n`
    r_buf: Vec<f32>,
    /// scratch: one block of fused sigmoids (pass 2a block activations)
    sig_buf: Vec<f32>,
    /// scratch: one block of fused softplus values
    sp_buf: Vec<f32>,
}

impl NativeLogReg {
    pub fn new(d: usize, p_pad: usize, lam: f32, beta1: f32, beta2: f32,
               eps: f32) -> Self {
        assert!(p_pad >= d + 1);
        NativeLogReg {
            d,
            p_pad,
            lam,
            beta1,
            beta2,
            eps,
            z_buf: vec![0.0; GRAD_BLOCK],
            r_buf: vec![0.0; GRAD_BLOCK],
            sig_buf: vec![0.0; GRAD_BLOCK],
            sp_buf: vec![0.0; GRAD_BLOCK],
        }
    }

    /// Matches the python spec defaults (lam=1e-5, Adam betas).
    pub fn for_spec(d: usize, p_pad: usize) -> Self {
        Self::new(d, p_pad, 1e-5, 0.9, 0.999, 1e-8)
    }

    fn unpack_batch<'a>(&self, batch: &'a Batch)
                        -> anyhow::Result<(&'a [f32], &'a [i32])> {
        anyhow::ensure!(batch.arrays.len() == 2, "logreg batch needs (x, y)");
        let x = match &batch.arrays[0].0 {
            Array::F32(v) => v.as_slice(),
            _ => anyhow::bail!("x must be f32"),
        };
        let y = match &batch.arrays[1].0 {
            Array::I32(v) => v.as_slice(),
            _ => anyhow::bail!("y must be i32"),
        };
        anyhow::ensure!(x.len() == y.len() * self.d, "bad batch geometry");
        Ok((x, y))
    }

    /// loss + optional gradient accumulation (shared fwd/bwd core) — the
    /// blocked two-pass kernel (see the module docs): per
    /// [`GRAD_BLOCK`]-sample block, compute all logits first
    /// ([`tensor::gemv_block`]), then one fused exponential per sample
    /// ([`sigmoid_softplus`]) and a group-of-4 gradient fold
    /// ([`tensor::ger_acc`]). Logits, loss and the accuracy count are
    /// bit-identical to the sample-at-a-time reference
    /// ([`NativeLogReg::loss_grad_scalar`]); the gradient — the bias
    /// included, whose residuals go through the fused sigmoid (last-ulp
    /// different for z < 0) — matches it to accumulation tolerance, and
    /// its exact bits are pinned by the fixed-order comparator test
    /// instead.
    fn loss_grad(&mut self, theta: &[f32], x: &[f32], y: &[i32],
                 mut grad: Option<&mut [f32]>) -> (f32, f32) {
        let d = self.d;
        let b = theta[0];
        let w = &theta[1..1 + d];
        let n = y.len();
        let inv_n = 1.0 / n as f32;
        if let Some(g) = grad.as_deref_mut() {
            g.fill(0.0);
        }
        let mut loss = 0.0f32;
        let mut correct = 0.0f32;
        let mut lo = 0usize;
        while lo < n {
            let hi = (lo + GRAD_BLOCK).min(n);
            let nb = hi - lo;
            let xb = &x[lo * d..hi * d];
            // pass 1: the block's raw logits X·w (z_buf[i] + b below)
            tensor::gemv_block(&mut self.z_buf[..nb], xb, w);
            if let Some(g) = grad.as_deref_mut() {
                // pass 2a: fold the bias into the block's logits, then
                // ONE exponential per sample yields both activations —
                // the dispatched block kernel, bit-identical to calling
                // the fused scalar helper per sample
                for z in self.z_buf[..nb].iter_mut() {
                    *z += b;
                }
                tensor::sigmoid_softplus_block(&self.z_buf[..nb],
                                               &mut self.sig_buf[..nb],
                                               &mut self.sp_buf[..nb]);
                for (i, &yi) in y[lo..hi].iter().enumerate() {
                    let z = self.z_buf[i];
                    let yf = yi as f32;
                    loss += self.sp_buf[i] - yf * z;
                    if ((z > 0.0) as i32) == yi {
                        correct += 1.0;
                    }
                    let r = (self.sig_buf[i] - yf) * inv_n;
                    self.r_buf[i] = r;
                    g[0] += r;
                }
                // pass 2b: fold the block's residuals, 4 rows per pass
                tensor::ger_acc(&mut g[1..1 + d], xb,
                                &self.r_buf[..nb]);
            } else {
                for (i, &yi) in y[lo..hi].iter().enumerate() {
                    let z = self.z_buf[i] + b;
                    let yf = yi as f32;
                    loss += softplus(z) - yf * z;
                    if ((z > 0.0) as i32) == yi {
                        correct += 1.0;
                    }
                }
            }
            lo = hi;
        }
        loss *= inv_n;
        // l2 over all live params (w AND b), matching the jax _l2 helper
        let live = &theta[..1 + d];
        loss += 0.5 * self.lam * tensor::sqnorm(live);
        if let Some(g) = grad.as_deref_mut() {
            tensor::axpy(&mut g[..1 + d], self.lam, live);
        }
        (loss, correct)
    }

    /// The pre-blocked sample-at-a-time path, retained verbatim as the
    /// comparator reference: per sample, one `dot`, separate
    /// `sigmoid`/`softplus` exponentials, one `axpy` into the gradient.
    /// Used by the comparator tests and the micro_hotpath
    /// blocked-vs-scalar ablation — NOT on the training hot path.
    pub fn loss_grad_scalar(&self, theta: &[f32], x: &[f32], y: &[i32],
                            mut grad: Option<&mut [f32]>) -> (f32, f32) {
        let b = theta[0];
        let w = &theta[1..1 + self.d];
        let n = y.len();
        let inv_n = 1.0 / n as f32;
        if let Some(g) = grad.as_deref_mut() {
            g.fill(0.0);
        }
        let mut loss = 0.0f32;
        let mut correct = 0.0f32;
        for (i, &yi) in y.iter().enumerate() {
            let xi = &x[i * self.d..(i + 1) * self.d];
            let z = tensor::dot(xi, w) + b;
            let yf = yi as f32;
            loss += softplus(z) - yf * z;
            if ((z > 0.0) as i32) == yi {
                correct += 1.0;
            }
            if let Some(g) = grad.as_deref_mut() {
                let r = (sigmoid(z) - yf) * inv_n;
                g[0] += r;
                tensor::axpy(&mut g[1..1 + self.d], r, xi);
            }
        }
        loss *= inv_n;
        let live = &theta[..1 + self.d];
        loss += 0.5 * self.lam * tensor::sqnorm(live);
        if let Some(g) = grad.as_deref_mut() {
            tensor::axpy(&mut g[..1 + self.d], self.lam, live);
        }
        (loss, correct)
    }

    /// Gradient through the sample-at-a-time reference path (see
    /// [`NativeLogReg::loss_grad_scalar`]); same contract as
    /// [`Compute::grad`].
    pub fn grad_scalar(&self, theta: &[f32], batch: &Batch,
                       out_grad: &mut [f32]) -> anyhow::Result<f32> {
        let (x, y) = self.unpack_batch(batch)?;
        let (loss, _) = self.loss_grad_scalar(theta, x, y, Some(out_grad));
        Ok(loss)
    }
}

impl Compute for NativeLogReg {
    fn p_pad(&self) -> usize {
        self.p_pad
    }

    fn grad(&mut self, theta: &[f32], batch: &Batch, out_grad: &mut [f32])
            -> anyhow::Result<f32> {
        let (x, y) = self.unpack_batch(batch)?;
        let (loss, _) = self.loss_grad(theta, x, y, Some(out_grad));
        Ok(loss)
    }

    fn eval(&mut self, theta: &[f32], batch: &Batch)
            -> anyhow::Result<(f32, f32)> {
        let (x, y) = self.unpack_batch(batch)?;
        let (loss, correct) = self.loss_grad(theta, x, y, None);
        Ok((loss, correct))
    }

    fn update(&mut self, theta: &mut [f32], h: &mut [f32], vhat: &mut [f32],
              grad: &[f32], alpha: f32) -> anyhow::Result<()> {
        tensor::amsgrad_update(theta, h, vhat, grad, alpha, self.beta1,
                               self.beta2, self.eps);
        Ok(())
    }

    fn innov(&mut self, g1: &[f32], g2: &[f32]) -> anyhow::Result<f32> {
        Ok(tensor::sqnorm_diff(g1, g2))
    }

    fn backend_name(&self) -> &'static str {
        "native"
    }

    fn fork(&self) -> Option<Box<dyn Compute + Send>> {
        // stateless: a worker-thread clone computes bit-identical floats
        Some(Box::new(self.clone()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::util::rng::Rng;

    fn toy_data(n: usize, d: usize, seed: u64) -> Dataset {
        let mut rng = Rng::new(seed);
        let w: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let mut x = Vec::with_capacity(n * d);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let mut z = 0.0;
            for &wj in &w {
                let v = rng.normal_f32(0.0, 1.0);
                x.push(v);
                z += wj * v;
            }
            y.push((z > 0.0) as i32);
        }
        Dataset::Labeled { x, sample_shape: vec![d], y }
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let d = 6;
        let mut m = NativeLogReg::for_spec(d, 16);
        let data = toy_data(32, d, 1);
        let batch = data.gather(&(0..32).collect::<Vec<_>>());
        let mut rng = Rng::new(2);
        let mut theta = vec![0.0f32; 16];
        for t in theta[..d + 1].iter_mut() {
            *t = rng.normal_f32(0.0, 0.3);
        }
        let mut g = vec![0.0f32; 16];
        m.grad(&theta, &batch, &mut g).unwrap();
        let eps = 1e-3f32;
        for i in 0..d + 1 {
            let mut tp = theta.clone();
            tp[i] += eps;
            let mut tm = theta.clone();
            tm[i] -= eps;
            let mut scratch = vec![0.0f32; 16];
            let lp = m.grad(&tp, &batch, &mut scratch).unwrap();
            let lm = m.grad(&tm, &batch, &mut scratch).unwrap();
            let fd = (lp - lm) / (2.0 * eps);
            assert!((g[i] - fd).abs() < 2e-3 * (1.0 + fd.abs()),
                    "coord {i}: {} vs {}", g[i], fd);
        }
        // padding carries zero gradient
        assert!(g[d + 1..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn fused_helper_matches_separate_activations() {
        // softplus: bit-identical everywhere (same expression); sigmoid:
        // bit-identical for z >= 0, last-ulp-close for z < 0
        let grid: Vec<f32> = (-400..=400).map(|i| i as f32 * 0.25).collect();
        for &z in grid.iter().chain(&[0.0, -0.0, 1e-30, -1e-30, 88.0,
                                      -88.0]) {
            let (sig, sp) = sigmoid_softplus(z);
            assert_eq!(sp, softplus(z), "softplus at z={z}");
            if z >= 0.0 {
                assert_eq!(sig, sigmoid(z), "sigmoid at z={z}");
            } else {
                assert!((sig - sigmoid(z)).abs()
                            <= 1e-6 * (1.0 + sigmoid(z).abs()),
                        "sigmoid at z={z}: {sig} vs {}", sigmoid(z));
            }
            assert!((0.0..=1.0).contains(&sig), "sig out of range at {z}");
            assert!(sp >= 0.0 && sp.is_finite(), "softplus at z={z}: {sp}");
        }
        // extremes stay finite/saturated, never NaN
        assert_eq!(sigmoid_softplus(1e4).0, 1.0);
        assert_eq!(sigmoid_softplus(-1e4).0, 0.0);
        assert_eq!(sigmoid_softplus(-1e4).1, 0.0);
        assert_eq!(sigmoid_softplus(1e4).1, 1e4);
    }

    /// The blocked-kernel comparator (the PR acceptance gate): the
    /// blocked path must match the sample-at-a-time reference to f32
    /// accumulation tolerance — loss, every gradient coordinate, and
    /// the accuracy count EXACTLY (logits are bit-identical).
    #[test]
    fn blocked_grad_matches_scalar_reference() {
        let mut rng = Rng::new(17);
        // n spans: < one group, exact group, < one block, exact block,
        // block+tail, several blocks
        for &(n, d) in &[(1usize, 6usize), (3, 6), (4, 6), (63, 6),
                         (64, 6), (65, 6), (130, 22), (256, 9)] {
            let data = toy_data(n, d, 100 + n as u64);
            let batch = data.gather(&(0..n).collect::<Vec<_>>());
            let p = (d + 2).next_power_of_two().max(16);
            let mut m = NativeLogReg::for_spec(d, p);
            let mut theta = vec![0.0f32; p];
            for t in theta[..d + 1].iter_mut() {
                *t = rng.normal_f32(0.0, 0.5);
            }
            let mut g_blocked = vec![0.0f32; p];
            let loss_blocked =
                m.grad(&theta, &batch, &mut g_blocked).unwrap();
            let mut g_scalar = vec![0.0f32; p];
            let loss_scalar =
                m.grad_scalar(&theta, &batch, &mut g_scalar).unwrap();
            assert!((loss_blocked - loss_scalar).abs()
                        <= 1e-5 * (1.0 + loss_scalar.abs()),
                    "(n={n}, d={d}): loss {loss_blocked} vs {loss_scalar}");
            for j in 0..p {
                assert!((g_blocked[j] - g_scalar[j]).abs()
                            <= 1e-4 * (1.0 + g_scalar[j].abs()),
                        "(n={n}, d={d}) coord {j}: {} vs {}",
                        g_blocked[j], g_scalar[j]);
            }
            // eval shares the blocked logits pass; accuracy counts are
            // decided on bit-identical z, so they must agree exactly
            let (_, correct) = m.eval(&theta, &batch).unwrap();
            let (_, correct_ref) =
                m.loss_grad_scalar(&theta, match &batch.arrays[0].0 {
                    crate::data::Array::F32(v) => v,
                    _ => unreachable!(),
                }, match &batch.arrays[1].0 {
                    crate::data::Array::I32(v) => v,
                    _ => unreachable!(),
                }, None);
            assert_eq!(correct, correct_ref, "(n={n}, d={d})");
        }
    }

    /// PR-3-style bit-level pin: an INDEPENDENT inline reference of the
    /// documented blocked semantics — per-sample `dot` logits, the fused
    /// single-exp activations, bias/loss accumulated in sample order,
    /// weight gradient in `ger_acc`'s fixed 4-row groups over the whole
    /// batch (valid because GRAD_BLOCK is a multiple of GER_GROUP) —
    /// must reproduce the production kernel exactly.
    #[test]
    fn blocked_grad_is_pinned_to_documented_order_bit_for_bit() {
        let mut rng = Rng::new(23);
        for &(n, d) in &[(70usize, 22usize), (64, 9), (5, 3)] {
            let data = toy_data(n, d, 300 + n as u64);
            let batch = data.gather(&(0..n).collect::<Vec<_>>());
            let (x, y) = match (&batch.arrays[0].0, &batch.arrays[1].0) {
                (crate::data::Array::F32(x), crate::data::Array::I32(y)) => {
                    (x.as_slice(), y.as_slice())
                }
                _ => unreachable!(),
            };
            let p = 64;
            let mut m = NativeLogReg::for_spec(d, p);
            let mut theta = vec![0.0f32; p];
            for t in theta[..d + 1].iter_mut() {
                *t = rng.normal_f32(0.0, 0.5);
            }
            let mut got = vec![0.0f32; p];
            let loss_got = m.grad(&theta, &batch, &mut got).unwrap();

            // ---- independent reference ----
            let b = theta[0];
            let w = &theta[1..1 + d];
            let inv_n = 1.0 / n as f32;
            let mut want = vec![0.0f32; p];
            let mut r = vec![0.0f32; n];
            let mut loss_want = 0.0f32;
            for i in 0..n {
                let z = tensor::dot(&x[i * d..(i + 1) * d], w) + b;
                let yf = y[i] as f32;
                let (sig, sp) = sigmoid_softplus(z);
                loss_want += sp - yf * z;
                r[i] = (sig - yf) * inv_n;
                want[0] += r[i];
            }
            let mut i = 0;
            while i + tensor::GER_GROUP <= n {
                for j in 0..d {
                    want[1 + j] += (r[i] * x[i * d + j]
                        + r[i + 1] * x[(i + 1) * d + j])
                        + (r[i + 2] * x[(i + 2) * d + j]
                            + r[i + 3] * x[(i + 3) * d + j]);
                }
                i += tensor::GER_GROUP;
            }
            while i < n {
                for j in 0..d {
                    want[1 + j] += r[i] * x[i * d + j];
                }
                i += 1;
            }
            loss_want *= inv_n;
            let live = &theta[..1 + d];
            loss_want += 0.5 * m.lam * tensor::sqnorm(live);
            tensor::axpy(&mut want[..1 + d], m.lam, live);

            assert_eq!(loss_got, loss_want, "(n={n}, d={d}): loss");
            assert_eq!(got, want, "(n={n}, d={d}): gradient");
        }
    }

    #[test]
    fn adam_descends() {
        let d = 8;
        let p = 1024;
        let mut m = NativeLogReg::for_spec(d, p);
        let data = toy_data(256, d, 3);
        let all: Vec<usize> = (0..256).collect();
        let batch = data.gather(&all);
        let mut theta = vec![0.0f32; p];
        let mut h = vec![0.0f32; p];
        let mut vhat = vec![0.0f32; p];
        let mut g = vec![0.0f32; p];
        let loss0 = m.grad(&theta, &batch, &mut g).unwrap();
        for _ in 0..80 {
            m.grad(&theta, &batch, &mut g).unwrap();
            m.update(&mut theta, &mut h, &mut vhat, &g, 0.05).unwrap();
        }
        let loss1 = m.grad(&theta, &batch, &mut g).unwrap();
        assert!(loss1 < 0.5 * loss0, "{loss0} -> {loss1}");
    }

    #[test]
    fn eval_counts_match_manual() {
        let d = 2;
        let mut m = NativeLogReg::for_spec(d, 8);
        // theta = [b=0, w=(1,0)] -> z = x0
        let theta = [0.0f32, 1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0];
        let data = Dataset::Labeled {
            x: vec![2.0, 0.0, -2.0, 0.0, 3.0, 0.0, -1.0, 0.0],
            sample_shape: vec![2],
            y: vec![1, 0, 0, 0],
        };
        let batch = data.gather(&[0, 1, 2, 3]);
        let (_, correct) = m.eval(&theta, &batch).unwrap();
        assert_eq!(correct, 3.0);
    }
}
