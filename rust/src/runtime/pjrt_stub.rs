//! Stand-in [`Engine`] for builds without the `pjrt` cargo feature.
//!
//! Keeps every call site compiling against the same API; construction
//! always fails with a pointer at the feature flag, so none of the
//! [`Compute`] methods can ever be reached (they error defensively
//! anyway). [`super::load_backend`] catches the construction error and
//! falls back to the native backend where one exists.

use super::{Compute, Manifest, SpecEntry};
use crate::data::Batch;

/// Placeholder for the PJRT artifact engine (feature `pjrt` disabled).
pub struct Engine {
    pub spec: SpecEntry,
    /// number of PJRT executions, for telemetry (always 0 in the stub)
    pub exec_count: u64,
}

fn unavailable() -> anyhow::Error {
    anyhow::anyhow!(
        "the PJRT artifact backend is not compiled in; rebuild with \
         `cargo build --features pjrt` (and a real xla crate in \
         rust/vendor/xla) to execute AOT artifacts"
    )
}

impl Engine {
    pub fn new(manifest: &Manifest, spec_name: &str) -> anyhow::Result<Engine> {
        // Validate the spec name so callers get the more precise error
        // when the manifest simply lacks the spec.
        let _ = manifest.spec(spec_name)?;
        Err(unavailable())
    }

    pub fn init_theta(&self) -> anyhow::Result<Vec<f32>> {
        self.spec.load_init()
    }
}

impl Compute for Engine {
    fn p_pad(&self) -> usize {
        self.spec.p_pad
    }

    fn grad(&mut self, _theta: &[f32], _batch: &Batch,
            _out_grad: &mut [f32]) -> anyhow::Result<f32> {
        Err(unavailable())
    }

    fn eval(&mut self, _theta: &[f32], _batch: &Batch)
            -> anyhow::Result<(f32, f32)> {
        Err(unavailable())
    }

    fn update(&mut self, _theta: &mut [f32], _h: &mut [f32],
              _vhat: &mut [f32], _grad: &[f32], _alpha: f32)
              -> anyhow::Result<()> {
        Err(unavailable())
    }

    fn innov(&mut self, _g1: &[f32], _g2: &[f32]) -> anyhow::Result<f32> {
        Err(unavailable())
    }

    fn backend_name(&self) -> &'static str {
        "pjrt-unavailable"
    }
}
