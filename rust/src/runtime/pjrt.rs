//! L3 <-> PJRT bridge: load AOT HLO-text artifacts and execute them on the
//! CPU PJRT client. Python never runs here — the artifacts were lowered
//! once by `make artifacts`.
//!
//! [`Engine`] bundles the four compiled executables of one experiment spec
//! (grad, eval, update, innov) and exposes them through the [`Compute`]
//! trait. Compiled only with the `pjrt` cargo feature; the default build
//! uses the stub in `pjrt_stub.rs` plus the [`super::native`] backend.

use super::{Compute, Dtype, InputSpec, Manifest, SpecEntry};
use crate::data::{Array, Batch};

fn literal_f32(v: &[f32], shape: &[usize]) -> anyhow::Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(v).reshape(&dims)?)
}

fn literal_i32(v: &[i32], shape: &[usize]) -> anyhow::Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(v).reshape(&dims)?)
}

fn batch_literals(batch: &Batch) -> anyhow::Result<Vec<xla::Literal>> {
    batch
        .arrays
        .iter()
        .map(|(arr, shape)| match arr {
            Array::F32(v) => literal_f32(v, shape),
            Array::I32(v) => literal_i32(v, shape),
        })
        .collect()
}

/// One compiled HLO artifact.
struct Exe {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl Exe {
    fn compile(client: &xla::PjRtClient, path: &std::path::Path)
               -> anyhow::Result<Exe> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e}", path.display()))?;
        Ok(Exe {
            exe,
            name: path.display().to_string(),
        })
    }

    /// Execute and return the decomposed output tuple (return_tuple=True
    /// at lowering time, so the single output is always a tuple).
    fn run(&self, args: &[xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
        let bufs = self
            .exe
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow::anyhow!("execute {}: {e}", self.name))?;
        let mut out = bufs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch {}: {e}", self.name))?;
        Ok(out
            .decompose_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {}: {e}", self.name))?)
    }
}

/// Compiled artifact set for one experiment spec (the PJRT-backed
/// [`Compute`] implementation).
pub struct Engine {
    pub spec: SpecEntry,
    #[allow(dead_code)]
    client: xla::PjRtClient,
    grad_exe: Exe,
    eval_exe: Exe,
    update_exe: Exe,
    innov_exe: Exe,
    /// number of PJRT executions, for telemetry
    pub exec_count: u64,
}

impl Engine {
    /// Compile all four artifacts of `spec_name` on a fresh CPU client.
    pub fn new(manifest: &Manifest, spec_name: &str) -> anyhow::Result<Engine> {
        let spec = manifest.spec(spec_name)?.clone();
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e}"))?;
        let grad_exe = Exe::compile(&client, &spec.grad_hlo)?;
        let eval_exe = Exe::compile(&client, &spec.eval_hlo)?;
        let update_exe = Exe::compile(&client, &spec.update_hlo)?;
        let innov_exe = Exe::compile(&client, &spec.innov_hlo)?;
        Ok(Engine {
            spec,
            client,
            grad_exe,
            eval_exe,
            update_exe,
            innov_exe,
            exec_count: 0,
        })
    }

    /// Initial padded parameter vector for this spec.
    pub fn init_theta(&self) -> anyhow::Result<Vec<f32>> {
        self.spec.load_init()
    }

    fn check_batch(&self, batch: &Batch, specs: &[InputSpec])
                   -> anyhow::Result<()> {
        anyhow::ensure!(
            batch.arrays.len() == specs.len(),
            "batch has {} arrays, artifact expects {}",
            batch.arrays.len(),
            specs.len()
        );
        for ((arr, shape), ispec) in batch.arrays.iter().zip(specs) {
            anyhow::ensure!(
                shape == &ispec.shape,
                "batch shape {shape:?} != artifact shape {:?}",
                ispec.shape
            );
            let want_f32 = matches!(ispec.dtype, Dtype::F32);
            let is_f32 = matches!(arr, Array::F32(_));
            anyhow::ensure!(want_f32 == is_f32, "batch dtype mismatch");
        }
        Ok(())
    }
}

impl Compute for Engine {
    fn p_pad(&self) -> usize {
        self.spec.p_pad
    }

    fn grad(&mut self, theta: &[f32], batch: &Batch, out_grad: &mut [f32])
            -> anyhow::Result<f32> {
        self.check_batch(batch, &self.spec.grad_inputs)?;
        let mut args = vec![literal_f32(theta, &[self.spec.p_pad])?];
        args.extend(batch_literals(batch)?);
        let out = self.grad_exe.run(&args)?;
        self.exec_count += 1;
        anyhow::ensure!(out.len() == 2, "grad artifact returned {} outputs",
                        out.len());
        let loss: f32 = out[0].to_vec::<f32>()?[0];
        let g = out[1].to_vec::<f32>()?;
        anyhow::ensure!(g.len() == out_grad.len(), "grad length mismatch");
        out_grad.copy_from_slice(&g);
        Ok(loss)
    }

    fn eval(&mut self, theta: &[f32], batch: &Batch)
            -> anyhow::Result<(f32, f32)> {
        self.check_batch(batch, &self.spec.eval_inputs)?;
        let mut args = vec![literal_f32(theta, &[self.spec.p_pad])?];
        args.extend(batch_literals(batch)?);
        let out = self.eval_exe.run(&args)?;
        self.exec_count += 1;
        anyhow::ensure!(out.len() == 2, "eval artifact returned {} outputs",
                        out.len());
        Ok((out[0].to_vec::<f32>()?[0], out[1].to_vec::<f32>()?[0]))
    }

    fn update(&mut self, theta: &mut [f32], h: &mut [f32], vhat: &mut [f32],
              grad: &[f32], alpha: f32) -> anyhow::Result<()> {
        let p = self.spec.p_pad;
        let args = [
            literal_f32(theta, &[p])?,
            literal_f32(h, &[p])?,
            literal_f32(vhat, &[p])?,
            literal_f32(grad, &[p])?,
            xla::Literal::scalar(alpha),
        ];
        let out = self.update_exe.run(&args)?;
        self.exec_count += 1;
        anyhow::ensure!(out.len() == 3, "update artifact returned {} outputs",
                        out.len());
        theta.copy_from_slice(&out[0].to_vec::<f32>()?);
        h.copy_from_slice(&out[1].to_vec::<f32>()?);
        vhat.copy_from_slice(&out[2].to_vec::<f32>()?);
        Ok(())
    }

    fn innov(&mut self, g1: &[f32], g2: &[f32]) -> anyhow::Result<f32> {
        let p = self.spec.p_pad;
        let args = [literal_f32(g1, &[p])?, literal_f32(g2, &[p])?];
        let out = self.innov_exe.run(&args)?;
        self.exec_count += 1;
        Ok(out[0].to_vec::<f32>()?[0])
    }

    fn backend_name(&self) -> &'static str {
        "pjrt"
    }
}
