//! The TCP socket transport: one training run spanning real OS
//! processes — a [`SocketServer`] inside the server process's
//! [`Trainer`](crate::algorithms::Trainer) and one [`run_worker`] loop
//! per worker process (`cada serve` / `cada worker`).
//!
//! Because a [`WorkerJob`](super::WorkerJob) is a closure, the socket
//! transport does not execute jobs — it speaks the serializable round
//! protocol of [`super::wire`]: per round, the server ships each
//! *selected* worker a [`RoundMsg`](super::wire::RoundMsg) (iteration,
//! frozen RHS, the recipient's server-tracked staleness, the round's
//! participant set, server-sampled batch indices, and theta/snapshot
//! *delta broadcasts* — only shard ranges whose version advanced since
//! that worker's last acknowledged round) and collects one
//! [`WireStep`](super::wire::WireStep) per selected worker. Every
//! simulated quantity (link times, jitter, participation) stays a pure
//! function of the round on the server, and floats cross the wire
//! bit-exactly, so a loopback socket run reproduces `InProc`
//! bit-for-bit (enforced by
//! `tests/golden_parity.rs::socket_matches_inproc_bit_for_bit`).
//!
//! The server is *nonblocking*: a hand-rolled readiness poll over
//! nonblocking `TcpStream`s (no extra deps) admits a registered
//! population of N slots at handshake, drives each round over an
//! externally chosen subset of those slots (the caller draws it with
//! [`ParticipationCfg::select`]), **rejects duplicate, stale and
//! unselected step frames** instead of folding them, and — with churn
//! tolerance on — survives worker disconnects mid-round (the dead
//! slot's step is synthesized as a skip) and re-admits late
//! (re)joiners into vacant slots. A fresh connection has acknowledged
//! nothing, so its next round header re-ships every range: late-joiner
//! catch-up rides the ordinary delta-broadcast machinery.
//!
//! Unlike the simulated `upload_bytes` config constant, [`WireStats`]
//! counts the bytes that actually crossed the wire — the measured
//! upload/broadcast sizes the compressed-upload line of work needs.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

use super::fault::FaultPlan;
use super::wire::{self, Msg, WireRound, WireStep, WireWorkerCfg};
use super::ParticipationCfg;
use crate::compress::{Payload, PayloadRef};
use crate::coordinator::rules::Decision;
use crate::coordinator::worker::WorkerState;
use crate::data::Dataset;
use crate::runtime::Compute;
use crate::util::crc::crc32;
use crate::util::rng::Rng;

/// Default for how long the server waits for workers to connect /
/// answer, and a worker waits for the next round, before declaring the
/// peer hung. Generous: a slow CI box must never trip it, a genuine
/// hang must not stall a job forever. Override via
/// [`ParticipationCfg::socket_timeout_s`] /
/// [`SocketServerBuilder::timeout`] — a 256-worker soak should not
/// inherit interactive-scale patience.
pub const SOCKET_TIMEOUT: Duration = Duration::from_secs(120);

/// Measured wire traffic of one socket run (actual bytes on the wire,
/// not the simulated `upload_bytes` constant).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct WireStats {
    /// rounds driven over the wire
    pub rounds: u64,
    /// server -> worker bytes (handshake + round headers): the measured
    /// broadcast/download traffic
    pub bytes_sent: u64,
    /// worker -> server bytes (handshake + step results): the measured
    /// upload traffic
    pub bytes_received: u64,
    /// theta ranges shipped in round headers (dirty ranges only)
    pub theta_ranges_sent: u64,
    /// payload bytes of those theta ranges (4 bytes per f32)
    pub theta_range_bytes: u64,
    /// CADA1 snapshot ranges shipped (only after a refresh)
    pub snapshot_ranges_sent: u64,
    pub snapshot_range_bytes: u64,
    /// dense bytes the delivered innovation uploads decompress to
    /// (4 bytes per f32 per upload): what the uploads *carry*
    pub upload_raw_bytes: u64,
    /// encoded bytes of those upload payloads as they crossed the wire;
    /// `upload_raw_bytes / upload_wire_bytes` is the measured
    /// compression ratio (1x under `Identity`)
    pub upload_wire_bytes: u64,
    /// wall time the server spent building + encoding round headers
    /// (dirty-range scan and serialization, not the socket write)
    pub header_encode_ns: u64,
    /// wall time the server spent parsing + decompressing step frames
    /// (not the socket read)
    pub step_decode_ns: u64,
    /// step frames dropped instead of folded: duplicates from a worker
    /// that already answered, stale frames carrying an old round id,
    /// frames from unselected workers, frames whose claimed id differs
    /// from their connection's slot, or frames that failed to decode
    pub steps_rejected: u64,
    /// frames whose payload CRC-32 did not match the prefix (protocol
    /// v4): detected corruption, handled as a lost upload — counted
    /// here and per-worker through
    /// [`RoundOutcome::rejected`], never folded
    pub frames_corrupt: u64,
    /// mid-run (re)admissions into vacant population slots (churn mode)
    pub rejoins: u64,
}

/// One connected worker process, with the per-shard versions it last
/// acknowledged (the delta-broadcast bookkeeping) and its partial-frame
/// accumulator (the stream is nonblocking, so a step frame may arrive
/// across several polls).
struct WorkerConn {
    stream: TcpStream,
    /// bytes read off the nonblocking stream but not yet consumed as
    /// complete frames
    recv: Vec<u8>,
    /// per-shard theta versions this worker holds (empty = nothing yet)
    held_theta: Vec<u64>,
    /// snapshot version this worker holds
    held_snap: Option<u64>,
}

/// The static per-run facts a handshake needs, retained so mid-run
/// (re)joiners can be greeted with the same checks and `Welcome` the
/// startup population got.
#[derive(Clone, Copy)]
struct GreetInfo {
    cfg: WireWorkerCfg,
    batch: usize,
    data_len: usize,
    data_fp: u64,
}

/// What one [`SocketServer::run_round`] produced beyond the steps
/// themselves: the participation bookkeeping the trainer folds into
/// [`CommStats`](super::CommStats) and telemetry.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RoundOutcome {
    /// one step per selected worker, in `selected` order; a vacated
    /// slot's entry is a synthesized skip (NaN `lhs`, no upload)
    pub steps: Vec<WireStep>,
    /// population slots whose frames were dropped this round
    /// (duplicate / stale / unselected / mislabelled), one entry per
    /// dropped frame
    pub rejected: Vec<usize>,
    /// population slots (re)admitted mid-round (churn mode)
    pub rejoined: Vec<usize>,
    /// population slots that disconnected mid-round (churn mode)
    pub vacated: Vec<usize>,
}

/// The step a vacated slot contributes: an explicit skip (no upload, no
/// gradient work) so the algorithm's staleness bookkeeping still
/// advances for the dead worker. `lhs`/`loss` are NaN — the fold guards
/// its accounting with `is_finite`, so a synthesized skip adds nothing
/// to the drift terms or the loss curve.
fn skip_step(k: u64, w: usize) -> WireStep {
    WireStep {
        k,
        w,
        decision: Decision { upload: false, rule_triggered: false },
        lhs: f64::NAN,
        loss: f32::NAN,
        grad_evals: 0,
        payload: Payload::Dense(Vec::new()),
    }
}

/// Write all of `buf` to a *nonblocking* stream, napping 1 ms on
/// `WouldBlock` until `deadline`.
fn write_all_nb(stream: &mut TcpStream, mut buf: &[u8], deadline: Instant)
                -> anyhow::Result<()> {
    while !buf.is_empty() {
        match stream.write(buf) {
            Ok(0) => anyhow::bail!("connection closed mid-write"),
            Ok(n) => buf = &buf[n..],
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                anyhow::ensure!(
                    Instant::now() < deadline,
                    "send stalled past the socket timeout"
                );
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// Write one framed message (same layout as [`wire::write_frame`]:
/// length, payload CRC-32, payload) to a nonblocking stream. Returns
/// the wire bytes: [`wire::FRAME_PREFIX`] + payload.
fn write_frame_nb(stream: &mut TcpStream, payload: &[u8],
                  deadline: Instant) -> anyhow::Result<usize> {
    anyhow::ensure!(
        payload.len() <= wire::MAX_FRAME,
        "frame of {} bytes exceeds the {} byte cap",
        payload.len(),
        wire::MAX_FRAME
    );
    write_all_nb(stream, &(payload.len() as u32).to_le_bytes(), deadline)?;
    write_all_nb(stream, &crc32(payload).to_le_bytes(), deadline)?;
    write_all_nb(stream, payload, deadline)?;
    Ok(wire::FRAME_PREFIX + payload.len())
}

/// Drain everything currently readable from a nonblocking stream into
/// the connection's frame accumulator. Returns `(hit_eof, bytes_read)`.
fn fill_recv(conn: &mut WorkerConn) -> std::io::Result<(bool, usize)> {
    let mut tmp = [0u8; 16 * 1024];
    let mut total = 0usize;
    loop {
        match conn.stream.read(&mut tmp) {
            Ok(0) => return Ok((true, total)),
            Ok(n) => {
                conn.recv.extend_from_slice(&tmp[..n]);
                total += n;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                return Ok((false, total))
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
}

/// One frame popped off a nonblocking accumulator: either intact, or
/// detected-corrupt (payload CRC-32 mismatch). A corrupt frame leaves
/// the framing aligned — the length prefix was trusted, the body was
/// not — so the caller can count it and keep the connection.
enum TakenFrame {
    Intact(Vec<u8>),
    Corrupt { len: usize, want: u32, got: u32 },
}

/// Pop one complete frame off the accumulator, if one has fully
/// arrived. Applies the same `MAX_FRAME` hostile-length guard as
/// [`wire::read_frame`] (an `Err` here means the framing itself can no
/// longer be trusted) and the same CRC-32 body check (a mismatch is
/// survivable: [`TakenFrame::Corrupt`]).
fn take_frame(recv: &mut Vec<u8>) -> anyhow::Result<Option<TakenFrame>> {
    const PREFIX: usize = wire::FRAME_PREFIX;
    if recv.len() < PREFIX {
        return Ok(None);
    }
    let len =
        u32::from_le_bytes([recv[0], recv[1], recv[2], recv[3]]) as usize;
    anyhow::ensure!(
        len <= wire::MAX_FRAME,
        "wire frame of {len} bytes exceeds the {} byte cap",
        wire::MAX_FRAME
    );
    if recv.len() < PREFIX + len {
        return Ok(None);
    }
    let want = u32::from_le_bytes([recv[4], recv[5], recv[6], recv[7]]);
    let got = crc32(&recv[PREFIX..PREFIX + len]);
    let taken = if got == want {
        TakenFrame::Intact(recv[PREFIX..PREFIX + len].to_vec())
    } else {
        TakenFrame::Corrupt { len, want, got }
    };
    recv.drain(..PREFIX + len);
    Ok(Some(taken))
}

/// Builds a [`SocketServer`]: `SocketServer::builder(addr)
/// .population(n).select(s).quorum(k).build()`. Defaults reproduce the
/// historical fixed-M server: population 1, everyone selected every
/// round, no quorum, no churn, 120 s timeouts — the fixed-M path is the
/// `population == selected == quorum` degenerate case.
#[derive(Clone, Debug)]
pub struct SocketServerBuilder {
    addr: String,
    population: usize,
    select: usize,
    quorum: usize,
    timeout: Duration,
    churn: bool,
    min_live: usize,
    fault: FaultPlan,
}

impl SocketServerBuilder {
    /// Registered population N: how many worker slots the handshake
    /// admits.
    pub fn population(mut self, n: usize) -> Self {
        self.population = n;
        self
    }

    /// Advisory per-round selection size S (0 = everyone). The caller
    /// draws each round's actual subset (see
    /// [`ParticipationCfg::select`]) and passes it to
    /// [`SocketServer::run_round`]; the builder only validates the
    /// sizes are consistent.
    pub fn select(mut self, s: usize) -> Self {
        self.select = s;
        self
    }

    /// Advisory semi-sync quorum K within the selected subset (0 =
    /// wait for the whole subset). Like `select`, recorded and
    /// validated here; the event clock applies it.
    pub fn quorum(mut self, k: usize) -> Self {
        self.quorum = k;
        self
    }

    /// Socket accept/read/write patience (handshake and per-round).
    pub fn timeout(mut self, timeout: Duration) -> Self {
        self.timeout = timeout;
        self
    }

    /// Churn tolerance: vacate disconnected slots (synthesizing skip
    /// steps) instead of failing the round, and admit late (re)joiners
    /// into vacant slots mid-run. `min_live` is the floor of live
    /// sockets below which even a churn-mode round fails (0 = 1).
    pub fn churn(mut self, on: bool, min_live: usize) -> Self {
        self.churn = on;
        self.min_live = min_live;
        self
    }

    /// Copy every knob [`ParticipationCfg`] carries; `m` is the run's
    /// worker count (the meaning of `population = 0`).
    pub fn participation(mut self, p: &ParticipationCfg, m: usize) -> Self {
        self.population = if p.population == 0 { m } else { p.population };
        self.select = p.effective_selected(self.population);
        self.quorum = p.quorum;
        self.timeout = p.socket_timeout();
        self.churn = p.churn;
        self.min_live = if p.churn { p.min_live() } else { 0 };
        self
    }

    /// Deterministic fault injection (chaos testing): the server-side
    /// events of `plan` — dropped/delayed round headers, a scheduled
    /// crash at `kill_server_at`. [`FaultPlan::none`] (the default) is
    /// a zero-cost no-op on every hot path.
    pub fn fault(mut self, plan: FaultPlan) -> Self {
        self.fault = plan;
        self
    }

    /// Bind the listen address (port 0 picks an ephemeral port; see
    /// [`SocketServer::local_addr`]). Workers are accepted later, by
    /// [`SocketServer::handshake`] — so a caller can learn the bound
    /// address and launch workers before the first round blocks.
    pub fn build(self) -> anyhow::Result<SocketServer> {
        self.fault.validate()?;
        anyhow::ensure!(
            self.population >= 1,
            "socket transport needs >= 1 worker"
        );
        anyhow::ensure!(
            self.select <= self.population,
            "per-round selection {} exceeds the population {}",
            self.select,
            self.population
        );
        let subset = if self.select == 0 {
            self.population
        } else {
            self.select
        };
        anyhow::ensure!(
            self.quorum <= subset,
            "quorum {} exceeds the per-round selection {subset}",
            self.quorum
        );
        anyhow::ensure!(
            self.min_live <= self.population,
            "min_live {} exceeds the population {}",
            self.min_live,
            self.population
        );
        let listener = TcpListener::bind(&self.addr).map_err(|e| {
            anyhow::anyhow!("binding socket transport on {}: {e}", self.addr)
        })?;
        listener.set_nonblocking(true)?;
        let mut conns = Vec::with_capacity(self.population);
        conns.resize_with(self.population, || None);
        Ok(SocketServer {
            listener: Some(listener),
            conns,
            m: self.population,
            select: self.select,
            quorum: self.quorum,
            stats: WireStats::default(),
            scratch: Vec::new(),
            timeout: self.timeout,
            churn: self.churn,
            min_live: self.min_live.max(1),
            greet_info: None,
            fault: self.fault,
            killed: false,
        })
    }
}

/// Server side of the socket transport: owns the nonblocking listener,
/// the N population slots (a slot is `None` while vacated by churn),
/// their ack state, and the measured byte counters.
pub struct SocketServer {
    /// `None` after [`SocketServer::kill`]: the crashed server accepts
    /// nobody and greets nobody
    listener: Option<TcpListener>,
    conns: Vec<Option<WorkerConn>>,
    m: usize,
    select: usize,
    quorum: usize,
    stats: WireStats,
    scratch: Vec<u8>,
    timeout: Duration,
    churn: bool,
    min_live: usize,
    greet_info: Option<GreetInfo>,
    fault: FaultPlan,
    killed: bool,
}

impl SocketServer {
    /// Start configuring a server; see [`SocketServerBuilder`].
    pub fn builder(addr: &str) -> SocketServerBuilder {
        SocketServerBuilder {
            addr: addr.to_string(),
            population: 1,
            select: 0,
            quorum: 0,
            timeout: SOCKET_TIMEOUT,
            churn: false,
            min_live: 0,
            fault: FaultPlan::none(),
        }
    }

    /// The bound listen address (the actual port when bound to port 0).
    pub fn local_addr(&self) -> anyhow::Result<SocketAddr> {
        let listener = self
            .listener
            .as_ref()
            .ok_or_else(|| anyhow::anyhow!("server was killed"))?;
        Ok(listener.local_addr()?)
    }

    /// Simulate a server crash (the scheduled `kill_server_at` fault):
    /// drop the listener, stop speaking. The live worker streams are
    /// deliberately parked, not shut down — a real crash sends no
    /// `Shutdown` goodbye, so workers see a bare EOF and must decide to
    /// heal or die on their own. `Drop` becomes a no-op afterwards.
    pub fn kill(&mut self) {
        self.killed = true;
        self.listener = None;
    }

    /// Registered population N: worker slots this server coordinates.
    pub fn workers(&self) -> usize {
        self.m
    }

    /// The advisory per-round selection size (0 = everyone).
    pub fn select_size(&self) -> usize {
        self.select
    }

    /// The advisory semi-sync quorum (0 = the whole subset).
    pub fn quorum_size(&self) -> usize {
        self.quorum
    }

    /// Measured wire traffic so far.
    pub fn stats(&self) -> &WireStats {
        &self.stats
    }

    /// Does the next round need to accept + handshake workers first?
    /// (Lets the caller compute the dataset fingerprint only once.)
    pub fn needs_handshake(&self) -> bool {
        self.greet_info.is_none()
    }

    fn live(&self) -> usize {
        self.conns.iter().flatten().count()
    }

    /// Accept the N population connections and exchange the handshake
    /// (no-op once done): each worker's `Hello` fingerprint (dataset
    /// length + content checksum, backend parameter count) must match
    /// this run, and gets back a `Welcome` with its assigned slot and
    /// the static run config. The config is retained so churn-mode
    /// (re)joiners can be greeted identically mid-run.
    pub fn handshake(&mut self, cfg: &WireWorkerCfg, batch: usize,
                     data_len: usize, data_fp: u64) -> anyhow::Result<()> {
        if self.greet_info.is_some() {
            return Ok(());
        }
        self.greet_info = Some(GreetInfo { cfg: *cfg, batch, data_len,
                                           data_fp });
        let deadline = Instant::now() + self.timeout;
        while self.live() < self.m {
            let accepted = match self.listener.as_ref() {
                Some(listener) => listener.accept(),
                None => anyhow::bail!("server was killed"),
            };
            match accepted {
                Ok((stream, peer)) => {
                    self.greet(stream, peer).map_err(|e| {
                        anyhow::anyhow!("handshake with worker {peer}: {e:#}")
                    })?;
                }
                Err(e)
                    if e.kind() == ErrorKind::WouldBlock =>
                {
                    anyhow::ensure!(
                        Instant::now() < deadline,
                        "timed out waiting for {} of {} worker \
                         process(es) to connect (start them with `cada \
                         worker --connect <this address>`)",
                        self.m - self.live(),
                        self.m
                    );
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }

    /// Validate one new connection's `Hello`/`Rejoin` against the run
    /// and install it: `Hello` takes the first vacant slot, `Rejoin`
    /// the slot it claims (which must be vacant). The stream is
    /// blocking (bounded by the read timeout) for the exchange, then
    /// joins the nonblocking pool. Returns the assigned slot.
    fn greet(&mut self, mut stream: TcpStream, peer: SocketAddr)
             -> anyhow::Result<usize> {
        let info = self
            .greet_info
            .ok_or_else(|| anyhow::anyhow!("greeting before handshake"))?;
        stream.set_nodelay(true)?;
        stream.set_nonblocking(false)?;
        stream.set_read_timeout(Some(self.timeout))?;
        let hail = match wire::recv(&mut stream, &mut self.scratch)? {
            Some((msg, bytes)) => {
                self.stats.bytes_received += bytes as u64;
                msg
            }
            None => anyhow::bail!("{peer} closed before saying hello"),
        };
        let (want_slot, n, fp, p) = match hail {
            Msg::Hello { n, fp, p } => (None, n as usize, fp, p as usize),
            Msg::Rejoin { w, n, fp, p } => {
                (Some(w as usize), n as usize, fp, p as usize)
            }
            other => anyhow::bail!("expected Hello or Rejoin, got {other:?}"),
        };
        anyhow::ensure!(
            n == info.data_len,
            "worker dataset has {n} samples, this run needs {} \
             (same preset/seed/n on both sides?)",
            info.data_len
        );
        // length alone cannot tell a wrong --seed/--run apart: the
        // content checksum fails silent divergence at connect time
        anyhow::ensure!(
            fp == info.data_fp,
            "worker dataset content differs from this run's \
             (fingerprint {fp:#018x} vs {:#018x}): same \
             preset/seed/n/run on both sides?",
            info.data_fp
        );
        anyhow::ensure!(
            p == info.cfg.p,
            "worker backend has p = {p}, this run needs p = {}",
            info.cfg.p
        );
        let w = match want_slot {
            Some(w) => {
                anyhow::ensure!(
                    w < self.m,
                    "rejoin claims slot {w}, population is {}",
                    self.m
                );
                anyhow::ensure!(
                    self.conns[w].is_none(),
                    "rejoin claims slot {w}, which is still connected"
                );
                w
            }
            None => self
                .conns
                .iter()
                .position(|c| c.is_none())
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "no vacant slot for {peer} (population {} is \
                         fully connected)",
                        self.m
                    )
                })?,
        };
        let welcome = Msg::Welcome {
            w: w as u32,
            m: self.m as u32,
            batch: info.batch as u32,
            cfg: info.cfg,
        };
        self.stats.bytes_sent +=
            wire::send(&mut stream, &welcome, &mut self.scratch)? as u64;
        stream.set_nonblocking(true)?;
        self.conns[w] = Some(WorkerConn {
            stream,
            recv: Vec::new(),
            held_theta: Vec::new(),
            held_snap: None,
        });
        Ok(w)
    }

    /// Churn mode, between polls: admit every connection queued on the
    /// listener into a vacant slot. A (re)joiner sits out the open
    /// round — catch-up happens through its cleared ack state when it
    /// is next selected. A broken joiner (bad fingerprint, no vacant
    /// slot) is dropped without failing the round.
    fn admit_joiners(&mut self, rejoined: &mut Vec<usize>)
                     -> anyhow::Result<()> {
        loop {
            let accepted = match self.listener.as_ref() {
                Some(listener) => listener.accept(),
                None => return Ok(()),
            };
            match accepted {
                Ok((stream, peer)) => {
                    if let Ok(w) = self.greet(stream, peer) {
                        self.stats.rejoins += 1;
                        rejoined.push(w);
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    return Ok(())
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(e.into()),
            }
        }
    }

    /// Vacate slot `w` after a disconnect, enforcing the churn floor.
    fn vacate(&mut self, w: usize, k: u64) -> anyhow::Result<()> {
        self.conns[w] = None;
        let live = self.live();
        anyhow::ensure!(
            live >= self.min_live,
            "worker {w} disconnected in round {k} and only {live} live \
             socket(s) remain, below the churn floor (min_live = {})",
            self.min_live
        );
        Ok(())
    }

    /// Collect worker `w`'s dirty ranges: only the shard ranges this
    /// connection has not acknowledged at the current version, as
    /// `(start, slice)` pairs borrowing the round-frozen vectors. The
    /// caller hands them straight to
    /// [`wire::encode_round_header`] — building a per-worker header
    /// copies no floats outside the output frame itself (the old path
    /// cloned every dirty range into an owned
    /// [`RoundMsg`](super::wire::RoundMsg) first).
    #[allow(clippy::type_complexity)]
    fn dirty_ranges<'r>(conn: &mut WorkerConn, round: &'r WireRound,
                        stats: &mut WireStats)
                        -> (Vec<(u32, &'r [f32])>, Vec<(u32, &'r [f32])>) {
        let mut theta = Vec::new();
        for (s, r) in round.layout.ranges().enumerate() {
            if r.is_empty() {
                continue;
            }
            if conn.held_theta.get(s) != Some(&round.versions[s]) {
                stats.theta_ranges_sent += 1;
                stats.theta_range_bytes += 4 * r.len() as u64;
                theta.push((r.start as u32, &round.theta[r]));
            }
        }
        conn.held_theta.clear();
        conn.held_theta.extend_from_slice(&round.versions);
        let mut snapshot = Vec::new();
        if let Some((snap, version)) = &round.snapshot {
            if conn.held_snap != Some(*version) {
                stats.snapshot_ranges_sent += 1;
                stats.snapshot_range_bytes += 4 * snap.len() as u64;
                snapshot.push((0u32, snap.as_slice()));
                conn.held_snap = Some(*version);
            }
        }
        (theta, snapshot)
    }

    /// Drive one round over `selected` (sorted, unique population
    /// slots): ship each selected worker its header, collect one step
    /// per selected worker, and return them in `selected` order
    /// (physical arrival order never leaks into the fold). The caller
    /// owns the selection — [`ParticipationCfg::select`] is the
    /// canonical way to draw it; this method only checks it is
    /// well-formed. `batches[i]` is the minibatch for `selected[i]`.
    ///
    /// Frames that are not the open round's expected next step — a
    /// duplicate from a worker that already answered, a stale frame
    /// carrying an old `k`, a frame from an unselected worker, or one
    /// whose claimed id differs from its connection's slot — are
    /// dropped and counted ([`WireStats::steps_rejected`],
    /// [`RoundOutcome::rejected`]) instead of folded. With churn
    /// tolerance on, a worker disconnecting mid-round vacates its slot
    /// and its step is synthesized as a skip; new connections are
    /// admitted into vacant slots between polls.
    pub fn run_round(&mut self, round: &WireRound, selected: &[usize],
                     batches: &[Vec<u32>])
                     -> anyhow::Result<RoundOutcome> {
        anyhow::ensure!(
            self.greet_info.is_some(),
            "run_round before the handshake admitted the population"
        );
        anyhow::ensure!(
            !selected.is_empty() && batches.len() == selected.len(),
            "run_round wants a non-empty selection with one batch per \
             selected worker (got {} selected, {} batches)",
            selected.len(),
            batches.len()
        );
        anyhow::ensure!(
            selected.windows(2).all(|p| p[0] < p[1])
                && selected[selected.len() - 1] < self.m,
            "run_round selection must be sorted, unique and within the \
             population of {}",
            self.m
        );
        // position of slot w in the selected list; usize::MAX = not
        // selected this round
        let mut pos_of = vec![usize::MAX; self.m];
        for (i, &w) in selected.iter().enumerate() {
            pos_of[w] = i;
        }
        // full participation ships no list at all, keeping the
        // degenerate header bytes independent of the selection feature
        let wire_selected: Vec<u32> = if selected.len() == self.m {
            Vec::new()
        } else {
            selected.iter().map(|&w| w as u32).collect()
        };
        let deadline = Instant::now() + self.timeout;
        let mut outcome = RoundOutcome::default();
        let mut slots: Vec<Option<WireStep>> =
            Vec::with_capacity(selected.len());
        slots.resize_with(selected.len(), || None);

        // dispatch: one header per selected, live worker
        for (i, &w) in selected.iter().enumerate() {
            if self.conns[w].is_none() {
                // vacated in an earlier round and not yet refilled: the
                // algorithm still folds a skip so staleness advances
                anyhow::ensure!(
                    self.churn,
                    "worker {w} is disconnected (vacant population \
                     slot) and churn tolerance is off"
                );
                slots[i] = Some(skip_step(round.k, w));
                continue;
            }
            if !self.fault.is_none() {
                if self.fault.drop_header(round.k, w) {
                    // injected network failure: sever the link instead
                    // of sending the header
                    anyhow::ensure!(
                        self.churn,
                        "fault injection dropped worker {w}'s round-{} \
                         header and churn tolerance is off",
                        round.k
                    );
                    crate::warn_log!(
                        "fault: dropping worker {w}'s round-{} header",
                        round.k
                    );
                    self.vacate(w, round.k)?;
                    slots[i] = Some(skip_step(round.k, w));
                    outcome.vacated.push(w);
                    continue;
                }
                if self.fault.delay_header(round.k, w) {
                    std::thread::sleep(Duration::from_millis(
                        self.fault.delay_ms,
                    ));
                }
            }
            // the selected set was filtered against live slots above,
            // so a vacant slot here is a server-side logic bug — which
            // R4 says must surface as an error, not a panic, since
            // this loop is driven by whatever the sockets deliver
            let conn = match self.conns[w].as_mut() {
                Some(conn) => conn,
                None => anyhow::bail!(
                    "round {}: selected worker {w} has no live \
                     connection slot",
                    round.k
                ),
            };
            let t0 = Instant::now();
            let (theta, snapshot) =
                Self::dirty_ranges(conn, round, &mut self.stats);
            wire::encode_round_header(
                &wire::RoundHeaderRef {
                    k: round.k,
                    rhs: round.rhs,
                    tau: round.taus.get(w).copied().unwrap_or(0),
                    selected: &wire_selected,
                    batch: batches[i].as_slice(),
                    theta: &theta,
                    snapshot: &snapshot,
                },
                &mut self.scratch,
            );
            self.stats.header_encode_ns +=
                t0.elapsed().as_nanos() as u64;
            match write_frame_nb(&mut conn.stream, &self.scratch, deadline)
            {
                Ok(bytes) => self.stats.bytes_sent += bytes as u64,
                Err(e) => {
                    if !self.churn {
                        return Err(anyhow::anyhow!(
                            "sending round {} to worker {w}: {e:#}",
                            round.k
                        ));
                    }
                    self.vacate(w, round.k)?;
                    slots[i] = Some(skip_step(round.k, w));
                    outcome.vacated.push(w);
                }
            }
        }

        // poll: sweep every live slot for readable frames (and, in
        // churn mode, the listener for joiners) until each selected
        // slot has a step
        while slots.iter().any(|s| s.is_none()) {
            anyhow::ensure!(
                Instant::now() < deadline,
                "timed out waiting for {} worker step(s) in round {}",
                slots.iter().filter(|s| s.is_none()).count(),
                round.k
            );
            if self.churn {
                self.admit_joiners(&mut outcome.rejoined)?;
            }
            for w in 0..self.m {
                let mut eof = false;
                let mut framing_err: Option<anyhow::Error> = None;
                let mut frames: Vec<TakenFrame> = Vec::new();
                {
                    let Some(conn) = self.conns[w].as_mut() else {
                        continue;
                    };
                    match fill_recv(conn) {
                        Ok((hit_eof, bytes)) => {
                            eof = hit_eof;
                            self.stats.bytes_received += bytes as u64;
                        }
                        Err(e) => {
                            if !self.churn {
                                return Err(anyhow::anyhow!(
                                    "reading worker {w}'s round-{} \
                                     result: {e:#}",
                                    round.k
                                ));
                            }
                            eof = true;
                        }
                    }
                    loop {
                        match take_frame(&mut conn.recv) {
                            Ok(Some(f)) => frames.push(f),
                            Ok(None) => break,
                            Err(e) => {
                                framing_err = Some(e);
                                break;
                            }
                        }
                    }
                }
                if let Some(e) = framing_err {
                    // a hostile length prefix: the byte stream can no
                    // longer be re-synchronized, so the connection goes
                    if !self.churn {
                        return Err(anyhow::anyhow!(
                            "worker {w}'s round-{} stream: {e:#}",
                            round.k
                        ));
                    }
                    crate::warn_log!(
                        "worker {w}: unrecoverable framing in round {}: \
                         {e:#}; dropping the connection",
                        round.k
                    );
                    eof = true;
                }
                for frame in frames {
                    let frame = match frame {
                        TakenFrame::Intact(f) => f,
                        TakenFrame::Corrupt { len, want, got } => {
                            // detected corruption is a lost upload: the
                            // sender will not repeat it, so the slot
                            // folds a skip (if still open) and the
                            // connection survives — the framing stayed
                            // aligned
                            self.stats.frames_corrupt += 1;
                            outcome.rejected.push(w);
                            crate::warn_log!(
                                "worker {w}: corrupt {len}-byte frame \
                                 in round {} (payload hashes to \
                                 {got:#010x}, prefix claims \
                                 {want:#010x}); treating it as a lost \
                                 upload",
                                round.k
                            );
                            let pos = pos_of[w];
                            if pos != usize::MAX && slots[pos].is_none()
                            {
                                slots[pos] =
                                    Some(skip_step(round.k, w));
                            }
                            continue;
                        }
                    };
                    // parse the frame as a borrowed view and decompress
                    // straight into the dense vector the fold consumes:
                    // one parse, one allocation, no intermediate owned
                    // payload copy
                    let t0 = Instant::now();
                    let parsed = wire::decode_step_view(&frame)
                        .and_then(|view| {
                            let dense = view.payload.decompress()?;
                            Ok((view, dense))
                        });
                    self.stats.step_decode_ns +=
                        t0.elapsed().as_nanos() as u64;
                    let (view, dense) = match parsed {
                        Ok(ok) => ok,
                        Err(e) => {
                            // CRC-valid but undecodable: a hostile or
                            // version-skewed peer. Reject the frame
                            // with its forensics instead of failing the
                            // round — the sender may still answer
                            // correctly
                            self.stats.steps_rejected += 1;
                            outcome.rejected.push(w);
                            crate::warn_log!(
                                "worker {w}: rejecting an undecodable \
                                 {}-byte frame (tag {}) in round {}: \
                                 {e:#}",
                                frame.len(),
                                frame.first().copied().unwrap_or(0),
                                round.k
                            );
                            continue;
                        }
                    };
                    let pos = pos_of[w];
                    let fresh = pos != usize::MAX
                        && slots[pos].is_none()
                        && view.k == round.k
                        && view.w == w;
                    if !fresh {
                        // duplicate, stale round, unselected slot, or a
                        // mislabelled id: drop it, count it, keep going
                        self.stats.steps_rejected += 1;
                        outcome.rejected.push(w);
                        continue;
                    }
                    if view.decision.upload {
                        self.stats.upload_raw_bytes +=
                            view.payload.raw_bytes();
                        self.stats.upload_wire_bytes +=
                            view.payload.encoded_bytes();
                    }
                    slots[pos] = Some(WireStep {
                        k: view.k,
                        w: view.w,
                        decision: view.decision,
                        lhs: view.lhs,
                        loss: view.loss,
                        grad_evals: view.grad_evals,
                        payload: Payload::Dense(dense),
                    });
                }
                if eof {
                    anyhow::ensure!(
                        self.churn,
                        "worker {w} disconnected during round {}",
                        round.k
                    );
                    self.vacate(w, round.k)?;
                    outcome.vacated.push(w);
                    let pos = pos_of[w];
                    if pos != usize::MAX && slots[pos].is_none() {
                        slots[pos] = Some(skip_step(round.k, w));
                    }
                }
            }
            if slots.iter().any(|s| s.is_none()) {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        outcome.steps = slots.into_iter().flatten().collect();
        self.stats.rounds += 1;
        Ok(outcome)
    }
}

impl Drop for SocketServer {
    fn drop(&mut self) {
        // a killed server crashed: no goodbye, workers get a bare EOF
        if self.killed {
            return;
        }
        // best-effort: let worker processes exit cleanly instead of
        // discovering the EOF
        for conn in self.conns.iter_mut().flatten() {
            let _ = conn.stream.set_nonblocking(false);
            let _ = conn
                .stream
                .set_write_timeout(Some(Duration::from_secs(1)));
            let _ = wire::send(&mut conn.stream, &Msg::Shutdown,
                               &mut self.scratch);
        }
    }
}

/// Outcome of one worker process's run (logging/tests).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkerReport {
    /// the slot the server assigned in the handshake
    pub w: usize,
    pub rounds: u64,
    pub uploads: u64,
}

/// Per-process knobs for [`run_worker_opts`]. `Default` reproduces
/// [`run_worker`]: interactive-scale timeouts, fresh `Hello` handshake,
/// no healing, no faults.
#[derive(Clone, Debug)]
pub struct WorkerOpts {
    /// connect-retry budget (the server may still be binding)
    pub connect: Duration,
    /// read timeout: bounds the wait for the *next* round header, so a
    /// long-unselected worker still notices a hung server
    pub timeout: Duration,
    /// claim this population slot with a churn-mode `Rejoin` handshake
    /// instead of a fresh `Hello`
    pub rejoin_slot: Option<u32>,
    /// worker-side deterministic fault injection: corrupt or truncate
    /// this worker's own step frames, die at a scheduled round, part
    /// ahead of a scheduled server crash
    pub fault: FaultPlan,
    /// self-healing: when the connection dies without a `Shutdown`
    /// goodbye, reconnect and `Rejoin` the same slot with gradient
    /// state intact instead of returning — the worker survives a
    /// server restart
    pub heal: bool,
}

impl Default for WorkerOpts {
    fn default() -> Self {
        WorkerOpts {
            connect: SOCKET_TIMEOUT,
            timeout: SOCKET_TIMEOUT,
            rejoin_slot: None,
            fault: FaultPlan::none(),
            heal: false,
        }
    }
}

impl WorkerOpts {
    /// The worker-side view of a run's [`ParticipationCfg`]: its
    /// timeout and connect-retry budget.
    pub fn from_participation(p: &ParticipationCfg) -> Self {
        WorkerOpts {
            connect: p.connect_retry(),
            timeout: p.socket_timeout(),
            rejoin_slot: None,
            fault: FaultPlan::none(),
            heal: false,
        }
    }
}

/// Connect with retries until `timeout` (the server process may still
/// be binding when a worker launches). Every attempt is individually
/// bounded via [`TcpStream::connect_timeout`], so a black-holed SYN
/// (firewall DROP) cannot stretch the overall deadline by the kernel's
/// multi-minute TCP connect timeout. Between attempts the worker backs
/// off exponentially (50 ms doubling to a 2 s ceiling) with jitter
/// seeded from the address, so a rebooting server is not hammered at a
/// fixed rate by a synchronized fleet of waiters.
pub fn connect_retry(addr: &str, timeout: Duration)
                     -> anyhow::Result<TcpStream> {
    use std::net::ToSocketAddrs;
    let deadline = Instant::now() + timeout;
    let mut last_err = String::from("no addresses resolved");
    // deterministic per-address jitter stream (FNV-1a of the address):
    // no clock entropy, but distinct workers resolve distinct source
    // ports anyway — the jitter only needs to de-synchronize retries
    let mut jitter = Rng::new(addr.bytes().fold(
        0xcbf2_9ce4_8422_2325u64,
        |h, b| (h ^ b as u64).wrapping_mul(0x100_0000_01b3),
    ));
    let mut attempt = 0u32;
    loop {
        // re-resolve each attempt: the name may start resolving while
        // the server host boots
        match addr.to_socket_addrs() {
            Ok(addrs) => {
                for sa in addrs {
                    let left = deadline
                        .saturating_duration_since(Instant::now());
                    // per-attempt bound: short enough to stay
                    // responsive, never zero (connect_timeout rejects
                    // a zero duration)
                    let per = left
                        .min(Duration::from_secs(5))
                        .max(Duration::from_millis(50));
                    match TcpStream::connect_timeout(&sa, per) {
                        Ok(stream) => return Ok(stream),
                        Err(e) => last_err = e.to_string(),
                    }
                }
            }
            Err(e) => last_err = e.to_string(),
        }
        if Instant::now() >= deadline {
            return Err(anyhow::anyhow!(
                "connecting to cada server at {addr}: {last_err}"));
        }
        let base = Duration::from_millis(50u64 << attempt.min(5));
        let nap = (base + base.mul_f64(jitter.f64() * 0.5))
            .min(Duration::from_secs(2))
            .min(deadline.saturating_duration_since(Instant::now()));
        if !nap.is_zero() {
            std::thread::sleep(nap);
        }
        attempt += 1;
    }
}

/// [`run_worker_opts`] with the historical defaults (120 s timeouts,
/// fresh `Hello` handshake).
pub fn run_worker(addr: &str, data: &Dataset, compute: &mut dyn Compute)
                  -> anyhow::Result<WorkerReport> {
    run_worker_opts(addr, data, compute, &WorkerOpts::default())
}

/// The worker process's whole life: connect, handshake, then answer
/// round headers until the server says shutdown (or closes the
/// connection between rounds, which a finished run also does).
///
/// `data` must be the same dataset the server samples indices from
/// (same preset, run seed and size — the handshake cross-checks both
/// the length and a whole-dataset content fingerprint), and `compute`
/// a backend with the server's parameter count. Under per-round
/// selection the worker simply blocks until its next header: the
/// header carries the server-tracked staleness `tau`, which the worker
/// adopts so its rule sees the same staleness it would on any other
/// transport (a bit-exact no-op under full participation).
pub fn run_worker_opts(addr: &str, data: &Dataset,
                       compute: &mut dyn Compute, opts: &WorkerOpts)
                       -> anyhow::Result<WorkerReport> {
    let mut life = WorkerLife {
        slot: opts.rejoin_slot,
        state: None,
        theta: Vec::new(),
        snapshot: None,
        batch: 0,
        report: WorkerReport::default(),
    };
    // consecutive connections that died without completing a round:
    // bounded, so a healing worker cannot spin forever against a
    // server that keeps cutting it off
    let mut barren = 0u32;
    loop {
        let rounds_before = life.report.rounds;
        match worker_session(addr, data, compute, opts, &mut life)? {
            SessionEnd::Done => return Ok(life.report),
            SessionEnd::Lost(reason) => {
                if !opts.heal {
                    anyhow::bail!(
                        "worker {} lost its server: {reason}",
                        life.report.w
                    );
                }
                barren = if life.report.rounds > rounds_before {
                    0
                } else {
                    barren + 1
                };
                anyhow::ensure!(
                    barren <= 8,
                    "worker {} gave up healing after {barren} \
                     reconnects without completing a round: {reason}",
                    life.report.w
                );
                crate::warn_log!(
                    "worker {}: {reason}; healing (attempt {barren} \
                     since the last completed round)",
                    life.report.w
                );
            }
        }
    }
}

/// The worker state that must outlive any single connection for a
/// healed worker to stay bit-identical: the claimed slot, the
/// gradient-side [`WorkerState`] (its `g_stale` and error-feedback
/// residual), and the broadcast replicas. A fresh churn rejoiner
/// rebuilds these from zero; a healed worker must not.
struct WorkerLife {
    slot: Option<u32>,
    state: Option<WorkerState>,
    theta: Vec<f32>,
    snapshot: Option<Vec<f32>>,
    batch: usize,
    report: WorkerReport,
}

/// How one connection's life ended.
enum SessionEnd {
    /// the server said `Shutdown` (or, without healing, closed the
    /// connection): the run is over
    Done,
    /// the connection died without a goodbye — retryable under
    /// [`WorkerOpts::heal`]
    Lost(String),
}

/// One connection's worth of [`run_worker_opts`]: connect, handshake
/// (`Hello` first, `Rejoin` ever after), answer round headers until
/// the server says shutdown or the link dies. I/O failures come back
/// as [`SessionEnd::Lost`]; only semantic mismatches (wrong dataset,
/// wrong slot, protocol violations) are `Err`.
fn worker_session(addr: &str, data: &Dataset, compute: &mut dyn Compute,
                  opts: &WorkerOpts, life: &mut WorkerLife)
                  -> anyhow::Result<SessionEnd> {
    let mut scratch = Vec::new();
    let mut stream = connect_retry(addr, opts.connect)?;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(opts.timeout))?;
    let hail = match life.slot {
        Some(w) => Msg::Rejoin {
            w,
            n: data.len() as u64,
            fp: data.fingerprint(),
            p: compute.p_pad() as u64,
        },
        None => Msg::Hello {
            n: data.len() as u64,
            fp: data.fingerprint(),
            p: compute.p_pad() as u64,
        },
    };
    if let Err(e) = wire::send(&mut stream, &hail, &mut scratch) {
        return Ok(SessionEnd::Lost(format!("handshake send: {e:#}")));
    }
    let welcome = match wire::recv(&mut stream, &mut scratch) {
        Ok(msg) => msg,
        Err(e) => {
            return Ok(SessionEnd::Lost(format!("handshake recv: {e:#}")))
        }
    };
    let (w, cfg, batch) = match welcome {
        Some((Msg::Welcome { w, cfg, batch, .. }, _)) => {
            (w as usize, cfg, batch as usize)
        }
        Some((other, _)) => {
            anyhow::bail!("expected Welcome, got {other:?}")
        }
        None => anyhow::bail!(
            "server closed during the handshake (dataset/backend \
             mismatch, or too many workers for this run?)"
        ),
    };
    if let Some(want) = life.slot {
        anyhow::ensure!(
            w == want as usize,
            "rejoin asked for slot {want}, server assigned {w}"
        );
    }
    anyhow::ensure!(
        cfg.p == compute.p_pad(),
        "server wants p = {}, backend has p = {}",
        cfg.p,
        compute.p_pad()
    );
    let WorkerLife { slot, state, theta, snapshot, batch: life_batch,
                     report } = life;
    if state.is_none() {
        // first Welcome: build the per-run state. A healed reconnect
        // keeps it — recreating it (or re-calling `set_compress`)
        // would zero `g_stale` and the error-feedback residual,
        // silently desyncing the server's fold
        let mut fresh = WorkerState::new(w, cfg.p, cfg.rule);
        // the server's compression config: the worker compresses (rule
        // LHS on the decompressed innovation, error-feedback residual),
        // the server decodes what arrives
        fresh.set_compress(cfg.compress);
        *state = Some(fresh);
        *theta = vec![0.0f32; cfg.p];
        *snapshot = cfg.rule.needs_snapshot().then(|| vec![0.0f32; cfg.p]);
        *life_batch = batch;
        *slot = Some(w as u32);
        report.w = w;
    }
    let batch = *life_batch;
    // installed by the branch above on first Welcome, kept across
    // healed reconnects; a None is a session-wiring bug, surfaced as
    // an error per R4 because this path runs on hostile-input bytes
    let state = match state.as_mut() {
        Some(state) => state,
        None => anyhow::bail!(
            "worker {w}: session has no per-run state after Welcome"
        ),
    };
    loop {
        let round = match wire::recv(&mut stream, &mut scratch) {
            Ok(Some((Msg::Round(round), _))) => round,
            Ok(Some((Msg::Shutdown, _))) => return Ok(SessionEnd::Done),
            Ok(None) => {
                // EOF without a goodbye: historically the end of the
                // run; under healing it is a presumed server crash
                return Ok(if opts.heal {
                    SessionEnd::Lost(
                        "server closed without a Shutdown".to_string(),
                    )
                } else {
                    SessionEnd::Done
                });
            }
            Ok(Some((other, _))) => {
                anyhow::bail!("expected a round header, got {other:?}")
            }
            Err(e) => {
                return Ok(SessionEnd::Lost(format!(
                    "waiting for a round header: {e:#}"
                )))
            }
        };
        if opts.fault.kill_worker_round(w).map_or(false, |at| round.k >= at)
        {
            // scheduled death: vanish without answering — the server
            // vacates the slot and folds a skip
            crate::warn_log!(
                "fault: worker {w} dies on round {}", round.k
            );
            return Ok(SessionEnd::Done);
        }
        // a header only ever reaches selected workers, but check
        // anyway: answering an unselected round would desync the fold
        if !round.selected.is_empty() {
            anyhow::ensure!(
                round.selected.binary_search(&(w as u32)).is_ok(),
                "round {} selects {:?}, but its header reached worker \
                 {w}",
                round.k,
                round.selected
            );
        }
        for delta in &round.theta {
            delta.apply(theta)?;
        }
        if let Some(snap) = snapshot.as_mut() {
            for delta in &round.snapshot {
                delta.apply(snap)?;
            }
        }
        anyhow::ensure!(
            round.batch.len() == batch,
            "round {} header carries {} batch indices, expected {batch}",
            round.k,
            round.batch.len()
        );
        let mut picks = Vec::with_capacity(round.batch.len());
        for &i in &round.batch {
            let i = i as usize;
            anyhow::ensure!(
                i < data.len(),
                "round {} batch index {i} outside the {}-sample dataset \
                 (mismatched dataset?)",
                round.k,
                data.len()
            );
            picks.push(i);
        }
        // adopt the server-tracked staleness: a worker left unselected
        // (or freshly rejoined) resumes with the server's count, so its
        // rule decides exactly as the InProc mirror does
        state.tau = round.tau;
        let minibatch = data.gather(&picks);
        let step = state.step(
            round.k,
            cfg.rule,
            cfg.max_delay,
            theta,
            snapshot.as_deref(),
            round.rhs,
            &minibatch,
            compute,
            cfg.use_artifact_innov,
        )?;
        // lossy schemes stash the encoded payload in the worker state;
        // Identity ships the dense innovation exactly as the
        // pre-compression protocol did — borrowed straight from the
        // worker's delta buffer, never cloned into an owned payload
        let stashed = if step.decision.upload {
            report.uploads += 1;
            state.take_payload()
        } else {
            None
        };
        let payload = match &stashed {
            Some(p) => p.as_payload_ref(),
            None if step.decision.upload => {
                PayloadRef::Dense(state.last_delta())
            }
            None => PayloadRef::Dense(&[]),
        };
        let stepref = wire::WireStepRef {
            k: round.k,
            w,
            decision: step.decision,
            lhs: step.lhs,
            loss: step.loss,
            grad_evals: step.grad_evals,
            payload,
        };
        if opts.fault.is_none() {
            // fault-free fast path: stream the frame straight out,
            // byte-identical to every earlier protocol revision
            if let Err(e) =
                wire::send_step(&mut stream, &stepref, &mut scratch)
            {
                return Ok(SessionEnd::Lost(format!(
                    "sending the round-{} step: {e:#}",
                    round.k
                )));
            }
        } else if let Err(e) = send_step_faulted(
            &mut stream,
            &stepref,
            &opts.fault,
            round.k,
            w,
            &mut scratch,
        ) {
            return match e {
                StepSendEnd::Truncated(cut) => {
                    Ok(SessionEnd::Lost(format!(
                        "fault injection truncated the round-{} step \
                         at byte {cut}",
                        round.k
                    )))
                }
                StepSendEnd::Io(err) => Ok(SessionEnd::Lost(format!(
                    "sending the round-{} step: {err:#}",
                    round.k
                ))),
            };
        }
        report.rounds += 1;
        if opts.fault.kill_server_at == Some(round.k + 1) {
            // the server is scheduled to crash before the next round:
            // part first (worker-side FIN) so the server's port avoids
            // TIME_WAIT and a restarted server can rebind immediately
            return Ok(SessionEnd::Lost(format!(
                "parting ahead of the scheduled server crash at round \
                 {}",
                round.k + 1
            )));
        }
    }
}

/// How a fault-path step send failed.
enum StepSendEnd {
    /// the injected truncation cut the frame at this byte; the
    /// connection is dead by design
    Truncated(usize),
    Io(anyhow::Error),
}

/// Send one step frame with the worker-side fault plan applied: the
/// frame is built in memory (length, CRC-32, payload) so an injected
/// corruption can flip a payload bit *after* the checksum was stamped,
/// and an injected truncation can cut the byte stream mid-frame.
fn send_step_faulted(stream: &mut TcpStream, step: &wire::WireStepRef<'_>,
                     fault: &FaultPlan, k: u64, w: usize,
                     scratch: &mut Vec<u8>)
                     -> Result<(), StepSendEnd> {
    wire::encode_step(step, scratch);
    let mut framed =
        Vec::with_capacity(wire::FRAME_PREFIX + scratch.len());
    framed.extend_from_slice(&(scratch.len() as u32).to_le_bytes());
    framed.extend_from_slice(&crc32(scratch).to_le_bytes());
    framed.extend_from_slice(scratch);
    if let Some(cut) = fault.truncate_step(k, w, framed.len()) {
        // a partial write then a dead socket: the server must survive
        // the half-frame
        let _ = stream.write_all(&framed[..cut]);
        let _ = stream.flush();
        return Err(StepSendEnd::Truncated(cut));
    }
    if let Some((byte, mask)) = fault.corrupt_step(k, w, framed.len()) {
        crate::warn_log!(
            "fault: flipping bit mask {mask:#04x} at byte {byte} of \
             worker {w}'s round-{k} step"
        );
        framed[byte] ^= mask;
    }
    stream
        .write_all(&framed)
        .and_then(|()| stream.flush())
        .map_err(|e| StepSendEnd::Io(e.into()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::shard::ShardLayout;
    use std::sync::mpsc;
    use std::sync::Arc;

    fn round(k: u64, p: usize, shards: usize, versions: Vec<u64>,
             snapshot: Option<(Arc<Vec<f32>>, u64)>) -> WireRound {
        WireRound {
            k,
            rhs: 0.5,
            theta: Arc::new((0..p).map(|i| i as f32).collect()),
            layout: ShardLayout::new(p, shards),
            versions,
            snapshot,
            taus: Vec::new(),
        }
    }

    fn test_cfg(p: usize) -> WireWorkerCfg {
        WireWorkerCfg {
            rule: crate::coordinator::rules::RuleKind::Always,
            max_delay: 50,
            use_artifact_innov: false,
            p,
            compress: crate::compress::CompressCfg::default(),
        }
    }

    /// Scripted worker: connect, hail, expect a `Welcome`.
    fn script_connect(addr: &str, hail: Msg) -> (TcpStream, usize) {
        let mut stream =
            connect_retry(addr, Duration::from_secs(10)).unwrap();
        stream.set_nodelay(true).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        let mut scratch = Vec::new();
        wire::send(&mut stream, &hail, &mut scratch).unwrap();
        match wire::recv(&mut stream, &mut scratch).unwrap() {
            Some((Msg::Welcome { w, .. }, _)) => (stream, w as usize),
            other => panic!("expected Welcome, got {other:?}"),
        }
    }

    fn expect_round(stream: &mut TcpStream, scratch: &mut Vec<u8>)
                    -> wire::RoundMsg {
        match wire::recv(stream, scratch).unwrap() {
            Some((Msg::Round(r), _)) => r,
            other => panic!("expected a round header, got {other:?}"),
        }
    }

    fn send_step(stream: &mut TcpStream, k: u64, w: usize,
                 scratch: &mut Vec<u8>) {
        wire::send_step(
            stream,
            &wire::WireStepRef {
                k,
                w,
                decision: Decision { upload: false,
                                     rule_triggered: false },
                lhs: 0.25,
                loss: 0.5,
                grad_evals: 1,
                payload: PayloadRef::Dense(&[]),
            },
            scratch,
        )
        .unwrap();
    }

    fn expect_shutdown(stream: &mut TcpStream, scratch: &mut Vec<u8>) {
        match wire::recv(stream, scratch).unwrap() {
            Some((Msg::Shutdown, _)) | None => {}
            Some((other, _)) => panic!("expected Shutdown, got {other:?}"),
        }
    }

    #[test]
    fn header_ships_only_dirty_ranges() {
        let p = 2048;
        let snap = Arc::new(vec![1.0f32; p]);
        let mut conn = WorkerConn {
            // a bound-but-unused stream stand-in is overkill; connect a
            // loopback pair just to own a TcpStream
            stream: loopback_stream(),
            recv: Vec::new(),
            held_theta: Vec::new(),
            held_snap: None,
        };
        let mut stats = WireStats::default();
        // first round: everything is dirty
        let r0 = round(0, p, 2, vec![0, 0], Some((Arc::clone(&snap), 1)));
        let (theta0, snap0) =
            SocketServer::dirty_ranges(&mut conn, &r0, &mut stats);
        assert_eq!(theta0.len(), 2);
        assert_eq!(snap0.len(), 1);
        assert_eq!(stats.theta_ranges_sent, 2);
        assert_eq!(stats.theta_range_bytes, 4 * p as u64);
        assert_eq!(stats.snapshot_ranges_sent, 1);
        // the borrowed ranges encode into the round header the worker
        // decodes back — same message the old owned path shipped
        let mut buf = Vec::new();
        wire::encode_round_header(
            &wire::RoundHeaderRef {
                k: r0.k,
                rhs: r0.rhs,
                tau: 0,
                selected: &[],
                batch: &[3, 1],
                theta: &theta0,
                snapshot: &snap0,
            },
            &mut buf,
        );
        match wire::decode(&buf).unwrap() {
            Msg::Round(h0) => {
                assert_eq!(h0.k, 0);
                assert_eq!(h0.batch, vec![3, 1]);
                assert_eq!(h0.theta.len(), 2);
                assert_eq!(h0.theta[0].start, 0);
                assert_eq!(h0.theta[1].start, 1024);
                assert_eq!(h0.snapshot.len(), 1);
                assert_eq!(h0.snapshot[0].data, *snap);
            }
            other => panic!("wrong message: {other:?}"),
        }
        // second round: shard 1 moved, snapshot did not
        let r1 = round(1, p, 2, vec![0, 1], Some((snap, 1)));
        let (theta1, snap1) =
            SocketServer::dirty_ranges(&mut conn, &r1, &mut stats);
        assert_eq!(theta1.len(), 1);
        assert_eq!(theta1[0].0, 1024);
        assert!(snap1.is_empty());
        assert_eq!(stats.theta_ranges_sent, 3);
        assert_eq!(stats.snapshot_ranges_sent, 1);
    }

    fn loopback_stream() -> TcpStream {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stream = TcpStream::connect(addr).unwrap();
        let _accepted = listener.accept().unwrap();
        stream
    }

    #[test]
    fn builder_validates_population_selection_and_quorum() {
        assert!(SocketServer::builder("127.0.0.1:0")
            .population(0)
            .build()
            .is_err());
        assert!(SocketServer::builder("127.0.0.1:0")
            .population(4)
            .select(8)
            .build()
            .is_err());
        assert!(SocketServer::builder("127.0.0.1:0")
            .population(4)
            .select(2)
            .quorum(3)
            .build()
            .is_err());
        let s = SocketServer::builder("127.0.0.1:0")
            .population(4)
            .select(2)
            .quorum(2)
            .build()
            .unwrap();
        assert_eq!(s.workers(), 4);
        assert_eq!(s.select_size(), 2);
        assert_eq!(s.quorum_size(), 2);
        assert!(s.needs_handshake());
    }

    #[test]
    fn handshake_rejects_mismatched_fingerprints() {
        let cfg = test_cfg(64);
        let mut server =
            SocketServer::builder("127.0.0.1:0").build().unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let bad = std::thread::spawn(move || {
            let mut stream =
                connect_retry(&addr, Duration::from_secs(10)).unwrap();
            let mut scratch = Vec::new();
            // dataset length 7 != the server's 100
            wire::send(&mut stream, &Msg::Hello { n: 7, fp: 1, p: 64 },
                       &mut scratch)
                .unwrap();
            // the server drops us without a Welcome
            assert!(wire::recv(&mut stream, &mut scratch)
                .map(|m| m.is_none())
                .unwrap_or(true));
        });
        let err = server.handshake(&cfg, 8, 100, 1).unwrap_err();
        assert!(err.to_string().contains("samples"), "{err}");
        bad.join().unwrap();

        // right length, wrong CONTENT: the fingerprint catches a worker
        // regenerated from the wrong seed/run
        let mut server =
            SocketServer::builder("127.0.0.1:0").build().unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let bad = std::thread::spawn(move || {
            let mut stream =
                connect_retry(&addr, Duration::from_secs(10)).unwrap();
            let mut scratch = Vec::new();
            wire::send(&mut stream,
                       &Msg::Hello { n: 100, fp: 2, p: 64 },
                       &mut scratch)
                .unwrap();
            assert!(wire::recv(&mut stream, &mut scratch)
                .map(|m| m.is_none())
                .unwrap_or(true));
        });
        let err = server.handshake(&cfg, 8, 100, 1).unwrap_err();
        assert!(err.to_string().contains("fingerprint"), "{err}");
        bad.join().unwrap();
    }

    /// Duplicate steps from an answered worker and unsolicited steps
    /// from an unselected worker are dropped + counted, never folded.
    #[test]
    fn rejects_duplicate_and_unselected_steps() {
        const P: usize = 4;
        let cfg = test_cfg(P);
        let mut server = SocketServer::builder("127.0.0.1:0")
            .population(2)
            .timeout(Duration::from_secs(10))
            .build()
            .unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let (go_tx, go_rx) = mpsc::channel::<()>();
        let (rogue_tx, rogue_rx) = mpsc::channel::<()>();

        let a_addr = addr.clone();
        let a = std::thread::spawn(move || {
            let (mut stream, w) = script_connect(
                &a_addr,
                Msg::Hello { n: 100, fp: 1, p: P as u64 },
            );
            assert_eq!(w, 0, "first connector takes slot 0");
            go_tx.send(()).unwrap();
            let mut scratch = Vec::new();
            let r0 = expect_round(&mut stream, &mut scratch);
            assert_eq!(r0.k, 0);
            assert_eq!(r0.selected, vec![0],
                       "partial rounds ship the participant set");
            send_step(&mut stream, 0, 0, &mut scratch);
            send_step(&mut stream, 0, 0, &mut scratch); // duplicate
            let r1 = expect_round(&mut stream, &mut scratch);
            assert_eq!(r1.k, 1);
            send_step(&mut stream, 1, 0, &mut scratch);
            expect_shutdown(&mut stream, &mut scratch);
        });
        let b_addr = addr;
        let b = std::thread::spawn(move || {
            go_rx.recv().unwrap();
            let (mut stream, w) = script_connect(
                &b_addr,
                Msg::Hello { n: 100, fp: 1, p: P as u64 },
            );
            assert_eq!(w, 1);
            let mut scratch = Vec::new();
            // never selected: shove an unsolicited step at the server
            send_step(&mut stream, 0, 1, &mut scratch);
            rogue_tx.send(()).unwrap();
            expect_shutdown(&mut stream, &mut scratch);
        });
        server.handshake(&cfg, 2, 100, 1).unwrap();
        // the rogue step is on the wire before round 0 even starts
        rogue_rx.recv().unwrap();
        std::thread::sleep(Duration::from_millis(50));

        let r0 = round(0, P, 1, vec![7], None);
        let out0 = server.run_round(&r0, &[0], &[vec![1, 2]]).unwrap();
        assert_eq!(out0.steps.len(), 1);
        assert_eq!(out0.steps[0].w, 0);
        assert_eq!(out0.steps[0].k, 0);
        let r1 = round(1, P, 1, vec![7], None);
        let out1 = server.run_round(&r1, &[0], &[vec![0, 3]]).unwrap();
        assert_eq!(out1.steps.len(), 1);
        assert_eq!(out1.steps[0].k, 1);
        // both rogue frames got rejected by the time their sender's
        // next accepted frame closed a round (TCP orders per stream):
        // worker 1's unselected step and worker 0's duplicate
        let mut rejected = out0.rejected.clone();
        rejected.extend_from_slice(&out1.rejected);
        rejected.sort_unstable();
        assert_eq!(rejected, vec![0, 1],
                   "one duplicate from worker 0, one unselected step \
                    from worker 1");
        assert_eq!(server.stats().steps_rejected, 2);
        assert_eq!(server.stats().rounds, 2);
        drop(server);
        a.join().unwrap();
        b.join().unwrap();
    }

    /// A worker dying mid-round vacates its slot (its step synthesized
    /// as a skip), a rejoiner reclaims the slot mid-run, and its first
    /// selected round re-ships the full theta — the delta-broadcast
    /// catch-up reconstructs a bit-identical replica.
    #[test]
    fn churn_vacates_dead_workers_and_a_rejoiner_catches_up() {
        const P: usize = 8;
        let cfg = test_cfg(P);
        let mut server = SocketServer::builder("127.0.0.1:0")
            .population(2)
            .churn(true, 1)
            .timeout(Duration::from_secs(10))
            .build()
            .unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let (go_tx, go_rx) = mpsc::channel::<()>();
        let (jw_tx, jw_rx) = mpsc::channel::<()>();

        let a_addr = addr.clone();
        let a = std::thread::spawn(move || {
            let (mut stream, w) = script_connect(
                &a_addr,
                Msg::Hello { n: 100, fp: 1, p: P as u64 },
            );
            assert_eq!(w, 0);
            go_tx.send(()).unwrap();
            let mut scratch = Vec::new();
            let r0 = expect_round(&mut stream, &mut scratch);
            assert_eq!(r0.k, 0);
            send_step(&mut stream, 0, 0, &mut scratch);
            let r1 = expect_round(&mut stream, &mut scratch);
            assert_eq!(r1.k, 1);
            assert!(r1.theta.is_empty(),
                    "the survivor already acked theta");
            // hold round 1 open until the joiner's Welcome lands, so
            // the rejoin deterministically happens mid-round
            jw_rx.recv().unwrap();
            send_step(&mut stream, 1, 0, &mut scratch);
            let r2 = expect_round(&mut stream, &mut scratch);
            assert_eq!(r2.k, 2);
            assert!(r2.theta.is_empty());
            send_step(&mut stream, 2, 0, &mut scratch);
            expect_shutdown(&mut stream, &mut scratch);
        });
        let b_addr = addr.clone();
        let b = std::thread::spawn(move || {
            go_rx.recv().unwrap();
            let (mut stream, w) = script_connect(
                &b_addr,
                Msg::Hello { n: 100, fp: 1, p: P as u64 },
            );
            assert_eq!(w, 1);
            let mut scratch = Vec::new();
            let r0 = expect_round(&mut stream, &mut scratch);
            assert_eq!(r0.k, 0);
            // die without answering: the server synthesizes our skip
        });
        server.handshake(&cfg, 2, 100, 1).unwrap();

        let r0 = round(0, P, 1, vec![7], None);
        let out0 = server
            .run_round(&r0, &[0, 1], &[vec![0, 1], vec![2, 3]])
            .unwrap();
        assert_eq!(out0.vacated, vec![1]);
        assert_eq!(out0.steps.len(), 2);
        let synth = &out0.steps[1];
        assert_eq!(synth.w, 1);
        assert!(!synth.decision.upload);
        assert!(synth.lhs.is_nan() && synth.grad_evals == 0);
        b.join().unwrap();

        // a rejoiner reclaims slot 1 while round 1 is open
        let j_addr = addr;
        let joiner = std::thread::spawn(move || {
            let (mut stream, w) = script_connect(
                &j_addr,
                Msg::Rejoin { w: 1, n: 100, fp: 1, p: P as u64 },
            );
            assert_eq!(w, 1);
            jw_tx.send(()).unwrap();
            let mut scratch = Vec::new();
            // first selected round after the rejoin: nothing is acked,
            // so the header carries the whole theta
            let r2 = expect_round(&mut stream, &mut scratch);
            assert_eq!(r2.k, 2);
            let mut theta = vec![0.0f32; P];
            for d in &r2.theta {
                d.apply(&mut theta).unwrap();
            }
            let want: Vec<f32> = (0..P).map(|i| i as f32).collect();
            assert_eq!(theta, want,
                       "late joiner must reconstruct theta bit-for-bit");
            send_step(&mut stream, 2, 1, &mut scratch);
            expect_shutdown(&mut stream, &mut scratch);
        });
        let r1 = round(1, P, 1, vec![7], None);
        let out1 = server.run_round(&r1, &[0], &[vec![0, 1]]).unwrap();
        assert_eq!(out1.rejoined, vec![1]);
        assert_eq!(out1.steps.len(), 1);
        let r2 = round(2, P, 1, vec![7], None);
        let out2 = server
            .run_round(&r2, &[0, 1], &[vec![0, 1], vec![2, 3]])
            .unwrap();
        assert_eq!(out2.steps.len(), 2);
        assert!(out2.steps.iter().all(|s| s.k == 2));
        assert!(out2.vacated.is_empty());
        assert_eq!(server.stats().rejoins, 1);
        drop(server);
        a.join().unwrap();
        joiner.join().unwrap();
    }

    /// The nonblocking frame accumulator: a partial frame cut at every
    /// byte boundary stays buffered (no frame, no panic, no error), a
    /// flipped payload bit is detected and drained as survivable
    /// corruption, and a hostile length prefix is an unrecoverable
    /// framing error.
    #[test]
    fn nonblocking_take_frame_survives_truncation_and_corruption() {
        let mut payload = Vec::new();
        wire::encode(&Msg::Hello { n: 100, fp: 7, p: 64 }, &mut payload);
        let mut framed = Vec::new();
        wire::write_frame(&mut framed, &payload).unwrap();

        for cut in 0..framed.len() {
            let mut recv = framed[..cut].to_vec();
            match take_frame(&mut recv) {
                Ok(None) => {}
                Ok(Some(_)) => panic!("cut at {cut} produced a frame"),
                Err(e) => panic!("cut at {cut} errored: {e}"),
            }
            assert_eq!(recv.len(), cut,
                       "partial bytes must stay buffered");
        }

        // two concatenated frames pop one at a time, both intact
        let mut recv = [framed.as_slice(), framed.as_slice()].concat();
        for _ in 0..2 {
            match take_frame(&mut recv).unwrap() {
                Some(TakenFrame::Intact(f)) => assert_eq!(f, payload),
                _ => panic!("expected an intact frame"),
            }
        }
        assert!(recv.is_empty());
        assert!(take_frame(&mut recv).unwrap().is_none());

        // every single-bit payload corruption is detected and drained
        for byte in wire::FRAME_PREFIX..framed.len() {
            let mut recv = framed.clone();
            recv[byte] ^= 0x10;
            match take_frame(&mut recv).unwrap() {
                Some(TakenFrame::Corrupt { len, want, got }) => {
                    assert_eq!(len, payload.len());
                    assert_ne!(want, got);
                }
                _ => panic!("corrupt byte {byte} went undetected"),
            }
            assert!(recv.is_empty(),
                    "the corrupt frame must be drained");
        }

        // a hostile length prefix (claims ~4 GiB) cannot be resynced
        let mut recv = framed.clone();
        recv[3] = 0xFF;
        assert!(take_frame(&mut recv).is_err());
    }

    /// A CRC-corrupt step frame is detected, counted, folded as a skip
    /// (a lost upload), and the connection survives to answer the next
    /// round cleanly — even without churn tolerance.
    #[test]
    fn corrupt_step_folds_as_a_skip_without_dropping_the_worker() {
        const P: usize = 4;
        let cfg = test_cfg(P);
        let mut server = SocketServer::builder("127.0.0.1:0")
            .timeout(Duration::from_secs(10))
            .build()
            .unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let worker = std::thread::spawn(move || {
            let (mut stream, w) = script_connect(
                &addr,
                Msg::Hello { n: 100, fp: 1, p: P as u64 },
            );
            assert_eq!(w, 0);
            let mut scratch = Vec::new();
            let r0 = expect_round(&mut stream, &mut scratch);
            assert_eq!(r0.k, 0);
            // frame a valid step, then flip one payload bit *after*
            // the CRC was stamped
            wire::encode_step(
                &wire::WireStepRef {
                    k: 0,
                    w: 0,
                    decision: Decision { upload: false,
                                         rule_triggered: false },
                    lhs: 0.25,
                    loss: 0.5,
                    grad_evals: 1,
                    payload: PayloadRef::Dense(&[]),
                },
                &mut scratch,
            );
            let mut framed = Vec::new();
            framed
                .extend_from_slice(&(scratch.len() as u32).to_le_bytes());
            framed.extend_from_slice(&crc32(&scratch).to_le_bytes());
            framed.extend_from_slice(&scratch);
            let last = framed.len() - 1;
            framed[last] ^= 0x01;
            stream.write_all(&framed).unwrap();
            stream.flush().unwrap();
            // the server folded a skip and moved on: round 1 still
            // reaches this worker on the same connection
            let r1 = expect_round(&mut stream, &mut scratch);
            assert_eq!(r1.k, 1);
            send_step(&mut stream, 1, 0, &mut scratch);
            expect_shutdown(&mut stream, &mut scratch);
        });
        server.handshake(&cfg, 2, 100, 1).unwrap();
        let r0 = round(0, P, 1, vec![7], None);
        let out0 = server.run_round(&r0, &[0], &[vec![1, 2]]).unwrap();
        assert_eq!(out0.steps.len(), 1);
        assert!(out0.steps[0].lhs.is_nan(),
                "a corrupt upload folds as a skip");
        assert_eq!(out0.rejected, vec![0]);
        assert_eq!(server.stats().frames_corrupt, 1);
        assert!(out0.vacated.is_empty(),
                "corruption must not cost the connection");
        let r1 = round(1, P, 1, vec![7], None);
        let out1 = server.run_round(&r1, &[0], &[vec![0, 3]]).unwrap();
        assert_eq!(out1.steps[0].k, 1);
        assert_eq!(out1.steps[0].lhs, 0.25);
        assert_eq!(server.stats().frames_corrupt, 1);
        drop(server);
        worker.join().unwrap();
    }

    /// [`SocketServer::kill`] simulates a crash: the listener is gone
    /// and the goodbye is suppressed — a worker sees a bare EOF, never
    /// a `Shutdown` message.
    #[test]
    fn a_killed_server_goes_silent_instead_of_saying_goodbye() {
        const P: usize = 4;
        let cfg = test_cfg(P);
        let mut server = SocketServer::builder("127.0.0.1:0")
            .timeout(Duration::from_secs(10))
            .build()
            .unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let worker = std::thread::spawn(move || {
            let (mut stream, _) = script_connect(
                &addr,
                Msg::Hello { n: 100, fp: 1, p: P as u64 },
            );
            let mut scratch = Vec::new();
            match wire::recv(&mut stream, &mut scratch).unwrap() {
                None => {}
                Some((msg, _)) => {
                    panic!("a crashed server spoke: {msg:?}")
                }
            }
        });
        server.handshake(&cfg, 2, 100, 1).unwrap();
        server.kill();
        assert!(server.local_addr().is_err());
        drop(server);
        worker.join().unwrap();
    }
}
