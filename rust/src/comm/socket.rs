//! The TCP socket transport: one training run spanning real OS
//! processes — a [`SocketServer`] inside the server process's
//! [`Trainer`](crate::algorithms::Trainer) and one [`run_worker`] loop
//! per worker process (`cada serve` / `cada worker`).
//!
//! Because a [`WorkerJob`](super::WorkerJob) is a closure, the socket
//! transport does not execute jobs — it speaks the serializable round
//! protocol of [`super::wire`]: per round, the server ships each worker
//! a [`RoundMsg`](super::wire::RoundMsg) (iteration, frozen RHS,
//! server-sampled batch indices, and theta/snapshot *delta broadcasts* —
//! only shard ranges whose version advanced since that worker's last
//! acknowledged round) and collects one
//! [`WireStep`](super::wire::WireStep) per worker. Every simulated
//! quantity (link times, jitter, participation) stays a pure function
//! of the round on the server, and floats cross the wire bit-exactly,
//! so a loopback socket run reproduces `InProc` bit-for-bit (enforced
//! by `tests/golden_parity.rs::socket_matches_inproc_bit_for_bit`).
//!
//! Unlike the simulated `upload_bytes` config constant, [`WireStats`]
//! counts the bytes that actually crossed the wire — the measured
//! upload/broadcast sizes the compressed-upload line of work needs.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

use super::wire::{self, Msg, WireRound, WireStep, WireWorkerCfg};
use crate::compress::{Payload, PayloadRef};
use crate::coordinator::worker::WorkerState;
use crate::data::Dataset;
use crate::runtime::Compute;

/// How long the server waits for workers to connect / answer, and a
/// worker waits for the next round, before declaring the peer hung.
/// Generous: a slow CI box must never trip it, a genuine hang must not
/// stall a job forever.
pub const SOCKET_TIMEOUT: Duration = Duration::from_secs(120);

/// Measured wire traffic of one socket run (actual bytes on the wire,
/// not the simulated `upload_bytes` constant).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct WireStats {
    /// rounds driven over the wire
    pub rounds: u64,
    /// server -> worker bytes (handshake + round headers): the measured
    /// broadcast/download traffic
    pub bytes_sent: u64,
    /// worker -> server bytes (handshake + step results): the measured
    /// upload traffic
    pub bytes_received: u64,
    /// theta ranges shipped in round headers (dirty ranges only)
    pub theta_ranges_sent: u64,
    /// payload bytes of those theta ranges (4 bytes per f32)
    pub theta_range_bytes: u64,
    /// CADA1 snapshot ranges shipped (only after a refresh)
    pub snapshot_ranges_sent: u64,
    pub snapshot_range_bytes: u64,
    /// dense bytes the delivered innovation uploads decompress to
    /// (4 bytes per f32 per upload): what the uploads *carry*
    pub upload_raw_bytes: u64,
    /// encoded bytes of those upload payloads as they crossed the wire;
    /// `upload_raw_bytes / upload_wire_bytes` is the measured
    /// compression ratio (1x under `Identity`)
    pub upload_wire_bytes: u64,
    /// wall time the server spent building + encoding round headers
    /// (dirty-range scan and serialization, not the socket write)
    pub header_encode_ns: u64,
    /// wall time the server spent parsing + decompressing step frames
    /// (not the socket read)
    pub step_decode_ns: u64,
}

/// One connected worker process, with the per-shard versions it last
/// acknowledged (the delta-broadcast bookkeeping).
struct WorkerConn {
    stream: TcpStream,
    /// per-shard theta versions this worker holds (empty = nothing yet)
    held_theta: Vec<u64>,
    /// snapshot version this worker holds
    held_snap: Option<u64>,
}

/// Server side of the socket transport: owns the listener, the M worker
/// connections, their ack state, and the measured byte counters.
pub struct SocketServer {
    listener: TcpListener,
    conns: Vec<WorkerConn>,
    m: usize,
    stats: WireStats,
    scratch: Vec<u8>,
    timeout: Duration,
}

impl SocketServer {
    /// Bind the listen address (port 0 picks an ephemeral port; see
    /// [`SocketServer::local_addr`]). Workers are accepted later, by
    /// [`SocketServer::handshake`] — so a caller can learn the bound
    /// address and launch workers before the first round blocks.
    pub fn bind(addr: &str, m: usize) -> anyhow::Result<SocketServer> {
        anyhow::ensure!(m >= 1, "socket transport needs >= 1 worker");
        let listener = TcpListener::bind(addr).map_err(|e| {
            anyhow::anyhow!("binding socket transport on {addr}: {e}")
        })?;
        Ok(SocketServer {
            listener,
            conns: Vec::new(),
            m,
            stats: WireStats::default(),
            scratch: Vec::new(),
            timeout: SOCKET_TIMEOUT,
        })
    }

    /// The bound listen address (the actual port when bound to port 0).
    pub fn local_addr(&self) -> anyhow::Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Number of worker processes this server coordinates.
    pub fn workers(&self) -> usize {
        self.m
    }

    /// Measured wire traffic so far.
    pub fn stats(&self) -> &WireStats {
        &self.stats
    }

    /// Does the next round need to accept + handshake workers first?
    /// (Lets the caller compute the dataset fingerprint only once.)
    pub fn needs_handshake(&self) -> bool {
        self.conns.is_empty()
    }

    /// Accept the M worker connections and exchange the handshake
    /// (no-op once connected): each worker's `Hello` fingerprint
    /// (dataset length + content checksum, backend parameter count)
    /// must match this run, and gets back a `Welcome` with its assigned
    /// id and the static run config.
    pub fn handshake(&mut self, cfg: &WireWorkerCfg, batch: usize,
                     data_len: usize, data_fp: u64) -> anyhow::Result<()> {
        if !self.conns.is_empty() {
            return Ok(());
        }
        self.listener.set_nonblocking(true)?;
        let deadline = Instant::now() + self.timeout;
        while self.conns.len() < self.m {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    let w = self.conns.len();
                    self.greet(stream, peer, w, cfg, batch, data_len,
                               data_fp)
                        .map_err(|e| {
                            anyhow::anyhow!(
                                "handshake with worker {w} ({peer}): {e:#}")
                        })?;
                }
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock =>
                {
                    anyhow::ensure!(
                        Instant::now() < deadline,
                        "timed out waiting for {} of {} worker \
                         process(es) to connect (start them with `cada \
                         worker --connect <this address>`)",
                        self.m - self.conns.len(),
                        self.m
                    );
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        self.listener.set_nonblocking(false)?;
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn greet(&mut self, mut stream: TcpStream, peer: SocketAddr, w: usize,
             cfg: &WireWorkerCfg, batch: usize, data_len: usize,
             data_fp: u64) -> anyhow::Result<()> {
        stream.set_nodelay(true)?;
        stream.set_nonblocking(false)?;
        stream.set_read_timeout(Some(self.timeout))?;
        let hello = match wire::recv(&mut stream, &mut self.scratch)? {
            Some((msg, bytes)) => {
                self.stats.bytes_received += bytes as u64;
                msg
            }
            None => anyhow::bail!("{peer} closed before saying hello"),
        };
        let (n, fp, p) = match hello {
            Msg::Hello { n, fp, p } => (n as usize, fp, p as usize),
            other => anyhow::bail!("expected Hello, got {other:?}"),
        };
        anyhow::ensure!(
            n == data_len,
            "worker dataset has {n} samples, this run needs {data_len} \
             (same preset/seed/n on both sides?)"
        );
        // length alone cannot tell a wrong --seed/--run apart: the
        // content checksum fails silent divergence at connect time
        anyhow::ensure!(
            fp == data_fp,
            "worker dataset content differs from this run's \
             (fingerprint {fp:#018x} vs {data_fp:#018x}): same \
             preset/seed/n/run on both sides?"
        );
        anyhow::ensure!(
            p == cfg.p,
            "worker backend has p = {p}, this run needs p = {}",
            cfg.p
        );
        let welcome = Msg::Welcome {
            w: w as u32,
            m: self.m as u32,
            batch: batch as u32,
            cfg: *cfg,
        };
        self.stats.bytes_sent +=
            wire::send(&mut stream, &welcome, &mut self.scratch)? as u64;
        self.conns.push(WorkerConn {
            stream,
            held_theta: Vec::new(),
            held_snap: None,
        });
        Ok(())
    }

    /// Collect worker `w`'s dirty ranges: only the shard ranges this
    /// connection has not acknowledged at the current version, as
    /// `(start, slice)` pairs borrowing the round-frozen vectors. The
    /// caller hands them straight to
    /// [`wire::encode_round_header`] — building a per-worker header
    /// copies no floats outside the output frame itself (the old path
    /// cloned every dirty range into an owned
    /// [`RoundMsg`](super::wire::RoundMsg) first).
    #[allow(clippy::type_complexity)]
    fn dirty_ranges<'r>(conn: &mut WorkerConn, round: &'r WireRound,
                        stats: &mut WireStats)
                        -> (Vec<(u32, &'r [f32])>, Vec<(u32, &'r [f32])>) {
        let mut theta = Vec::new();
        for (s, r) in round.layout.ranges().enumerate() {
            if r.is_empty() {
                continue;
            }
            if conn.held_theta.get(s) != Some(&round.versions[s]) {
                stats.theta_ranges_sent += 1;
                stats.theta_range_bytes += 4 * r.len() as u64;
                theta.push((r.start as u32, &round.theta[r]));
            }
        }
        conn.held_theta.clear();
        conn.held_theta.extend_from_slice(&round.versions);
        let mut snapshot = Vec::new();
        if let Some((snap, version)) = &round.snapshot {
            if conn.held_snap != Some(*version) {
                stats.snapshot_ranges_sent += 1;
                stats.snapshot_range_bytes += 4 * snap.len() as u64;
                snapshot.push((0u32, snap.as_slice()));
                conn.held_snap = Some(*version);
            }
        }
        (theta, snapshot)
    }

    /// Drive one round across the worker processes: ship each its
    /// header, collect one step result per worker, and return them in
    /// worker order. On a failure mid-round the results of workers that
    /// did receive a header are still drained (mirroring the `Threaded`
    /// transport), then the first error is returned.
    pub fn run_round(&mut self, round: &WireRound,
                     batches: &[Vec<u32>])
                     -> anyhow::Result<Vec<WireStep>> {
        anyhow::ensure!(
            self.conns.len() == self.m && batches.len() == self.m,
            "run_round wants {} workers (have {} connected, {} batches)",
            self.m,
            self.conns.len(),
            batches.len()
        );
        let mut first_err: Option<anyhow::Error> = None;
        let mut dispatched = 0usize;
        for (w, conn) in self.conns.iter_mut().enumerate() {
            // zero-copy header: collect borrowed dirty ranges and
            // serialize them straight into the frame scratch
            let t0 = Instant::now();
            let (theta, snapshot) =
                Self::dirty_ranges(conn, round, &mut self.stats);
            wire::encode_round_header(
                &wire::RoundHeaderRef {
                    k: round.k,
                    rhs: round.rhs,
                    batch: batches[w].as_slice(),
                    theta: &theta,
                    snapshot: &snapshot,
                },
                &mut self.scratch,
            );
            self.stats.header_encode_ns +=
                t0.elapsed().as_nanos() as u64;
            match wire::write_frame(&mut conn.stream, &self.scratch) {
                Ok(bytes) => {
                    self.stats.bytes_sent += bytes as u64;
                    dispatched += 1;
                }
                Err(e) => {
                    first_err = Some(anyhow::anyhow!(
                        "sending round {} to worker {w}: {e:#}",
                        round.k
                    ));
                    break;
                }
            }
        }
        // collect every dispatched worker's result, draining even after
        // an error so no completion leaks into a later read
        let mut steps = Vec::with_capacity(dispatched);
        for (w, conn) in self.conns.iter_mut().take(dispatched).enumerate()
        {
            match wire::read_frame(&mut conn.stream, &mut self.scratch) {
                Ok(Some(bytes)) => {
                    self.stats.bytes_received += bytes as u64;
                    // parse the frame as a borrowed view and decompress
                    // straight into the dense vector the fold consumes:
                    // one parse, one allocation, no intermediate owned
                    // payload copy
                    let t0 = Instant::now();
                    let parsed = wire::decode_step_view(&self.scratch)
                        .and_then(|view| {
                            let dense = view.payload.decompress()?;
                            Ok((view, dense))
                        });
                    self.stats.step_decode_ns +=
                        t0.elapsed().as_nanos() as u64;
                    match parsed {
                        Ok((view, dense)) => {
                            if view.w != w {
                                if first_err.is_none() {
                                    first_err = Some(anyhow::anyhow!(
                                        "worker {w} answered as worker {}",
                                        view.w
                                    ));
                                }
                                continue;
                            }
                            if view.decision.upload {
                                self.stats.upload_raw_bytes +=
                                    view.payload.raw_bytes();
                                self.stats.upload_wire_bytes +=
                                    view.payload.encoded_bytes();
                            }
                            steps.push(WireStep {
                                w: view.w,
                                decision: view.decision,
                                lhs: view.lhs,
                                loss: view.loss,
                                grad_evals: view.grad_evals,
                                payload: Payload::Dense(dense),
                            });
                        }
                        Err(e) => {
                            if first_err.is_none() {
                                first_err = Some(anyhow::anyhow!(
                                    "worker {w}'s round-{} result: {e:#}",
                                    round.k
                                ));
                            }
                        }
                    }
                }
                Ok(None) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow::anyhow!(
                            "worker {w} disconnected during round {}",
                            round.k
                        ));
                    }
                }
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow::anyhow!(
                            "reading worker {w}'s round-{} result: {e:#}",
                            round.k
                        ));
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        self.stats.rounds += 1;
        Ok(steps)
    }
}

impl Drop for SocketServer {
    fn drop(&mut self) {
        // best-effort: let worker processes exit cleanly instead of
        // discovering the EOF
        for conn in &mut self.conns {
            let _ = wire::send(&mut conn.stream, &Msg::Shutdown,
                               &mut self.scratch);
        }
    }
}

/// Outcome of one worker process's run (logging/tests).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkerReport {
    /// the id the server assigned in the handshake
    pub w: usize,
    pub rounds: u64,
    pub uploads: u64,
}

/// Connect with retries until `timeout` (the server process may still
/// be binding when a worker launches). Every attempt is individually
/// bounded via [`TcpStream::connect_timeout`], so a black-holed SYN
/// (firewall DROP) cannot stretch the overall deadline by the kernel's
/// multi-minute TCP connect timeout.
pub fn connect_retry(addr: &str, timeout: Duration)
                     -> anyhow::Result<TcpStream> {
    use std::net::ToSocketAddrs;
    let deadline = Instant::now() + timeout;
    let mut last_err = String::from("no addresses resolved");
    loop {
        // re-resolve each attempt: the name may start resolving while
        // the server host boots
        match addr.to_socket_addrs() {
            Ok(addrs) => {
                for sa in addrs {
                    let left = deadline
                        .saturating_duration_since(Instant::now());
                    // per-attempt bound: short enough to stay
                    // responsive, never zero (connect_timeout rejects
                    // a zero duration)
                    let per = left
                        .min(Duration::from_secs(5))
                        .max(Duration::from_millis(50));
                    match TcpStream::connect_timeout(&sa, per) {
                        Ok(stream) => return Ok(stream),
                        Err(e) => last_err = e.to_string(),
                    }
                }
            }
            Err(e) => last_err = e.to_string(),
        }
        if Instant::now() >= deadline {
            return Err(anyhow::anyhow!(
                "connecting to cada server at {addr}: {last_err}"));
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// The worker process's whole life: connect, handshake, then answer
/// round headers until the server says shutdown (or closes the
/// connection between rounds, which a finished run also does).
///
/// `data` must be the same dataset the server samples indices from
/// (same preset, run seed and size — the handshake cross-checks both
/// the length and a whole-dataset content fingerprint), and `compute`
/// a backend with the server's parameter count.
pub fn run_worker(addr: &str, data: &Dataset, compute: &mut dyn Compute)
                  -> anyhow::Result<WorkerReport> {
    let mut stream = connect_retry(addr, SOCKET_TIMEOUT)?;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(SOCKET_TIMEOUT))?;
    let mut scratch = Vec::new();
    wire::send(
        &mut stream,
        &Msg::Hello {
            n: data.len() as u64,
            fp: data.fingerprint(),
            p: compute.p_pad() as u64,
        },
        &mut scratch,
    )?;
    let welcome = wire::recv(&mut stream, &mut scratch)?;
    let (w, cfg, batch) = match welcome {
        Some((Msg::Welcome { w, cfg, batch, .. }, _)) => {
            (w as usize, cfg, batch as usize)
        }
        Some((other, _)) => {
            anyhow::bail!("expected Welcome, got {other:?}")
        }
        None => anyhow::bail!(
            "server closed during the handshake (dataset/backend \
             mismatch, or too many workers for this run?)"
        ),
    };
    anyhow::ensure!(
        cfg.p == compute.p_pad(),
        "server wants p = {}, backend has p = {}",
        cfg.p,
        compute.p_pad()
    );
    let mut state = WorkerState::new(w, cfg.p, cfg.rule);
    // the server's compression config: the worker compresses (rule LHS
    // on the decompressed innovation, error-feedback residual), the
    // server decodes what arrives
    state.set_compress(cfg.compress);
    let mut theta = vec![0.0f32; cfg.p];
    let mut snapshot = cfg
        .rule
        .needs_snapshot()
        .then(|| vec![0.0f32; cfg.p]);
    let mut report = WorkerReport { w, rounds: 0, uploads: 0 };
    loop {
        let round = match wire::recv(&mut stream, &mut scratch)? {
            Some((Msg::Round(round), _)) => round,
            Some((Msg::Shutdown, _)) | None => return Ok(report),
            Some((other, _)) => {
                anyhow::bail!("expected a round header, got {other:?}")
            }
        };
        for delta in &round.theta {
            delta.apply(&mut theta)?;
        }
        if let Some(snap) = snapshot.as_mut() {
            for delta in &round.snapshot {
                delta.apply(snap)?;
            }
        }
        anyhow::ensure!(
            round.batch.len() == batch,
            "round {} header carries {} batch indices, expected {batch}",
            round.k,
            round.batch.len()
        );
        let mut picks = Vec::with_capacity(round.batch.len());
        for &i in &round.batch {
            let i = i as usize;
            anyhow::ensure!(
                i < data.len(),
                "round {} batch index {i} outside the {}-sample dataset \
                 (mismatched dataset?)",
                round.k,
                data.len()
            );
            picks.push(i);
        }
        let minibatch = data.gather(&picks);
        let step = state.step(
            round.k,
            cfg.rule,
            cfg.max_delay,
            &theta,
            snapshot.as_deref(),
            round.rhs,
            &minibatch,
            compute,
            cfg.use_artifact_innov,
        )?;
        // lossy schemes stash the encoded payload in the worker state;
        // Identity ships the dense innovation exactly as the
        // pre-compression protocol did — borrowed straight from the
        // worker's delta buffer, never cloned into an owned payload
        let stashed = if step.decision.upload {
            report.uploads += 1;
            state.take_payload()
        } else {
            None
        };
        let payload = match &stashed {
            Some(p) => p.as_payload_ref(),
            None if step.decision.upload => {
                PayloadRef::Dense(state.last_delta())
            }
            None => PayloadRef::Dense(&[]),
        };
        wire::send_step(
            &mut stream,
            &wire::WireStepRef {
                w,
                decision: step.decision,
                lhs: step.lhs,
                loss: step.loss,
                grad_evals: step.grad_evals,
                payload,
            },
            &mut scratch,
        )?;
        report.rounds += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::shard::ShardLayout;
    use std::sync::Arc;

    fn round(k: u64, p: usize, shards: usize, versions: Vec<u64>,
             snapshot: Option<(Arc<Vec<f32>>, u64)>) -> WireRound {
        WireRound {
            k,
            rhs: 0.5,
            theta: Arc::new((0..p).map(|i| i as f32).collect()),
            layout: ShardLayout::new(p, shards),
            versions,
            snapshot,
        }
    }

    #[test]
    fn header_ships_only_dirty_ranges() {
        let p = 2048;
        let snap = Arc::new(vec![1.0f32; p]);
        let mut conn = WorkerConn {
            // a bound-but-unused stream stand-in is overkill; connect a
            // loopback pair just to own a TcpStream
            stream: loopback_stream(),
            held_theta: Vec::new(),
            held_snap: None,
        };
        let mut stats = WireStats::default();
        // first round: everything is dirty
        let r0 = round(0, p, 2, vec![0, 0], Some((Arc::clone(&snap), 1)));
        let (theta0, snap0) =
            SocketServer::dirty_ranges(&mut conn, &r0, &mut stats);
        assert_eq!(theta0.len(), 2);
        assert_eq!(snap0.len(), 1);
        assert_eq!(stats.theta_ranges_sent, 2);
        assert_eq!(stats.theta_range_bytes, 4 * p as u64);
        assert_eq!(stats.snapshot_ranges_sent, 1);
        // the borrowed ranges encode into the round header the worker
        // decodes back — same message the old owned path shipped
        let mut buf = Vec::new();
        wire::encode_round_header(
            &wire::RoundHeaderRef {
                k: r0.k,
                rhs: r0.rhs,
                batch: &[3, 1],
                theta: &theta0,
                snapshot: &snap0,
            },
            &mut buf,
        );
        match wire::decode(&buf).unwrap() {
            Msg::Round(h0) => {
                assert_eq!(h0.k, 0);
                assert_eq!(h0.batch, vec![3, 1]);
                assert_eq!(h0.theta.len(), 2);
                assert_eq!(h0.theta[0].start, 0);
                assert_eq!(h0.theta[1].start, 1024);
                assert_eq!(h0.snapshot.len(), 1);
                assert_eq!(h0.snapshot[0].data, *snap);
            }
            other => panic!("wrong message: {other:?}"),
        }
        // second round: shard 1 moved, snapshot did not
        let r1 = round(1, p, 2, vec![0, 1], Some((snap, 1)));
        let (theta1, snap1) =
            SocketServer::dirty_ranges(&mut conn, &r1, &mut stats);
        assert_eq!(theta1.len(), 1);
        assert_eq!(theta1[0].0, 1024);
        assert!(snap1.is_empty());
        assert_eq!(stats.theta_ranges_sent, 3);
        assert_eq!(stats.snapshot_ranges_sent, 1);
    }

    fn loopback_stream() -> TcpStream {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stream = TcpStream::connect(addr).unwrap();
        let _accepted = listener.accept().unwrap();
        stream
    }

    #[test]
    fn handshake_rejects_mismatched_fingerprints() {
        let cfg = WireWorkerCfg {
            rule: crate::coordinator::rules::RuleKind::Always,
            max_delay: 50,
            use_artifact_innov: false,
            p: 64,
            compress: crate::compress::CompressCfg::default(),
        };
        let mut server = SocketServer::bind("127.0.0.1:0", 1).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let bad = std::thread::spawn(move || {
            let mut stream =
                connect_retry(&addr, Duration::from_secs(10)).unwrap();
            let mut scratch = Vec::new();
            // dataset length 7 != the server's 100
            wire::send(&mut stream, &Msg::Hello { n: 7, fp: 1, p: 64 },
                       &mut scratch)
                .unwrap();
            // the server drops us without a Welcome
            assert!(wire::recv(&mut stream, &mut scratch)
                .map(|m| m.is_none())
                .unwrap_or(true));
        });
        let err = server.handshake(&cfg, 8, 100, 1).unwrap_err();
        assert!(err.to_string().contains("samples"), "{err}");
        bad.join().unwrap();

        // right length, wrong CONTENT: the fingerprint catches a worker
        // regenerated from the wrong seed/run
        let mut server = SocketServer::bind("127.0.0.1:0", 1).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let bad = std::thread::spawn(move || {
            let mut stream =
                connect_retry(&addr, Duration::from_secs(10)).unwrap();
            let mut scratch = Vec::new();
            wire::send(&mut stream,
                       &Msg::Hello { n: 100, fp: 2, p: 64 },
                       &mut scratch)
                .unwrap();
            assert!(wire::recv(&mut stream, &mut scratch)
                .map(|m| m.is_none())
                .unwrap_or(true));
        });
        let err = server.handshake(&cfg, 8, 100, 1).unwrap_err();
        assert!(err.to_string().contains("fingerprint"), "{err}");
        bad.join().unwrap();
    }
}
