//! The TCP socket transport: one training run spanning real OS
//! processes — a [`SocketServer`] inside the server process's
//! [`Trainer`](crate::algorithms::Trainer) and one [`run_worker`] loop
//! per worker process (`cada serve` / `cada worker`).
//!
//! Because a [`WorkerJob`](super::WorkerJob) is a closure, the socket
//! transport does not execute jobs — it speaks the serializable round
//! protocol of [`super::wire`]: per round, the server ships each
//! *selected* worker a [`RoundMsg`](super::wire::RoundMsg) (iteration,
//! frozen RHS, the recipient's server-tracked staleness, the round's
//! participant set, server-sampled batch indices, and theta/snapshot
//! *delta broadcasts* — only shard ranges whose version advanced since
//! that worker's last acknowledged round) and collects one
//! [`WireStep`](super::wire::WireStep) per selected worker. Every
//! simulated quantity (link times, jitter, participation) stays a pure
//! function of the round on the server, and floats cross the wire
//! bit-exactly, so a loopback socket run reproduces `InProc`
//! bit-for-bit (enforced by
//! `tests/golden_parity.rs::socket_matches_inproc_bit_for_bit`).
//!
//! The server is *nonblocking*: a hand-rolled readiness poll over
//! nonblocking `TcpStream`s (no extra deps) admits a registered
//! population of N slots at handshake, drives each round over an
//! externally chosen subset of those slots (the caller draws it with
//! [`ParticipationCfg::select`]), **rejects duplicate, stale and
//! unselected step frames** instead of folding them, and — with churn
//! tolerance on — survives worker disconnects mid-round (the dead
//! slot's step is synthesized as a skip) and re-admits late
//! (re)joiners into vacant slots. A fresh connection has acknowledged
//! nothing, so its next round header re-ships every range: late-joiner
//! catch-up rides the ordinary delta-broadcast machinery.
//!
//! Unlike the simulated `upload_bytes` config constant, [`WireStats`]
//! counts the bytes that actually crossed the wire — the measured
//! upload/broadcast sizes the compressed-upload line of work needs.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::{Duration, Instant};

use super::wire::{self, Msg, WireRound, WireStep, WireWorkerCfg};
use super::ParticipationCfg;
use crate::compress::{Payload, PayloadRef};
use crate::coordinator::rules::Decision;
use crate::coordinator::worker::WorkerState;
use crate::data::Dataset;
use crate::runtime::Compute;

/// Default for how long the server waits for workers to connect /
/// answer, and a worker waits for the next round, before declaring the
/// peer hung. Generous: a slow CI box must never trip it, a genuine
/// hang must not stall a job forever. Override via
/// [`ParticipationCfg::socket_timeout_s`] /
/// [`SocketServerBuilder::timeout`] — a 256-worker soak should not
/// inherit interactive-scale patience.
pub const SOCKET_TIMEOUT: Duration = Duration::from_secs(120);

/// Measured wire traffic of one socket run (actual bytes on the wire,
/// not the simulated `upload_bytes` constant).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct WireStats {
    /// rounds driven over the wire
    pub rounds: u64,
    /// server -> worker bytes (handshake + round headers): the measured
    /// broadcast/download traffic
    pub bytes_sent: u64,
    /// worker -> server bytes (handshake + step results): the measured
    /// upload traffic
    pub bytes_received: u64,
    /// theta ranges shipped in round headers (dirty ranges only)
    pub theta_ranges_sent: u64,
    /// payload bytes of those theta ranges (4 bytes per f32)
    pub theta_range_bytes: u64,
    /// CADA1 snapshot ranges shipped (only after a refresh)
    pub snapshot_ranges_sent: u64,
    pub snapshot_range_bytes: u64,
    /// dense bytes the delivered innovation uploads decompress to
    /// (4 bytes per f32 per upload): what the uploads *carry*
    pub upload_raw_bytes: u64,
    /// encoded bytes of those upload payloads as they crossed the wire;
    /// `upload_raw_bytes / upload_wire_bytes` is the measured
    /// compression ratio (1x under `Identity`)
    pub upload_wire_bytes: u64,
    /// wall time the server spent building + encoding round headers
    /// (dirty-range scan and serialization, not the socket write)
    pub header_encode_ns: u64,
    /// wall time the server spent parsing + decompressing step frames
    /// (not the socket read)
    pub step_decode_ns: u64,
    /// step frames dropped instead of folded: duplicates from a worker
    /// that already answered, stale frames carrying an old round id,
    /// frames from unselected workers, or frames whose claimed id
    /// differs from their connection's slot
    pub steps_rejected: u64,
    /// mid-run (re)admissions into vacant population slots (churn mode)
    pub rejoins: u64,
}

/// One connected worker process, with the per-shard versions it last
/// acknowledged (the delta-broadcast bookkeeping) and its partial-frame
/// accumulator (the stream is nonblocking, so a step frame may arrive
/// across several polls).
struct WorkerConn {
    stream: TcpStream,
    /// bytes read off the nonblocking stream but not yet consumed as
    /// complete frames
    recv: Vec<u8>,
    /// per-shard theta versions this worker holds (empty = nothing yet)
    held_theta: Vec<u64>,
    /// snapshot version this worker holds
    held_snap: Option<u64>,
}

/// The static per-run facts a handshake needs, retained so mid-run
/// (re)joiners can be greeted with the same checks and `Welcome` the
/// startup population got.
#[derive(Clone, Copy)]
struct GreetInfo {
    cfg: WireWorkerCfg,
    batch: usize,
    data_len: usize,
    data_fp: u64,
}

/// What one [`SocketServer::run_round`] produced beyond the steps
/// themselves: the participation bookkeeping the trainer folds into
/// [`CommStats`](super::CommStats) and telemetry.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RoundOutcome {
    /// one step per selected worker, in `selected` order; a vacated
    /// slot's entry is a synthesized skip (NaN `lhs`, no upload)
    pub steps: Vec<WireStep>,
    /// population slots whose frames were dropped this round
    /// (duplicate / stale / unselected / mislabelled), one entry per
    /// dropped frame
    pub rejected: Vec<usize>,
    /// population slots (re)admitted mid-round (churn mode)
    pub rejoined: Vec<usize>,
    /// population slots that disconnected mid-round (churn mode)
    pub vacated: Vec<usize>,
}

/// The step a vacated slot contributes: an explicit skip (no upload, no
/// gradient work) so the algorithm's staleness bookkeeping still
/// advances for the dead worker. `lhs`/`loss` are NaN — the fold guards
/// its accounting with `is_finite`, so a synthesized skip adds nothing
/// to the drift terms or the loss curve.
fn skip_step(k: u64, w: usize) -> WireStep {
    WireStep {
        k,
        w,
        decision: Decision { upload: false, rule_triggered: false },
        lhs: f64::NAN,
        loss: f32::NAN,
        grad_evals: 0,
        payload: Payload::Dense(Vec::new()),
    }
}

/// Write all of `buf` to a *nonblocking* stream, napping 1 ms on
/// `WouldBlock` until `deadline`.
fn write_all_nb(stream: &mut TcpStream, mut buf: &[u8], deadline: Instant)
                -> anyhow::Result<()> {
    while !buf.is_empty() {
        match stream.write(buf) {
            Ok(0) => anyhow::bail!("connection closed mid-write"),
            Ok(n) => buf = &buf[n..],
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                anyhow::ensure!(
                    Instant::now() < deadline,
                    "send stalled past the socket timeout"
                );
                std::thread::sleep(Duration::from_millis(1));
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// Write one length-prefixed frame (same layout as
/// [`wire::write_frame`]) to a nonblocking stream. Returns the wire
/// bytes: 4-byte prefix + payload.
fn write_frame_nb(stream: &mut TcpStream, payload: &[u8],
                  deadline: Instant) -> anyhow::Result<usize> {
    anyhow::ensure!(
        payload.len() <= wire::MAX_FRAME,
        "frame of {} bytes exceeds the {} byte cap",
        payload.len(),
        wire::MAX_FRAME
    );
    write_all_nb(stream, &(payload.len() as u32).to_le_bytes(), deadline)?;
    write_all_nb(stream, payload, deadline)?;
    Ok(4 + payload.len())
}

/// Drain everything currently readable from a nonblocking stream into
/// the connection's frame accumulator. Returns `(hit_eof, bytes_read)`.
fn fill_recv(conn: &mut WorkerConn) -> std::io::Result<(bool, usize)> {
    let mut tmp = [0u8; 16 * 1024];
    let mut total = 0usize;
    loop {
        match conn.stream.read(&mut tmp) {
            Ok(0) => return Ok((true, total)),
            Ok(n) => {
                conn.recv.extend_from_slice(&tmp[..n]);
                total += n;
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                return Ok((false, total))
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
}

/// Pop one complete length-prefixed frame off the accumulator, if one
/// has fully arrived. Applies the same `MAX_FRAME` hostile-length guard
/// as [`wire::read_frame`].
fn take_frame(recv: &mut Vec<u8>) -> anyhow::Result<Option<Vec<u8>>> {
    if recv.len() < 4 {
        return Ok(None);
    }
    let len =
        u32::from_le_bytes([recv[0], recv[1], recv[2], recv[3]]) as usize;
    anyhow::ensure!(
        len <= wire::MAX_FRAME,
        "wire frame of {len} bytes exceeds the {} byte cap",
        wire::MAX_FRAME
    );
    if recv.len() < 4 + len {
        return Ok(None);
    }
    let frame = recv[4..4 + len].to_vec();
    recv.drain(..4 + len);
    Ok(Some(frame))
}

/// Builds a [`SocketServer`]: `SocketServer::builder(addr)
/// .population(n).select(s).quorum(k).build()`. Defaults reproduce the
/// historical fixed-M server: population 1, everyone selected every
/// round, no quorum, no churn, 120 s timeouts — the fixed-M path is the
/// `population == selected == quorum` degenerate case.
#[derive(Clone, Debug)]
pub struct SocketServerBuilder {
    addr: String,
    population: usize,
    select: usize,
    quorum: usize,
    timeout: Duration,
    churn: bool,
    min_live: usize,
}

impl SocketServerBuilder {
    /// Registered population N: how many worker slots the handshake
    /// admits.
    pub fn population(mut self, n: usize) -> Self {
        self.population = n;
        self
    }

    /// Advisory per-round selection size S (0 = everyone). The caller
    /// draws each round's actual subset (see
    /// [`ParticipationCfg::select`]) and passes it to
    /// [`SocketServer::run_round`]; the builder only validates the
    /// sizes are consistent.
    pub fn select(mut self, s: usize) -> Self {
        self.select = s;
        self
    }

    /// Advisory semi-sync quorum K within the selected subset (0 =
    /// wait for the whole subset). Like `select`, recorded and
    /// validated here; the event clock applies it.
    pub fn quorum(mut self, k: usize) -> Self {
        self.quorum = k;
        self
    }

    /// Socket accept/read/write patience (handshake and per-round).
    pub fn timeout(mut self, timeout: Duration) -> Self {
        self.timeout = timeout;
        self
    }

    /// Churn tolerance: vacate disconnected slots (synthesizing skip
    /// steps) instead of failing the round, and admit late (re)joiners
    /// into vacant slots mid-run. `min_live` is the floor of live
    /// sockets below which even a churn-mode round fails (0 = 1).
    pub fn churn(mut self, on: bool, min_live: usize) -> Self {
        self.churn = on;
        self.min_live = min_live;
        self
    }

    /// Copy every knob [`ParticipationCfg`] carries; `m` is the run's
    /// worker count (the meaning of `population = 0`).
    pub fn participation(mut self, p: &ParticipationCfg, m: usize) -> Self {
        self.population = if p.population == 0 { m } else { p.population };
        self.select = p.effective_selected(self.population);
        self.quorum = p.quorum;
        self.timeout = p.socket_timeout();
        self.churn = p.churn;
        self.min_live = if p.churn { p.min_live() } else { 0 };
        self
    }

    /// Bind the listen address (port 0 picks an ephemeral port; see
    /// [`SocketServer::local_addr`]). Workers are accepted later, by
    /// [`SocketServer::handshake`] — so a caller can learn the bound
    /// address and launch workers before the first round blocks.
    pub fn build(self) -> anyhow::Result<SocketServer> {
        anyhow::ensure!(
            self.population >= 1,
            "socket transport needs >= 1 worker"
        );
        anyhow::ensure!(
            self.select <= self.population,
            "per-round selection {} exceeds the population {}",
            self.select,
            self.population
        );
        let subset = if self.select == 0 {
            self.population
        } else {
            self.select
        };
        anyhow::ensure!(
            self.quorum <= subset,
            "quorum {} exceeds the per-round selection {subset}",
            self.quorum
        );
        anyhow::ensure!(
            self.min_live <= self.population,
            "min_live {} exceeds the population {}",
            self.min_live,
            self.population
        );
        let listener = TcpListener::bind(&self.addr).map_err(|e| {
            anyhow::anyhow!("binding socket transport on {}: {e}", self.addr)
        })?;
        listener.set_nonblocking(true)?;
        let mut conns = Vec::with_capacity(self.population);
        conns.resize_with(self.population, || None);
        Ok(SocketServer {
            listener,
            conns,
            m: self.population,
            select: self.select,
            quorum: self.quorum,
            stats: WireStats::default(),
            scratch: Vec::new(),
            timeout: self.timeout,
            churn: self.churn,
            min_live: self.min_live.max(1),
            greet_info: None,
        })
    }
}

/// Server side of the socket transport: owns the nonblocking listener,
/// the N population slots (a slot is `None` while vacated by churn),
/// their ack state, and the measured byte counters.
pub struct SocketServer {
    listener: TcpListener,
    conns: Vec<Option<WorkerConn>>,
    m: usize,
    select: usize,
    quorum: usize,
    stats: WireStats,
    scratch: Vec<u8>,
    timeout: Duration,
    churn: bool,
    min_live: usize,
    greet_info: Option<GreetInfo>,
}

impl SocketServer {
    /// Start configuring a server; see [`SocketServerBuilder`].
    pub fn builder(addr: &str) -> SocketServerBuilder {
        SocketServerBuilder {
            addr: addr.to_string(),
            population: 1,
            select: 0,
            quorum: 0,
            timeout: SOCKET_TIMEOUT,
            churn: false,
            min_live: 0,
        }
    }

    /// The bound listen address (the actual port when bound to port 0).
    pub fn local_addr(&self) -> anyhow::Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Registered population N: worker slots this server coordinates.
    pub fn workers(&self) -> usize {
        self.m
    }

    /// The advisory per-round selection size (0 = everyone).
    pub fn select_size(&self) -> usize {
        self.select
    }

    /// The advisory semi-sync quorum (0 = the whole subset).
    pub fn quorum_size(&self) -> usize {
        self.quorum
    }

    /// Measured wire traffic so far.
    pub fn stats(&self) -> &WireStats {
        &self.stats
    }

    /// Does the next round need to accept + handshake workers first?
    /// (Lets the caller compute the dataset fingerprint only once.)
    pub fn needs_handshake(&self) -> bool {
        self.greet_info.is_none()
    }

    fn live(&self) -> usize {
        self.conns.iter().flatten().count()
    }

    /// Accept the N population connections and exchange the handshake
    /// (no-op once done): each worker's `Hello` fingerprint (dataset
    /// length + content checksum, backend parameter count) must match
    /// this run, and gets back a `Welcome` with its assigned slot and
    /// the static run config. The config is retained so churn-mode
    /// (re)joiners can be greeted identically mid-run.
    pub fn handshake(&mut self, cfg: &WireWorkerCfg, batch: usize,
                     data_len: usize, data_fp: u64) -> anyhow::Result<()> {
        if self.greet_info.is_some() {
            return Ok(());
        }
        self.greet_info = Some(GreetInfo { cfg: *cfg, batch, data_len,
                                           data_fp });
        let deadline = Instant::now() + self.timeout;
        while self.live() < self.m {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    self.greet(stream, peer).map_err(|e| {
                        anyhow::anyhow!("handshake with worker {peer}: {e:#}")
                    })?;
                }
                Err(e)
                    if e.kind() == ErrorKind::WouldBlock =>
                {
                    anyhow::ensure!(
                        Instant::now() < deadline,
                        "timed out waiting for {} of {} worker \
                         process(es) to connect (start them with `cada \
                         worker --connect <this address>`)",
                        self.m - self.live(),
                        self.m
                    );
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }

    /// Validate one new connection's `Hello`/`Rejoin` against the run
    /// and install it: `Hello` takes the first vacant slot, `Rejoin`
    /// the slot it claims (which must be vacant). The stream is
    /// blocking (bounded by the read timeout) for the exchange, then
    /// joins the nonblocking pool. Returns the assigned slot.
    fn greet(&mut self, mut stream: TcpStream, peer: SocketAddr)
             -> anyhow::Result<usize> {
        let info = self
            .greet_info
            .ok_or_else(|| anyhow::anyhow!("greeting before handshake"))?;
        stream.set_nodelay(true)?;
        stream.set_nonblocking(false)?;
        stream.set_read_timeout(Some(self.timeout))?;
        let hail = match wire::recv(&mut stream, &mut self.scratch)? {
            Some((msg, bytes)) => {
                self.stats.bytes_received += bytes as u64;
                msg
            }
            None => anyhow::bail!("{peer} closed before saying hello"),
        };
        let (want_slot, n, fp, p) = match hail {
            Msg::Hello { n, fp, p } => (None, n as usize, fp, p as usize),
            Msg::Rejoin { w, n, fp, p } => {
                (Some(w as usize), n as usize, fp, p as usize)
            }
            other => anyhow::bail!("expected Hello or Rejoin, got {other:?}"),
        };
        anyhow::ensure!(
            n == info.data_len,
            "worker dataset has {n} samples, this run needs {} \
             (same preset/seed/n on both sides?)",
            info.data_len
        );
        // length alone cannot tell a wrong --seed/--run apart: the
        // content checksum fails silent divergence at connect time
        anyhow::ensure!(
            fp == info.data_fp,
            "worker dataset content differs from this run's \
             (fingerprint {fp:#018x} vs {:#018x}): same \
             preset/seed/n/run on both sides?",
            info.data_fp
        );
        anyhow::ensure!(
            p == info.cfg.p,
            "worker backend has p = {p}, this run needs p = {}",
            info.cfg.p
        );
        let w = match want_slot {
            Some(w) => {
                anyhow::ensure!(
                    w < self.m,
                    "rejoin claims slot {w}, population is {}",
                    self.m
                );
                anyhow::ensure!(
                    self.conns[w].is_none(),
                    "rejoin claims slot {w}, which is still connected"
                );
                w
            }
            None => self
                .conns
                .iter()
                .position(|c| c.is_none())
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "no vacant slot for {peer} (population {} is \
                         fully connected)",
                        self.m
                    )
                })?,
        };
        let welcome = Msg::Welcome {
            w: w as u32,
            m: self.m as u32,
            batch: info.batch as u32,
            cfg: info.cfg,
        };
        self.stats.bytes_sent +=
            wire::send(&mut stream, &welcome, &mut self.scratch)? as u64;
        stream.set_nonblocking(true)?;
        self.conns[w] = Some(WorkerConn {
            stream,
            recv: Vec::new(),
            held_theta: Vec::new(),
            held_snap: None,
        });
        Ok(w)
    }

    /// Churn mode, between polls: admit every connection queued on the
    /// listener into a vacant slot. A (re)joiner sits out the open
    /// round — catch-up happens through its cleared ack state when it
    /// is next selected. A broken joiner (bad fingerprint, no vacant
    /// slot) is dropped without failing the round.
    fn admit_joiners(&mut self, rejoined: &mut Vec<usize>)
                     -> anyhow::Result<()> {
        loop {
            match self.listener.accept() {
                Ok((stream, peer)) => {
                    if let Ok(w) = self.greet(stream, peer) {
                        self.stats.rejoins += 1;
                        rejoined.push(w);
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    return Ok(())
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(e.into()),
            }
        }
    }

    /// Vacate slot `w` after a disconnect, enforcing the churn floor.
    fn vacate(&mut self, w: usize, k: u64) -> anyhow::Result<()> {
        self.conns[w] = None;
        let live = self.live();
        anyhow::ensure!(
            live >= self.min_live,
            "worker {w} disconnected in round {k} and only {live} live \
             socket(s) remain, below the churn floor (min_live = {})",
            self.min_live
        );
        Ok(())
    }

    /// Collect worker `w`'s dirty ranges: only the shard ranges this
    /// connection has not acknowledged at the current version, as
    /// `(start, slice)` pairs borrowing the round-frozen vectors. The
    /// caller hands them straight to
    /// [`wire::encode_round_header`] — building a per-worker header
    /// copies no floats outside the output frame itself (the old path
    /// cloned every dirty range into an owned
    /// [`RoundMsg`](super::wire::RoundMsg) first).
    #[allow(clippy::type_complexity)]
    fn dirty_ranges<'r>(conn: &mut WorkerConn, round: &'r WireRound,
                        stats: &mut WireStats)
                        -> (Vec<(u32, &'r [f32])>, Vec<(u32, &'r [f32])>) {
        let mut theta = Vec::new();
        for (s, r) in round.layout.ranges().enumerate() {
            if r.is_empty() {
                continue;
            }
            if conn.held_theta.get(s) != Some(&round.versions[s]) {
                stats.theta_ranges_sent += 1;
                stats.theta_range_bytes += 4 * r.len() as u64;
                theta.push((r.start as u32, &round.theta[r]));
            }
        }
        conn.held_theta.clear();
        conn.held_theta.extend_from_slice(&round.versions);
        let mut snapshot = Vec::new();
        if let Some((snap, version)) = &round.snapshot {
            if conn.held_snap != Some(*version) {
                stats.snapshot_ranges_sent += 1;
                stats.snapshot_range_bytes += 4 * snap.len() as u64;
                snapshot.push((0u32, snap.as_slice()));
                conn.held_snap = Some(*version);
            }
        }
        (theta, snapshot)
    }

    /// Drive one round over `selected` (sorted, unique population
    /// slots): ship each selected worker its header, collect one step
    /// per selected worker, and return them in `selected` order
    /// (physical arrival order never leaks into the fold). The caller
    /// owns the selection — [`ParticipationCfg::select`] is the
    /// canonical way to draw it; this method only checks it is
    /// well-formed. `batches[i]` is the minibatch for `selected[i]`.
    ///
    /// Frames that are not the open round's expected next step — a
    /// duplicate from a worker that already answered, a stale frame
    /// carrying an old `k`, a frame from an unselected worker, or one
    /// whose claimed id differs from its connection's slot — are
    /// dropped and counted ([`WireStats::steps_rejected`],
    /// [`RoundOutcome::rejected`]) instead of folded. With churn
    /// tolerance on, a worker disconnecting mid-round vacates its slot
    /// and its step is synthesized as a skip; new connections are
    /// admitted into vacant slots between polls.
    pub fn run_round(&mut self, round: &WireRound, selected: &[usize],
                     batches: &[Vec<u32>])
                     -> anyhow::Result<RoundOutcome> {
        anyhow::ensure!(
            self.greet_info.is_some(),
            "run_round before the handshake admitted the population"
        );
        anyhow::ensure!(
            !selected.is_empty() && batches.len() == selected.len(),
            "run_round wants a non-empty selection with one batch per \
             selected worker (got {} selected, {} batches)",
            selected.len(),
            batches.len()
        );
        anyhow::ensure!(
            selected.windows(2).all(|p| p[0] < p[1])
                && selected[selected.len() - 1] < self.m,
            "run_round selection must be sorted, unique and within the \
             population of {}",
            self.m
        );
        // position of slot w in the selected list; usize::MAX = not
        // selected this round
        let mut pos_of = vec![usize::MAX; self.m];
        for (i, &w) in selected.iter().enumerate() {
            pos_of[w] = i;
        }
        // full participation ships no list at all, keeping the
        // degenerate header bytes independent of the selection feature
        let wire_selected: Vec<u32> = if selected.len() == self.m {
            Vec::new()
        } else {
            selected.iter().map(|&w| w as u32).collect()
        };
        let deadline = Instant::now() + self.timeout;
        let mut outcome = RoundOutcome::default();
        let mut slots: Vec<Option<WireStep>> =
            Vec::with_capacity(selected.len());
        slots.resize_with(selected.len(), || None);

        // dispatch: one header per selected, live worker
        for (i, &w) in selected.iter().enumerate() {
            let Some(conn) = self.conns[w].as_mut() else {
                // vacated in an earlier round and not yet refilled: the
                // algorithm still folds a skip so staleness advances
                anyhow::ensure!(
                    self.churn,
                    "worker {w} is disconnected (vacant population \
                     slot) and churn tolerance is off"
                );
                slots[i] = Some(skip_step(round.k, w));
                continue;
            };
            let t0 = Instant::now();
            let (theta, snapshot) =
                Self::dirty_ranges(conn, round, &mut self.stats);
            wire::encode_round_header(
                &wire::RoundHeaderRef {
                    k: round.k,
                    rhs: round.rhs,
                    tau: round.taus.get(w).copied().unwrap_or(0),
                    selected: &wire_selected,
                    batch: batches[i].as_slice(),
                    theta: &theta,
                    snapshot: &snapshot,
                },
                &mut self.scratch,
            );
            self.stats.header_encode_ns +=
                t0.elapsed().as_nanos() as u64;
            match write_frame_nb(&mut conn.stream, &self.scratch, deadline)
            {
                Ok(bytes) => self.stats.bytes_sent += bytes as u64,
                Err(e) => {
                    if !self.churn {
                        return Err(anyhow::anyhow!(
                            "sending round {} to worker {w}: {e:#}",
                            round.k
                        ));
                    }
                    self.vacate(w, round.k)?;
                    slots[i] = Some(skip_step(round.k, w));
                    outcome.vacated.push(w);
                }
            }
        }

        // poll: sweep every live slot for readable frames (and, in
        // churn mode, the listener for joiners) until each selected
        // slot has a step
        while slots.iter().any(|s| s.is_none()) {
            anyhow::ensure!(
                Instant::now() < deadline,
                "timed out waiting for {} worker step(s) in round {}",
                slots.iter().filter(|s| s.is_none()).count(),
                round.k
            );
            if self.churn {
                self.admit_joiners(&mut outcome.rejoined)?;
            }
            for w in 0..self.m {
                let mut eof = false;
                let mut frames: Vec<Vec<u8>> = Vec::new();
                {
                    let Some(conn) = self.conns[w].as_mut() else {
                        continue;
                    };
                    match fill_recv(conn) {
                        Ok((hit_eof, bytes)) => {
                            eof = hit_eof;
                            self.stats.bytes_received += bytes as u64;
                        }
                        Err(e) => {
                            if !self.churn {
                                return Err(anyhow::anyhow!(
                                    "reading worker {w}'s round-{} \
                                     result: {e:#}",
                                    round.k
                                ));
                            }
                            eof = true;
                        }
                    }
                    while let Some(f) = take_frame(&mut conn.recv)? {
                        frames.push(f);
                    }
                }
                for frame in frames {
                    // parse the frame as a borrowed view and decompress
                    // straight into the dense vector the fold consumes:
                    // one parse, one allocation, no intermediate owned
                    // payload copy
                    let t0 = Instant::now();
                    let parsed = wire::decode_step_view(&frame)
                        .and_then(|view| {
                            let dense = view.payload.decompress()?;
                            Ok((view, dense))
                        });
                    self.stats.step_decode_ns +=
                        t0.elapsed().as_nanos() as u64;
                    let (view, dense) = parsed.map_err(|e| {
                        anyhow::anyhow!(
                            "worker {w}'s round-{} result: {e:#}",
                            round.k
                        )
                    })?;
                    let pos = pos_of[w];
                    let fresh = pos != usize::MAX
                        && slots[pos].is_none()
                        && view.k == round.k
                        && view.w == w;
                    if !fresh {
                        // duplicate, stale round, unselected slot, or a
                        // mislabelled id: drop it, count it, keep going
                        self.stats.steps_rejected += 1;
                        outcome.rejected.push(w);
                        continue;
                    }
                    if view.decision.upload {
                        self.stats.upload_raw_bytes +=
                            view.payload.raw_bytes();
                        self.stats.upload_wire_bytes +=
                            view.payload.encoded_bytes();
                    }
                    slots[pos] = Some(WireStep {
                        k: view.k,
                        w: view.w,
                        decision: view.decision,
                        lhs: view.lhs,
                        loss: view.loss,
                        grad_evals: view.grad_evals,
                        payload: Payload::Dense(dense),
                    });
                }
                if eof {
                    anyhow::ensure!(
                        self.churn,
                        "worker {w} disconnected during round {}",
                        round.k
                    );
                    self.vacate(w, round.k)?;
                    outcome.vacated.push(w);
                    let pos = pos_of[w];
                    if pos != usize::MAX && slots[pos].is_none() {
                        slots[pos] = Some(skip_step(round.k, w));
                    }
                }
            }
            if slots.iter().any(|s| s.is_none()) {
                std::thread::sleep(Duration::from_millis(1));
            }
        }
        outcome.steps = slots.into_iter().flatten().collect();
        self.stats.rounds += 1;
        Ok(outcome)
    }
}

impl Drop for SocketServer {
    fn drop(&mut self) {
        // best-effort: let worker processes exit cleanly instead of
        // discovering the EOF
        for conn in self.conns.iter_mut().flatten() {
            let _ = conn.stream.set_nonblocking(false);
            let _ = conn
                .stream
                .set_write_timeout(Some(Duration::from_secs(1)));
            let _ = wire::send(&mut conn.stream, &Msg::Shutdown,
                               &mut self.scratch);
        }
    }
}

/// Outcome of one worker process's run (logging/tests).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WorkerReport {
    /// the slot the server assigned in the handshake
    pub w: usize,
    pub rounds: u64,
    pub uploads: u64,
}

/// Per-process knobs for [`run_worker_opts`]. `Default` reproduces
/// [`run_worker`]: interactive-scale timeouts, fresh `Hello` handshake.
#[derive(Clone, Copy, Debug)]
pub struct WorkerOpts {
    /// connect-retry budget (the server may still be binding)
    pub connect: Duration,
    /// read timeout: bounds the wait for the *next* round header, so a
    /// long-unselected worker still notices a hung server
    pub timeout: Duration,
    /// claim this population slot with a churn-mode `Rejoin` handshake
    /// instead of a fresh `Hello`
    pub rejoin_slot: Option<u32>,
}

impl Default for WorkerOpts {
    fn default() -> Self {
        WorkerOpts {
            connect: SOCKET_TIMEOUT,
            timeout: SOCKET_TIMEOUT,
            rejoin_slot: None,
        }
    }
}

impl WorkerOpts {
    /// The worker-side view of a run's [`ParticipationCfg`]: its
    /// timeout and connect-retry budget.
    pub fn from_participation(p: &ParticipationCfg) -> Self {
        WorkerOpts {
            connect: p.connect_retry(),
            timeout: p.socket_timeout(),
            rejoin_slot: None,
        }
    }
}

/// Connect with retries until `timeout` (the server process may still
/// be binding when a worker launches). Every attempt is individually
/// bounded via [`TcpStream::connect_timeout`], so a black-holed SYN
/// (firewall DROP) cannot stretch the overall deadline by the kernel's
/// multi-minute TCP connect timeout.
pub fn connect_retry(addr: &str, timeout: Duration)
                     -> anyhow::Result<TcpStream> {
    use std::net::ToSocketAddrs;
    let deadline = Instant::now() + timeout;
    let mut last_err = String::from("no addresses resolved");
    loop {
        // re-resolve each attempt: the name may start resolving while
        // the server host boots
        match addr.to_socket_addrs() {
            Ok(addrs) => {
                for sa in addrs {
                    let left = deadline
                        .saturating_duration_since(Instant::now());
                    // per-attempt bound: short enough to stay
                    // responsive, never zero (connect_timeout rejects
                    // a zero duration)
                    let per = left
                        .min(Duration::from_secs(5))
                        .max(Duration::from_millis(50));
                    match TcpStream::connect_timeout(&sa, per) {
                        Ok(stream) => return Ok(stream),
                        Err(e) => last_err = e.to_string(),
                    }
                }
            }
            Err(e) => last_err = e.to_string(),
        }
        if Instant::now() >= deadline {
            return Err(anyhow::anyhow!(
                "connecting to cada server at {addr}: {last_err}"));
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// [`run_worker_opts`] with the historical defaults (120 s timeouts,
/// fresh `Hello` handshake).
pub fn run_worker(addr: &str, data: &Dataset, compute: &mut dyn Compute)
                  -> anyhow::Result<WorkerReport> {
    run_worker_opts(addr, data, compute, &WorkerOpts::default())
}

/// The worker process's whole life: connect, handshake, then answer
/// round headers until the server says shutdown (or closes the
/// connection between rounds, which a finished run also does).
///
/// `data` must be the same dataset the server samples indices from
/// (same preset, run seed and size — the handshake cross-checks both
/// the length and a whole-dataset content fingerprint), and `compute`
/// a backend with the server's parameter count. Under per-round
/// selection the worker simply blocks until its next header: the
/// header carries the server-tracked staleness `tau`, which the worker
/// adopts so its rule sees the same staleness it would on any other
/// transport (a bit-exact no-op under full participation).
pub fn run_worker_opts(addr: &str, data: &Dataset,
                       compute: &mut dyn Compute, opts: &WorkerOpts)
                       -> anyhow::Result<WorkerReport> {
    let mut stream = connect_retry(addr, opts.connect)?;
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(opts.timeout))?;
    let mut scratch = Vec::new();
    let hail = match opts.rejoin_slot {
        Some(w) => Msg::Rejoin {
            w,
            n: data.len() as u64,
            fp: data.fingerprint(),
            p: compute.p_pad() as u64,
        },
        None => Msg::Hello {
            n: data.len() as u64,
            fp: data.fingerprint(),
            p: compute.p_pad() as u64,
        },
    };
    wire::send(&mut stream, &hail, &mut scratch)?;
    let welcome = wire::recv(&mut stream, &mut scratch)?;
    let (w, cfg, batch) = match welcome {
        Some((Msg::Welcome { w, cfg, batch, .. }, _)) => {
            (w as usize, cfg, batch as usize)
        }
        Some((other, _)) => {
            anyhow::bail!("expected Welcome, got {other:?}")
        }
        None => anyhow::bail!(
            "server closed during the handshake (dataset/backend \
             mismatch, or too many workers for this run?)"
        ),
    };
    if let Some(want) = opts.rejoin_slot {
        anyhow::ensure!(
            w == want as usize,
            "rejoin asked for slot {want}, server assigned {w}"
        );
    }
    anyhow::ensure!(
        cfg.p == compute.p_pad(),
        "server wants p = {}, backend has p = {}",
        cfg.p,
        compute.p_pad()
    );
    let mut state = WorkerState::new(w, cfg.p, cfg.rule);
    // the server's compression config: the worker compresses (rule LHS
    // on the decompressed innovation, error-feedback residual), the
    // server decodes what arrives
    state.set_compress(cfg.compress);
    let mut theta = vec![0.0f32; cfg.p];
    let mut snapshot = cfg
        .rule
        .needs_snapshot()
        .then(|| vec![0.0f32; cfg.p]);
    let mut report = WorkerReport { w, rounds: 0, uploads: 0 };
    loop {
        let round = match wire::recv(&mut stream, &mut scratch)? {
            Some((Msg::Round(round), _)) => round,
            Some((Msg::Shutdown, _)) | None => return Ok(report),
            Some((other, _)) => {
                anyhow::bail!("expected a round header, got {other:?}")
            }
        };
        // a header only ever reaches selected workers, but check
        // anyway: answering an unselected round would desync the fold
        if !round.selected.is_empty() {
            anyhow::ensure!(
                round.selected.binary_search(&(w as u32)).is_ok(),
                "round {} selects {:?}, but its header reached worker \
                 {w}",
                round.k,
                round.selected
            );
        }
        for delta in &round.theta {
            delta.apply(&mut theta)?;
        }
        if let Some(snap) = snapshot.as_mut() {
            for delta in &round.snapshot {
                delta.apply(snap)?;
            }
        }
        anyhow::ensure!(
            round.batch.len() == batch,
            "round {} header carries {} batch indices, expected {batch}",
            round.k,
            round.batch.len()
        );
        let mut picks = Vec::with_capacity(round.batch.len());
        for &i in &round.batch {
            let i = i as usize;
            anyhow::ensure!(
                i < data.len(),
                "round {} batch index {i} outside the {}-sample dataset \
                 (mismatched dataset?)",
                round.k,
                data.len()
            );
            picks.push(i);
        }
        // adopt the server-tracked staleness: a worker left unselected
        // (or freshly rejoined) resumes with the server's count, so its
        // rule decides exactly as the InProc mirror does
        state.tau = round.tau;
        let minibatch = data.gather(&picks);
        let step = state.step(
            round.k,
            cfg.rule,
            cfg.max_delay,
            &theta,
            snapshot.as_deref(),
            round.rhs,
            &minibatch,
            compute,
            cfg.use_artifact_innov,
        )?;
        // lossy schemes stash the encoded payload in the worker state;
        // Identity ships the dense innovation exactly as the
        // pre-compression protocol did — borrowed straight from the
        // worker's delta buffer, never cloned into an owned payload
        let stashed = if step.decision.upload {
            report.uploads += 1;
            state.take_payload()
        } else {
            None
        };
        let payload = match &stashed {
            Some(p) => p.as_payload_ref(),
            None if step.decision.upload => {
                PayloadRef::Dense(state.last_delta())
            }
            None => PayloadRef::Dense(&[]),
        };
        wire::send_step(
            &mut stream,
            &wire::WireStepRef {
                k: round.k,
                w,
                decision: step.decision,
                lhs: step.lhs,
                loss: step.loss,
                grad_evals: step.grad_evals,
                payload,
            },
            &mut scratch,
        )?;
        report.rounds += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::shard::ShardLayout;
    use std::sync::mpsc;
    use std::sync::Arc;

    fn round(k: u64, p: usize, shards: usize, versions: Vec<u64>,
             snapshot: Option<(Arc<Vec<f32>>, u64)>) -> WireRound {
        WireRound {
            k,
            rhs: 0.5,
            theta: Arc::new((0..p).map(|i| i as f32).collect()),
            layout: ShardLayout::new(p, shards),
            versions,
            snapshot,
            taus: Vec::new(),
        }
    }

    fn test_cfg(p: usize) -> WireWorkerCfg {
        WireWorkerCfg {
            rule: crate::coordinator::rules::RuleKind::Always,
            max_delay: 50,
            use_artifact_innov: false,
            p,
            compress: crate::compress::CompressCfg::default(),
        }
    }

    /// Scripted worker: connect, hail, expect a `Welcome`.
    fn script_connect(addr: &str, hail: Msg) -> (TcpStream, usize) {
        let mut stream =
            connect_retry(addr, Duration::from_secs(10)).unwrap();
        stream.set_nodelay(true).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        let mut scratch = Vec::new();
        wire::send(&mut stream, &hail, &mut scratch).unwrap();
        match wire::recv(&mut stream, &mut scratch).unwrap() {
            Some((Msg::Welcome { w, .. }, _)) => (stream, w as usize),
            other => panic!("expected Welcome, got {other:?}"),
        }
    }

    fn expect_round(stream: &mut TcpStream, scratch: &mut Vec<u8>)
                    -> wire::RoundMsg {
        match wire::recv(stream, scratch).unwrap() {
            Some((Msg::Round(r), _)) => r,
            other => panic!("expected a round header, got {other:?}"),
        }
    }

    fn send_step(stream: &mut TcpStream, k: u64, w: usize,
                 scratch: &mut Vec<u8>) {
        wire::send_step(
            stream,
            &wire::WireStepRef {
                k,
                w,
                decision: Decision { upload: false,
                                     rule_triggered: false },
                lhs: 0.25,
                loss: 0.5,
                grad_evals: 1,
                payload: PayloadRef::Dense(&[]),
            },
            scratch,
        )
        .unwrap();
    }

    fn expect_shutdown(stream: &mut TcpStream, scratch: &mut Vec<u8>) {
        match wire::recv(stream, scratch).unwrap() {
            Some((Msg::Shutdown, _)) | None => {}
            Some((other, _)) => panic!("expected Shutdown, got {other:?}"),
        }
    }

    #[test]
    fn header_ships_only_dirty_ranges() {
        let p = 2048;
        let snap = Arc::new(vec![1.0f32; p]);
        let mut conn = WorkerConn {
            // a bound-but-unused stream stand-in is overkill; connect a
            // loopback pair just to own a TcpStream
            stream: loopback_stream(),
            recv: Vec::new(),
            held_theta: Vec::new(),
            held_snap: None,
        };
        let mut stats = WireStats::default();
        // first round: everything is dirty
        let r0 = round(0, p, 2, vec![0, 0], Some((Arc::clone(&snap), 1)));
        let (theta0, snap0) =
            SocketServer::dirty_ranges(&mut conn, &r0, &mut stats);
        assert_eq!(theta0.len(), 2);
        assert_eq!(snap0.len(), 1);
        assert_eq!(stats.theta_ranges_sent, 2);
        assert_eq!(stats.theta_range_bytes, 4 * p as u64);
        assert_eq!(stats.snapshot_ranges_sent, 1);
        // the borrowed ranges encode into the round header the worker
        // decodes back — same message the old owned path shipped
        let mut buf = Vec::new();
        wire::encode_round_header(
            &wire::RoundHeaderRef {
                k: r0.k,
                rhs: r0.rhs,
                tau: 0,
                selected: &[],
                batch: &[3, 1],
                theta: &theta0,
                snapshot: &snap0,
            },
            &mut buf,
        );
        match wire::decode(&buf).unwrap() {
            Msg::Round(h0) => {
                assert_eq!(h0.k, 0);
                assert_eq!(h0.batch, vec![3, 1]);
                assert_eq!(h0.theta.len(), 2);
                assert_eq!(h0.theta[0].start, 0);
                assert_eq!(h0.theta[1].start, 1024);
                assert_eq!(h0.snapshot.len(), 1);
                assert_eq!(h0.snapshot[0].data, *snap);
            }
            other => panic!("wrong message: {other:?}"),
        }
        // second round: shard 1 moved, snapshot did not
        let r1 = round(1, p, 2, vec![0, 1], Some((snap, 1)));
        let (theta1, snap1) =
            SocketServer::dirty_ranges(&mut conn, &r1, &mut stats);
        assert_eq!(theta1.len(), 1);
        assert_eq!(theta1[0].0, 1024);
        assert!(snap1.is_empty());
        assert_eq!(stats.theta_ranges_sent, 3);
        assert_eq!(stats.snapshot_ranges_sent, 1);
    }

    fn loopback_stream() -> TcpStream {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stream = TcpStream::connect(addr).unwrap();
        let _accepted = listener.accept().unwrap();
        stream
    }

    #[test]
    fn builder_validates_population_selection_and_quorum() {
        assert!(SocketServer::builder("127.0.0.1:0")
            .population(0)
            .build()
            .is_err());
        assert!(SocketServer::builder("127.0.0.1:0")
            .population(4)
            .select(8)
            .build()
            .is_err());
        assert!(SocketServer::builder("127.0.0.1:0")
            .population(4)
            .select(2)
            .quorum(3)
            .build()
            .is_err());
        let s = SocketServer::builder("127.0.0.1:0")
            .population(4)
            .select(2)
            .quorum(2)
            .build()
            .unwrap();
        assert_eq!(s.workers(), 4);
        assert_eq!(s.select_size(), 2);
        assert_eq!(s.quorum_size(), 2);
        assert!(s.needs_handshake());
    }

    #[test]
    fn handshake_rejects_mismatched_fingerprints() {
        let cfg = test_cfg(64);
        let mut server =
            SocketServer::builder("127.0.0.1:0").build().unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let bad = std::thread::spawn(move || {
            let mut stream =
                connect_retry(&addr, Duration::from_secs(10)).unwrap();
            let mut scratch = Vec::new();
            // dataset length 7 != the server's 100
            wire::send(&mut stream, &Msg::Hello { n: 7, fp: 1, p: 64 },
                       &mut scratch)
                .unwrap();
            // the server drops us without a Welcome
            assert!(wire::recv(&mut stream, &mut scratch)
                .map(|m| m.is_none())
                .unwrap_or(true));
        });
        let err = server.handshake(&cfg, 8, 100, 1).unwrap_err();
        assert!(err.to_string().contains("samples"), "{err}");
        bad.join().unwrap();

        // right length, wrong CONTENT: the fingerprint catches a worker
        // regenerated from the wrong seed/run
        let mut server =
            SocketServer::builder("127.0.0.1:0").build().unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let bad = std::thread::spawn(move || {
            let mut stream =
                connect_retry(&addr, Duration::from_secs(10)).unwrap();
            let mut scratch = Vec::new();
            wire::send(&mut stream,
                       &Msg::Hello { n: 100, fp: 2, p: 64 },
                       &mut scratch)
                .unwrap();
            assert!(wire::recv(&mut stream, &mut scratch)
                .map(|m| m.is_none())
                .unwrap_or(true));
        });
        let err = server.handshake(&cfg, 8, 100, 1).unwrap_err();
        assert!(err.to_string().contains("fingerprint"), "{err}");
        bad.join().unwrap();
    }

    /// Duplicate steps from an answered worker and unsolicited steps
    /// from an unselected worker are dropped + counted, never folded.
    #[test]
    fn rejects_duplicate_and_unselected_steps() {
        const P: usize = 4;
        let cfg = test_cfg(P);
        let mut server = SocketServer::builder("127.0.0.1:0")
            .population(2)
            .timeout(Duration::from_secs(10))
            .build()
            .unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let (go_tx, go_rx) = mpsc::channel::<()>();
        let (rogue_tx, rogue_rx) = mpsc::channel::<()>();

        let a_addr = addr.clone();
        let a = std::thread::spawn(move || {
            let (mut stream, w) = script_connect(
                &a_addr,
                Msg::Hello { n: 100, fp: 1, p: P as u64 },
            );
            assert_eq!(w, 0, "first connector takes slot 0");
            go_tx.send(()).unwrap();
            let mut scratch = Vec::new();
            let r0 = expect_round(&mut stream, &mut scratch);
            assert_eq!(r0.k, 0);
            assert_eq!(r0.selected, vec![0],
                       "partial rounds ship the participant set");
            send_step(&mut stream, 0, 0, &mut scratch);
            send_step(&mut stream, 0, 0, &mut scratch); // duplicate
            let r1 = expect_round(&mut stream, &mut scratch);
            assert_eq!(r1.k, 1);
            send_step(&mut stream, 1, 0, &mut scratch);
            expect_shutdown(&mut stream, &mut scratch);
        });
        let b_addr = addr;
        let b = std::thread::spawn(move || {
            go_rx.recv().unwrap();
            let (mut stream, w) = script_connect(
                &b_addr,
                Msg::Hello { n: 100, fp: 1, p: P as u64 },
            );
            assert_eq!(w, 1);
            let mut scratch = Vec::new();
            // never selected: shove an unsolicited step at the server
            send_step(&mut stream, 0, 1, &mut scratch);
            rogue_tx.send(()).unwrap();
            expect_shutdown(&mut stream, &mut scratch);
        });
        server.handshake(&cfg, 2, 100, 1).unwrap();
        // the rogue step is on the wire before round 0 even starts
        rogue_rx.recv().unwrap();
        std::thread::sleep(Duration::from_millis(50));

        let r0 = round(0, P, 1, vec![7], None);
        let out0 = server.run_round(&r0, &[0], &[vec![1, 2]]).unwrap();
        assert_eq!(out0.steps.len(), 1);
        assert_eq!(out0.steps[0].w, 0);
        assert_eq!(out0.steps[0].k, 0);
        let r1 = round(1, P, 1, vec![7], None);
        let out1 = server.run_round(&r1, &[0], &[vec![0, 3]]).unwrap();
        assert_eq!(out1.steps.len(), 1);
        assert_eq!(out1.steps[0].k, 1);
        // both rogue frames got rejected by the time their sender's
        // next accepted frame closed a round (TCP orders per stream):
        // worker 1's unselected step and worker 0's duplicate
        let mut rejected = out0.rejected.clone();
        rejected.extend_from_slice(&out1.rejected);
        rejected.sort_unstable();
        assert_eq!(rejected, vec![0, 1],
                   "one duplicate from worker 0, one unselected step \
                    from worker 1");
        assert_eq!(server.stats().steps_rejected, 2);
        assert_eq!(server.stats().rounds, 2);
        drop(server);
        a.join().unwrap();
        b.join().unwrap();
    }

    /// A worker dying mid-round vacates its slot (its step synthesized
    /// as a skip), a rejoiner reclaims the slot mid-run, and its first
    /// selected round re-ships the full theta — the delta-broadcast
    /// catch-up reconstructs a bit-identical replica.
    #[test]
    fn churn_vacates_dead_workers_and_a_rejoiner_catches_up() {
        const P: usize = 8;
        let cfg = test_cfg(P);
        let mut server = SocketServer::builder("127.0.0.1:0")
            .population(2)
            .churn(true, 1)
            .timeout(Duration::from_secs(10))
            .build()
            .unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let (go_tx, go_rx) = mpsc::channel::<()>();
        let (jw_tx, jw_rx) = mpsc::channel::<()>();

        let a_addr = addr.clone();
        let a = std::thread::spawn(move || {
            let (mut stream, w) = script_connect(
                &a_addr,
                Msg::Hello { n: 100, fp: 1, p: P as u64 },
            );
            assert_eq!(w, 0);
            go_tx.send(()).unwrap();
            let mut scratch = Vec::new();
            let r0 = expect_round(&mut stream, &mut scratch);
            assert_eq!(r0.k, 0);
            send_step(&mut stream, 0, 0, &mut scratch);
            let r1 = expect_round(&mut stream, &mut scratch);
            assert_eq!(r1.k, 1);
            assert!(r1.theta.is_empty(),
                    "the survivor already acked theta");
            // hold round 1 open until the joiner's Welcome lands, so
            // the rejoin deterministically happens mid-round
            jw_rx.recv().unwrap();
            send_step(&mut stream, 1, 0, &mut scratch);
            let r2 = expect_round(&mut stream, &mut scratch);
            assert_eq!(r2.k, 2);
            assert!(r2.theta.is_empty());
            send_step(&mut stream, 2, 0, &mut scratch);
            expect_shutdown(&mut stream, &mut scratch);
        });
        let b_addr = addr.clone();
        let b = std::thread::spawn(move || {
            go_rx.recv().unwrap();
            let (mut stream, w) = script_connect(
                &b_addr,
                Msg::Hello { n: 100, fp: 1, p: P as u64 },
            );
            assert_eq!(w, 1);
            let mut scratch = Vec::new();
            let r0 = expect_round(&mut stream, &mut scratch);
            assert_eq!(r0.k, 0);
            // die without answering: the server synthesizes our skip
        });
        server.handshake(&cfg, 2, 100, 1).unwrap();

        let r0 = round(0, P, 1, vec![7], None);
        let out0 = server
            .run_round(&r0, &[0, 1], &[vec![0, 1], vec![2, 3]])
            .unwrap();
        assert_eq!(out0.vacated, vec![1]);
        assert_eq!(out0.steps.len(), 2);
        let synth = &out0.steps[1];
        assert_eq!(synth.w, 1);
        assert!(!synth.decision.upload);
        assert!(synth.lhs.is_nan() && synth.grad_evals == 0);
        b.join().unwrap();

        // a rejoiner reclaims slot 1 while round 1 is open
        let j_addr = addr;
        let joiner = std::thread::spawn(move || {
            let (mut stream, w) = script_connect(
                &j_addr,
                Msg::Rejoin { w: 1, n: 100, fp: 1, p: P as u64 },
            );
            assert_eq!(w, 1);
            jw_tx.send(()).unwrap();
            let mut scratch = Vec::new();
            // first selected round after the rejoin: nothing is acked,
            // so the header carries the whole theta
            let r2 = expect_round(&mut stream, &mut scratch);
            assert_eq!(r2.k, 2);
            let mut theta = vec![0.0f32; P];
            for d in &r2.theta {
                d.apply(&mut theta).unwrap();
            }
            let want: Vec<f32> = (0..P).map(|i| i as f32).collect();
            assert_eq!(theta, want,
                       "late joiner must reconstruct theta bit-for-bit");
            send_step(&mut stream, 2, 1, &mut scratch);
            expect_shutdown(&mut stream, &mut scratch);
        });
        let r1 = round(1, P, 1, vec![7], None);
        let out1 = server.run_round(&r1, &[0], &[vec![0, 1]]).unwrap();
        assert_eq!(out1.rejoined, vec![1]);
        assert_eq!(out1.steps.len(), 1);
        let r2 = round(2, P, 1, vec![7], None);
        let out2 = server
            .run_round(&r2, &[0, 1], &[vec![0, 1], vec![2, 3]])
            .unwrap();
        assert_eq!(out2.steps.len(), 2);
        assert!(out2.steps.iter().all(|s| s.k == 2));
        assert!(out2.vacated.is_empty());
        assert_eq!(server.stats().rejoins, 1);
        drop(server);
        a.join().unwrap();
        joiner.join().unwrap();
    }
}
