//! Deterministic fault injection for the socket stack.
//!
//! A [`FaultPlan`] is the chaos-engineering twin of the link jitter and
//! compression seeding: every injected fault is a **pure function of
//! `(fault_seed, round, worker, event)`**, so a faulty run is exactly
//! as reproducible as a clean one — rerun the same plan and the same
//! frames corrupt, the same connections drop, the same processes die at
//! the same rounds. That is what lets CI assert hard things about
//! crashed runs ("resume is bit-identical to uninterrupted") instead of
//! merely "it didn't panic".
//!
//! The plan is carried in the `[fault]` TOML section / `--fault-*`
//! flags and flows to both sides of the wire:
//!
//! * **server side** ([`crate::comm::SocketServer`]): `drop_p` closes a
//!   selected worker's connection instead of sending its round header;
//!   `delay_p` sleeps `delay_ms` before the header write (exercises the
//!   poll-loop deadlines); `kill_server_at` makes the trainer save a
//!   checkpoint and crash before broadcasting that round.
//! * **worker side** ([`crate::comm::run_worker`]): `corrupt_p` flips
//!   one payload bit in the worker's outgoing step frame (the server
//!   detects the CRC mismatch and folds a skip — a lost upload);
//!   `truncate_p` sends only a prefix of the step frame and drops the
//!   connection; `kill_workers` exits the worker process on the first
//!   round header at or past the named round.
//!
//! [`FaultPlan::none()`] is the default and is checked once per use
//! site (`is_none()`), so fault-free paths stay bit-identical to — and
//! as fast as — builds that never heard of fault injection.
//!
//! Which faults preserve bit-identity of the training state? Payload
//! corruption and permanent kills do: both runs of a seeded plan see
//! the identical lost uploads and vacated slots. Reconnect-flavoured
//! faults (`drop_p`/`truncate_p` against healing workers) are
//! deterministic in *which* events fire but the rejoin lands whenever
//! the poll loop next admits joiners — use those in liveness tests, not
//! in bit-identity assertions.

use crate::util::rng::Rng;

/// Mix constants shared with the selection stream: faults draw from the
/// same family of per-(round, worker) decorrelated streams.
const ROUND_MIX: u64 = 0x9E37_79B9_7F4A_7C15;
const WORKER_MIX: u64 = 0xD1B5_4A32_D192_ED03;

/// The injected-fault event classes, each with its own RNG stream so
/// e.g. enabling `delay_p` never changes which frames `corrupt_p`
/// picks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultEvent {
    /// server: close the connection instead of sending the round header
    Drop,
    /// worker: flip one payload bit in the outgoing step frame
    Corrupt,
    /// worker: send a prefix of the step frame, then drop the link
    Truncate,
    /// server: sleep `delay_ms` before the header write
    Delay,
}

impl FaultEvent {
    fn stream(self) -> u64 {
        match self {
            FaultEvent::Drop => 1,
            FaultEvent::Corrupt => 2,
            FaultEvent::Truncate => 3,
            FaultEvent::Delay => 4,
        }
    }
}

/// A deterministic fault-injection plan (`[fault]` / `--fault-*`).
/// The default plan injects nothing and costs nothing.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultPlan {
    /// root seed for every fault stream (analogous to `jitter_seed`)
    pub seed: u64,
    /// per-(round, selected worker) probability the server drops the
    /// connection instead of sending the round header
    pub drop_p: f64,
    /// per-(round, worker) probability the worker bit-flips its own
    /// outgoing step frame's payload
    pub corrupt_p: f64,
    /// per-(round, worker) probability the worker truncates its
    /// outgoing step frame and drops the connection
    pub truncate_p: f64,
    /// per-(round, selected worker) probability the server sleeps
    /// `delay_ms` before writing the round header
    pub delay_p: f64,
    /// milliseconds a delayed header write sleeps
    pub delay_ms: u64,
    /// `(round, worker)` pairs: the worker exits on the first round
    /// header with `k >= round` (so the effective kill round is the
    /// first round at or past it in which the worker is selected)
    pub kill_workers: Vec<(u64, u32)>,
    /// the trainer saves a checkpoint and crashes (suppressing the
    /// clean Shutdown broadcast) before broadcasting this round
    pub kill_server_at: Option<u64>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            seed: 0,
            drop_p: 0.0,
            corrupt_p: 0.0,
            truncate_p: 0.0,
            delay_p: 0.0,
            delay_ms: 0,
            kill_workers: Vec::new(),
            kill_server_at: None,
        }
    }
}

impl FaultPlan {
    /// The inert plan: injects nothing, costs one boolean check.
    pub fn none() -> Self {
        FaultPlan::default()
    }

    /// True when this plan can never fire an event — the fast path the
    /// hot loops check once before consulting any stream.
    pub fn is_none(&self) -> bool {
        self.drop_p == 0.0
            && self.corrupt_p == 0.0
            && self.truncate_p == 0.0
            && self.delay_p == 0.0
            && self.kill_workers.is_empty()
            && self.kill_server_at.is_none()
    }

    /// Validate the probabilities and the kill schedule.
    pub fn validate(&self) -> anyhow::Result<()> {
        for (name, p) in [("drop_p", self.drop_p),
                          ("corrupt_p", self.corrupt_p),
                          ("truncate_p", self.truncate_p),
                          ("delay_p", self.delay_p)] {
            anyhow::ensure!(
                p.is_finite() && (0.0..=1.0).contains(&p),
                "[fault] {name} must be a probability in [0, 1], got {p}"
            );
        }
        Ok(())
    }

    /// The pure per-event stream: `(seed, round, worker, event)` fully
    /// determine every draw, mirroring the selection-stream idiom.
    fn stream_rng(&self, event: FaultEvent, round: u64, worker: u64)
                  -> Rng {
        let stream = round
            .wrapping_mul(ROUND_MIX)
            .wrapping_add(worker.wrapping_mul(WORKER_MIX))
            .wrapping_add(event.stream());
        Rng::new(self.seed ^ stream)
    }

    fn roll(&self, event: FaultEvent, p: f64, round: u64, worker: u64)
            -> bool {
        p > 0.0 && self.stream_rng(event, round, worker).f64() < p
    }

    /// Server side: drop worker `w`'s connection instead of sending its
    /// round-`k` header?
    pub fn drop_header(&self, k: u64, w: usize) -> bool {
        self.roll(FaultEvent::Drop, self.drop_p, k, w as u64)
    }

    /// Server side: sleep `delay_ms` before writing worker `w`'s
    /// round-`k` header?
    pub fn delay_header(&self, k: u64, w: usize) -> bool {
        self.delay_ms > 0
            && self.roll(FaultEvent::Delay, self.delay_p, k, w as u64)
    }

    /// Worker side: corrupt this worker's round-`k` step frame? Returns
    /// the (byte index, xor mask) to flip, chosen past the 8-byte
    /// `[len][crc]` prefix so framing stays aligned and exactly the
    /// payload integrity check trips.
    pub fn corrupt_step(&self, k: u64, w: usize, frame_len: usize)
                        -> Option<(usize, u8)> {
        const PREFIX: usize = super::wire::FRAME_PREFIX;
        if frame_len <= PREFIX {
            return None;
        }
        let mut rng = self.stream_rng(FaultEvent::Corrupt, k, w as u64);
        if !(self.corrupt_p > 0.0 && rng.f64() < self.corrupt_p) {
            return None;
        }
        let byte = PREFIX + rng.below(frame_len - PREFIX);
        let mask = 1u8 << rng.below(8);
        Some((byte, mask))
    }

    /// Worker side: truncate this worker's round-`k` step frame?
    /// Returns the number of bytes to send (strictly less than
    /// `frame_len`) before dropping the connection.
    pub fn truncate_step(&self, k: u64, w: usize, frame_len: usize)
                         -> Option<usize> {
        if frame_len == 0 {
            return None;
        }
        let mut rng = self.stream_rng(FaultEvent::Truncate, k, w as u64);
        if !(self.truncate_p > 0.0 && rng.f64() < self.truncate_p) {
            return None;
        }
        Some(rng.below(frame_len))
    }

    /// The round at (or past) which worker `w` is scheduled to die, if
    /// any (the earliest schedule entry naming it).
    pub fn kill_worker_round(&self, w: usize) -> Option<u64> {
        self.kill_workers
            .iter()
            .filter(|&&(_, kw)| kw as usize == w)
            .map(|&(r, _)| r)
            .min()
    }

    /// Is the server scheduled to crash before broadcasting round `k`?
    pub fn server_killed_at(&self, k: u64) -> bool {
        self.kill_server_at == Some(k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn the_default_plan_is_inert() {
        let plan = FaultPlan::none();
        assert!(plan.is_none());
        for k in 0..64 {
            for w in 0..8 {
                assert!(!plan.drop_header(k, w));
                assert!(!plan.delay_header(k, w));
                assert!(plan.corrupt_step(k, w, 4096).is_none());
                assert!(plan.truncate_step(k, w, 4096).is_none());
            }
        }
        assert_eq!(plan.kill_worker_round(0), None);
        assert!(!plan.server_killed_at(0));
    }

    #[test]
    fn faults_are_pure_in_seed_round_worker_event() {
        let plan = FaultPlan {
            seed: 0xFA_17,
            drop_p: 0.3,
            corrupt_p: 0.3,
            truncate_p: 0.3,
            delay_p: 0.3,
            delay_ms: 5,
            ..FaultPlan::default()
        };
        let twin = plan.clone();
        let mut fired = 0u32;
        for k in 0..50 {
            for w in 0..4 {
                assert_eq!(plan.drop_header(k, w),
                           twin.drop_header(k, w));
                assert_eq!(plan.corrupt_step(k, w, 512),
                           twin.corrupt_step(k, w, 512));
                assert_eq!(plan.truncate_step(k, w, 512),
                           twin.truncate_step(k, w, 512));
                assert_eq!(plan.delay_header(k, w),
                           twin.delay_header(k, w));
                fired += plan.drop_header(k, w) as u32;
            }
        }
        // at p=0.3 over 200 trials, firing 20..=100 times is ~certain
        assert!((20..=100).contains(&fired), "drop fired {fired}/200");
    }

    #[test]
    fn event_streams_are_decorrelated() {
        // the same (seed, round, worker) must not force drop and
        // corrupt to co-fire: each event class has its own stream
        let plan = FaultPlan {
            seed: 7,
            drop_p: 0.5,
            corrupt_p: 0.5,
            ..FaultPlan::default()
        };
        let mut agree = 0u32;
        let trials = 400;
        for k in 0..100u64 {
            for w in 0..4 {
                let d = plan.drop_header(k, w);
                let c = plan.corrupt_step(k, w, 64).is_some();
                agree += (d == c) as u32;
            }
        }
        // perfectly correlated streams would agree 400/400
        assert!((100..=300).contains(&agree),
                "drop/corrupt agreed {agree}/{trials}");
    }

    #[test]
    fn certain_probabilities_always_fire_and_stay_in_bounds() {
        let plan = FaultPlan {
            seed: 3,
            corrupt_p: 1.0,
            truncate_p: 1.0,
            ..FaultPlan::default()
        };
        for k in 0..32 {
            for len in [9usize, 16, 100, 4096] {
                let (byte, mask) =
                    plan.corrupt_step(k, 1, len).expect("p=1 fires");
                assert!((8..len).contains(&byte),
                        "corrupt byte {byte} outside payload of {len}");
                assert_eq!(mask.count_ones(), 1);
                let cut =
                    plan.truncate_step(k, 1, len).expect("p=1 fires");
                assert!(cut < len, "truncation {cut} >= frame {len}");
            }
            // a frame with no payload past the prefix cannot corrupt
            assert!(plan.corrupt_step(k, 1, 8).is_none());
        }
    }

    #[test]
    fn kill_schedule_picks_the_earliest_round_per_worker() {
        let plan = FaultPlan {
            kill_workers: vec![(9, 2), (5, 2), (7, 0)],
            kill_server_at: Some(12),
            ..FaultPlan::default()
        };
        assert!(!plan.is_none());
        assert_eq!(plan.kill_worker_round(2), Some(5));
        assert_eq!(plan.kill_worker_round(0), Some(7));
        assert_eq!(plan.kill_worker_round(1), None);
        assert!(plan.server_killed_at(12));
        assert!(!plan.server_killed_at(11));
    }

    #[test]
    fn validate_rejects_non_probabilities() {
        for bad in [-0.1, 1.5, f64::NAN, f64::INFINITY] {
            let plan = FaultPlan { drop_p: bad, ..FaultPlan::default() };
            assert!(plan.validate().is_err(), "accepted drop_p = {bad}");
        }
        assert!(FaultPlan::none().validate().is_ok());
    }
}
