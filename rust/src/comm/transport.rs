//! Transport abstraction for the round engine: HOW a round's worker jobs
//! execute, decoupled from WHAT they compute.
//!
//! A [`WorkerJob`] is a self-contained closure built by the algorithm
//! (see [`Algorithm::make_step`](crate::algorithms::Algorithm::make_step)):
//! it owns everything it touches — the worker's state, the round-frozen
//! broadcast tensors behind `Arc`s, its minibatch — so a transport may
//! run it on any thread. Two implementations:
//!
//! * [`InProc`] — runs each job inline on the caller's backend, in
//!   worker order: the deterministic sequential semantics the golden
//!   parity suite pins down.
//! * [`Threaded`] — one persistent thread per worker, each owning a
//!   forked [`Compute`] backend, fed through channel mailboxes with the
//!   server collecting completions as an event-driven aggregator.
//!   Completion order is nondeterministic, but outcomes are re-sorted
//!   into worker order before the algorithm folds them, and all
//!   *simulated* quantities (link times, jitter, participation) are pure
//!   functions of the round — so `Threaded` is bit-identical to
//!   [`InProc`] (enforced by `tests/golden_parity.rs`).
//!
//! The mailbox message types ([`ToWorker`](crate::coordinator::ToWorker) /
//! [`FromWorker`](crate::coordinator::FromWorker)) live in
//! [`crate::coordinator`] next to the rest of the server/worker protocol.

use std::any::Any;
use std::panic::AssertUnwindSafe;
use std::sync::mpsc;
use std::thread::JoinHandle;

use crate::coordinator::{FromWorker, ToWorker};
use crate::runtime::Compute;
use crate::util::panic_message;

/// Opaque outcome of one worker job; the algorithm that built the job
/// downcasts it back in `absorb_step`.
pub type JobOut = Box<dyn Any + Send>;

/// A self-contained worker-round computation: runs on whatever backend
/// the executing thread owns.
pub type WorkerJob =
    Box<dyn FnOnce(&mut dyn Compute) -> anyhow::Result<JobOut> + Send>;

/// Which transport a run uses (the `[comm] transport` knob).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum TransportKind {
    #[default]
    InProc,
    Threaded,
    /// TCP sockets across OS processes: `WorkerJob`s cannot cross a
    /// process boundary, so this transport is driven through the
    /// serializable round protocol of [`crate::comm::wire`] (a
    /// [`SocketServer`](crate::comm::socket::SocketServer) inside the
    /// trainer + one `cada worker` process per worker) instead of
    /// [`Transport::execute`].
    Socket,
}

impl TransportKind {
    pub fn parse(s: &str) -> anyhow::Result<TransportKind> {
        match s {
            "inproc" => Ok(TransportKind::InProc),
            "threaded" => Ok(TransportKind::Threaded),
            "socket" => Ok(TransportKind::Socket),
            other => anyhow::bail!(
                "unknown transport '{other}' (have: inproc, threaded, \
                 socket)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::InProc => "inproc",
            TransportKind::Threaded => "threaded",
            TransportKind::Socket => "socket",
        }
    }
}

/// Executes one round of worker jobs and returns every outcome **in
/// worker order**, whatever the physical completion order was.
pub trait Transport {
    fn name(&self) -> &'static str;

    fn execute(&mut self, jobs: Vec<(usize, WorkerJob)>,
               compute: &mut dyn Compute)
               -> anyhow::Result<Vec<(usize, JobOut)>>;
}

/// Sequential in-process execution on the caller's backend.
pub struct InProc;

impl Transport for InProc {
    fn name(&self) -> &'static str {
        "inproc"
    }

    fn execute(&mut self, jobs: Vec<(usize, WorkerJob)>,
               compute: &mut dyn Compute)
               -> anyhow::Result<Vec<(usize, JobOut)>> {
        let mut out = Vec::with_capacity(jobs.len());
        for (w, job) in jobs {
            out.push((w, job(compute)?));
        }
        Ok(out)
    }
}

/// Persistent worker threads with channel mailboxes; the server thread
/// dispatches a round's jobs and collects completions as they arrive.
pub struct Threaded {
    mailboxes: Vec<mpsc::Sender<ToWorker>>,
    results: mpsc::Receiver<FromWorker>,
    handles: Vec<JoinHandle<()>>,
}

impl Threaded {
    /// Spawn one thread per backend; worker `w` owns `backends[w]` for
    /// its whole life (backends come from [`Compute::fork`]).
    pub fn spawn(backends: Vec<Box<dyn Compute + Send>>)
                 -> anyhow::Result<Threaded> {
        let (res_tx, res_rx) = mpsc::channel::<FromWorker>();
        let mut mailboxes = Vec::with_capacity(backends.len());
        let mut handles = Vec::with_capacity(backends.len());
        for (w, mut compute) in backends.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<ToWorker>();
            let out = res_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("cada-worker-{w}"))
                .spawn(move || {
                    while let Ok(msg) = rx.recv() {
                        match msg {
                            ToWorker::Job(job) => {
                                // a panicking job must still produce a
                                // completion message, or the server's
                                // collect loop would block forever
                                let outcome = std::panic::catch_unwind(
                                    AssertUnwindSafe(|| {
                                        job(&mut *compute)
                                    }))
                                .unwrap_or_else(|panic| {
                                    Err(anyhow::anyhow!(
                                        "worker thread {w} panicked: {}",
                                        panic_message(panic.as_ref())))
                                });
                                if out.send(FromWorker { w, outcome })
                                    .is_err()
                                {
                                    break; // server side is gone
                                }
                            }
                            ToWorker::Shutdown => break,
                        }
                    }
                })
                .map_err(|e| anyhow::anyhow!(
                    "spawning worker thread {w}: {e}"))?;
            mailboxes.push(tx);
            handles.push(handle);
        }
        Ok(Threaded { mailboxes, results: res_rx, handles })
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.mailboxes.len()
    }
}

impl Transport for Threaded {
    fn name(&self) -> &'static str {
        "threaded"
    }

    fn execute(&mut self, jobs: Vec<(usize, WorkerJob)>,
               _compute: &mut dyn Compute)
               -> anyhow::Result<Vec<(usize, JobOut)>> {
        // Dispatch; on a dead mailbox, stop dispatching but fall through
        // to collect what was already sent — bailing out here would
        // leave those completions queued for the NEXT round to consume.
        let mut dispatched = 0usize;
        let mut first_err: Option<anyhow::Error> = None;
        for (w, job) in jobs {
            let sent = self
                .mailboxes
                .get(w)
                .ok_or_else(|| anyhow::anyhow!(
                    "no worker thread {w} (transport has {})",
                    self.mailboxes.len()))
                .and_then(|tx| {
                    tx.send(ToWorker::Job(job)).map_err(|_| {
                        anyhow::anyhow!("worker thread {w} is gone")
                    })
                });
            match sent {
                Ok(()) => dispatched += 1,
                Err(e) => {
                    first_err = Some(e);
                    break;
                }
            }
        }
        // Collect every dispatched completion (draining even after an
        // error, so a failed round cannot leave stale results behind).
        let mut out = Vec::with_capacity(dispatched);
        for _ in 0..dispatched {
            match self.results.recv() {
                Ok(FromWorker { w, outcome }) => match outcome {
                    Ok(o) => out.push((w, o)),
                    Err(e) => {
                        if first_err.is_none() {
                            first_err = Some(e);
                        }
                    }
                },
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(anyhow::anyhow!(
                            "worker threads exited before completing \
                             the round"));
                    }
                    break;
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        // physical completion order is racy; the fold order is worker
        // order, which is what makes Threaded bit-identical to InProc
        out.sort_by_key(|&(w, _)| w);
        Ok(out)
    }
}

impl Drop for Threaded {
    fn drop(&mut self) {
        for tx in &self.mailboxes {
            let _ = tx.send(ToWorker::Shutdown);
        }
        for handle in self.handles.drain(..) {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::NativeLogReg;

    fn forked(m: usize) -> Vec<Box<dyn Compute + Send>> {
        let base = NativeLogReg::for_spec(4, 16);
        (0..m).map(|_| base.fork().expect("native forks")).collect()
    }

    fn square_job(w: usize) -> WorkerJob {
        Box::new(move |_c: &mut dyn Compute| {
            Ok(Box::new(w * w) as JobOut)
        })
    }

    #[test]
    fn inproc_runs_in_worker_order() {
        let mut t = InProc;
        let mut base = NativeLogReg::for_spec(4, 16);
        let jobs: Vec<(usize, WorkerJob)> =
            (0..5).map(|w| (w, square_job(w))).collect();
        let out = t.execute(jobs, &mut base).unwrap();
        let vals: Vec<usize> = out
            .into_iter()
            .map(|(w, o)| {
                assert_eq!(*o.downcast::<usize>().unwrap(), w * w);
                w
            })
            .collect();
        assert_eq!(vals, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn threaded_returns_outcomes_in_worker_order() {
        let mut t = Threaded::spawn(forked(8)).unwrap();
        assert_eq!(t.workers(), 8);
        let mut base = NativeLogReg::for_spec(4, 16);
        for round in 0..10 {
            let jobs: Vec<(usize, WorkerJob)> =
                (0..8).map(|w| (w, square_job(w + round))).collect();
            let out = t.execute(jobs, &mut base).unwrap();
            assert_eq!(out.len(), 8);
            for (i, (w, o)) in out.into_iter().enumerate() {
                assert_eq!(w, i);
                assert_eq!(*o.downcast::<usize>().unwrap(),
                           (w + round) * (w + round));
            }
        }
    }

    #[test]
    fn threaded_propagates_job_errors_and_survives() {
        let mut t = Threaded::spawn(forked(3)).unwrap();
        let mut base = NativeLogReg::for_spec(4, 16);
        let jobs: Vec<(usize, WorkerJob)> = (0..3)
            .map(|w| {
                let job: WorkerJob = if w == 1 {
                    Box::new(|_c: &mut dyn Compute| {
                        Err(anyhow::anyhow!("boom"))
                    })
                } else {
                    square_job(w)
                };
                (w, job)
            })
            .collect();
        let err = t.execute(jobs, &mut base).unwrap_err();
        assert!(err.to_string().contains("boom"), "{err}");
        // the failed round drained fully: the next round is clean
        let jobs: Vec<(usize, WorkerJob)> =
            (0..3).map(|w| (w, square_job(w))).collect();
        let out = t.execute(jobs, &mut base).unwrap();
        assert_eq!(out.len(), 3);
    }

    #[test]
    fn threaded_turns_job_panics_into_errors_not_deadlocks() {
        let mut t = Threaded::spawn(forked(3)).unwrap();
        let mut base = NativeLogReg::for_spec(4, 16);
        let jobs: Vec<(usize, WorkerJob)> = (0..3)
            .map(|w| {
                let job: WorkerJob = if w == 2 {
                    Box::new(|_c: &mut dyn Compute| -> anyhow::Result<JobOut> {
                        panic!("job exploded")
                    })
                } else {
                    square_job(w)
                };
                (w, job)
            })
            .collect();
        let err = t.execute(jobs, &mut base).unwrap_err();
        assert!(err.to_string().contains("panicked"), "{err}");
        assert!(err.to_string().contains("job exploded"), "{err}");
        // the panicking round still settled fully: the next one is clean
        let jobs: Vec<(usize, WorkerJob)> =
            (0..3).map(|w| (w, square_job(w))).collect();
        let out = t.execute(jobs, &mut base).unwrap();
        assert_eq!(out.len(), 3);
        assert!(out.iter().enumerate().all(|(i, (w, _))| i == *w));
    }

    #[test]
    fn dispatch_failure_drains_already_sent_jobs() {
        let mut t = Threaded::spawn(forked(2)).unwrap();
        let mut base = NativeLogReg::for_spec(4, 16);
        // worker 5 does not exist: jobs 0 and 1 are already dispatched
        // when the bad send fails; execute must still collect them so
        // the next round starts from an empty results channel
        let jobs: Vec<(usize, WorkerJob)> = vec![
            (0, square_job(0)),
            (1, square_job(1)),
            (5, square_job(5)),
        ];
        let err = t.execute(jobs, &mut base).unwrap_err();
        assert!(err.to_string().contains("no worker thread 5"), "{err}");
        let jobs: Vec<(usize, WorkerJob)> =
            (0..2).map(|w| (w, square_job(w))).collect();
        let out = t.execute(jobs, &mut base).unwrap();
        assert_eq!(out.len(), 2);
        for (i, (w, o)) in out.into_iter().enumerate() {
            assert_eq!(w, i);
            assert_eq!(*o.downcast::<usize>().unwrap(), w * w);
        }
    }

    #[test]
    fn transport_kind_parses() {
        assert_eq!(TransportKind::parse("inproc").unwrap(),
                   TransportKind::InProc);
        assert_eq!(TransportKind::parse("threaded").unwrap(),
                   TransportKind::Threaded);
        assert_eq!(TransportKind::parse("socket").unwrap(),
                   TransportKind::Socket);
        assert!(TransportKind::parse("carrier-pigeon").is_err());
        assert_eq!(TransportKind::Threaded.name(), "threaded");
        assert_eq!(TransportKind::Socket.name(), "socket");
    }
}
