//! Per-worker link models and the round event clock's settlement logic.
//!
//! The seed's single global [`CostModel`](super::CostModel) charged every
//! worker the same latency and advanced simulated time additively, so a
//! round's cost ignored stragglers entirely. Here every worker gets its
//! own [`LinkModel`] (heterogeneous latency/bandwidth/asymmetry plus a
//! seeded log-normal straggler jitter and a device compute multiplier,
//! so slow DEVICES are priced as well as slow links), and
//! [`LinkSet::settle_uploads`] turns one round's upload set into an
//! event-clock verdict: which uploads the server waits for (the
//! participation policy), which arrive late, and by how much the
//! simulated clock advances — the max over the awaited workers, not the
//! sum. An upload's arrival time is device compute + transmission
//! ([`LinkSet::arrival_time_s`]); the default compute base of 0 seconds
//! keeps every pre-compute config bit-identical.
//!
//! Determinism is a hard requirement (the `Threaded` transport must be
//! bit-identical to `InProc`): the jitter for (round k, worker w) is a
//! pure function of `(jitter_seed, k, w)`, never of execution order.

use std::cmp::Ordering;

use super::CostModel;
use crate::util::rng::Rng;

/// One worker's simulated device + network link: an asymmetric-uplink
/// cost model, a multiplicative log-normal jitter on the upload path
/// (the straggler model of arXiv:2201.04301's heterogeneous-worker
/// setting), and a device compute multiplier (the worker-grouping
/// setting of arXiv:2201.04301 prices slow DEVICES, not just slow
/// links).
#[derive(Clone, Debug, PartialEq)]
pub struct LinkModel {
    pub cost: CostModel,
    /// sigma of the log-normal upload jitter; 0 disables jitter exactly
    /// (the multiplier is the constant 1.0, not a degenerate draw)
    pub jitter_sigma: f64,
    /// device speed factor scaling the base per-round compute time
    /// ([`CostModel::compute_s`]): a 2.0 device takes twice the base
    /// compute seconds before its upload leaves. 1.0 = nominal; with
    /// the default `compute_s = 0` the multiplier is inert and every
    /// simulated time is bit-identical to the pre-compute model.
    pub compute_mult: f64,
}

impl LinkModel {
    pub fn new(cost: CostModel) -> Self {
        LinkModel { cost, jitter_sigma: 0.0, compute_mult: 1.0 }
    }
}

/// The M per-worker links of one run plus the jitter stream seed.
#[derive(Clone, Debug, PartialEq)]
pub struct LinkSet {
    links: Vec<LinkModel>,
    jitter_seed: u64,
}

/// When does the server stop waiting for a round's uploads?
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Participation {
    /// Fully synchronous: wait for every upload (the paper's setting).
    Full,
    /// Semi-synchronous: proceed after the fastest `k` arrivals; the
    /// remaining uploads are folded in stale next round (the semi-sync
    /// averaging regime of arXiv:2007.06134).
    SemiSync { k: usize },
}

/// One round's settlement: who the server waited for, who straggled, and
/// the event-clock advance.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundVerdict {
    /// uploads folded this round, in worker order
    pub fresh: Vec<usize>,
    /// uploads arriving after the quorum closed but within finite
    /// simulated time, in worker order (stale-folded next round)
    pub deferred: Vec<usize>,
    /// uploads the quorum left behind whose simulated arrival time is
    /// not finite (dead links): transmitted, charged, never delivered
    pub lost: Vec<usize>,
    /// event-clock advance for the upload phase: the simulated arrival
    /// time of the slowest awaited upload; under full participation
    /// additionally floored by the slowest device's compute across ALL
    /// workers, so a no-upload round still costs `max compute` (0 only
    /// when nothing uploads AND the compute base is 0; infinite when a
    /// full quorum must wait on a dead link)
    pub upload_dt_s: f64,
    /// simulated arrival time of every pending upload, `(worker, s)` —
    /// device compute + transmission (see [`LinkSet::arrival_time_s`])
    pub arrival_s: Vec<(usize, f64)>,
}

impl LinkSet {
    pub fn new(links: Vec<LinkModel>, jitter_seed: u64) -> Self {
        LinkSet { links, jitter_seed }
    }

    /// All `m` workers share one cost model, jitter off — the exact
    /// semantics of the seed's global [`CostModel`].
    pub fn homogeneous(m: usize, cost: CostModel) -> Self {
        LinkSet::new(vec![LinkModel::new(cost); m], 0)
    }

    pub fn len(&self) -> usize {
        self.links.len()
    }

    pub fn is_empty(&self) -> bool {
        self.links.is_empty()
    }

    pub fn link(&self, w: usize) -> &LinkModel {
        &self.links[w]
    }

    /// Deterministic straggler multiplier for (round `k`, worker `w`):
    /// `exp(sigma * z)` with `z` standard normal drawn from a stream
    /// keyed by `(jitter_seed, k, w)` only. Exactly 1.0 when sigma is 0,
    /// so jitter-off runs are bit-identical to the unjittered model.
    pub fn jitter_mult(&self, k: u64, w: usize) -> f64 {
        let sigma = self.links[w].jitter_sigma;
        if sigma <= 0.0 {
            return 1.0;
        }
        let stream = k
            .wrapping_mul(0x9E3779B97F4A7C15)
            .wrapping_add(w as u64 + 1)
            .wrapping_mul(0xA24BAED4963EE407);
        let mut rng = Rng::new(self.jitter_seed ^ stream);
        (sigma * rng.normal()).exp()
    }

    /// Simulated upload time of worker `w` at round `k` (jittered).
    pub fn upload_time_s(&self, k: u64, w: usize, bytes: usize) -> f64 {
        self.links[w].cost.upload_time_s(bytes) * self.jitter_mult(k, w)
    }

    /// Simulated device compute seconds of one round on worker `w`:
    /// base [`CostModel::compute_s`] scaled by the worker's
    /// [`LinkModel::compute_mult`]. Exactly 0 under the default
    /// `compute_s = 0`, so compute-free configs never perturb the clock.
    pub fn compute_time_s(&self, w: usize) -> f64 {
        self.links[w].cost.compute_s * self.links[w].compute_mult
    }

    /// When worker `w`'s round-`k` upload reaches the server, measured
    /// from the start of the round's local phase: the device computes
    /// its gradient step first, then transmits — so slow devices
    /// straggle exactly like slow links.
    pub fn arrival_time_s(&self, k: u64, w: usize, bytes: usize) -> f64 {
        self.compute_time_s(w) + self.upload_time_s(k, w, bytes)
    }

    /// Broadcast cost: downloads proceed in parallel, so the clock
    /// advances by the SLOWEST worker's download — under heterogeneous
    /// links the seed's "one latency hit for all workers" is wrong.
    pub fn max_download_s(&self, bytes: usize) -> f64 {
        self.links
            .iter()
            .map(|l| l.cost.download_time_s(bytes))
            .fold(0.0, f64::max)
    }

    /// [`LinkSet::max_download_s`] restricted to a round's selected
    /// participants: unselected workers receive no broadcast, so they
    /// must not pace the clock. Iterates in the given order folding
    /// `f64::max`, so `selected == 0..m` is bit-identical to the
    /// unrestricted fold.
    pub fn max_download_among(&self, selected: &[usize], bytes: usize)
                              -> f64 {
        selected
            .iter()
            .map(|&w| self.links[w].cost.download_time_s(bytes))
            .fold(0.0, f64::max)
    }

    /// Worker `w`'s nominal (unjittered) round seconds — device compute
    /// plus the deterministic upload time of a `bytes`-sized payload.
    /// This is the pure speed metric [`SelectPolicy::Grouped`] ranks
    /// workers by: no jitter and no round index, so the ranking (and
    /// with it the selection) stays a pure function of the config.
    ///
    /// [`SelectPolicy::Grouped`]: super::SelectPolicy::Grouped
    pub fn nominal_round_s(&self, w: usize, bytes: usize) -> f64 {
        self.compute_time_s(w) + self.links[w].cost.upload_time_s(bytes)
    }

    /// [`LinkSet::nominal_round_s`] for every worker at once.
    pub fn nominal_speeds(&self, bytes: usize) -> Vec<f64> {
        (0..self.links.len())
            .map(|w| self.nominal_round_s(w, bytes))
            .collect()
    }

    /// Settle one round's upload set under a participation policy.
    ///
    /// `pending` is the set of workers whose rule fired this round, in
    /// worker order. The verdict's `fresh`/`deferred` sets come back in
    /// worker order too, so folding them is deterministic regardless of
    /// (simulated or physical) arrival order; with `Full` — or
    /// `SemiSync { k >= pending.len() }` — `fresh == pending` and the
    /// clock advances by the slowest upload arrival. Under `Full` the
    /// advance is additionally floored by the slowest device's compute
    /// time across ALL workers (skippers still compute and report their
    /// decision in a synchronous round); semi-sync quorums — including
    /// `k >= pending.len()` — deliberately never wait on non-pending
    /// devices, so the two policies coincide exactly only while the
    /// compute base is 0.
    pub fn settle_uploads(&self, k: u64, pending: &[usize], bytes: usize,
                          policy: Participation) -> RoundVerdict {
        self.settle_among(k, pending, bytes, policy, None)
    }

    /// [`LinkSet::settle_uploads`] restricted to a round's selected
    /// participants: the `Full` compute floor waits only on devices the
    /// round actually selected — an unselected slow device must not
    /// gate a round it took no part in. `participants == 0..m` is
    /// bit-identical to the unrestricted settlement.
    pub fn settle_uploads_among(&self, k: u64, pending: &[usize],
                                bytes: usize, policy: Participation,
                                participants: &[usize]) -> RoundVerdict {
        self.settle_among(k, pending, bytes, policy, Some(participants))
    }

    fn settle_among(&self, k: u64, pending: &[usize], bytes: usize,
                    policy: Participation,
                    participants: Option<&[usize]>) -> RoundVerdict {
        let arrival_s: Vec<(usize, f64)> = pending
            .iter()
            .map(|&w| (w, self.arrival_time_s(k, w, bytes)))
            .collect();
        let quorum = match policy {
            Participation::Full => pending.len(),
            // a quorum of 0 would stall the server forever; wait for at
            // least one arrival (and never more than there are uploads)
            Participation::SemiSync { k } => k.max(1).min(pending.len()),
        };
        let mut order: Vec<usize> = (0..arrival_s.len()).collect();
        order.sort_by(|&a, &b| {
            arrival_s[a]
                .1
                .partial_cmp(&arrival_s[b].1)
                .unwrap_or(Ordering::Equal)
                .then(arrival_s[a].0.cmp(&arrival_s[b].0))
        });
        let mut fresh: Vec<usize> =
            order[..quorum].iter().map(|&i| arrival_s[i].0).collect();
        // behind the quorum, only finitely-late uploads ever arrive; a
        // dead link's (infinite-time) upload must not fold next round
        let mut deferred = Vec::new();
        let mut lost = Vec::new();
        for &i in &order[quorum..] {
            let (w, t) = arrival_s[i];
            if t.is_finite() {
                deferred.push(w);
            } else {
                lost.push(w);
            }
        }
        fresh.sort_unstable();
        deferred.sort_unstable();
        lost.sort_unstable();
        let mut upload_dt_s = order[..quorum]
            .iter()
            .map(|&i| arrival_s[i].1)
            .fold(0.0, f64::max);
        if matches!(policy, Participation::Full) {
            // a fully-synchronous round closes only once EVERY device
            // has finished its local compute — workers whose rule skips
            // the upload still evaluate their gradients and report the
            // decision, so a slow device gates the round even when it
            // transmits nothing. (Semi-sync quorums explicitly do not
            // wait, so no floor there.) Exactly 0 under the default
            // compute base, preserving bit-identical pre-compute runs.
            let compute_floor = match participants {
                None => (0..self.links.len())
                    .map(|w| self.compute_time_s(w))
                    .fold(0.0, f64::max),
                Some(p) => p
                    .iter()
                    .map(|&w| self.compute_time_s(w))
                    .fold(0.0, f64::max),
            };
            upload_dt_s = upload_dt_s.max(compute_floor);
        }
        RoundVerdict { fresh, deferred, lost, upload_dt_s, arrival_s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cost(latency_s: f64, down_bw: f64, asymmetry: f64) -> CostModel {
        CostModel { latency_s, down_bw, asymmetry, compute_s: 0.0 }
    }

    #[test]
    fn homogeneous_matches_single_cost_model() {
        let base = CostModel::default();
        let links = LinkSet::homogeneous(4, base.clone());
        assert_eq!(links.len(), 4);
        for w in 0..4 {
            assert_eq!(links.upload_time_s(9, w, 400),
                       base.upload_time_s(400));
        }
        assert_eq!(links.max_download_s(400), base.download_time_s(400));
    }

    #[test]
    fn free_links_advance_no_time() {
        let links = LinkSet::homogeneous(3, CostModel::free());
        let v = links.settle_uploads(0, &[0, 1, 2], 4096,
                                     Participation::Full);
        assert_eq!(v.upload_dt_s, 0.0);
        assert_eq!(links.max_download_s(1 << 20), 0.0);
        assert!(v.arrival_s.iter().all(|&(_, t)| t == 0.0));
    }

    #[test]
    fn zero_bandwidth_link_is_infinitely_slow() {
        let links = LinkSet::new(
            vec![LinkModel::new(cost(0.01, 0.0, 1.0))], 0);
        assert!(links.upload_time_s(0, 0, 100).is_infinite());
        // ...but a zero-byte message still costs only its latency
        assert_eq!(links.upload_time_s(0, 0, 0), 0.01);
    }

    #[test]
    fn infinite_bandwidth_is_latency_only() {
        let links = LinkSet::new(
            vec![LinkModel::new(cost(0.25, f64::INFINITY, 10.0))], 0);
        assert_eq!(links.upload_time_s(0, 0, 1 << 30), 0.25);
        assert_eq!(links.max_download_s(1 << 30), 0.25);
    }

    #[test]
    fn jitter_is_deterministic_per_seed_round_worker() {
        let mut link = LinkModel::new(CostModel::default());
        link.jitter_sigma = 0.7;
        let a = LinkSet::new(vec![link.clone(); 3], 42);
        let b = LinkSet::new(vec![link.clone(); 3], 42);
        let c = LinkSet::new(vec![link; 3], 43);
        for k in 0..20 {
            for w in 0..3 {
                // same (seed, k, w) => same draw, independent of call order
                assert_eq!(a.jitter_mult(k, w), b.jitter_mult(k, w));
                assert!(a.jitter_mult(k, w) > 0.0);
            }
            // different rounds/workers/seeds decorrelate
            assert_ne!(a.jitter_mult(k, 0), a.jitter_mult(k, 1));
            assert_ne!(a.jitter_mult(k, 0), a.jitter_mult(k + 1, 0));
        }
        assert_ne!(a.jitter_mult(0, 0), c.jitter_mult(0, 0));
    }

    #[test]
    fn sigma_zero_is_exactly_one() {
        let links = LinkSet::homogeneous(2, CostModel::default());
        for k in 0..50 {
            assert_eq!(links.jitter_mult(k, 0), 1.0);
            assert_eq!(links.jitter_mult(k, 1), 1.0);
        }
    }

    #[test]
    fn compute_multiplier_prices_slow_devices() {
        // identical links; worker 1's device is 10x slower. Its upload
        // ARRIVES later (compute + transmit), so a k=1 quorum defers it
        // and the full quorum waits for it.
        let mut base = cost(0.01, 1000.0, 1.0);
        base.compute_s = 0.1;
        let mut slow = LinkModel::new(base.clone());
        slow.compute_mult = 10.0;
        let links = LinkSet::new(
            vec![LinkModel::new(base.clone()), slow], 0);
        assert_eq!(links.compute_time_s(0), 0.1);
        assert_eq!(links.compute_time_s(1), 1.0);
        // transmission itself is untouched by device speed
        assert_eq!(links.upload_time_s(0, 0, 0),
                   links.upload_time_s(0, 1, 0));
        assert_eq!(links.arrival_time_s(0, 1, 0),
                   1.0 + links.upload_time_s(0, 1, 0));
        let full = links.settle_uploads(0, &[0, 1], 0,
                                        Participation::Full);
        assert_eq!(full.upload_dt_s, 1.0 + 0.01);
        let semi = links.settle_uploads(0, &[0, 1], 0,
                                        Participation::SemiSync { k: 1 });
        assert_eq!(semi.fresh, vec![0]);
        assert_eq!(semi.deferred, vec![1]);
        assert_eq!(semi.upload_dt_s, 0.1 + 0.01);
        // fully-sync rounds wait for every DEVICE even when its rule
        // skips the upload: worker 1 pends nothing, yet its compute
        // time floors the round
        let skip = links.settle_uploads(0, &[0], 0, Participation::Full);
        assert_eq!(skip.fresh, vec![0]);
        assert_eq!(skip.upload_dt_s, 1.0);
        // the default base compute of 0 keeps the clock bit-identical
        let free = LinkSet::new(
            vec![LinkModel { compute_mult: 50.0,
                             ..LinkModel::new(cost(0.01, 1000.0, 1.0)) }],
            0);
        assert_eq!(free.arrival_time_s(3, 0, 64),
                   free.upload_time_s(3, 0, 64));
    }

    #[test]
    fn full_participation_waits_for_slowest() {
        // worker 1 has 10x the latency: it is the straggler
        let links = LinkSet::new(
            vec![
                LinkModel::new(cost(0.01, 1000.0, 1.0)),
                LinkModel::new(cost(0.10, 1000.0, 1.0)),
            ],
            0,
        );
        let v = links.settle_uploads(0, &[0, 1], 0, Participation::Full);
        assert_eq!(v.fresh, vec![0, 1]);
        assert!(v.deferred.is_empty());
        assert_eq!(v.upload_dt_s, 0.10);
    }

    #[test]
    fn semi_sync_defers_stragglers_and_shrinks_round_time() {
        let links = LinkSet::new(
            vec![
                LinkModel::new(cost(0.01, 1000.0, 1.0)),
                LinkModel::new(cost(0.50, 1000.0, 1.0)),
                LinkModel::new(cost(0.02, 1000.0, 1.0)),
            ],
            0,
        );
        let v = links.settle_uploads(3, &[0, 1, 2], 0,
                                     Participation::SemiSync { k: 2 });
        assert_eq!(v.fresh, vec![0, 2]);
        assert_eq!(v.deferred, vec![1]);
        assert_eq!(v.upload_dt_s, 0.02);
    }

    #[test]
    fn semi_sync_k_at_least_m_reduces_to_full() {
        let links = LinkSet::homogeneous(4, CostModel::default());
        let pending = [0usize, 2, 3];
        let full = links.settle_uploads(7, &pending, 128,
                                        Participation::Full);
        for k in [3usize, 4, 99] {
            let semi = links.settle_uploads(
                7, &pending, 128, Participation::SemiSync { k });
            assert_eq!(semi, full, "k={k}");
        }
    }

    #[test]
    fn dead_link_uploads_are_lost_not_deferred() {
        // worker 1 has zero bandwidth: its upload never arrives
        let links = LinkSet::new(
            vec![
                LinkModel::new(cost(0.01, 1000.0, 1.0)),
                LinkModel::new(cost(0.01, 0.0, 1.0)),
                LinkModel::new(cost(0.02, 1000.0, 1.0)),
            ],
            0,
        );
        let v = links.settle_uploads(0, &[0, 1, 2], 64,
                                     Participation::SemiSync { k: 2 });
        assert_eq!(v.fresh, vec![0, 2]);
        assert!(v.deferred.is_empty());
        assert_eq!(v.lost, vec![1]);
        assert!(v.upload_dt_s.is_finite());
        // a FULL quorum over a dead link waits forever, consistently
        let full = links.settle_uploads(0, &[0, 1, 2], 64,
                                        Participation::Full);
        assert_eq!(full.fresh, vec![0, 1, 2]);
        assert!(full.upload_dt_s.is_infinite());
    }

    #[test]
    fn settle_among_all_matches_unrestricted_bitwise() {
        let mut base = cost(0.01, 1000.0, 1.0);
        base.compute_s = 0.2;
        let mut slow = LinkModel::new(base.clone());
        slow.compute_mult = 7.0;
        slow.jitter_sigma = 0.5;
        let links = LinkSet::new(
            vec![LinkModel::new(base.clone()), slow,
                 LinkModel::new(base)],
            11,
        );
        let all = [0usize, 1, 2];
        for policy in [Participation::Full,
                       Participation::SemiSync { k: 2 }] {
            for k in 0..10u64 {
                assert_eq!(
                    links.settle_uploads(k, &[0, 2], 64, policy),
                    links.settle_uploads_among(k, &[0, 2], 64, policy,
                                               &all),
                    "k={k} {policy:?}"
                );
            }
        }
        assert_eq!(links.max_download_s(512),
                   links.max_download_among(&all, 512));
    }

    #[test]
    fn settle_among_floors_only_on_selected_devices() {
        // worker 1 is a 10x-slow device but UNSELECTED: its compute
        // must not gate a full round it took no part in
        let mut base = cost(0.01, 1000.0, 1.0);
        base.compute_s = 0.1;
        let mut slow = LinkModel::new(base.clone());
        slow.compute_mult = 10.0;
        let links = LinkSet::new(
            vec![LinkModel::new(base.clone()), slow,
                 LinkModel::new(base)],
            0,
        );
        let v = links.settle_uploads_among(0, &[0], 0,
                                           Participation::Full, &[0, 2]);
        assert_eq!(v.fresh, vec![0]);
        assert_eq!(v.upload_dt_s, 0.1 + 0.01);
        // selecting the slow device restores the old floor
        let v = links.settle_uploads_among(0, &[0], 0,
                                           Participation::Full, &[0, 1]);
        assert_eq!(v.upload_dt_s, 1.0);
        // broadcasts likewise only pace selected workers
        let mut lag = LinkModel::new(cost(0.5, 1000.0, 1.0));
        lag.cost.compute_s = 0.0;
        let links = LinkSet::new(
            vec![LinkModel::new(cost(0.01, 1000.0, 1.0)), lag], 0);
        assert_eq!(links.max_download_among(&[0], 0), 0.01);
        assert_eq!(links.max_download_among(&[0, 1], 0), 0.5);
    }

    #[test]
    fn nominal_speed_is_unjittered_and_deterministic() {
        let mut base = cost(0.01, 1000.0, 1.0);
        base.compute_s = 0.1;
        let mut jittery = LinkModel::new(base.clone());
        jittery.jitter_sigma = 2.0;
        jittery.compute_mult = 3.0;
        let links = LinkSet::new(
            vec![LinkModel::new(base), jittery], 77);
        // compute + unjittered upload, independent of the round index
        assert_eq!(links.nominal_round_s(0, 0), 0.1 + 0.01);
        assert_eq!(links.nominal_round_s(1, 0), 0.3 + 0.01);
        assert_eq!(links.nominal_speeds(0),
                   vec![0.11, links.nominal_round_s(1, 0)]);
        // the jittered per-round upload time differs; the nominal
        // metric never does
        assert_ne!(links.upload_time_s(1, 1, 64),
                   links.link(1).cost.upload_time_s(64));
    }

    #[test]
    fn empty_round_settles_to_zero() {
        let links = LinkSet::homogeneous(3, CostModel::default());
        for policy in [Participation::Full,
                       Participation::SemiSync { k: 2 }] {
            let v = links.settle_uploads(0, &[], 128, policy);
            assert!(v.fresh.is_empty() && v.deferred.is_empty());
            assert_eq!(v.upload_dt_s, 0.0);
        }
    }
}
